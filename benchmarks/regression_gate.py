"""CI bench-regression gate: fail on >Nx throughput regressions.

Compares the bench-smoke artifacts just produced (``--current``) against a
reference — preferably the previous successful ``main`` run's artifact
(``--previous``, downloaded by CI when one exists), falling back to the
baselines committed in git (``--baseline``, snapshotted by CI *before* the
smoke run overwrites ``experiments/bench/``).

Watched metrics (the headline throughputs of the session API — all
best-of-N steady-state timings; one-shot latencies like ``cached_s`` carry
too much same-machine noise to gate on):

* ``engine.json`` ``config=group_b``          → ``steady_triples_per_s``
  (cached-plan re-execution — the plan-cache amortization claim)
* ``engine.json`` ``config=distributed_fused`` → ``triples_per_s``
  (the fused device-resident mesh path)
* ``engine.json`` ``config=join_exchange_repartition`` → ``triples_per_s``
  (the repartition-by-join-key ⋈ exchange on the large-parent config)
* ``engine.json`` ``config=warm_process_cold_start`` → ``warm_speedup``
  (fresh-process start from the persistent plan store vs cold compile)

A metric fails when ``current < reference / threshold`` (default 2.0 —
"regresses more than 2x") against the **previous main artifact** — the
same runner class, so the comparison is meaningful. Committed-baseline
comparisons only warn: those numbers come from whatever machine produced
the commit, and a cross-machine 2x is noise, not signal — this is the
soft-fail on the first run (and whenever no previous artifact exists).
Missing references soft-pass entirely, and a reference row whose
``devices`` field differs from the current row's is ignored — the CI
matrix legs (1 vs 8 virtual devices) each compare only against their own
artifact lineage.

Run: ``python -m benchmarks.regression_gate --current experiments/bench \
       --baseline /tmp/bench-baseline [--previous /tmp/bench-prev]``
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

# (file stem, row "config" value, metric key) — higher is better
METRICS: List[Tuple[str, str, str]] = [
    ("engine", "group_b", "steady_triples_per_s"),
    ("engine", "distributed_fused", "triples_per_s"),
    # the repartition ⋈ exchange on the large-parent config (the path that
    # scales past the all_gather wall — see docs/engine.md §4)
    ("engine", "join_exchange_repartition", "triples_per_s"),
    # fresh-process start against a populated persistent plan store vs the
    # cold compile that populated it (docs/plan_store.md — gated ≥10× in
    # the bench itself; the 2x threshold here catches store-path rot)
    ("engine", "warm_process_cold_start", "warm_speedup"),
    # the radix bucketization kernel behind every exchange/global-δ (the
    # sort-path comparison is asserted bit-identical inside the bench)
    ("partition", "partition", "radix_rows_per_s"),
    # steady-state 2-hop BGP answering through the query plan-cache tier
    # (docs/query.md — cold vs cached is gated ≥10× inside the bench; this
    # catches jitted-execution-path rot)
    ("query", "join_2hop", "queries_per_s"),
    # sustained multi-tenant ingest through the serve front door, compile
    # rounds excluded (docs/serve.md — compile dedup and bit-identity are
    # hard-asserted inside the bench; this catches flush-path rot)
    ("serve", "serve_multi_tenant", "sustained_ingests_per_s"),
]


def load_row(root: Optional[str], stem: str, config: str,
             key: str) -> Optional[Dict]:
    """The first row carrying the metric from ``root/stem.json``, or None."""
    if not root:
        return None
    path = os.path.join(root, f"{stem}.json")
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            rows = json.load(f)
    except (OSError, ValueError):
        return None
    for row in rows:
        if isinstance(row, dict) and row.get("config") == config \
                and key in row:
            return row
    return None


def metric_value(row: Optional[Dict], key: str) -> Optional[float]:
    if row is None:
        return None
    try:
        return float(row[key])
    except (TypeError, ValueError, KeyError):
        return None


def comparable(cur: Dict, ref: Optional[Dict]) -> bool:
    """A reference only counts when it measured the same thing: the device
    count must match (the distributed throughput differs by orders of
    magnitude between the 1-device and 8-virtual-device CI legs, and both
    legs share one committed baseline file)."""
    if ref is None:
        return False
    if "devices" in cur and "devices" in ref \
            and cur["devices"] != ref["devices"]:
        return False
    return True


def find_reference(cur: Dict, stem: str, config: str, key: str,
                   previous: Optional[str], baseline: Optional[str]
                   ) -> Tuple[Optional[float], str]:
    prev = load_row(previous, stem, config, key)
    if comparable(cur, prev):
        return metric_value(prev, key), "previous main artifact"
    base = load_row(baseline, stem, config, key)
    if comparable(cur, base):
        return metric_value(base, key), "committed baseline"
    return None, "none"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", default=os.path.join("experiments", "bench"))
    ap.add_argument("--baseline", default=None,
                    help="snapshot of the committed experiments/bench")
    ap.add_argument("--previous", default=None,
                    help="downloaded bench artifact of the last main run")
    ap.add_argument("--threshold", type=float, default=2.0,
                    help="fail when current < reference / threshold")
    args = ap.parse_args(argv)

    failures: List[str] = []
    for stem, config, key in METRICS:
        label = f"{stem}.json[{config}].{key}"
        cur_row = load_row(args.current, stem, config, key)
        cur = metric_value(cur_row, key)
        if cur is None:
            print(f"[gate] WARN {label}: missing from current run "
                  "(soft-pass)")
            continue
        ref, origin = find_reference(cur_row, stem, config, key,
                                     args.previous, args.baseline)
        if ref is None or ref <= 0:
            print(f"[gate] WARN {label}: no reference (first run?) — "
                  f"current={cur:.0f} (soft-pass)")
            continue
        ratio = cur / ref
        regressed = cur * args.threshold < ref
        hard = origin == "previous main artifact"
        verdict = ("FAIL" if regressed and hard
                   else "WARN" if regressed else "ok")
        print(f"[gate] {verdict} {label}: current={cur:.0f} vs "
              f"{origin}={ref:.0f} ({ratio:.2f}x)"
              + (" (cross-machine baseline: soft)" if regressed and not hard
                 else ""))
        if verdict == "FAIL":
            failures.append(
                f"{label} regressed {1 / max(ratio, 1e-9):.1f}x "
                f"(current {cur:.0f} < {origin} {ref:.0f} / "
                f"{args.threshold})")
    if failures:
        print("[gate] bench regression gate FAILED:")
        for f in failures:
            print(f"[gate]   - {f}")
        return 1
    print("[gate] bench regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
