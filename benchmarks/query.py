"""KGQuery benchmark: cold vs cached BGP latency over the resident KG.

The query tier's value proposition mirrors the creation tier's: pay the
plan+compile cost once per query *structure*, then answer every
structurally-identical BGP (any constants in the same shape) at jitted
steady-state rates. Cells:

* ``query_cold``   — first ``engine.query(q)`` on a session: lowering +
                     capacity annotation + static verification + jit
                     compile + execute.
* ``query_cached`` — the same BGP again: the query plan-cache tier returns
                     the compiled closure, only execution remains. Gated
                     in-bench: the repeat MUST be a cache hit with zero
                     recompiles, and ≥ 10× faster than cold (≥ 2× on a
                     mesh, where every call re-pays the final unshard +
                     host-visible δ).
* ``queries_per_s``— best-of-N steady-state rate for a 2-hop join BGP and
                     a single-pattern scan (the regression gate keys on
                     the join cell).

Every row carries ``devices``; with >1 visible device the same cells run
through the shard_map mesh path (cost-modeled ⋈ exchanges + sharded δ),
so the CI multi-device leg benchmarks the collective query path.

Run: ``PYTHONPATH=src python -m benchmarks.query [--smoke]``
Artifacts: ``experiments/bench/query.json``.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import numpy as np

from repro.api import (EngineConfig, KGEngine, Query, TriplePattern,
                       clear_plan_cache)
from repro.data.synthetic import make_group_b_dis
from repro.relalg import host_int

from .common import print_csv, save_rows, timeit


def _queries() -> Dict[str, Query]:
    return {
        "scan_1pat": Query(patterns=[TriplePattern("?s", "?p", "?o")]),
        "join_2hop": Query(patterns=[TriplePattern("?s", "?p", "?o"),
                                     TriplePattern("?o", "?p2", "?o2")]),
    }


def bench_queries(n_rows: int, engine: str, dedup: str, repeats: int,
                  mesh) -> List[Dict]:
    n_dev = int(mesh.shape["data"]) if mesh is not None else 1
    session = KGEngine(make_group_b_dis(n_rows, 0.6, seed=0),
                       config=EngineConfig(engine=engine, dedup=dedup,
                                           mesh=mesh))
    kg, _ = session.create_kg()
    kg_triples = int(host_int(kg.count))
    rows: List[Dict] = []
    for name, q in _queries().items():
        clear_plan_cache()
        t0 = time.perf_counter()
        res_cold = session.query(q)
        res_cold.data.block_until_ready()
        cold_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        res_hit = session.query(q)
        res_hit.data.block_until_ready()
        cached_s = time.perf_counter() - t0
        st = session.stats()["query"]
        # hard gates: the repeat is a plan-cache hit, recompile-free, and
        # answers bit-identically
        assert st["last_cache_hit"] and st["recompiles"] == 0, st
        assert np.array_equal(res_hit.to_codes(), res_cold.to_codes())
        # the mesh path re-pays the final unshard + host-visible δ per
        # call, so its cached floor is higher than the single-device one
        factor = 10 if mesh is None else 2
        assert cached_s * factor <= cold_s, \
            (f"cached {name} only {cold_s / cached_s:.1f}x faster than "
             f"cold (gate {factor}x, devices={n_dev})")

        steady_s = timeit(
            lambda: session.query(q).data.block_until_ready(),
            repeats=max(3, repeats), inner=10)
        answers = int(host_int(res_cold.count))
        rows.append({
            "config": name, "devices": n_dev, "engine": engine,
            "dedup": dedup, "kg_triples": kg_triples, "answers": answers,
            "cold_s": round(cold_s, 5),
            "cached_s": round(cached_s, 5),
            "steady_s": round(steady_s, 5),
            "speedup_cached": round(cold_s / max(cached_s, 1e-9), 2),
            "queries_per_s": round(1.0 / max(steady_s, 1e-9), 1),
        })
    return rows


def run(scale: float = 1.0, engine: str = "sdm", dedup: str = "hash",
        repeats: int = 3) -> List[Dict]:
    n = max(32, int(2000 * scale))
    rows = bench_queries(n, engine, dedup, repeats, mesh=None)
    if jax.device_count() > 1:
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((jax.device_count(),), ("data",))
        rows += bench_queries(n, engine, dedup, repeats, mesh=mesh)
    return rows


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny cells, correctness gates only (CI)")
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--engine", default="sdm")
    ap.add_argument("--dedup", default="hash")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args(argv)
    rows = run(scale=0.02 if args.smoke else args.scale, engine=args.engine,
               dedup=args.dedup, repeats=1 if args.smoke else args.repeats)
    save_rows("query", rows)
    print_csv(rows)
    return rows


if __name__ == "__main__":
    main()
