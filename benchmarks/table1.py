"""Paper Table 1: input-dataset size reduction by MapSDI pre-processing.

Paper mapping: Table 1 lists each pre-processed source's size before and
after applying Rules 1–3 (the paper's headline: 59,200 KB -> 895 KB). For
each volume point of the Fig. 8 grid this reports rows and (decoded) byte
sizes before/after projection + dedup + merge, plus how often each rule
fired.
"""
from __future__ import annotations

import argparse
from typing import Dict, List

from repro.configs.mapsdi_paper import CONFIG as PAPER
from repro.core.transform import apply_mapsdi
from repro.data.synthetic import make_group_a_dis

from .common import print_csv, save_rows


def _table_bytes(tables: Dict) -> int:
    """Approx decoded size: 4 bytes per valid cell (int32 codes)."""
    return sum(int(t.count) * t.n_attrs * 4 for t in tables.values())


def run(scale: float = 1.0, redundancy: float = 0.25, seed: int = 0,
        volumes=None) -> List[Dict]:
    rows: List[Dict] = []
    for vol in (volumes or PAPER.volumes):
        n = max(1, int(PAPER.rows_for_volume(vol) * scale))
        dis = make_group_a_dis(n, redundancy, seed=seed)
        before_rows = sum(int(t.count) for t in dis.sources.values())
        before_b = _table_bytes(dis.sources)
        dis2, stats = apply_mapsdi(dis)
        after_rows = sum(int(t.count) for t in dis2.sources.values())
        after_b = _table_bytes(dis2.sources)
        rows.append({
            "volume": vol,
            "rows_before": before_rows, "rows_after": after_rows,
            "bytes_before": before_b, "bytes_after": after_b,
            "reduction_x": round(before_b / max(after_b, 1), 1),
            "rule1": stats.rule1_applications,
            "rule2": stats.rule2_applications,
            "rule3": stats.rule3_merges,
        })
    return rows


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    args = ap.parse_args(argv)
    rows = run(scale=args.scale)
    save_rows("table1", rows)
    print_csv(rows)
    return rows


if __name__ == "__main__":
    main()
