"""Benchmark entry point: one function per paper table/figure.

``python -m benchmarks.run [--scale S]`` runs:

  * group_a     — Fig. 8 volume x redundancy grid (2 engines)
  * group_b     — Fig. 9 join scenarios
  * table1      — Table 1 source-size reduction
  * motivating  — Fig. 1 duplicate blow-up
  * roofline    — collated §Roofline table (from dry-run artifacts)

Artifacts land in ``experiments/bench/*.json``.
"""
from __future__ import annotations

import argparse
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.25,
                    help="row-count multiplier for the paper grids "
                         "(1.0 = the scaled-down paper testbed)")
    ap.add_argument("--only", default="",
                    help="comma list: group_a,group_b,table1,motivating,"
                         "roofline")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    from . import group_a, group_b, motivating, roofline, table1

    jobs = [("group_a", lambda: group_a.main(["--scale", str(args.scale)])),
            ("group_b", lambda: group_b.main(["--scale", str(args.scale)])),
            ("table1", lambda: table1.main(["--scale", str(args.scale)])),
            ("motivating", lambda: motivating.main(
                ["--rows", str(max(200, int(4000 * args.scale)))])),
            ("roofline", lambda: roofline.main([]))]
    for name, fn in jobs:
        if only and name not in only:
            continue
        print(f"\n===== {name} =====")
        t0 = time.perf_counter()
        fn()
        print(f"[{name}: {time.perf_counter() - t0:.1f}s]")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
