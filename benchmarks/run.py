"""Benchmark entry point: one function per paper table/figure.

``python -m benchmarks.run [--scale S] [--smoke]`` runs:

  * group_a     — paper Fig. 8: volume x redundancy grid (2 engines)
  * group_b     — paper Fig. 9: join-condition scenarios
  * table1      — paper Table 1: source-size reduction by pre-processing
  * motivating  — paper Fig. 1: the duplicate blow-up
  * dedup       — δ operator sweep: lex vs hash-first vs distributed
  * partition   — local shard bucketization: sort path vs radix kernel
  * planner     — eager fixpoint vs optimizing planner (docs/planner.md)
  * engine      — KGEngine sessions: cold vs cached vs ingest (docs/engine.md)
  * query       — KGQuery BGPs: cold vs cached latency, queries/s
                  (docs/query.md)
  * serve       — multi-tenant front door: K-compiles-for-T-tenants,
                  typed backpressure, bit-identical isolation
                  (docs/serve.md)
  * roofline    — collated §Roofline table (from dry-run artifacts)

``--smoke`` exercises exactly one tiny cell per group (CI wiring: fast,
asserts all correctness invariants, skips nothing structurally).
Artifacts land in ``experiments/bench/*.json``.
"""
from __future__ import annotations

import argparse
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.25,
                    help="row-count multiplier for the paper grids "
                         "(1.0 = the scaled-down paper testbed)")
    ap.add_argument("--only", default="",
                    help="comma list: group_a,group_b,table1,motivating,"
                         "dedup,partition,planner,engine,query,serve,"
                         "roofline")
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny cell per group (CI)")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    from . import dedup, engine, group_a, group_b, motivating, partition, \
        planner, query, roofline, serve, table1

    if args.smoke:
        from repro.configs.mapsdi_paper import CONFIG as PAPER

        from .common import print_csv, save_rows

        def _smoke(name, fn):
            rows = fn()
            save_rows(name, rows)
            print_csv(rows)
            return rows

        jobs = [
            ("group_a", lambda: _smoke("group_a", lambda: group_a.run(
                scale=0.02, volumes=PAPER.volumes[:1],
                redundancies=PAPER.redundancies[:1], engines=["sdm"]))),
            ("group_b", lambda: _smoke("group_b", lambda: group_b.run(
                scale=0.02, scenarios=PAPER.group_b_scenarios[:1]))),
            ("table1", lambda: _smoke("table1", lambda: table1.run(
                scale=0.02, volumes=PAPER.volumes[:1]))),
            ("motivating", lambda: motivating.main(["--rows", "120"])),
            ("dedup", lambda: dedup.main(["--smoke"])),
            ("partition", lambda: partition.main(["--smoke"])),
            ("planner", lambda: planner.main(["--smoke"])),
            ("engine", lambda: engine.main(["--smoke"])),
            ("query", lambda: query.main(["--smoke"])),
            ("serve", lambda: serve.main(["--smoke"])),
            ("roofline", lambda: roofline.main([])),
        ]
    else:
        jobs = [
            ("group_a", lambda: group_a.main(["--scale", str(args.scale)])),
            ("group_b", lambda: group_b.main(["--scale", str(args.scale)])),
            ("table1", lambda: table1.main(["--scale", str(args.scale)])),
            ("motivating", lambda: motivating.main(
                ["--rows", str(max(200, int(4000 * args.scale)))])),
            ("dedup", lambda: dedup.main([])),
            ("partition", lambda: partition.main([])),
            ("planner", lambda: planner.main(
                ["--scale", str(args.scale)])),
            ("engine", lambda: engine.main(
                ["--scale", str(args.scale)])),
            ("query", lambda: query.main(
                ["--scale", str(args.scale)])),
            ("serve", lambda: serve.main([])),
            ("roofline", lambda: roofline.main([])),
        ]
    for name, fn in jobs:
        if only and name not in only:
            continue
        print(f"\n===== {name} =====")
        t0 = time.perf_counter()
        fn()
        print(f"[{name}: {time.perf_counter() - t0:.1f}s]")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
