"""Planner benchmark: eager materializing fixpoint vs the optimizing planner.

Paper mapping: MapSDI's pre-processing is relational rewriting; SDM-RDFizer
and "Scaling Up KG Creation" locate the next order of magnitude in *planning*
the evaluation rather than per-operator tricks. This group measures exactly
that step: the historical eager driver (`apply_mapsdi_eager` — device
rewrites with a host sync per source per fixpoint iteration, then the
RDFizer closure) against the planner (`KGEngine` — symbolic fixpoint,
plan-time capacities, ONE jitted closure fusing pre-processing and
semantification).

Per config it reports preprocess/plan seconds, semantify/execute seconds,
the device→host sync counts (via the relalg transfer ledger), verifies the
two paths produce the *bit-identical* KG, and asserts the planner fixpoint
is sync-free under ``forbid_transfers``. Steady-state speedup compares what
each path must redo when source extensions change: eager = re-materialize +
semantify, planned = one closure call.

Configs: the paper figures (fig3, group_a, group_b) plus ``shared_multi`` —
many maps over one wide shared source with nulls, the σ-pushdown + CSE
showcase.

Run: ``PYTHONPATH=src python -m benchmarks.planner [--smoke]``
"""
from __future__ import annotations

import argparse
import time
from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.api import EngineConfig, KGEngine
from repro.core import RDFizer, apply_mapsdi_eager, parse_dis
from repro.core.transform import plan_mapsdi
from repro.data.synthetic import (FIG3_MAP, fig4_gene_source,
                                  make_group_a_dis, make_group_b_dis)
from repro.relalg import count_transfers, forbid_transfers, host_int

from .common import print_csv, save_rows, timeit


def fig3_dis():
    records, attrs = fig4_gene_source()
    return parse_dis({"sources": {"genes": {"attrs": attrs,
                                            "records": records}},
                      "maps": [FIG3_MAP]})


def make_shared_multi_dis(n_rows: int, null_frac: float = 0.3,
                          redundancy: float = 0.6, seed: int = 0):
    """Six maps over ONE wide source with overlapping attr subsets, nulls in
    the subject attrs and a σ-selective species attr — the workload where
    selection pushdown and cross-map sharing pay."""
    rng = np.random.default_rng(seed)
    n_distinct = max(1, int(round(n_rows * (1.0 - redundancy))))
    pools = {a: np.array([f"{a}_{i:07d}" for i in range(n_distinct)])
             for a in ["a0", "a1", "a2", "a3", "a4", "a5", "a6", "a7"]}
    species = np.array(["HUMAN", "MOUSE", "RAT"])
    records = []
    for i in range(n_rows):
        rec: Dict[str, object] = {"ID": int(i)}
        for a, pool in pools.items():
            if rng.random() < null_frac:
                rec[a] = None
            else:
                rec[a] = str(pool[rng.integers(0, n_distinct)])
        rec["sp"] = str(species[rng.integers(0, 3)])
        records.append(rec)
    attrs = ["ID"] + sorted(pools) + ["sp"]

    def m(name, subj_attr, poms, selections=None):
        out = {"name": name, "source": "wide",
               "subject": {"template": f"http://ex/{name}/{{{subj_attr}}}",
                           "class": f"ex:{name}"},
               "poms": poms}
        if selections:
            out["selections"] = selections
        return out

    maps = [
        m("M0", "a0", [{"predicate": "ex:p1", "object": {"reference": "a1"}},
                       {"predicate": "ex:p2", "object": {"reference": "a2"}}]),
        m("M1", "a0", [{"predicate": "ex:p1", "object": {"reference": "a1"}},
                       {"predicate": "ex:p3", "object": {"reference": "a3"}}]),
        m("M2", "a4", [{"predicate": "ex:p4", "object": {"reference": "a5"}}]),
        m("M3", "a4", [{"predicate": "ex:p5", "object": {"reference": "a5"}}]),
        m("M4", "a6", [{"predicate": "ex:p6", "object": {"reference": "a7"}}],
          selections=[{"attr": "sp", "eq": "HUMAN"}]),
        m("M5", "a6", [{"predicate": "ex:p7", "object": {"reference": "a7"}}]),
    ]
    return parse_dis({"sources": {"wide": {"attrs": attrs,
                                           "records": records}},
                      "maps": maps})


CONFIGS: Dict[str, Callable[[float], object]] = {
    "fig3": lambda scale: fig3_dis(),
    "group_a": lambda scale: make_group_a_dis(
        n_rows=max(32, int(4000 * scale)), redundancy=0.75, seed=1),
    "group_b": lambda scale: make_group_b_dis(
        n_rows=max(32, int(4000 * scale)), redundancy=0.6, seed=2),
    "shared_multi": lambda scale: make_shared_multi_dis(
        n_rows=max(64, int(6000 * scale)), seed=3),
}


def _bench_eager(dis, engine: str, dedup: str, repeats: int
                 ) -> Tuple[np.ndarray, Dict[str, float]]:
    with count_transfers() as ledger:
        t0 = time.perf_counter()
        dis2, _ = apply_mapsdi_eager(dis, dedup=dedup)
        pre_s = time.perf_counter() - t0
    rdfizer = RDFizer(dis2, engine, dedup=dedup)

    def sem():
        kg, _ = rdfizer()
        kg.data.block_until_ready()
        return kg

    kg = sem()  # compile
    sem_s = timeit(sem, repeats=repeats)
    # re-preprocess timing with warm op caches: what a new extension costs
    pre2_s = timeit(lambda: apply_mapsdi_eager(dis, dedup=dedup),
                    repeats=repeats)
    return kg.to_codes(), {
        "eager_preprocess_s": min(pre_s, pre2_s),
        "eager_semantify_s": sem_s,
        "eager_syncs": ledger.device_to_host,
    }


def _bench_planned(dis, engine: str, dedup: str, repeats: int
                   ) -> Tuple[np.ndarray, Dict[str, float]]:
    # the symbolic fixpoint must be sync-free — hard assertion, every config
    with forbid_transfers() as ledger:
        plan_mapsdi(dis)
    t0 = time.perf_counter()
    session = KGEngine(dis, config=EngineConfig(engine=engine,
                                                dedup=dedup))
    plan_s = time.perf_counter() - t0

    def run():
        kg, _ = session.run()
        kg.data.block_until_ready()
        return kg

    kg = run()  # compile
    exec_s = timeit(run, repeats=repeats)
    return kg.to_codes(), {
        "planned_plan_s": plan_s,
        "planned_exec_s": exec_s,
        "planned_fixpoint_syncs": ledger.device_to_host,
    }


def run(configs=None, scale: float = 1.0, engine: str = "sdm",
        dedup: str = "hash", repeats: int = 3) -> List[Dict]:
    rows: List[Dict] = []
    for name in (configs or CONFIGS):
        dis = CONFIGS[name](scale)
        n_rows = sum(host_int(t.count) for t in dis.sources.values())
        kg_e, eager = _bench_eager(CONFIGS[name](scale), engine, dedup,
                                   repeats)
        kg_p, planned = _bench_planned(dis, engine, dedup, repeats)
        assert np.array_equal(kg_e, kg_p), f"KG mismatch on {name}"
        eager_total = eager["eager_preprocess_s"] + eager["eager_semantify_s"]
        rec: Dict[str, object] = {
            "config": name, "rows": n_rows, "engine": engine, "dedup": dedup,
            **{k: round(v, 5) if isinstance(v, float) else v
               for k, v in {**eager, **planned}.items()},
            # steady state: what each path redoes per new source extension
            "speedup_steady": round(eager_total / max(
                planned["planned_exec_s"], 1e-9), 2),
            # cold: including one-off planning
            "speedup_cold": round(eager_total / max(
                planned["planned_plan_s"] + planned["planned_exec_s"],
                1e-9), 2),
            "bitwise_equal": True,
        }
        rows.append(rec)
    return rows


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny cells, correctness + sync-freedom only (CI)")
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--engine", default="sdm")
    ap.add_argument("--dedup", default="hash")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args(argv)
    if args.smoke:
        rows = run(configs=["fig3", "shared_multi"], scale=0.02,
                   engine=args.engine, dedup=args.dedup, repeats=1)
    else:
        rows = run(scale=args.scale, engine=args.engine, dedup=args.dedup,
                   repeats=args.repeats)
    for rec in rows:
        assert rec["planned_fixpoint_syncs"] == 0
    save_rows("planner", rows)
    print_csv(rows)
    return rows


if __name__ == "__main__":
    main()
