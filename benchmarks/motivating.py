"""Paper Fig. 1 (motivating example): the duplicate blow-up.

Paper mapping: the motivating example semantifies three overlapping
genomic sources blindly and explodes into raw triples (the paper:
2,049,442,714 raw vs 102,549 distinct — a 16,445x blow-up), which the
sink δ must then eliminate; MapSDI's pre-processing produces the distinct
set directly. This reports the blow-up factor and the rows each framework
actually pushed through the RDFizer.
"""
from __future__ import annotations

import argparse
from typing import Dict, List

from repro.core.pipeline import mapsdi_create_kg
from repro.core.tframework import t_framework_create_kg
from repro.data.synthetic import make_motivating_dis

from .common import print_csv, save_rows


def run(n_rows: int = 4000, seed: int = 0) -> List[Dict]:
    dis_t = make_motivating_dis(n_rows, seed=seed)
    kg_t, stats_t = t_framework_create_kg(dis_t)
    dis_m = make_motivating_dis(n_rows, seed=seed)
    kg_m, stats_m = mapsdi_create_kg(dis_m)
    assert kg_m.row_set() == kg_t.row_set()
    blow = stats_t["raw_triples"] / max(int(kg_t.count), 1)
    rows = [{
        "rows_per_source": n_rows,
        "raw_triples_tframework": stats_t["raw_triples"],
        "distinct_triples": int(kg_t.count),
        "blowup_x": round(blow, 1),
        "mapsdi_rows_processed": sum(
            stats_m["source_rows_after"].values()),
        "tframework_rows_processed": sum(
            stats_t["source_rows"].values()),
    }]
    return rows


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=4000)
    args = ap.parse_args(argv)
    rows = run(n_rows=args.rows)
    save_rows("motivating", rows)
    print_csv(rows)
    return rows


if __name__ == "__main__":
    main()
