"""Radix-partition micro-benchmark: sort-based vs histogram-scatter kernel.

Paper mapping: every cross-device operator — the all_to_all join exchange
and the global-δ repartition behind the scaled-up integration numbers —
starts with the same local step: bucket this shard's rows by target shard.
This sweep isolates that step and compares

* ``sort``  — the historical path (stable ``lax.sort`` on the target id +
              ``searchsorted`` boundaries + scatter,
              :func:`repro.core.distributed._partition_local_sorted`),
* ``radix`` — the one-pass histogram → prefix-sum → scatter kernel package
              (:func:`repro.kernels.radix_partition.radix_partition`;
              Pallas on TPU, jnp oracle elsewhere),

over an N × K × n_buckets grid of random code matrices, recording warm
rows/sec per cell (best-of-R jitted calls) and asserting the two paths are
bit-identical (buckets, counts and overflow flag) before timing anything.
Artifacts land in ``experiments/bench/partition.json``.

Run: ``PYTHONPATH=src python -m benchmarks.partition [--smoke]``
"""
from __future__ import annotations

import argparse
import functools
from typing import Dict, List

import jax
import numpy as np

from repro.core.distributed import _partition_local_sorted
from repro.kernels.radix_partition import radix_partition

from .common import print_csv, save_rows, timeit

GRID_N = (4096, 16384, 65536)
GRID_K = (2, 5, 8)
GRID_B = (4, 8, 16)               # n_buckets = target shard counts
SMOKE_N, SMOKE_K, SMOKE_B = (2048,), (3,), (8,)


def make_rows(n: int, k: int, seed: int = 0) -> np.ndarray:
    """[n, k] int32 codes (uniform — every bucket gets ~n/n_buckets rows)."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, 1 << 20, size=(n, k)).astype(np.int32)


def _cap_bucket(n: int, n_buckets: int) -> int:
    """Comfortable per-bucket capacity for uniform rows (~2x the mean)."""
    return max(8, (2 * n) // n_buckets)


def _warm_rows_per_sec(fn, n: int, repeats: int = 3) -> float:
    def call():
        buckets, counts, overflow = fn()
        buckets.block_until_ready()
    call()                     # compile
    return n / max(timeit(call, repeats=repeats), 1e-9)


def run(ns=GRID_N, ks=GRID_K, n_buckets=GRID_B, seed: int = 0,
        repeats: int = 3) -> List[Dict]:
    rows_out: List[Dict] = []
    for n in ns:
        for k in ks:
            for nb in n_buckets:
                codes = jax.numpy.asarray(make_rows(n, k, seed))
                count = jax.numpy.int32(n)
                cb = _cap_bucket(n, nb)
                sort_fn = jax.jit(functools.partial(
                    _partition_local_sorted, codes, count, nb, cb, None))
                radix_fn = jax.jit(functools.partial(
                    radix_partition, codes, count,
                    n_buckets=nb, cap_bucket=cb))
                sb, sc, so = jax.device_get(sort_fn())
                rb, rc, ro = jax.device_get(radix_fn())
                assert bool(so) == bool(ro) and not bool(ro), (n, k, nb)
                assert (sc == rc).all() and (sb == rb).all(), (n, k, nb)
                rec = {
                    "n": n, "k": k, "n_buckets": nb, "cap_bucket": cb,
                    "config": "partition",
                    "sort_rows_per_s": round(_warm_rows_per_sec(
                        sort_fn, n, repeats)),
                    "radix_rows_per_s": round(_warm_rows_per_sec(
                        radix_fn, n, repeats)),
                }
                rec["radix_speedup"] = round(
                    rec["radix_rows_per_s"]
                    / max(rec["sort_rows_per_s"], 1), 2)
                rows_out.append(rec)
    return rows_out


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny cell (CI): N=2048, K=3, buckets=8")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args(argv)
    if args.smoke:
        rows = run(SMOKE_N, SMOKE_K, SMOKE_B, repeats=2)
    else:
        rows = run(repeats=args.repeats)
    save_rows("partition", rows)
    print_csv(rows)
    return rows


if __name__ == "__main__":
    main()
