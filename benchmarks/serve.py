"""Multi-tenant serving benchmark: the front-door claims, gated.

The serve tier's story (``docs/serve.md``) is three claims, each asserted
here in every invocation (including ``--smoke`` — CI runs this module on
the 1-device and the 8-virtual-device legs):

* **K compiles for T tenants** — ``config="serve_multi_tenant"``
  registers T tenants over K structural DIS shapes and streams rounds of
  per-tenant ingest micro-batches (sized to stay inside the seed capacity
  bucket). Gate: ``registry.compiles() == K`` *exactly* — the plan cache
  deduplicates every structurally-shared compile, and nothing recompiled.
  Reports sustained ingest throughput (``sustained_ingests_per_s``,
  wired into ``benchmarks/regression_gate.py``) and linear-interpolation
  p50/p99 request latency (the shared :func:`repro.serve.percentile` —
  NOT the historical ``int(n * 0.99)`` index arithmetic, which returned
  the max for every sample count ≤ 100).
* **bit-identical isolation** — every tenant's final KG must equal, bit
  for bit, a dedicated single-tenant session fed the identical delta
  stream in the identical order. Multiplexing is an operational
  optimization, never a semantic one.
* **typed backpressure, zero silent drops** —
  ``config="serve_backpressure"`` fills a tiny queue past its high-water
  and induces a recompile storm (a bucket-crossing delta under a long
  stall window). Gate: every submit returned a Ticket or a typed
  ``Overloaded`` (reasons ``queue_full`` and ``recompile_storm`` both
  observed), accepted + rejected == submitted, and every accepted ticket
  resolved — the door never loses a request on the floor.

With >1 local device a mesh tenant pair (``config="serve_mesh_pair"``)
additionally runs two same-shape tenants through the fused shard_map
path: one compile, bit-identical KGs.

Run: ``PYTHONPATH=src python -m benchmarks.serve [--smoke]``
Artifacts: ``experiments/bench/serve.json``.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import numpy as np

from repro.api import EngineConfig, KGEngine, clear_plan_cache
from repro.data.synthetic import (make_group_b_dis,
                                  make_group_b_extension_records)
from repro.relalg import Table, host_int
from repro.serve import FrontDoor, Overloaded, Ticket, percentile

from .common import print_csv, save_rows


def _codes(kg: Table) -> np.ndarray:
    n = host_int(kg.count)
    return np.asarray(kg.data)[:n]


def _replay_dedicated(dis, config: EngineConfig,
                      history: List[Dict[str, List[Dict]]]) -> Table:
    """A dedicated single-tenant session fed the tenant's exact delta
    stream: one ``ingest`` per front-door flush, sources interned in the
    same order — the bit-identity oracle."""
    engine = KGEngine(dis, config=config)
    kg, _ = engine.create_kg()
    for recs in history:
        deltas = {name: Table.from_records(r, engine.sources[name].attrs,
                                           engine.vocab)
                  for name, r in recs.items() if r}
        if deltas:
            kg, _ = engine.ingest(deltas)
    return kg


def bench_multi_tenant(tenants: int, shapes: int, seed_rows: int,
                       batch_rows: int, rounds: int) -> Dict[str, object]:
    assert 1 <= shapes <= tenants
    config = EngineConfig(engine="sdm", dedup="hash")
    clear_plan_cache()
    door = FrontDoor(config, flush_window=0.0,
                     max_queue=4 * tenants * rounds)
    mk = lambda shape: make_group_b_dis(  # noqa: E731
        seed_rows, 0.6, seed=100 + shape)
    for t in range(tenants):
        door.register(f"tenant{t}", mk(t % shapes))

    # per-tenant delta streams, remembered for the dedicated replay
    history: List[List[Dict]] = [[] for _ in range(tenants)]
    lat: List[float] = []
    sustained_s = 0.0
    sustained_n = 0
    for rnd in range(rounds):
        t0 = time.perf_counter()
        tickets: List[Ticket] = []
        for t in range(tenants):
            recs = make_group_b_extension_records(
                batch_rows, seed=5000 + rnd * tenants + t)
            history[t].append(recs)
            resp = door.submit(f"tenant{t}", recs)
            assert isinstance(resp, Ticket), \
                f"multi-tenant round {rnd} unexpectedly shed: {resp}"
            tickets.append(resp)
        door.pump(force=True)
        results = [tk.result(timeout=600) for tk in tickets]
        lat.extend(r.latency_s for r in results)
        if rnd > 0:   # round 0 pays the K compiles — not steady state
            sustained_s += time.perf_counter() - t0
            sustained_n += len(results)

    st = door.serve_stats()
    compiles = st["compiles"]
    assert compiles == shapes, \
        (f"compile dedup broken: {tenants} tenants over {shapes} shapes "
         f"cost {compiles} compiles (expected exactly {shapes}); "
         f"recompile_stalls={st['recompile_stalls']}")
    assert st["rejected"] == 0 and st["completed"] == tenants * rounds

    # bit-identity: EVERY tenant against its dedicated session
    for t in range(tenants):
        kg = door.kg(f"tenant{t}")
        oracle = _replay_dedicated(mk(t % shapes), config, history[t])
        assert host_int(kg.count) == host_int(oracle.count) \
            and np.array_equal(_codes(kg), _codes(oracle)), \
            f"tenant{t} KG diverged from its dedicated session"

    return {
        "config": "serve_multi_tenant", "devices": jax.device_count(),
        "tenants": tenants, "shapes": shapes, "seed_rows": seed_rows,
        "batch_rows": batch_rows, "rounds": rounds,
        "compiles": compiles,
        "compile_dedup_ratio": round(st["compile_dedup_ratio"], 2),
        "requests": st["completed"],
        "sustained_ingests_per_s": (sustained_n / sustained_s
                                    if sustained_s else 0.0),
        "p50_ms": percentile(lat, 50) * 1e3,
        "p99_ms": percentile(lat, 99) * 1e3,
        "recompile_stalls": st["recompile_stalls"],
        "plan_cache_hits": st["plan_cache"]["hits"],
        "bit_identical_tenants": tenants,
    }


def bench_backpressure(seed_rows: int, batch_rows: int
                       ) -> Dict[str, object]:
    config = EngineConfig(engine="sdm", dedup="hash")
    clear_plan_cache()
    door = FrontDoor(config, flush_window=0.0, max_queue=4, storm_queue=1,
                     stall_window_s=600.0)
    door.register("t0", make_group_b_dis(seed_rows, 0.6, seed=200))

    submitted = accepted = rejected = 0
    reasons: Dict[str, int] = {}
    tickets: List[Ticket] = []

    def submit(rows: int, seed: int) -> None:
        nonlocal submitted, accepted, rejected
        recs = make_group_b_extension_records(rows, seed=seed)
        resp = door.submit("t0", recs)
        submitted += 1
        if isinstance(resp, Overloaded):
            rejected += 1
            reasons[resp.reason] = reasons.get(resp.reason, 0) + 1
            assert resp.tenant_id == "t0" and resp.retry_after_s > 0
        else:
            accepted += 1
            tickets.append(resp)

    # 1) hard high-water: burst 2x the queue bound without pumping
    for i in range(8):
        submit(2, seed=7000 + i)
    assert reasons.get("queue_full", 0) == 4, reasons
    door.pump(force=True)

    # 2) recompile storm: one bucket-crossing delta under a long stall
    # window, then a trickle that lands above the storm low-water
    submit(16 * seed_rows, seed=7100)   # outgrows the seed bucket
    door.pump(force=True)
    st = door.serve_stats()
    assert st["recompile_stalls"] >= 1, \
        f"bucket-crossing delta caused no recompile: {st}"
    assert st["admission"]["in_storm"], "storm window did not open"
    submit(2, seed=7200)                # depth 0 < storm_queue=1: admitted
    submit(2, seed=7201)                # depth 1 >= storm_queue: shed
    assert reasons.get("recompile_storm", 0) >= 1, reasons
    door.pump(force=True)

    # zero silent drops: every submit is accounted for, every accepted
    # ticket resolved
    assert accepted + rejected == submitted
    results = [tk.result(timeout=600) for tk in tickets]
    assert len(results) == accepted
    st = door.serve_stats()
    assert st["accepted"] == accepted and st["rejected"] == rejected
    assert st["completed"] == accepted and st["errors"] == 0

    return {
        "config": "serve_backpressure", "devices": jax.device_count(),
        "submitted": submitted, "accepted": accepted, "rejected": rejected,
        "queue_full": reasons.get("queue_full", 0),
        "recompile_storm": reasons.get("recompile_storm", 0),
        "recompile_stalls": st["recompile_stalls"],
        "silent_drops": submitted - accepted - rejected,
    }


def bench_mesh_pair(seed_rows: int, batch_rows: int) -> Dict[str, object]:
    from repro.launch.mesh import make_mesh
    n_dev = jax.device_count()
    mesh = make_mesh((n_dev,), ("data",))
    config = EngineConfig(engine="sdm", dedup="hash", mesh=mesh)
    clear_plan_cache()
    door = FrontDoor(config, flush_window=0.0, max_queue=64)
    mk = lambda: make_group_b_dis(seed_rows, 0.6, seed=300)  # noqa: E731
    door.register("a", mk())
    door.register("b", mk())
    recs = make_group_b_extension_records(batch_rows, seed=7300)
    ta, tb = door.submit("a", recs), door.submit("b", recs)
    door.pump(force=True)
    ra, rb = ta.result(timeout=600), tb.result(timeout=600)
    assert ra.kg_triples == rb.kg_triples
    assert np.array_equal(_codes(door.kg("a")), _codes(door.kg("b")))
    oracle = _replay_dedicated(mk(), config, [recs])
    assert np.array_equal(_codes(door.kg("a")), _codes(oracle)), \
        "mesh tenant KG diverged from its dedicated mesh session"
    dedup = door.registry.compile_dedup()
    assert dedup["compiles"] == 1, dedup
    return {
        "config": "serve_mesh_pair", "devices": n_dev,
        "tenants": 2, "compiles": dedup["compiles"],
        "kg_triples": ra.kg_triples,
        "ingest_ms": round(max(ra.ingest_s, rb.ingest_s) * 1e3, 2),
    }


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizes; same gates")
    ap.add_argument("--tenants", type=int, default=None)
    ap.add_argument("--shapes", type=int, default=4)
    args = ap.parse_args(argv)

    if args.smoke:
        tenants = args.tenants or 32
        rows = [bench_multi_tenant(tenants=tenants, shapes=args.shapes,
                                   seed_rows=96, batch_rows=4, rounds=2),
                bench_backpressure(seed_rows=24, batch_rows=2)]
    else:
        tenants = args.tenants or 48
        rows = [bench_multi_tenant(tenants=tenants, shapes=args.shapes,
                                   seed_rows=512, batch_rows=16, rounds=6),
                bench_backpressure(seed_rows=48, batch_rows=4)]
    if jax.device_count() > 1:
        rows.append(bench_mesh_pair(seed_rows=64, batch_rows=4))
    save_rows("serve", rows)
    print_csv(rows)
    return rows


if __name__ == "__main__":
    raise SystemExit(0 if main() is not None else 1)
