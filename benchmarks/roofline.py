"""Roofline summary: collate the dry-run + roofline artifacts.

Beyond-paper group (no figure counterpart): the scaled-up system's memory
and cost model. Reads ``experiments/dryrun_scan`` (production compiles:
memory proof) and ``experiments/roofline`` (depth-extrapolated cost terms)
and prints the per-(arch x shape) table used by EXPERIMENTS.md §Roofline.
Run ``python -m repro.launch.dryrun`` / ``python -m repro.launch.roofline``
first to (re)generate the artifacts; with no artifacts present this prints
a hint and exits cleanly.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from .common import print_csv, save_rows

ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                    "experiments")


def load(dirname: str) -> Dict[str, Dict]:
    out = {}
    for path in sorted(glob.glob(os.path.join(ROOT, dirname, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        out[f"{rec['arch']}__{rec['shape']}__{rec.get('mesh', 'single')}"] \
            = rec
    return out


def run() -> List[Dict]:
    scans = load("dryrun_scan")
    roofs = load("roofline")
    rows: List[Dict] = []
    for key, roof in sorted(roofs.items()):
        if roof.get("status") != "ok":
            continue
        scan = scans.get(key, {})
        mem = scan.get("memory", {})
        t = roof["terms_seconds"]
        rows.append({
            "arch": roof["arch"], "shape": roof["shape"],
            "mesh": roof["mesh"],
            "compute_s": f"{t['compute_s']:.3e}",
            "memory_s": f"{t['memory_s']:.3e}",
            "collective_s": f"{t['collective_s']:.3e}",
            "dominant": roof["dominant"].replace("_s", ""),
            "useful_ratio": round(roof["useful_flops_ratio"], 3),
            "roofline_frac": round(roof["roofline_fraction"], 4),
            "hbm_gib_per_dev": round(
                (mem.get("argument_size_in_bytes", 0)
                 + mem.get("temp_size_in_bytes", 0)) / 2**30, 2),
        })
    return rows


def main(argv=None) -> List[Dict]:
    rows = run()
    save_rows("roofline_summary", rows)
    print_csv(rows)
    if not rows:
        print("(no roofline artifacts yet: run "
              "`python -m repro.launch.roofline` first)")
    return rows


if __name__ == "__main__":
    main()
