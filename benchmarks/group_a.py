"""Experiment group A (paper Fig. 8): volume x redundancy grid.

Paper mapping: Fig. 8 plots KG-creation time for MapSDI vs the traditional
framework over data volume (its 10k–100k-row testbed) × duplicate
redundancy (25%/50%/75%), for both studied engines (RMLMapper-style blind
generation and the duplicate-aware SDM-RDFizer) — the experiment behind
the paper's order-of-magnitude claim. For every cell we assert the two
frameworks produce the SAME knowledge graph (the paper's Q1) and record:

* ``*_warm_s``   steady-state semantification time (jitted closure,
                 best-of-3 — the paper's repeated-ETL regime),
* ``mapsdi_pre_s`` MapSDI's one-off transform/planning cost (host side),
* the triple blow-up the T-framework pays (raw vs distinct).
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

from repro.api import EngineConfig, KGEngine
from repro.configs.mapsdi_paper import CONFIG as PAPER
from repro.core.tframework import make_t_framework_fn
from repro.core.transform import apply_mapsdi
from repro.data.synthetic import make_group_a_dis

from .common import print_csv, save_rows, timeit


def _warm_time(fn, repeats=3) -> float:
    def call():
        kg, raw = fn()
        kg.data.block_until_ready()
    call()                      # compile
    return timeit(call, repeats=repeats)


def run(scale: float = 1.0, seed: int = 0,
        volumes=None, redundancies=None, engines=None) -> List[Dict]:
    rows: List[Dict] = []
    volumes = volumes or PAPER.volumes
    redundancies = redundancies or PAPER.redundancies
    engines = engines or PAPER.engines
    for vol in volumes:
        n = max(1, int(PAPER.rows_for_volume(vol) * scale))
        for red in redundancies:
            dis_m = make_group_a_dis(n, red, seed=seed)
            dis_t = make_group_a_dis(n, red, seed=seed)
            for engine in engines:
                t0 = time.perf_counter()
                dis_m2, _ = apply_mapsdi(dis_m)
                pre_s = time.perf_counter() - t0   # the one-off transform
                fn_m = KGEngine(
                    dis_m2, config=EngineConfig(engine=engine)).run
                fn_t = make_t_framework_fn(dis_t, engine)
                warm_m = _warm_time(fn_m)
                warm_t = _warm_time(fn_t)
                kg_m, _ = fn_m()
                kg_t, raw_t = fn_t()
                same = kg_m.row_set() == kg_t.row_set()
                rows.append({
                    "volume": vol, "redundancy": red, "engine": engine,
                    "rows": n,
                    "mapsdi_warm_s": round(warm_m, 4),
                    "tframework_warm_s": round(warm_t, 4),
                    "speedup": round(warm_t / max(warm_m, 1e-9), 2),
                    "mapsdi_pre_s": round(pre_s, 4),
                    "kg_triples": int(kg_m.count),
                    "raw_triples_t": int(raw_t),
                    "same_kg": same,
                })
                assert same, f"Q1 violated at vol={vol} red={red} {engine}"
    return rows


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    args = ap.parse_args(argv)
    rows = run(scale=args.scale)
    save_rows("group_a", rows)
    print_csv(rows)
    return rows


if __name__ == "__main__":
    main()
