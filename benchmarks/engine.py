"""KGEngine session benchmark: cold vs cached vs ingest steady state.

Paper mapping: MapSDI's value proposition is *amortization* — extract
knowledge from the mapping rules once, then semantify large and growing
sources cheaply. This group measures the session API that makes the
amortization literal:

* ``cold``    — ``mapsdi_create_kg`` with an empty plan cache: symbolic
                fixpoint + annotation + jit compile + execute.
* ``cached``  — a structurally-identical DIS in a fresh session: the plan
                cache returns the compiled closure, only execution remains.
                The acceptance bar is cached ≥ 10× faster than cold.
* ``ingest``  — steady-state micro-batches through ``engine.ingest``:
                within-bucket appends re-execute the cached closure with
                zero re-trace (triples/sec + recompile counts reported).

Hard correctness gates run in every invocation (including
``--smoke``): an out-of-capacity extension (16× the seed) must produce the
bit-exact KG of a fresh run over the accumulated sources with exactly one
recompile; the distributed shard_map δ path must reuse the session's
cached collective closure (trace-count guard); the fused mesh closure
(``config="distributed_fused"``, over ALL available devices — 8 on the CI
multi-device leg) must run with zero host gathers of intermediate triples
(``forbid_transfers`` passes around the closure) while producing the
bit-identical KG of the single-device planned path; and a fresh process
against a populated persistent plan store
(``config="warm_process_cold_start"``, see ``docs/plan_store.md``) must
reach its first KG ≥ 10× faster than the cold process that populated it,
bit-identically. The static verification layer (``docs/analysis.md``)
is gated too: ``config="verifier_overhead"`` asserts ``verify="plan"``
adds <5% to cold plan-build time, so the default stays on.

Run: ``PYTHONPATH=src python -m benchmarks.engine [--smoke]``
Artifacts: ``experiments/bench/engine.json``.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import numpy as np

from repro.api import (EngineConfig, KGEngine, clear_plan_cache,
                       plan_cache_stats)
from repro.core import parse_dis
from repro.core.distributed import repartition_trace_count
from repro.core.pipeline import mapsdi_create_kg
from repro.core.rdfizer import RDFizer
from repro.data.synthetic import (make_group_b_dis,
                                  make_group_b_extension_records)
from repro.launch.mesh import make_mesh
from repro.relalg import Table, forbid_transfers, host_int

from .common import print_csv, save_rows, timeit


def _gene_records(n: int, seed: int) -> List[Dict]:
    """Extension rows shaped like the group-B ``gene`` source (new samples
    over the same entity pools, so joins keep matching)."""
    return make_group_b_extension_records(n, seed, sources=("gene",))["gene"]


def _delta(engine: KGEngine, name: str, records: List[Dict]) -> Table:
    attrs = engine.sources[name].attrs
    return Table.from_records(records, attrs, engine.vocab)


def bench_cold_vs_cached(n_rows: int, engine: str, dedup: str,
                         repeats: int) -> Dict[str, object]:
    mk = lambda: make_group_b_dis(n_rows, 0.6, seed=0)  # noqa: E731
    clear_plan_cache()
    t0 = time.perf_counter()
    kg_cold, _stats = mapsdi_create_kg(mk(), engine=engine, dedup=dedup)
    kg_cold.data.block_until_ready()
    cold_s = time.perf_counter() - t0

    # fresh session, structurally identical DIS -> plan-cache hit
    t0 = time.perf_counter()
    kg_c, stats_c = mapsdi_create_kg(mk(), engine=engine, dedup=dedup)
    kg_c.data.block_until_ready()
    cached_s = time.perf_counter() - t0
    assert stats_c["plan_cache_hit"], "second one-shot call missed the cache"
    assert np.array_equal(kg_c.to_codes(), kg_cold.to_codes())

    # steady state: re-execution of one session's cached closure (best-of-N
    # even in --smoke — the regression gate keys on this, and a single
    # measurement of a millisecond-scale call is too noisy to gate on)
    session = KGEngine(mk(), config=EngineConfig(engine=engine, dedup=dedup))
    session.create_kg()
    steady_s = timeit(lambda: session.run(), repeats=max(3, repeats),
                      inner=10)

    kg_triples = int(host_int(kg_cold.count))
    row = {
        "config": "group_b", "rows": 2 * n_rows, "engine": engine,
        "dedup": dedup, "kg_triples": kg_triples,
        "cold_s": round(cold_s, 5),
        "cached_s": round(cached_s, 5),
        "steady_s": round(steady_s, 5),
        "speedup_cached": round(cold_s / max(cached_s, 1e-9), 2),
        "speedup_steady": round(cold_s / max(steady_s, 1e-9), 2),
        "cold_triples_per_s": round(kg_triples / max(cold_s, 1e-9)),
        "cached_triples_per_s": round(kg_triples / max(cached_s, 1e-9)),
        "steady_triples_per_s": round(kg_triples / max(steady_s, 1e-9)),
    }
    # acceptance gate: cached re-execution >= 10x faster than cold
    assert cached_s * 10 <= cold_s, \
        f"cached path only {cold_s / cached_s:.1f}x faster than cold"
    return row


def bench_ingest(n_rows: int, engine: str, dedup: str, batches: int,
                 batch_rows: int) -> Dict[str, object]:
    session = KGEngine(make_group_b_dis(n_rows, 0.6, seed=0),
                       config=EngineConfig(engine=engine, dedup=dedup))
    session.create_kg()
    # warm batch: absorbs the (at most one) bucket-crossing recompile so
    # the loop below times the cached steady state
    session.ingest({"gene": _delta(session, "gene",
                                   _gene_records(batch_rows, seed=99))})
    base_recompiles = session.stats()["recompiles"]
    t0 = time.perf_counter()
    triples = 0
    for b in range(batches):
        kg, stats = session.ingest(
            {"gene": _delta(session, "gene",
                            _gene_records(batch_rows, seed=100 + b))})
        triples = stats["kg_triples"]
    dt = time.perf_counter() - t0
    st = session.stats()
    return {
        "config": "ingest", "rows": 2 * n_rows, "engine": engine,
        "dedup": dedup, "batches": batches, "batch_rows": batch_rows,
        "kg_triples": triples,
        "ingest_s_per_batch": round(dt / max(batches, 1), 5),
        "ingest_triples_per_s": round(triples * batches / max(dt, 1e-9)),
        "recompiles": st["recompiles"] - base_recompiles,
        "plan_cache_hits": st["plan_cache_hits"],
    }


def check_overflow_recompile(n_rows: int, engine: str, dedup: str
                             ) -> Dict[str, object]:
    """Acceptance gate: a 16× out-of-capacity extension succeeds — the KG
    is bit-exact vs a fresh run over the accumulated sources — with exactly
    one recompile."""
    dis = make_group_b_dis(n_rows, 0.6, seed=0)
    session = KGEngine(dis, config=EngineConfig(engine=engine, dedup=dedup))
    session.create_kg()
    assert session.stats()["recompiles"] == 0
    kg, stats = session.ingest(
        {"gene": _delta(session, "gene",
                        _gene_records(16 * n_rows, seed=7))})
    assert stats["recompiles"] == 1, \
        f"expected exactly one recompile, got {stats['recompiles']}"
    acc = dis.copy()
    acc.sources = dict(session.sources)
    kg_ref, _ = RDFizer(acc, engine, dedup=dedup)()
    assert np.array_equal(kg.to_codes(), kg_ref.to_codes()), \
        "ingested KG differs from fresh run over accumulated sources"
    return {"config": "overflow_16x", "rows": 2 * n_rows, "engine": engine,
            "dedup": dedup, "kg_triples": stats["kg_triples"],
            "recompiles": stats["recompiles"], "bitwise_equal": True}


def check_distributed_closure_reuse(n_rows: int, dedup: str
                                    ) -> Dict[str, object]:
    """Acceptance gate: the shard_map δ path reuses the session's cached
    collective closure — the shard body is traced at most once across
    repeated ingests (trace-count guard)."""
    mesh = make_mesh((1,), ("data",))
    session = KGEngine(make_group_b_dis(n_rows, 0.6, seed=0),
                       config=EngineConfig(mesh=mesh, dedup=dedup))
    session.create_kg()
    t0 = repartition_trace_count()
    for b in range(2):
        kg, stats = session.ingest(
            {"gene": _delta(session, "gene",
                            _gene_records(max(4, n_rows // 16),
                                          seed=200 + b))})
    traces = repartition_trace_count() - t0
    assert traces == 0, \
        f"distributed δ re-traced {traces}x across same-bucket ingests"
    return {"config": "distributed_reuse", "rows": 2 * n_rows,
            "engine": "sdm", "dedup": dedup,
            "kg_triples": stats["kg_triples"], "sink_traces": traces}


def check_fused_mesh_device_resident(n_rows: int, engine: str, dedup: str,
                                     repeats: int) -> Dict[str, object]:
    """Acceptance gate: the fused mesh closure never gathers intermediate
    triples to host — ``forbid_transfers`` passes around the closure (input
    shard blocks and the final-KG read happen outside it) — and the KG it
    produces is bit-identical to the single-device planned path. Runs over
    ALL available devices, so the CI multi-device leg exercises the real
    collectives."""
    n_dev = jax.device_count()
    mesh = make_mesh((n_dev,), ("data",))
    mk = lambda: make_group_b_dis(n_rows, 0.6, seed=0)  # noqa: E731
    kg_single, _ = KGEngine(mk(), config=EngineConfig(
        engine=engine, dedup=dedup)).create_kg()
    session = KGEngine(mk(), config=EngineConfig(engine=engine, dedup=dedup,
                                                 mesh=mesh))
    kg_mesh, stats = session.create_kg()
    assert np.array_equal(kg_mesh.to_codes(), kg_single.to_codes()), \
        "fused mesh KG differs from the single-device planned path"
    entry = session._last["entry"]
    datas, counts = session._shard_sources(session.sources, entry.cap_locals)
    with forbid_transfers():   # zero host gathers of intermediate triples
        jax.block_until_ready(entry.fn(datas, counts))
    steady_s = timeit(lambda: jax.block_until_ready(entry.fn(datas, counts)),
                      repeats=max(3, repeats), inner=10)
    kg_triples = stats["kg_triples"]
    return {"config": "distributed_fused", "rows": 2 * n_rows,
            "engine": engine, "dedup": dedup, "devices": n_dev,
            "kg_triples": kg_triples,
            "steady_s": round(steady_s, 5),
            "triples_per_s": round(kg_triples / max(steady_s, 1e-9)),
            "host_transfers_in_closure": 0,
            "bitwise_equal_single_device": True}


_WARM_START_CHILD = r"""
import hashlib, json, sys, time
from repro.api import EngineConfig, KGEngine
from repro.data.synthetic import make_group_b_dis

root, n_rows = sys.argv[1], int(sys.argv[2])
dis = make_group_b_dis(n_rows, 0.6, seed=0)
t0 = time.perf_counter()          # post-import: plan + compile-or-load + run
session = KGEngine(dis, config=EngineConfig(plan_store=root))
kg, stats = session.create_kg()
kg.data.block_until_ready()
dt = time.perf_counter() - t0
print(json.dumps({
    "seconds": dt,
    "codes_sha": hashlib.sha256(kg.to_codes().tobytes()).hexdigest(),
    "kg_triples": stats["kg_triples"],
    "store_hits": stats["store_hits"],
    "store_rejects": stats["store_rejects"]}))
"""


def check_warm_process_cold_start(n_rows: int) -> Dict[str, object]:
    """Acceptance gate for the persistent plan store: a FRESH process
    against a store populated by a previous process rehydrates the
    AOT-serialized executable — no re-trace, no re-compile — and must be
    ≥ 10× faster to first KG than the cold process that populated it,
    with the bit-identical result (sha over ``to_codes()``)."""
    import hashlib  # noqa: F401  (used by the child)
    import os
    import subprocess
    import sys as _sys
    import tempfile
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    with tempfile.TemporaryDirectory() as root:
        runs = []
        for _ in range(2):   # run 1 populates (cold), run 2 rehydrates
            out = subprocess.run(
                [_sys.executable, "-c", _WARM_START_CHILD, root,
                 str(n_rows)], env=env, capture_output=True, text=True,
                timeout=600)
            assert out.returncode == 0, \
                f"stderr:\n{out.stderr}\nstdout:\n{out.stdout}"
            runs.append(json.loads(out.stdout.strip().splitlines()[-1]))
    cold, warm = runs
    assert cold["store_hits"] == 0, cold
    assert warm["store_hits"] == 1 and warm["store_rejects"] == 0, warm
    assert warm["codes_sha"] == cold["codes_sha"], \
        "store-rehydrated KG differs from the cold compile"
    cold_s, warm_s = cold["seconds"], warm["seconds"]
    assert warm_s * 10 <= cold_s, \
        f"warm process start only {cold_s / warm_s:.1f}x faster than cold"
    return {"config": "warm_process_cold_start", "rows": 2 * n_rows,
            "engine": "sdm", "dedup": None,
            "kg_triples": cold["kg_triples"],
            "cold_s": round(cold_s, 5), "warm_s": round(warm_s, 5),
            "warm_speedup": round(cold_s / max(warm_s, 1e-9), 2),
            "bitwise_equal": True}


def check_verifier_overhead(n_rows: int, engine: str, dedup: str,
                            repeats: int) -> Dict[str, object]:
    """Acceptance gate for the static verification layer (the reason
    ``verify="plan"`` can stay the default): the IR verifier + rewrite
    soundness gates add <5% to cold plan-build time, best-of-N with an
    absolute noise floor — a millisecond-scale verifier rides on a
    seconds-scale trace+compile. ``verify="full"`` (jaxpr audit on top)
    is recorded for the artifact but not gated."""
    mk = lambda: make_group_b_dis(n_rows, 0.6, seed=0)  # noqa: E731

    def cold(verify: str) -> float:
        best = float("inf")
        for _ in range(max(2, repeats)):
            clear_plan_cache()
            t0 = time.perf_counter()
            session = KGEngine(mk(), config=EngineConfig(
                engine=engine, dedup=dedup, verify=verify))
            kg, _ = session.create_kg()
            kg.data.block_until_ready()
            best = min(best, time.perf_counter() - t0)
        st = session.stats()["verify"]
        assert st["mode"] == verify and \
            st["plan_checks"] == (0 if verify == "off" else 1), st
        return best

    off_s = cold("off")
    plan_s = cold("plan")
    full_s = cold("full")
    overhead = plan_s - off_s

    # direct measurement of the verifier pass itself (the A/B delta above
    # is dominated by compile jitter; this is the actual added work)
    from repro.analysis import verify_plan
    from repro.plan.annotate import annotate
    session = KGEngine(mk(), config=EngineConfig(engine=engine, dedup=dedup,
                                                 verify="off"))
    session.create_kg()
    counts, caps = annotate(session._plan, mode=session.mode,
                            slack=session.slack)
    direct_s = timeit(
        lambda: verify_plan(session._plan, engine, counts=counts, caps=caps,
                            sources=session.sources,
                            slack=session.slack).raise_for_status(),
        repeats=max(3, repeats), inner=5)
    # the gate keys on the direct measure: back-to-back cold compiles of
    # the same plan jitter by O(100ms) on shared runners — far above the
    # millisecond-scale verifier — so the A/B delta is recorded in the
    # artifact but cannot be gated tightly
    assert direct_s <= 0.05 * off_s + 0.05, \
        (f"verify='plan' pass costs {direct_s:.3f}s against a "
         f"{off_s:.3f}s cold build (>5% + 50ms noise floor) — the "
         "default must stay cheap")
    return {"config": "verifier_overhead", "rows": 2 * n_rows,
            "engine": engine, "dedup": dedup,
            "cold_off_s": round(off_s, 5),
            "cold_plan_s": round(plan_s, 5),
            "cold_full_s": round(full_s, 5),
            "verify_plan_overhead_s": round(overhead, 5),
            "verify_plan_overhead_pct": round(100 * overhead
                                              / max(off_s, 1e-9), 2),
            "verify_full_overhead_s": round(full_s - off_s, 5),
            "verify_pass_s": round(direct_s, 5)}


def _join_heavy_dis(n_child: int, n_parent: int, seed: int = 0):
    """A join-heavy config with a LARGE parent relative to the child —
    the regime where the all_gather ⋈ exchange hits the ICI wall and
    hash-repartition wins (Iglesias et al. 2022's big-source bottleneck).
    Parent rows are mostly distinct (near-unique keys AND values) so
    pre-processing cannot shrink the gathered side and the join fan-out
    stays bounded."""
    rng = np.random.default_rng(seed)
    keys = [f"K{i}" for i in range(max(8, n_parent // 2))]
    child = [{"ID": int(i), "k": str(keys[rng.integers(0, len(keys))]),
              "v": f"v{i}"} for i in range(n_child)]
    parent = [{"ID": int(i), "k": str(keys[rng.integers(0, len(keys))]),
               "p": f"p{i}"} for i in range(n_parent)]
    return parse_dis({
        "sources": {
            "child": {"attrs": ["ID", "k", "v"], "records": child},
            "parent": {"attrs": ["ID", "k", "p"], "records": parent}},
        "maps": [
            {"name": "M1", "source": "child",
             "subject": {"template": "http://ex/C/{v}", "class": "ex:C"},
             "poms": [{"predicate": "ex:rel",
                       "object": {"parentTriplesMap": "M2",
                                  "joinCondition": {"child": "k",
                                                    "parent": "k"}}}]},
            {"name": "M2", "source": "parent",
             "subject": {"template": "http://ex/P/{p}", "class": "ex:P"},
             "poms": []}]})


def _auto_choices(session: KGEngine):
    return sorted({x.strategy
                   for x in session._last["entry"].exchanges.values()})


def check_join_exchange_crossover(n_rows: int, engine: str, dedup: str,
                                  repeats: int) -> List[Dict]:
    """Acceptance gates for the cost-modeled ⋈ exchange + the crossover
    measurement shipped in the bench artifact:

    * the large-parent config runs under ``join_exchange="repartition"``
      with ZERO host transfers inside the fused closure and produces the
      ``to_codes()``-bit-identical KG of both the gather strategy and the
      single-device planned path;
    * ``auto`` picks repartition on the large-parent config (with >1
      device) while keeping gather on the small-parent group-B config;
    * steady-state seconds for gather vs repartition land in the artifact
      (the repartition-vs-gather crossover on this machine/mesh).
    """
    n_dev = jax.device_count()
    mesh = make_mesh((n_dev,), ("data",))
    # the parent must be genuinely large: the cost model's crossover sits
    # near COLLECTIVE_LAUNCH_S · ICI_BW ≈ 100 KiB of gathered parent bytes
    # per device (~a few thousand rows per shard)
    n_child, n_parent = max(32, n_rows // 2), max(1 << 14, 8 * n_rows)
    big = lambda: _join_heavy_dis(n_child, n_parent)  # noqa: E731
    kg_single, _ = KGEngine(big(), config=EngineConfig(
        engine=engine, dedup=dedup)).create_kg()
    rows: List[Dict] = []
    steady: Dict[str, float] = {}
    kg_by_strategy = {}
    for strategy in ("gather", "repartition"):
        session = KGEngine(big(), config=EngineConfig(
            engine=engine, dedup=dedup, mesh=mesh, join_exchange=strategy))
        kg, stats = session.create_kg()
        assert np.array_equal(kg.to_codes(), kg_single.to_codes()), \
            f"{strategy} KG differs from the single-device planned path"
        kg_by_strategy[strategy] = kg
        entry = session._last["entry"]
        datas, counts = session._shard_sources(session.sources,
                                               entry.cap_locals)
        with forbid_transfers():   # device-resident incl. the ⋈ exchange
            jax.block_until_ready(entry.fn(datas, counts))
        steady[strategy] = timeit(
            lambda: jax.block_until_ready(entry.fn(datas, counts)),
            repeats=max(3, repeats), inner=10)
        rows.append({
            "config": f"join_exchange_{strategy}", "engine": engine,
            "dedup": dedup, "devices": n_dev,
            "child_rows": n_child, "parent_rows": n_parent,
            "kg_triples": stats["kg_triples"],
            "steady_s": round(steady[strategy], 5),
            "triples_per_s": round(stats["kg_triples"]
                                   / max(steady[strategy], 1e-9)),
            "host_transfers_in_closure": 0,
            "bitwise_equal_single_device": True})
    assert np.array_equal(kg_by_strategy["gather"].to_codes(),
                          kg_by_strategy["repartition"].to_codes())

    auto_big = KGEngine(big(), config=EngineConfig(
        engine=engine, dedup=dedup, mesh=mesh, join_exchange="auto"))
    auto_big.create_kg()
    big_choice = _auto_choices(auto_big)
    assert big_choice == (["repartition"] if n_dev > 1 else ["gather"]), \
        f"auto chose {big_choice} on the large-parent config ({n_dev} dev)"
    # fixed smoke-sized group-B (small parent): auto must keep gathering
    auto_small = KGEngine(make_group_b_dis(80, 0.6, seed=0),
                          config=EngineConfig(engine=engine, dedup=dedup,
                                              mesh=mesh,
                                              join_exchange="auto"))
    auto_small.create_kg()
    small_choice = _auto_choices(auto_small)
    assert small_choice == ["gather"], \
        f"auto chose {small_choice} on the small-parent group-B config"
    rows.append({
        "config": "join_exchange_auto", "engine": engine, "dedup": dedup,
        "devices": n_dev, "large_parent_choice": big_choice[0],
        "group_b_choice": small_choice[0],
        "gather_steady_s": round(steady["gather"], 5),
        "repartition_steady_s": round(steady["repartition"], 5),
        "repartition_speedup": round(steady["gather"]
                                     / max(steady["repartition"], 1e-9), 3)})
    return rows


def run(scale: float = 1.0, engine: str = "sdm", dedup: str = "hash",
        repeats: int = 3) -> List[Dict]:
    n = max(32, int(4000 * scale))
    rows = [
        bench_cold_vs_cached(n, engine, dedup, repeats),
        bench_ingest(n, engine, dedup, batches=max(2, repeats),
                     batch_rows=max(4, n // 16)),
        check_overflow_recompile(max(16, n // 4), engine, dedup),
        check_distributed_closure_reuse(max(16, n // 4), dedup),
        check_fused_mesh_device_resident(max(16, n // 4), engine, dedup,
                                         repeats),
        check_warm_process_cold_start(max(16, n // 4)),
        check_verifier_overhead(max(16, n // 4), engine, dedup, repeats),
    ]
    rows.extend(check_join_exchange_crossover(n, engine, dedup, repeats))
    rows.append({"config": "plan_cache", **plan_cache_stats()})
    return rows


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny cells, correctness gates only (CI)")
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--engine", default="sdm")
    ap.add_argument("--dedup", default="hash")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args(argv)
    rows = run(scale=0.02 if args.smoke else args.scale, engine=args.engine,
               dedup=args.dedup, repeats=1 if args.smoke else args.repeats)
    save_rows("engine", rows)
    print_csv(rows)
    return rows


if __name__ == "__main__":
    main()
