"""Shared benchmark utilities: timing, CSV/JSON artifacts.

Used by every group in this package; artifacts are one JSON list of row
dicts per group under ``experiments/bench/`` (the same rows are printed as
CSV for eyeballing). Latency quantiles come from the repo's single
:func:`repro.serve.stats.percentile` implementation (linear
interpolation), re-exported here so benchmark code never re-derives index
arithmetic.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Optional

from repro.serve.stats import percentile

__all__ = ["OUT_DIR", "percentile", "print_csv", "save_rows", "timeit"]

OUT_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                       "experiments", "bench")


def timeit(fn: Callable, *, repeats: int = 1, inner: int = 1) -> float:
    """Best-of-N wall time in seconds (first call may include compile).

    ``inner`` runs the function that many times per sample and divides —
    the per-call jitter amortization for millisecond-scale calls whose
    single-shot timings are dominated by scheduling noise (the regression
    gate keys on such timings)."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(inner):
            fn()
        best = min(best, (time.perf_counter() - t0) / inner)
    return best


def save_rows(name: str, rows: List[Dict], out_dir: Optional[str] = None
              ) -> str:
    out_dir = out_dir or OUT_DIR
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1, default=str)
    return path


def print_csv(rows: List[Dict]) -> None:
    if not rows:
        return
    keys = list(rows[0])
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))
