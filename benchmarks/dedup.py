"""δ micro-benchmark: lex-sort vs hash-first vs distributed dedup.

Paper mapping: duplicate elimination is the operator behind every headline
number — the Fig. 1 motivating example (2,049,442,714 raw vs 102,549
distinct triples), the Fig. 8 volume×redundancy grid and both engines'
sinks (SDM-RDFizer's duplicate-aware structures vs RMLMapper's sink δ).
This group isolates it: an N×K×redundancy sweep over random code matrices
comparing

* ``lex``  — K-key lexicographic ``lax.sort`` + neighbor compact
             (:func:`repro.relalg.ops.distinct_rows`),
* ``hash`` — rowhash + single-key sort + fused neighbor-flag kernel
             (:func:`repro.relalg.ops.distinct_rows_hashed`),
* ``dist`` — the shard_map repartition dedup over all local devices
             (:func:`repro.core.distributed.distributed_distinct_table`),

recording warm rows/sec per cell (best-of-R jitted calls) and asserting the
three row sets are identical. Artifacts land in
``experiments/bench/dedup.json``.

Run: ``PYTHONPATH=src python -m benchmarks.dedup [--smoke]``
"""
from __future__ import annotations

import argparse
from typing import Dict, List

import jax
import numpy as np

from repro.core.distributed import distributed_distinct_table
from repro.launch.mesh import make_mesh
from repro.relalg import Table, distinct

from .common import print_csv, save_rows, timeit

# redundancy = fraction of rows that are duplicates of an earlier row
GRID_N = (4096, 16384, 65536)
GRID_K = (2, 5, 8)
GRID_RED = (0.0, 0.5, 0.9)
SMOKE_N, SMOKE_K, SMOKE_RED = (512,), (3,), (0.5,)


def make_rows(n: int, k: int, redundancy: float, seed: int = 0) -> np.ndarray:
    """[n, k] int32 codes with ~``redundancy`` fraction of duplicate rows."""
    rng = np.random.default_rng(seed)
    n_distinct = max(1, int(round(n * (1.0 - redundancy))))
    base = rng.integers(0, 1 << 20, size=(n_distinct, k)).astype(np.int32)
    idx = rng.integers(0, n_distinct, size=n)
    idx[:n_distinct] = np.arange(n_distinct)  # every base row appears
    return base[idx]


def _warm_rows_per_sec(fn, n: int, repeats: int = 3) -> float:
    def call():
        out = fn()
        out.data.block_until_ready()
    call()                     # compile
    return n / max(timeit(call, repeats=repeats), 1e-9)


def run(ns=GRID_N, ks=GRID_K, redundancies=GRID_RED, seed: int = 0,
        repeats: int = 3, with_distributed: bool = True) -> List[Dict]:
    rows_out: List[Dict] = []
    n_dev = jax.device_count()
    mesh = make_mesh((n_dev,), ("data",)) if with_distributed else None
    for n in ns:
        for k in ks:
            for red in redundancies:
                codes = make_rows(n, k, red, seed)
                t = Table.from_codes(codes, [f"c{i}" for i in range(k)])
                lex = distinct(t, dedup="lex")
                hsh = distinct(t, dedup="hash")
                assert lex.row_set() == hsh.row_set(), (n, k, red)
                rec = {
                    "n": n, "k": k, "redundancy": red,
                    "distinct": int(lex.count),
                    "lex_rows_per_s": round(_warm_rows_per_sec(
                        jax.jit(lambda tt=t: distinct(tt, dedup="lex")),
                        n, repeats)),
                    "hash_rows_per_s": round(_warm_rows_per_sec(
                        jax.jit(lambda tt=t: distinct(tt, dedup="hash")),
                        n, repeats)),
                }
                if mesh is not None:
                    dist, overflow = distributed_distinct_table(
                        t, mesh, "data", dedup="hash")
                    assert not overflow
                    assert dist.row_set() == lex.row_set(), (n, k, red)
                    # end-to-end incl. shard/gather: the honest number for
                    # a host-resident table
                    rec["dist_rows_per_s"] = round(_warm_rows_per_sec(
                        lambda tt=t: distributed_distinct_table(
                            tt, mesh, "data", dedup="hash")[0], n, repeats))
                    rec["n_devices"] = n_dev
                rec["hash_speedup"] = round(
                    rec["hash_rows_per_s"] / max(rec["lex_rows_per_s"], 1), 2)
                rows_out.append(rec)
    return rows_out


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny cell (CI): N=512, K=3, red=0.5")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--no-distributed", action="store_true",
                    help="skip the shard_map variant")
    args = ap.parse_args(argv)
    if args.smoke:
        rows = run(SMOKE_N, SMOKE_K, SMOKE_RED, repeats=1,
                   with_distributed=not args.no_distributed)
    else:
        rows = run(repeats=args.repeats,
                   with_distributed=not args.no_distributed)
    save_rows("dedup", rows)
    print_csv(rows)
    return rows


if __name__ == "__main__":
    main()
