"""Experiment group B (paper Fig. 9): join-condition triple maps.

Paper mapping: Fig. 9 studies RefObjectMap joins under three duplication
scenarios — (a) no source dedup'd, (b) one, (c) both — comparing MapSDI
(Rule 2: projections pushed into the join child/parent, keeping the Z̄ set
of head + join attributes) against the T-framework, which joins the raw
sources. Reported per scenario: warm semantification time for both
frameworks, MapSDI's one-off pre-processing cost, and the raw-triple count
the T-framework pays; the Q1 assertion (identical KGs) runs on every cell.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

from repro.api import EngineConfig, KGEngine
from repro.configs.mapsdi_paper import CONFIG as PAPER
from repro.core.tframework import make_t_framework_fn
from repro.core.transform import apply_mapsdi
from repro.data.synthetic import make_group_b_dis

from .common import print_csv, save_rows, timeit


def _warm_time(fn, repeats=3) -> float:
    def call():
        kg, raw = fn()
        kg.data.block_until_ready()
    call()
    return timeit(call, repeats=repeats)


SCENARIOS = {(False, False): "a_no_dedup",
             (True, False): "b_one_dedup",
             (True, True): "c_both_dedup"}


def run(scale: float = 1.0, seed: int = 0, engine: str = "sdm",
        scenarios=None) -> List[Dict]:
    rows: List[Dict] = []
    n = max(1, int(PAPER.group_b_rows * scale))
    for (dl, dr) in (scenarios or PAPER.group_b_scenarios):
        dis_m = make_group_b_dis(n, PAPER.group_b_redundancy, seed=seed,
                                 dedup_left=dl, dedup_right=dr)
        dis_t = make_group_b_dis(n, PAPER.group_b_redundancy, seed=seed,
                                 dedup_left=dl, dedup_right=dr)
        t0 = time.perf_counter()
        dis_m2, _ = apply_mapsdi(dis_m)
        pre_s = time.perf_counter() - t0   # the one-off transform
        fn_m = KGEngine(dis_m2, config=EngineConfig(engine=engine)).run
        fn_t = make_t_framework_fn(dis_t, engine)
        warm_m = _warm_time(fn_m)
        warm_t = _warm_time(fn_t)
        kg_m, _ = fn_m()
        kg_t, raw_t = fn_t()
        same = kg_m.row_set() == kg_t.row_set()
        rows.append({
            "scenario": SCENARIOS[(dl, dr)], "engine": engine, "rows": n,
            "mapsdi_warm_s": round(warm_m, 4),
            "tframework_warm_s": round(warm_t, 4),
            "speedup": round(warm_t / max(warm_m, 1e-9), 2),
            "mapsdi_pre_s": round(pre_s, 4),
            "kg_triples": int(kg_m.count),
            "raw_triples_t": int(raw_t),
            "same_kg": same,
        })
        assert same, f"Q1 violated in scenario {SCENARIOS[(dl, dr)]}"
    return rows


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--engine", default="sdm")
    args = ap.parse_args(argv)
    rows = run(scale=args.scale, engine=args.engine)
    save_rows("group_b", rows)
    print_csv(rows)
    return rows


if __name__ == "__main__":
    main()
