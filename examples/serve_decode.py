"""Batched serving: continuous-batching decode on a reduced model.

16 requests through 4 concurrent decode slots; prefill admits requests
into free slots, one jitted serve_step advances every active slot per
tick. Prints throughput and latency percentiles.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
from repro.launch.serve import main

raise SystemExit(main([
    "--arch", "qwen3-1.7b",
    "--requests", "16", "--slots", "4",
    "--prompt-len", "32", "--gen-len", "16",
]))
