"""Elastic restart: checkpoint on one mesh, resume on a DIFFERENT mesh.

Phase 1 trains a reduced model data-parallel on 4 (forced host) devices
and checkpoints. Phase 2 — a separate process standing in for the
rescheduled job — restores the same checkpoint onto a 2-device mesh
(half the "pod" survived) and keeps training. The checkpoint stores only
logical metadata, so restore re-device_puts each leaf with the target
mesh's shardings.

Run:  PYTHONPATH=src python examples/elastic_restart.py
"""
import os
import subprocess
import sys
import tempfile

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PHASE = r"""
import os, sys
import numpy as np
import jax, jax.numpy as jnp
from repro.configs.base import get_config, reduced_config
from repro.data.pipeline import KGTokenPipeline
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.sharding import init_params, param_shardings
from repro.launch.mesh import make_mesh
from repro.models import auto_rules, get_model
from repro.models.layers import ShardCtx
from repro.train.optimizer import make_optimizer
from repro.train.train_step import make_train_step

ckpt, n_dev, start, stop = (sys.argv[1], int(sys.argv[2]), int(sys.argv[3]),
                            int(sys.argv[4]))
cfg = reduced_config(get_config("qwen3-1.7b"))
mesh = make_mesh((n_dev,), ("data",))
rules = auto_rules(cfg, mesh)
model = get_model(cfg.family)
opt = make_optimizer(cfg.optimizer, lr=1e-2)
step_fn = jax.jit(make_train_step(cfg, optimizer=opt,
                                  ctx=ShardCtx(mesh, rules)))
specs = model.param_specs(cfg)
shardings = param_shardings(specs, mesh, rules)
params = jax.device_put(init_params(specs, jax.random.PRNGKey(0)), shardings)
opt_state = opt.init(params)
manager = CheckpointManager(ckpt, keep_n=2, async_write=False)
if manager.latest_step() is not None:
    (params, opt_state), extra = manager.restore((params, opt_state))
    # elastic: re-place parameters with THIS mesh's shardings
    params = jax.device_put(params, shardings)
    print(f"[{n_dev}dev] restored step {extra['step']}", flush=True)

stream = (np.arange(20000) % 250 + 4).astype(np.int32)
pipe = KGTokenPipeline(stream, seq_len=32, global_batch=8)
for s in range(start, stop):
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(s).items()}
    params, opt_state, m = step_fn(params, opt_state, batch,
                                   jnp.asarray(s, jnp.int32))
    print(f"[{n_dev}dev] step {s} loss {float(m['loss']):.4f}", flush=True)
manager.save(stop - 1, (params, opt_state), extra={"step": stop - 1})
manager.close()
"""


def run_phase(ckpt: str, n_dev: int, start: int, stop: int) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", PHASE, ckpt, str(n_dev), str(start),
         str(stop)], env=env, capture_output=True, text=True, timeout=900)
    sys.stdout.write(out.stdout)
    if out.returncode:
        sys.stderr.write(out.stderr)
        raise SystemExit(f"phase on {n_dev} devices failed")


if __name__ == "__main__":
    ckpt = tempfile.mkdtemp(prefix="elastic_ckpt_")
    print("phase 1: 4-device data-parallel mesh")
    run_phase(ckpt, n_dev=4, start=0, stop=6)
    print("phase 2: resume the SAME checkpoint on a 2-device mesh")
    run_phase(ckpt, n_dev=2, start=6, stop=12)
    print("elastic restart OK:", ckpt)
