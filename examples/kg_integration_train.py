"""End-to-end driver: integrate a KG with MapSDI, then train an LM on it.

This is the "application on top of MapSDI" (paper §6): synthetic genomics
sources -> Rules 1-3 -> deduplicated triples -> token stream -> a reduced
qwen3-family model trained for 30 steps with checkpoints and two injected
node failures (the run survives both and resumes from the checkpoint).

Run:  PYTHONPATH=src python examples/kg_integration_train.py
"""
import tempfile

from repro.launch.train import main

raise SystemExit(main([
    "--arch", "qwen3-1.7b", "--reduced",
    "--steps", "30", "--batch", "8", "--seq", "64",
    "--rows", "3000", "--redundancy", "0.8",
    "--ckpt", tempfile.mkdtemp(prefix="mapsdi_ckpt_"),
    "--ckpt-every", "5",
    "--fail-at", "7", "--fail-at", "19",
]))
