"""Quickstart: the paper's Fig. 3/4 example end-to-end in ~40 lines.

Builds the 9-row gene source, the RML triple map that uses 4 of its 8
attributes, runs MapSDI (projection pushes duplicates out **before**
semantification) and the traditional framework, and prints both the
N-Triples output and the work each framework did.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import parse_dis
from repro.core.pipeline import mapsdi_create_kg
from repro.core.rdfizer import triples_to_ntriples
from repro.core.tframework import t_framework_create_kg
from repro.data.synthetic import FIG3_MAP, fig4_gene_source

records, attrs = fig4_gene_source()
dis = parse_dis({"sources": {"genes": {"attrs": attrs, "records": records}},
                 "maps": [FIG3_MAP]})

# --- traditional pipeline: semantify everything, dedup at the end --------
kg_t, stats_t = t_framework_create_kg(
    parse_dis({"sources": {"genes": {"attrs": attrs, "records": records}},
               "maps": [FIG3_MAP]}))
print(f"T-framework : {stats_t['raw_triples']} raw triples generated, "
      f"{stats_t['kg_triples']} after dedup")

# --- MapSDI: project + dedup the SOURCE, then semantify -------------------
kg_m, stats_m = mapsdi_create_kg(dis)
rows_after = sum(stats_m['source_rows_after'].values())
print(f"MapSDI      : {rows_after} source rows after Rule 1 "
      f"(from {sum(stats_m['source_rows_before'].values())}), "
      f"{stats_m['raw_triples']} raw triples, no duplicates generated")

assert kg_m.row_set() == kg_t.row_set(), "Q1: same knowledge graph"

print("\nKnowledge graph (N-Triples):")
for line in sorted(triples_to_ntriples(kg_m, dis)):
    print(" ", line)
