"""Quickstart: the paper's Fig. 3/4 example end-to-end in ~60 lines.

Builds the 9-row gene source, the RML triple map that uses 4 of its 8
attributes, runs MapSDI through the session API (``KGEngine`` plans once —
Rules 1-3 + σ + CSE — and compiles one jitted closure) and the traditional
framework, prints the *logical plan* the optimizer produced (with per-node
plan-time capacities), ingests a source extension through the same session
(cached closure, no re-plan), and shows both the N-Triples output and the
work each framework did.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import EngineConfig, KGEngine, Query, TriplePattern
from repro.core import parse_dis
from repro.core.rdfizer import triples_to_ntriples
from repro.core.tframework import t_framework_create_kg
from repro.core.transform import plan_mapsdi
from repro.data.synthetic import FIG3_MAP, fig4_gene_source
from repro.plan import explain
from repro.relalg import Table

records, attrs = fig4_gene_source()
dis = parse_dis({"sources": {"genes": {"attrs": attrs, "records": records}},
                 "maps": [FIG3_MAP]})

# --- traditional pipeline: semantify everything, dedup at the end --------
kg_t, stats_t = t_framework_create_kg(
    parse_dis({"sources": {"genes": {"attrs": attrs, "records": records}},
               "maps": [FIG3_MAP]}))
print(f"T-framework : {stats_t['raw_triples']} raw triples generated, "
      f"{stats_t['kg_triples']} after dedup")

# --- MapSDI session: plan once (Rules 1-3 + σ + CSE), then ONE closure ----
engine = KGEngine(dis, config=EngineConfig(engine="sdm"))
kg_m, stats_m = engine.create_kg()
rows_after = sum(stats_m['source_rows_after'].values())
print(f"MapSDI      : {rows_after} source rows after Rule 1 "
      f"(from {sum(stats_m['source_rows_before'].values())}), "
      f"{stats_m['raw_triples']} raw triples, no duplicates generated")

assert kg_m.row_set() == kg_t.row_set(), "Q1: same knowledge graph"

# --- incremental ingestion: the session reuses its compiled plan ----------
new_gene = [{"ID": 10, "ENSG": "ENSG00000284733", "ENSGV": ".2",
             "SYMBOL": "OR4F29", "SYMBOLV": "OR4F29-201",
             "ENST": "ENST00000426406", "SPECIES": "HUMAN",
             "ACC": "Q8NH21"}]
kg_i, stats_i = engine.ingest(
    {"genes": Table.from_records(new_gene, attrs, engine.vocab)})
print(f"ingest      : +1 row -> {stats_i['kg_triples']} triples "
      f"(recompiles={stats_i['recompiles']}, "
      f"cache_hit={stats_i['plan_cache_hit']})")

# --- BGP queries run on-device through the same plan machinery ------------
answers = engine.query(Query(
    patterns=[TriplePattern("?s", "?p", "?o")], project=("?p",)))
print(f"query       : {int(answers.count)} distinct predicates "
      f"(SELECT DISTINCT ?p WHERE {{ ?s ?p ?o }})")

# --- inspect the optimized plan (dump_plan/explain) -----------------------
print("\nOptimized logical plan (per-node plan-time rows/capacities):")
plan = plan_mapsdi(dis)
print(explain(plan, engine="sdm"))

print("\nKnowledge graph (N-Triples):")
for line in sorted(triples_to_ntriples(kg_i, dis)):
    print(" ", line)
