"""Quickstart: the paper's Fig. 3/4 example end-to-end in ~50 lines.

Builds the 9-row gene source, the RML triple map that uses 4 of its 8
attributes, runs MapSDI (the planner pushes projection + dedup below
semantification, then compiles everything to one jitted closure) and the
traditional framework, prints the *logical plan* the optimizer produced
(with per-node plan-time capacities), and both the N-Triples output and
the work each framework did.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import parse_dis
from repro.core.pipeline import mapsdi_create_kg
from repro.core.rdfizer import triples_to_ntriples
from repro.core.tframework import t_framework_create_kg
from repro.core.transform import plan_mapsdi
from repro.data.synthetic import FIG3_MAP, fig4_gene_source
from repro.plan import explain

records, attrs = fig4_gene_source()
dis = parse_dis({"sources": {"genes": {"attrs": attrs, "records": records}},
                 "maps": [FIG3_MAP]})

# --- traditional pipeline: semantify everything, dedup at the end --------
kg_t, stats_t = t_framework_create_kg(
    parse_dis({"sources": {"genes": {"attrs": attrs, "records": records}},
               "maps": [FIG3_MAP]}))
print(f"T-framework : {stats_t['raw_triples']} raw triples generated, "
      f"{stats_t['kg_triples']} after dedup")

# --- MapSDI: plan (Rules 1-3 + σ + CSE, symbolic), then ONE closure -------
kg_m, stats_m = mapsdi_create_kg(dis)
rows_after = sum(stats_m['source_rows_after'].values())
print(f"MapSDI      : {rows_after} source rows after Rule 1 "
      f"(from {sum(stats_m['source_rows_before'].values())}), "
      f"{stats_m['raw_triples']} raw triples, no duplicates generated")

assert kg_m.row_set() == kg_t.row_set(), "Q1: same knowledge graph"

# --- inspect the optimized plan (dump_plan/explain) -----------------------
print("\nOptimized logical plan (per-node plan-time rows/capacities):")
plan = plan_mapsdi(dis)
print(explain(plan, engine="sdm"))

print("\nKnowledge graph (N-Triples):")
for line in sorted(triples_to_ntriples(kg_m, dis)):
    print(" ", line)
