"""Version shims for the JAX APIs this repo uses.

The container pins an older jax (0.4.x) with two relevant API gaps:

* ``shard_map`` still lives in ``jax.experimental.shard_map``; newer
  releases expose it as ``jax.shard_map``;
* the Pallas-TPU compiler-params dataclass is ``TPUCompilerParams``; newer
  releases renamed it ``CompilerParams``.

Import :data:`shard_map` / :data:`TPUCompilerParams` from here instead.
"""
from __future__ import annotations

import jax
from jax.experimental.pallas import tpu as pltpu

_NEW_SHARD_MAP = hasattr(jax, "shard_map")
if not _NEW_SHARD_MAP:  # jax < 0.6
    from jax.experimental.shard_map import shard_map as _old_shard_map


def shard_map(f, mesh, in_specs, out_specs, axis_names=None, check_vma=None):
    """``jax.shard_map`` with the new keyword surface on any jax.

    ``axis_names`` (manual axes; default: all mesh axes) and ``check_vma``
    are translated for the pre-0.6 ``jax.experimental.shard_map`` signature
    (``auto`` = complement of the manual axes, ``check_rep``).

    When ``REPRO_PALLAS_INTERPRET`` forces interpret-mode Pallas kernels
    into the distributed bodies (the CI interpret leg), the replication
    check defaults to off: ``pallas_call`` has no replication rule, and
    every collective body here produces explicitly sharded outputs anyway.
    """
    if check_vma is None:
        from repro.kernels import pallas_interpret_forced
        if pallas_interpret_forced():
            check_vma = False
    if _NEW_SHARD_MAP:
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        if check_vma is not None:
            kw["check_vma"] = check_vma
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _old_shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, auto=auto,
                          check_rep=True if check_vma is None else check_vma)


def axis_size(name) -> int:
    """``lax.axis_size`` on any jax (pre-0.5: the psum-of-ones identity)."""
    from jax import lax
    if hasattr(lax, "axis_size"):
        return lax.axis_size(name)
    return lax.psum(1, name)


TPUCompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

__all__ = ["TPUCompilerParams", "axis_size", "shard_map"]
