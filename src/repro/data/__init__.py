"""Data substrate: synthetic genomics-like sources + the KG->token pipeline."""
from .synthetic import (fig4_gene_source, fig5_join_dis, make_group_a_dis,
                        make_group_b_dis, make_motivating_dis)

__all__ = ["fig4_gene_source", "fig5_join_dis", "make_group_a_dis",
           "make_group_b_dis", "make_motivating_dis"]
