"""Synthetic genomics-like testbeds with volume/redundancy dials.

Reproduces the *shape* of the paper's datasets (COSMIC mutations, CRG
protein-RNA interactions, GENCODE annotations): wide sources where a handful
of attributes carry a small number of distinct entities replicated across
many rows (transcripts per gene, samples per mutation, ...).

Dials match the experimental design of §4: ``volume`` scales row count
(25/50/75/100%), ``redundancy`` sets the fraction of duplicated rows
w.r.t. the projected attributes (25/50/75%).
"""
from __future__ import annotations

import zlib
from typing import Dict, List, Tuple

import numpy as np

from repro.core import DIS, parse_dis


def _stable_hash(s: str) -> int:
    """Process-independent hash (builtin ``hash`` is salted per process,
    which made generated KGs — and committed benchmark artifacts —
    irreproducible across runs)."""
    return zlib.crc32(s.encode())


# ---------------------------------------------------------------------------
# paper figures (exact reconstructions, used in unit tests)
# ---------------------------------------------------------------------------

def fig4_gene_source() -> Tuple[List[Dict], List[str]]:
    """The 9-row gene file of Fig. 4a (8 attrs, 4 used by the map)."""
    rows = [
        # ENSG, ENSGV, SYMBOL, SYMBOLV, ENST, SPECIES, ACC
        ("ENSG00000187583", ".10", "PLEKHN1", "PLEKHN1-203", "ENST00000379410", "HUMAN", "Q494U1"),
        ("ENSG00000187583", ".10", "PLEKHN1", "PLEKHN1-202", "ENST00000379409", "HUMAN", "Q494U1"),
        ("ENSG00000187583", ".10", "PLEKHN1", "PLEKHN1-201", "ENST00000379407", "HUMAN", "Q494U1"),
        ("ENSG00000187642", ".9", "PERM1", "PERM1-202", "ENST00000341290", "HUMAN", "Q5SV97"),
        ("ENSG00000187642", ".9", "PERM1", "PERM1-203", "ENST00000433179", "HUMAN", "Q5SV97"),
        ("ENSG00000131591", ".17", "C1orf159", "C1orf159-204", "ENST00000379339", "HUMAN", "Q96HA4"),
        ("ENSG00000131591", ".17", "C1orf159", "C1orf159-203", "ENST00000379339", "HUMAN", "Q96HA4"),
        ("ENSG00000131591", ".17", "C1orf159", "C1orf159-205", "ENST00000379325", "HUMAN", "Q96HA4"),
        ("ENSG00000131591", ".17", "C1orf159", "C1orf159-201", "ENST00000421241", "HUMAN", "Q96HA4"),
    ]
    attrs = ["ID", "ENSG", "ENSGV", "SYMBOL", "SYMBOLV", "ENST", "SPECIES", "ACC"]
    records = [
        {"ID": i + 1, "ENSG": g, "ENSGV": g + v, "SYMBOL": s, "SYMBOLV": sv,
         "ENST": t, "SPECIES": sp, "ACC": a}
        for i, (g, v, s, sv, t, sp, a) in enumerate(rows)]
    return records, attrs


FIG3_MAP = {
    "name": "GeneMap", "source": "genes",
    "subject": {"template": "http://project-iasis.eu/Gene/{ENSG}",
                "class": "iasis:Gene"},
    "poms": [
        {"predicate": "iasis:geneName", "object": {"reference": "SYMBOL"}},
        {"predicate": "iasis:specieType", "object": {"reference": "SPECIES"}},
        {"predicate": "iasis:uniprotID", "object": {"reference": "ACC"}},
    ],
}


def fig5_join_dis() -> DIS:
    """Fig. 5/6: two triple maps joined on Genename; 22 duplicate matches."""
    outer = [  # Genename, Biotype (+ unused attrs elided to HGNC only)
        ("STAT5B", 11367), ("STAT5B", 11367), ("STAT5B", 11367),
        ("STAT5B", 11367), ("STAT5B", 11367),
        ("KRAS", 6407), ("KRAS", 6407), ("KRAS", 6407),
        ("GAS7", 4169),
    ]
    inner = [  # Genename, Chromosome, Sample
        ("STAT5B", "chr17", "16857"), ("STAT5B", "chr17", "S52482"),
        ("STAT5B", "chr17", "1148969"),
        ("KRAS", "chr12", "CH-LA2"), ("KRAS", "chr12", "1559296"),
        ("EGFR", "chr7", "1479947"), ("EGFR", "chr7", "1544875"),
        ("GAS7", "chr17", "112146"),
    ]
    return parse_dis({
        "sources": {
            "gene": {"attrs": ["ID", "Genename", "HGNC", "Biotype"],
                     "records": [
                         {"ID": i + 1, "Genename": g, "HGNC": h,
                          "Biotype": "protein_coding"}
                         for i, (g, h) in enumerate(outer)]},
            "chrom": {"attrs": ["ID", "Genename", "Chromosome", "Sample"],
                      "records": [
                          {"ID": i + 1, "Genename": g, "Chromosome": c,
                           "Sample": s}
                          for i, (g, c, s) in enumerate(inner)]},
        },
        "maps": [
            {"name": "TripleMap1", "source": "gene",
             "subject": {"template": "http://project-iasis.eu/BioType/{Biotype}"},
             "poms": [{"predicate": "iasis:isRelatedTo",
                       "object": {"parentTriplesMap": "TripleMap2",
                                  "joinCondition": {"child": "Genename",
                                                    "parent": "Genename"}}}]},
            {"name": "TripleMap2", "source": "chrom",
             "subject": {"template": "http://project-iasis.eu/Chromosome/{Chromosome}",
                         "class": "iasis:Chromosome"},
             "poms": []},
        ],
    })


# ---------------------------------------------------------------------------
# scalable generators (experiment groups A and B)
# ---------------------------------------------------------------------------

def _entity_pool(rng: np.random.Generator, n: int, prefix: str) -> np.ndarray:
    return np.array([f"{prefix}{i:08d}" for i in range(n)])


def make_group_a_dis(n_rows: int, redundancy: float, seed: int = 0,
                     n_noise_attrs: int = 8) -> DIS:
    """Three sources, each with the *same* concept (a transcript id) under a
    different attribute name plus noise attributes; one triple map per
    source with an identical head — the group-A setup (one concept, one
    attribute per source, Rule 3 applies).

    ``redundancy`` r => only (1-r)·n distinct transcript values per source.
    """
    rng = np.random.default_rng(seed)
    n_distinct = max(1, int(round(n_rows * (1.0 - redundancy))))
    pool = _entity_pool(rng, n_distinct, "ENST")
    names = ["enst", "downstream_gene", "transcript_id"]
    sources = {}
    for si, attr in enumerate(names):
        vals = pool[rng.integers(0, n_distinct, size=n_rows)]
        recs = []
        for i in range(n_rows):
            rec = {"ID": int(i), attr: str(vals[i])}
            for k in range(n_noise_attrs):
                rec[f"noise{k}"] = int(rng.integers(0, 50))
            recs.append(rec)
        sources[f"src{si}"] = {
            "attrs": ["ID", attr] + [f"noise{k}" for k in range(n_noise_attrs)],
            "records": recs}
    maps = [
        {"name": f"TM{si}", "source": f"src{si}",
         "subject": {"template": "http://project-iasis.eu/Transcript/{%s}" % attr,
                     "class": "iasis:Transcript"},
         "poms": []}
        for si, attr in enumerate(names)]
    return parse_dis({"sources": sources, "maps": maps})


def make_group_b_dis(n_rows: int, redundancy: float = 0.75, seed: int = 0,
                     dedup_left: bool = False, dedup_right: bool = False
                     ) -> DIS:
    """Two sources joined by two triple maps (the group-B setup). The
    ``dedup_*`` flags pre-clean a source (the paper's scenarios a/b/c)."""
    rng = np.random.default_rng(seed)
    n_genes = max(1, int(round(n_rows * (1.0 - redundancy))))
    genes = _entity_pool(rng, n_genes, "GENE")
    bios = np.array(["protein_coding", "lncRNA", "miRNA", "snoRNA"])
    chroms = np.array([f"chr{i}" for i in range(1, 23)])

    gene_of_row = genes[rng.integers(0, n_genes, size=n_rows)]
    left = [{"ID": int(i), "Genename": str(g),
             "HGNC": int(rng.integers(1, 20000)),
             "enst": f"ENST{rng.integers(0, 10**8):08d}",
             "Biotype": str(bios[_stable_hash(g) % len(bios)])}
            for i, g in enumerate(gene_of_row)]
    gene_of_row_r = genes[rng.integers(0, n_genes, size=n_rows)]
    right = [{"ID": int(i), "Genename": str(g),
              "Chromosome": str(chroms[_stable_hash(g) % len(chroms)]),
              "Sample": f"S{rng.integers(0, 10**6):06d}"}
             for i, g in enumerate(gene_of_row_r)]

    def _dedup(recs, keys):
        seen, out = set(), []
        for r in recs:
            k = tuple(r[x] for x in keys)
            if k not in seen:
                seen.add(k)
                out.append(r)
        return out

    if dedup_left:
        left = _dedup(left, ["Genename", "Biotype"])
    if dedup_right:
        right = _dedup(right, ["Genename", "Chromosome"])

    return parse_dis({
        "sources": {
            "gene": {"attrs": ["ID", "Genename", "HGNC", "enst", "Biotype"],
                     "records": left},
            "chrom": {"attrs": ["ID", "Genename", "Chromosome", "Sample"],
                      "records": right},
        },
        "maps": [
            {"name": "TripleMap1", "source": "gene",
             "subject": {"template": "http://project-iasis.eu/BioType/{Biotype}",
                         "class": "iasis:BioType"},
             "poms": [{"predicate": "iasis:isRelatedTo",
                       "object": {"parentTriplesMap": "TripleMap2",
                                  "joinCondition": {"child": "Genename",
                                                    "parent": "Genename"}}}]},
            {"name": "TripleMap2", "source": "chrom",
             "subject": {"template": "http://project-iasis.eu/Chromosome/{Chromosome}",
                         "class": "iasis:Chromosome"},
             "poms": []},
        ],
    })


def make_group_b_extension_records(n_rows: int, seed: int = 0,
                                   sources: Tuple[str, ...] = ("gene",
                                                               "chrom")
                                   ) -> Dict[str, List[Dict]]:
    """Extension rows shaped like :func:`make_group_b_dis`'s sources — new
    samples over shared gene-entity pools so join conditions keep matching.
    The micro-batch generator behind ``benchmarks/engine.py`` and the
    ``kg_serve`` streaming driver (encode with the session's vocab via
    ``Table.from_records(recs, attrs, engine.vocab)``)."""
    rng = np.random.default_rng(seed)
    bios = ["protein_coding", "lncRNA", "miRNA", "snoRNA"]
    chroms = [f"chr{i}" for i in range(1, 23)]
    pool = _entity_pool(rng, max(1, n_rows // 2), "GENE")
    out: Dict[str, List[Dict]] = {}
    if "gene" in sources:
        genes = pool[rng.integers(0, len(pool), size=n_rows)]
        out["gene"] = [
            {"ID": int(i), "Genename": str(g),
             "HGNC": int(rng.integers(1, 20000)),
             "enst": f"ENST{rng.integers(0, 10**8):08d}",
             "Biotype": bios[_stable_hash(str(g)) % len(bios)]}
            for i, g in enumerate(genes)]
    if "chrom" in sources:
        genes_r = pool[rng.integers(0, len(pool), size=n_rows)]
        out["chrom"] = [
            {"ID": int(i), "Genename": str(g),
             "Chromosome": chroms[_stable_hash(str(g)) % len(chroms)],
             "Sample": f"S{rng.integers(0, 10**6):06d}"}
            for i, g in enumerate(genes_r)]
    return out


def make_motivating_dis(n_rows: int = 2000, overlap: float = 0.9,
                        seed: int = 0) -> DIS:
    """Fig. 1: three sources (mutations / downstream genes / drug
    resistances) that overlap heavily in the transcript they mention; blind
    semantification explodes into duplicates."""
    rng = np.random.default_rng(seed)
    n_shared = max(1, int(round(n_rows * 0.02)))
    pool = _entity_pool(rng, n_shared, "ENST")
    sources, maps = {}, []
    for si, attr in enumerate(["enst", "downstream_gene", "transcript_id"]):
        vals = pool[rng.integers(0, n_shared, size=n_rows)]
        recs = [{"ID": int(i), attr: str(vals[i]),
                 "extra": int(rng.integers(0, 10))} for i in range(n_rows)]
        sources[f"s{si}"] = {"attrs": ["ID", attr, "extra"], "records": recs}
        maps.append({
            "name": f"TM{si}", "source": f"s{si}",
            "subject": {"template": "http://project-iasis.eu/Transcript/{%s}" % attr,
                        "class": "iasis:Transcript"},
            "poms": []})
    return parse_dis({"sources": sources, "maps": maps})
