"""KG -> token batches: the MapSDI output feeding the LM application layer.

The paper's §6 names "development of applications on top of MapSDI" as the
goal; here the application is LM training over the integrated knowledge
graph. A deduplicated KG (a 5-column int32 triple ``Table``:
``(s_tmpl, s_val, pred, o_tmpl, o_val)``) is linearized into a token
stream: each triple becomes ``[BOT, s..., SEP, p..., SEP, o..., EOT]``
where every int32 code is factored into base-``radix`` digit tokens
(vocab-independent, reversible). The stream wraps cyclically so any
(seq_len, batch) grid is always fillable.

Determinism + elasticity: a batch is a pure function of
``(stream, step, shard_id, n_shards, weights)``. The cursor state is an
integer, checkpointed with the train state; after an elastic restart with
a different shard count, every shard recomputes its offsets from the same
formula — no rewinding, no duplicate/missing examples.

Straggler mitigation: :meth:`rebalance` takes per-shard weights from the
:class:`~repro.distributed.fault.StragglerMonitor` and re-apportions the
per-step token budget (slow hosts get fewer rows; totals preserved).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Sequence, Tuple

import numpy as np

from repro.relalg import Table

# special tokens (reserved low ids)
PAD, BOT, EOT, SEP = 0, 1, 2, 3
N_SPECIAL = 4


# ---------------------------------------------------------------------------
# triple linearization
# ---------------------------------------------------------------------------

def _digits(codes: np.ndarray, radix: int, width: int) -> np.ndarray:
    """[N] int -> [N, width] base-radix digit tokens (offset by specials)."""
    out = np.empty(codes.shape + (width,), dtype=np.int32)
    c = codes.astype(np.int64)
    for i in range(width - 1, -1, -1):
        out[..., i] = c % radix
        c = c // radix
    return out + N_SPECIAL


def linearize_kg(kg: Table, vocab_size: int, seed: int = 0) -> np.ndarray:
    """KG triples -> 1-D int32 token stream (shuffled, deterministic)."""
    codes = kg.to_codes()                       # [n, 5] valid rows only
    if codes.shape[0] == 0:
        return np.array([BOT, EOT], dtype=np.int32)
    radix = max(2, vocab_size - N_SPECIAL)
    maxc = max(int(codes.max()), 1)
    width = 1
    while radix ** width <= maxc:
        width += 1
    rng = np.random.default_rng(seed)
    codes = codes[rng.permutation(codes.shape[0])]
    n = codes.shape[0]
    s = _digits(codes[:, 1], radix, width)      # subject value
    p = _digits(codes[:, 2], radix, width)      # predicate
    o = _digits(codes[:, 4], radix, width)      # object value
    sep = np.full((n, 1), SEP, np.int32)
    bot = np.full((n, 1), BOT, np.int32)
    eot = np.full((n, 1), EOT, np.int32)
    rows = np.concatenate([bot, s, sep, p, sep, o, eot], axis=1)
    return rows.reshape(-1).astype(np.int32)


# ---------------------------------------------------------------------------
# deterministic, elastic, weighted batcher
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class KGTokenPipeline:
    """Deterministic cyclic batcher over a token stream.

    ``batch(step)`` -> {tokens, labels, loss_mask} of shape
    [global_batch, seq_len]; ``shard_batch(step, shard, n_shards)`` returns
    that shard's rows only (what one host materializes)."""

    stream: np.ndarray
    seq_len: int
    global_batch: int
    weights: Optional[np.ndarray] = None     # per-shard row weights

    def __post_init__(self):
        if self.stream.ndim != 1:
            raise ValueError("stream must be 1-D")
        if len(self.stream) < self.seq_len + 1:
            reps = (self.seq_len + 1) // max(len(self.stream), 1) + 1
            self.stream = np.tile(self.stream, reps)

    # -- row addressing ------------------------------------------------------
    def _row_offset(self, step: int, row: int) -> int:
        """Start position of (step, row) in the cyclic stream: rows advance
        by seq_len tokens; steps advance by global_batch rows."""
        idx = (step * self.global_batch + row) * self.seq_len
        return idx % (len(self.stream) - self.seq_len)

    def _take(self, off: int) -> np.ndarray:
        return self.stream[off:off + self.seq_len + 1]

    # -- public API -----------------------------------------------------------
    def rows_for_shard(self, shard: int, n_shards: int) -> Tuple[int, int]:
        """[start, stop) row range owned by ``shard``, after weighting."""
        if self.global_batch % n_shards:
            raise ValueError(f"global_batch {self.global_batch} "
                             f"not divisible by {n_shards} shards")
        if self.weights is None:
            per = self.global_batch // n_shards
            return shard * per, (shard + 1) * per
        w = np.asarray(self.weights, dtype=np.float64)
        if w.shape != (n_shards,):
            raise ValueError("weights shape mismatch")
        raw = w / w.sum() * self.global_batch
        counts = np.floor(raw).astype(int)
        # distribute the remainder to the largest fractional parts
        rem = self.global_batch - counts.sum()
        order = np.argsort(-(raw - counts))
        counts[order[:rem]] += 1
        starts = np.concatenate([[0], np.cumsum(counts)])
        return int(starts[shard]), int(starts[shard + 1])

    def rebalance(self, weights: Sequence[float]) -> None:
        self.weights = np.asarray(weights, dtype=np.float64)

    def shard_batch(self, step: int, shard: int, n_shards: int
                    ) -> Dict[str, np.ndarray]:
        lo, hi = self.rows_for_shard(shard, n_shards)
        rows = [self._take(self._row_offset(step, r)) for r in range(lo, hi)]
        grid = np.stack(rows) if rows else \
            np.zeros((0, self.seq_len + 1), np.int32)
        return self._to_batch(grid)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rows = [self._take(self._row_offset(step, r))
                for r in range(self.global_batch)]
        return self._to_batch(np.stack(rows))

    def _to_batch(self, grid: np.ndarray) -> Dict[str, np.ndarray]:
        tokens = grid[:, :-1].astype(np.int32)
        labels = grid[:, 1:].astype(np.int32)
        mask = (labels != PAD).astype(np.float32)
        return {"tokens": tokens, "labels": labels, "loss_mask": mask}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


# ---------------------------------------------------------------------------
# synthetic LM batches (smoke tests / dry-run stand-ins that need values)
# ---------------------------------------------------------------------------

def random_lm_batch(rng: np.random.Generator, cfg, batch: int, seq: int,
                    vit_dim: int = 1024) -> Dict[str, np.ndarray]:
    """Value-bearing batch for a reduced config (family aware)."""
    out: Dict[str, np.ndarray] = {}
    if cfg.family == "vlm":
        text = seq - cfg.n_prepend
        out["tokens"] = rng.integers(
            0, cfg.vocab_size, (batch, text)).astype(np.int32)
        out["labels"] = rng.integers(
            0, cfg.vocab_size, (batch, text)).astype(np.int32)
        out["patches"] = rng.normal(
            0, 1, (batch, cfg.n_prepend, vit_dim)).astype(np.float32)
    elif cfg.family == "encdec":
        out["tokens"] = rng.integers(
            0, cfg.vocab_size, (batch, seq)).astype(np.int32)
        out["labels"] = rng.integers(
            0, cfg.vocab_size, (batch, seq)).astype(np.int32)
        out["frames"] = rng.normal(
            0, 1, (batch, cfg.n_enc_frames, cfg.d_model)).astype(np.float32)
    else:
        out["tokens"] = rng.integers(
            0, cfg.vocab_size, (batch, seq)).astype(np.int32)
        out["labels"] = rng.integers(
            0, cfg.vocab_size, (batch, seq)).astype(np.int32)
    return out
