from .decode import make_prefill, make_serve_step, greedy_generate

__all__ = ["make_prefill", "make_serve_step", "greedy_generate"]
