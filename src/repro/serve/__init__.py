"""Serving tier: token-decode loops AND the multi-tenant ingest front
door (``docs/serve.md``).

The decode helpers (:func:`make_prefill` & co.) predate the front door
and keep their import path. The streaming-service surface is
:class:`FrontDoor` plus its typed request/response vocabulary; everything
here re-exports from :mod:`repro.api` as well for the one-stop stable
surface.
"""
from .admission import (AdmissionController, IngestResult, Overloaded,
                        Ticket)
from .batcher import MicroBatcher, PendingRequest
from .decode import greedy_generate, make_prefill, make_serve_step
from .frontdoor import FrontDoor
from .registry import SessionRegistry, TenantSession
from .stats import LatencyWindow, percentile

__all__ = [
    "AdmissionController", "FrontDoor", "IngestResult", "LatencyWindow",
    "MicroBatcher", "Overloaded", "PendingRequest", "SessionRegistry",
    "TenantSession", "Ticket", "greedy_generate", "make_prefill",
    "make_serve_step", "percentile",
]
