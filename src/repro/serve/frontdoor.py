"""The multi-tenant streaming front door.

One :class:`FrontDoor` multiplexes many tenant :class:`~repro.api.KGEngine`
sessions onto the process's single device mesh:

* **registration** — each tenant brings its own DIS; structurally
  identical DISes share compiled closures through the process-wide plan
  cache (K compiles for T tenants — :mod:`repro.serve.registry`);
* **submission** — ``submit(tenant_id, records)`` is the only hot-path
  entry. It runs admission control and either enqueues the raw records
  behind a :class:`~repro.serve.admission.Ticket` or sheds them with a
  typed :class:`~repro.serve.admission.Overloaded`. It never encodes,
  never touches a vocab, never blocks on the device;
* **flushing** — a single worker thread owns ALL engine work (KGEngine
  sessions are not thread-safe). It coalesces each tenant's pending
  requests into one ``engine.ingest`` per flush window
  (:mod:`repro.serve.batcher`), encodes records with the tenant's vocab
  at that point, and resolves tickets with per-request
  :class:`~repro.serve.admission.IngestResult`\\ s;
* **backpressure** — the worker reports engine recompiles to the
  admission controller, which tightens the queue watermark for a stall
  window (:mod:`repro.serve.admission`). Nothing is ever dropped
  silently: every submit gets a Ticket or an Overloaded, and ``stop``
  either drains the queue or *fails* the remaining tickets loudly.

Synchronous mode: tests and benchmarks may skip ``start()`` and call
``pump(force=True)`` from their own thread — same code path, no timer
jitter. Mixing both is rejected (``pump`` raises while a worker runs).
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Mapping, Optional, Sequence, Union

from repro.api.cache import PLAN_CACHE
from repro.api.config import EngineConfig
from repro.core.schema import DIS
from repro.relalg.table import Table

from .admission import AdmissionController, IngestResult, Overloaded, Ticket
from .batcher import MicroBatcher, PendingRequest
from .registry import SessionRegistry, TenantSession
from .stats import LatencyWindow

Records = Mapping[str, Sequence[Mapping[str, object]]]


class FrontDoor:
    """Multi-tenant streaming ingest service over one device mesh."""

    def __init__(self, config: Optional[EngineConfig] = None, *,
                 flush_window: float = 0.01,
                 max_batch_rows: int = 4096,
                 max_queue: int = 256,
                 storm_queue: Optional[int] = None,
                 stall_window_s: float = 0.25,
                 latency_window: int = 4096,
                 clock=time.monotonic):
        self.registry = SessionRegistry(default_config=config,
                                        latency_window=latency_window)
        self.batcher = MicroBatcher(flush_window=flush_window,
                                    max_batch_rows=max_batch_rows,
                                    clock=clock)
        self.admission = AdmissionController(max_queue=max_queue,
                                             storm_queue=storm_queue,
                                             stall_window_s=stall_window_s,
                                             clock=clock)
        self.latencies = LatencyWindow(latency_window)
        self._clock = clock
        self._lock = threading.Lock()          # counters only
        self.accepted = 0
        self.rejected = 0
        self.completed = 0
        self.errors = 0
        self.flushes = 0
        self._flush_id = 0
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._wake = threading.Event()

    # -- tenant lifecycle ----------------------------------------------------
    def register(self, tenant_id: str, dis: DIS,
                 config: Optional[EngineConfig] = None) -> TenantSession:
        return self.registry.register(tenant_id, dis, config=config)

    def kg(self, tenant_id: str) -> Optional[Table]:
        """The tenant's KG Table from its latest flush (``None`` before
        the first one)."""
        return self.registry.get(tenant_id).last_kg

    # -- door (any thread) ---------------------------------------------------
    def submit(self, tenant_id: str,
               records: Records) -> Union[Ticket, Overloaded]:
        """Admit-or-shed, then enqueue. Raw records only — encoding into
        the tenant vocab happens on the worker thread at flush time."""
        session = self.registry.get(tenant_id)   # KeyError if unknown
        depth = self.batcher.depth()
        shed = self.admission.admit(tenant_id, depth)
        if shed is not None:
            session.rejected += 1
            with self._lock:
                self.rejected += 1
            return shed
        ticket = Ticket(tenant_id, self._clock())
        self.batcher.add(tenant_id, records, ticket)
        session.requests += 1
        with self._lock:
            self.accepted += 1
        self._wake.set()
        return ticket

    # -- worker --------------------------------------------------------------
    def pump(self, force: bool = False) -> int:
        """Flush every due tenant once; returns the number of flushes.
        This is the worker loop body — callable directly only while no
        worker thread runs (synchronous mode)."""
        if (self._thread is not None and self._thread.is_alive()
                and threading.current_thread() is not self._thread):
            raise RuntimeError("pump() while the worker thread is running "
                               "— engines are single-threaded; use the "
                               "worker or synchronous mode, not both")
        return self._pump(force=force)

    def _pump(self, force: bool = False) -> int:
        n = 0
        for tenant_id in self.batcher.due(force=force):
            n += self._flush(tenant_id)
        return n

    def _flush(self, tenant_id: str) -> int:
        session = self.registry.get(tenant_id)
        taken, merged = self.batcher.pop_batch(tenant_id)
        if not taken:
            return 0
        engine = session.engine
        try:
            deltas = {
                name: Table.from_records(recs, engine.sources[name].attrs,
                                         engine.vocab)
                for name, recs in merged.items() if recs}
            recompiles_before = engine.recompiles
            t0 = self._clock()
            if deltas:
                kg, stats = engine.ingest(deltas)
                session.last_kg = kg
                session.kg_triples = int(stats["kg_triples"])
            ingest_s = self._clock() - t0
            stalls = engine.recompiles - recompiles_before
            if stalls:
                self.admission.note_recompile(stalls)
        except Exception as err:
            self._fail(session, taken, err)
            return 1
        now = self._clock()
        with self._lock:
            self._flush_id += 1
            flush_id = self._flush_id
            self.flushes += 1
            self.completed += len(taken)
        session.ingests += 1
        session.rows += sum(r.rows for r in taken)
        for req in taken:
            latency = now - req.enqueued_at
            session.latencies.record(latency)
            self.latencies.record(latency)
            req.ticket.resolve(IngestResult(
                tenant_id=tenant_id,
                kg_triples=session.kg_triples,
                latency_s=latency,
                ingest_s=ingest_s,
                batched_requests=len(taken),
                recompiles=engine.recompiles,
                flush_id=flush_id))
        return 1

    def _fail(self, session: TenantSession,
              taken: List[PendingRequest], err: BaseException) -> None:
        session.errors += 1
        with self._lock:
            self.errors += len(taken)
        for req in taken:
            req.ticket.fail(err)

    def _worker(self) -> None:
        while not self._stop.is_set():
            self._pump()
            deadline = self.batcher.next_deadline()
            # park until new work arrives or the oldest request is due
            self._wake.wait(timeout=deadline
                            if deadline is not None else 0.05)
            self._wake.clear()
        self._pump(force=True)   # drain everything still queued

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "FrontDoor":
        if self._thread is not None and self._thread.is_alive():
            raise RuntimeError("front door already started")
        self._stop.clear()
        self._thread = threading.Thread(target=self._worker,
                                        name="frontdoor-worker", daemon=True)
        self._thread.start()
        return self

    def stop(self, drain: bool = True, timeout: float = 30.0) -> None:
        """Stop the worker. With ``drain`` the queue is flushed first;
        without it the remaining tickets are *failed* with a
        ``RuntimeError`` — never left dangling, never dropped silently."""
        thread = self._thread
        if thread is not None and thread.is_alive():
            if not drain:
                # pull the queue out from under the worker, then fail it
                pending = self.batcher.drain_tickets()
                err = RuntimeError("front door stopped before flush")
                for req in pending:
                    req.ticket.fail(err)
                with self._lock:
                    self.errors += len(pending)
            self._stop.set()
            self._wake.set()
            thread.join(timeout=timeout)
            if thread.is_alive():
                raise RuntimeError("front door worker did not stop in "
                                   f"{timeout}s")
        elif drain:
            self._pump(force=True)
        else:
            pending = self.batcher.drain_tickets()
            err = RuntimeError("front door stopped before flush")
            for req in pending:
                req.ticket.fail(err)
            with self._lock:
                self.errors += len(pending)
        self._thread = None

    def drain(self, timeout: float = 30.0) -> None:
        """Block until the queue is empty (worker mode) or flush it in
        place (synchronous mode)."""
        if self._thread is not None and self._thread.is_alive():
            deadline = self._clock() + timeout
            while self.batcher.depth():
                if self._clock() > deadline:
                    raise TimeoutError(f"queue not drained in {timeout}s")
                self._wake.set()
                time.sleep(0.001)
        else:
            self._pump(force=True)

    # -- observability -------------------------------------------------------
    def serve_stats(self) -> Dict[str, object]:
        """One self-describing snapshot: global counters, compile-dedup
        ratio, admission/backpressure state, latency quantiles, plan
        cache/store tiers, and a per-tenant breakdown."""
        sessions = self.registry.sessions()
        dedup = self.registry.compile_dedup()
        store_hits = store_misses = 0
        plan_store = None
        for s in sessions:
            est = s.engine.stats()
            store_hits += int(est["store_hits"])
            store_misses += int(est["store_misses"])
            if plan_store is None and est["plan_store"] is not None:
                plan_store = est["plan_store"]
        with self._lock:
            counters = {"accepted": self.accepted,
                        "rejected": self.rejected,
                        "completed": self.completed,
                        "errors": self.errors,
                        "flushes": self.flushes}
        return {
            "tenants": dedup["tenants"],
            "shapes": dedup["shapes"],
            "compiles": dedup["compiles"],
            "compile_dedup_ratio": dedup["ratio"],
            "queue_depth": self.batcher.depth(),
            **counters,
            "recompile_stalls": self.admission.recompile_stalls,
            "admission": self.admission.stats(),
            "latency": self.latencies.snapshot(),
            "plan_cache": PLAN_CACHE.stats(),
            "plan_store_hits": store_hits,
            "plan_store_misses": store_misses,
            "plan_store": plan_store,
            "per_tenant": {
                s.tenant_id: {
                    "shape_id": s.shape_id,
                    "requests": s.requests,
                    "rejected": s.rejected,
                    "ingests": s.ingests,
                    "rows": s.rows,
                    "errors": s.errors,
                    "kg_triples": s.kg_triples,
                    "queue_depth": self.batcher.depth(s.tenant_id),
                    "recompiles": s.engine.recompiles,
                    "latency": s.latencies.snapshot(),
                } for s in sessions},
        }
