"""Serving-side observability primitives: percentiles + latency windows.

:func:`percentile` is the ONE latency-quantile implementation in the repo
— the linear-interpolation estimator (numpy's default ``"linear"``
method), shared by :func:`ServeStats`, ``repro.launch.kg_serve`` and the
``benchmarks`` package (re-exported from ``benchmarks/common.py``). The
historical ad-hoc index arithmetic (``int(len(lat) * 0.99)``) returned
the MAX for any sample count ≤ 100 and a biased median for even N; the
shared helper interpolates instead, and is regression-tested against
``numpy.percentile`` in ``tests/test_serve.py``.

:class:`LatencyWindow` is a bounded ring of recent latency samples with
cheap quantile snapshots — one per tenant plus one global window inside
the front door (``docs/serve.md``).
"""
from __future__ import annotations

import math
from collections import deque
from typing import Deque, Dict, Iterable, Sequence


def percentile(values: Sequence[float], q: float) -> float:
    """The ``q``-th percentile (``0 ≤ q ≤ 100``) of ``values`` by linear
    interpolation between closest ranks — numpy's default method, so
    ``percentile(v, q) == numpy.percentile(v, q)`` up to float rounding.

    ``values`` need not be pre-sorted (a sorted copy is taken; callers
    holding an already-sorted list pay one ``O(n)`` verification-free
    ``sorted`` pass). Raises ``ValueError`` on an empty sample or an
    out-of-range ``q`` — serving stats must never silently fabricate a
    latency.
    """
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q must be in [0, 100], got {q!r}")
    vals = sorted(float(v) for v in values)
    if not vals:
        raise ValueError("percentile of an empty sample")
    rank = (len(vals) - 1) * (q / 100.0)
    lo = math.floor(rank)
    hi = min(lo + 1, len(vals) - 1)
    frac = rank - lo
    return vals[lo] * (1.0 - frac) + vals[hi] * frac


class LatencyWindow:
    """Bounded ring of the most recent latency samples (seconds).

    ``maxlen`` bounds memory for long-running front doors; quantiles are
    computed over whatever the window currently holds (the *recent*
    latency distribution — what an operator dashboards, not the lifetime
    one). ``total`` keeps the lifetime sample count."""

    def __init__(self, maxlen: int = 4096):
        self._ring: Deque[float] = deque(maxlen=int(maxlen))
        self.total = 0

    def record(self, seconds: float) -> None:
        self._ring.append(float(seconds))
        self.total += 1

    def extend(self, seconds: Iterable[float]) -> None:
        for s in seconds:
            self.record(s)

    def __len__(self) -> int:
        return len(self._ring)

    def snapshot(self) -> Dict[str, float]:
        """``{count, total, p50_s, p99_s, max_s}`` over the window —
        all-zero quantiles when no sample has landed yet (an empty
        window is a real serving state, not an error)."""
        if not self._ring:
            return {"count": 0, "total": self.total,
                    "p50_s": 0.0, "p99_s": 0.0, "max_s": 0.0}
        vals = list(self._ring)
        return {"count": len(vals), "total": self.total,
                "p50_s": percentile(vals, 50.0),
                "p99_s": percentile(vals, 99.0),
                "max_s": max(vals)}
