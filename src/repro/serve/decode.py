"""Serving entry points: prefill + single-token serve_step per family.

``serve_step`` is the function the ``decode_32k`` / ``long_500k`` dry-run
cells lower: one new token against a seq_len-deep cache/state. The cache
layout (KV ring buffers for attention families, recurrent states for
ssm/rwkv/hybrid) is owned by the family module (``cache_specs``).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import get_model
from repro.models.layers import ShardCtx


def make_prefill(cfg, ctx: Optional[ShardCtx] = None) -> Callable:
    """(params, batch) -> (last-position logits, cache). Batch: tokens
    [B, S] (+ patches / frames for vlm / encdec)."""
    model = get_model(cfg.family)

    def prefill(params, batch):
        kwargs = {}
        if cfg.family == "vlm":
            kwargs["patches"] = batch["patches"]
        if cfg.family == "encdec":
            kwargs["frames"] = batch["frames"]
        return model.prefill(cfg, params, batch["tokens"], ctx=ctx, **kwargs)

    return prefill


def make_serve_step(cfg, ctx: Optional[ShardCtx] = None) -> Callable:
    """(params, cache, tokens [B,1]) -> (logits [B,1,V], cache)."""
    model = get_model(cfg.family)

    def serve_step(params, cache, tokens):
        return model.decode_step(cfg, params, cache, tokens, ctx=ctx)

    return serve_step


def greedy_generate(cfg, params, batch: Dict[str, jax.Array], n_new: int,
                    ctx: Optional[ShardCtx] = None) -> jax.Array:
    """Prefill + n_new greedy steps (examples / integration tests).

    Note: uses the family's prefill cache, whose max_len equals the prompt
    length for attention families — generation past it relies on the
    jnp-path kv_len masking, so we grow by concatenating fresh columns on
    the host side here (tiny model sizes only)."""
    prefill = make_prefill(cfg, ctx)
    logits, cache = prefill(params, batch)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    # pad attention caches so decode has room for n_new more positions
    if "k" in cache and cache["k"].ndim >= 4:
        pad = [(0, 0)] * cache["k"].ndim
        pad[-2] = (0, n_new)
        cache = dict(cache, k=jnp.pad(cache["k"], pad),
                     v=jnp.pad(cache["v"], pad))
    step = make_serve_step(cfg, ctx)
    for _ in range(n_new - 1):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
