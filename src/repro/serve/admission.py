"""Admission control: bounded queues, recompile-storm backpressure, typed
rejections.

The front door NEVER drops a request silently. Every ``submit`` returns
exactly one of two typed outcomes, decided synchronously at the door:

* a :class:`Ticket` — the request is queued; its :class:`IngestResult`
  (or error) arrives via ``ticket.result()`` once the micro-batcher
  flushes it;
* an :class:`Overloaded` — the request is shed *now*, with the reason
  (``"queue_full"`` | ``"recompile_storm"``), the queue depth observed,
  and a ``retry_after_s`` hint. Nothing was enqueued; the caller owns the
  retry.

Two watermarks implement "shed or delay, never lose":

* ``max_queue`` — the hard high-water: at this many queued requests the
  door sheds regardless of engine state (bounded memory, bounded tail
  latency).
* ``storm_queue`` — the low-water that applies only while a *recompile
  storm* is active: the worker just hit an engine recompile (a capacity
  bucket crossing or an overflow ladder — seconds of XLA work during
  which the queue can only grow), reported via :meth:`note_recompile`.
  For ``stall_window_s`` after the last recompile the door admits only up
  to ``storm_queue`` queued requests, shedding the overflow with
  ``"recompile_storm"`` — load the queue merely *delays* under normal
  operation is shed early when the service is provably stalled.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Mapping, Optional


@dataclasses.dataclass(frozen=True)
class IngestResult:
    """Outcome of one accepted request after its flush completed."""

    tenant_id: str
    kg_triples: int          # tenant KG size after the flush
    latency_s: float         # submit → result (queueing + batching + run)
    ingest_s: float          # the engine.ingest wall time of the flush
    batched_requests: int    # requests coalesced into the same flush
    recompiles: int          # tenant-engine cumulative recompile count
    flush_id: int            # monotone per-front-door flush sequence no.


@dataclasses.dataclass(frozen=True)
class Overloaded:
    """Typed shed response — the request was NOT enqueued."""

    tenant_id: str
    reason: str              # "queue_full" | "recompile_storm"
    queue_depth: int         # depth observed at the door
    retry_after_s: float     # backoff hint (the flush window or the
    #                          remaining stall window, whichever applies)

    def __bool__(self) -> bool:
        # `if not response:` reads as "was the request shed?" at call
        # sites that only branch on acceptance
        return False


class Ticket:
    """Handle for one accepted request; resolved by the worker."""

    __slots__ = ("tenant_id", "enqueued_at", "_event", "_result", "_error")

    def __init__(self, tenant_id: str, enqueued_at: float):
        self.tenant_id = tenant_id
        self.enqueued_at = enqueued_at
        self._event = threading.Event()
        self._result: Optional[IngestResult] = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> IngestResult:
        """Block until the flush lands; raises the flush's exception if
        it failed, ``TimeoutError`` if ``timeout`` elapses first."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request for tenant {self.tenant_id!r} not flushed within "
                f"{timeout}s")
        if self._error is not None:
            raise self._error
        assert self._result is not None
        return self._result

    # -- worker side ---------------------------------------------------------
    def resolve(self, result: IngestResult) -> None:
        self._result = result
        self._event.set()

    def fail(self, error: BaseException) -> None:
        self._error = error
        self._event.set()


class AdmissionController:
    """The door's admit/shed decision + storm bookkeeping (thread-safe).

    ``clock`` is injectable for deterministic tests (defaults to
    ``time.monotonic``).
    """

    def __init__(self, max_queue: int = 256,
                 storm_queue: Optional[int] = None,
                 stall_window_s: float = 0.25,
                 retry_after_s: float = 0.05,
                 clock=time.monotonic):
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.max_queue = int(max_queue)
        # default low-water: a quarter of the hard limit (min 1 so a calm
        # storm window still admits work and drains itself)
        self.storm_queue = (max(1, self.max_queue // 4)
                            if storm_queue is None else int(storm_queue))
        if not 0 <= self.storm_queue <= self.max_queue:
            raise ValueError(
                f"storm_queue must be in [0, max_queue], got "
                f"{self.storm_queue} vs max_queue={self.max_queue}")
        self.stall_window_s = float(stall_window_s)
        self.retry_after_s = float(retry_after_s)
        self._clock = clock
        self._lock = threading.Lock()
        self._storm_until = float("-inf")
        self.recompile_stalls = 0      # recompiles reported by the worker
        self.sheds: Dict[str, int] = {"queue_full": 0, "recompile_storm": 0}

    # -- worker side ---------------------------------------------------------
    def note_recompile(self, count: int = 1,
                       now: Optional[float] = None) -> None:
        """The worker observed ``count`` engine recompiles during a flush:
        open (or extend) the storm window."""
        if count <= 0:
            return
        now = self._clock() if now is None else now
        with self._lock:
            self.recompile_stalls += count
            self._storm_until = max(self._storm_until,
                                    now + self.stall_window_s)

    def in_storm(self, now: Optional[float] = None) -> bool:
        now = self._clock() if now is None else now
        with self._lock:
            return now < self._storm_until

    # -- door side -----------------------------------------------------------
    def admit(self, tenant_id: str, queue_depth: int,
              now: Optional[float] = None) -> Optional[Overloaded]:
        """``None`` to admit; an :class:`Overloaded` (already counted) to
        shed. ``queue_depth`` is the depth *before* this request."""
        now = self._clock() if now is None else now
        with self._lock:
            storming = now < self._storm_until
            if queue_depth >= self.max_queue:
                reason = "queue_full"
            elif storming and queue_depth >= self.storm_queue:
                reason = "recompile_storm"
            else:
                return None
            self.sheds[reason] += 1
            retry = (max(self._storm_until - now, self.retry_after_s)
                     if reason == "recompile_storm" else self.retry_after_s)
        return Overloaded(tenant_id=tenant_id, reason=reason,
                          queue_depth=queue_depth, retry_after_s=retry)

    def stats(self) -> Mapping[str, object]:
        with self._lock:
            return {"max_queue": self.max_queue,
                    "storm_queue": self.storm_queue,
                    "stall_window_s": self.stall_window_s,
                    "in_storm": self._clock() < self._storm_until,
                    "recompile_stalls": self.recompile_stalls,
                    "sheds": dict(self.sheds)}
