"""Per-tenant micro-batching of ingest deltas.

Each accepted request carries raw *records* (``source name → list of
attribute dicts``) — NOT encoded tables. Encoding interns strings into
the tenant's vocab, and vocabs are engine-session state owned by the
worker thread, so the door must not touch them; it only appends the rows
to the tenant's pending deque. At flush time the worker coalesces every
pending request for a tenant into ONE ``engine.ingest`` call: per-source
record lists are concatenated in arrival order (vocab interning order —
and hence the final KG's dictionary codes — depends only on that order,
which is what makes multi-tenant serving bit-identical to a dedicated
session fed the same stream).

A tenant becomes *due* when its oldest pending request has waited
``flush_window`` seconds, or its pending rows reach ``max_batch_rows``
(whichever first). The window trades latency for coalescing: a larger
window folds more requests into one device execution.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Deque, Dict, List, Mapping, Optional, Sequence, Tuple

from .admission import Ticket


@dataclasses.dataclass
class PendingRequest:
    """One accepted request waiting in a tenant's queue."""

    ticket: Ticket
    records: Mapping[str, Sequence[Mapping[str, object]]]
    rows: int
    enqueued_at: float


class MicroBatcher:
    """Bounded-ish per-tenant queues + the due/pop flush policy.

    Thread-safety: the door thread calls :meth:`add` / :meth:`depth`;
    the worker thread calls :meth:`due` / :meth:`pop_batch`. One lock
    guards the deques; all engine work happens outside it.
    """

    def __init__(self, flush_window: float = 0.01,
                 max_batch_rows: int = 4096,
                 clock=time.monotonic):
        if flush_window < 0:
            raise ValueError(f"flush_window must be >= 0, got {flush_window}")
        if max_batch_rows < 1:
            raise ValueError(
                f"max_batch_rows must be >= 1, got {max_batch_rows}")
        self.flush_window = float(flush_window)
        self.max_batch_rows = int(max_batch_rows)
        self._clock = clock
        self._lock = threading.Lock()
        self._queues: Dict[str, Deque[PendingRequest]] = {}
        self._depth = 0           # total queued requests across tenants

    # -- door side -----------------------------------------------------------
    def add(self, tenant_id: str,
            records: Mapping[str, Sequence[Mapping[str, object]]],
            ticket: Ticket) -> int:
        """Enqueue an accepted request; returns the new global depth."""
        rows = sum(len(v) for v in records.values())
        req = PendingRequest(ticket=ticket, records=records, rows=rows,
                             enqueued_at=ticket.enqueued_at)
        with self._lock:
            self._queues.setdefault(tenant_id, deque()).append(req)
            self._depth += 1
            return self._depth

    def depth(self, tenant_id: Optional[str] = None) -> int:
        with self._lock:
            if tenant_id is None:
                return self._depth
            q = self._queues.get(tenant_id)
            return len(q) if q else 0

    # -- worker side ---------------------------------------------------------
    def due(self, now: Optional[float] = None,
            force: bool = False) -> List[str]:
        """Tenant ids whose queues should flush now: oldest request older
        than the flush window, pending rows at/over ``max_batch_rows``, or
        everything non-empty when ``force`` (drain/stop)."""
        now = self._clock() if now is None else now
        out: List[str] = []
        with self._lock:
            for tid, q in self._queues.items():
                if not q:
                    continue
                if force or (now - q[0].enqueued_at) >= self.flush_window:
                    out.append(tid)
                    continue
                if sum(r.rows for r in q) >= self.max_batch_rows:
                    out.append(tid)
        return out

    def next_deadline(self, now: Optional[float] = None) -> Optional[float]:
        """Seconds until the earliest pending request becomes due — the
        worker's idle sleep bound. ``None`` when nothing is queued."""
        now = self._clock() if now is None else now
        with self._lock:
            oldest = min((q[0].enqueued_at for q in self._queues.values()
                          if q), default=None)
        if oldest is None:
            return None
        return max(0.0, self.flush_window - (now - oldest))

    def pop_batch(self, tenant_id: str
                  ) -> Tuple[List[PendingRequest],
                             Dict[str, List[Mapping[str, object]]]]:
        """Dequeue the tenant's pending requests (respecting
        ``max_batch_rows``, but always at least one request) and coalesce
        their records per source, arrival order preserved."""
        taken: List[PendingRequest] = []
        with self._lock:
            q = self._queues.get(tenant_id)
            rows = 0
            while q:
                nxt = q[0]
                if taken and rows + nxt.rows > self.max_batch_rows:
                    break
                taken.append(q.popleft())
                rows += nxt.rows
            self._depth -= len(taken)
        merged: Dict[str, List[Mapping[str, object]]] = {}
        for req in taken:
            for name, recs in req.records.items():
                merged.setdefault(name, []).extend(recs)
        return taken, merged

    def drain_tickets(self) -> List[PendingRequest]:
        """Remove and return EVERY queued request (stop paths fail them
        explicitly rather than leaving callers blocked — no silent drop)."""
        with self._lock:
            out = [req for q in self._queues.values() for req in q]
            for q in self._queues.values():
                q.clear()
            self._depth = 0
        return out
