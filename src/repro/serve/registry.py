"""Tenant session registry: T tenants, K shapes, K compiles.

Each tenant owns a :class:`~repro.api.KGEngine` session over its own DIS
(own sources, own vocab). Compiled closures are NOT per-tenant: the
process-wide plan cache keys on the engine's structural plan signature ×
capacity buckets, so tenants whose DISes are structurally identical (same
IR fingerprint, same emitter dictionary codes, same static config) share
one jitted closure per bucket — the first tenant of a shape compiles, the
rest hit. The registry makes that dedup *observable*: it groups tenants
by :attr:`~repro.api.KGEngine.plan_signature` and aggregates
:attr:`~repro.api.KGEngine.builds` across sessions, so
``compile_dedup()`` can assert "T tenants over K shapes cost exactly K
compiles" (``benchmarks/serve.py --smoke`` gates it).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Optional, Tuple

from repro.api.config import EngineConfig
from repro.api.engine import KGEngine
from repro.core.schema import DIS

from .stats import LatencyWindow


@dataclasses.dataclass
class TenantSession:
    """One tenant's slot in the front door: its engine session plus the
    per-tenant serving counters ``serve_stats()['per_tenant']`` reports."""

    tenant_id: str
    engine: KGEngine
    shape_key: Tuple                  # engine.plan_signature
    latencies: LatencyWindow
    ingests: int = 0                  # flushes executed for this tenant
    requests: int = 0                 # accepted requests (pre-coalescing)
    rejected: int = 0                 # Overloaded responses returned
    rows: int = 0                     # delta rows folded in
    errors: int = 0                   # flushes that raised
    kg_triples: int = 0               # last reported KG size
    last_kg: object = None            # KG Table from the latest flush

    @property
    def shape_id(self) -> str:
        """Short stable digest of the shape key — the human-readable
        shape handle in stats and logs."""
        return hashlib.sha256(repr(self.shape_key).encode()) \
            .hexdigest()[:12]


class SessionRegistry:
    """Tenant-id → :class:`TenantSession` map with shape bookkeeping.

    ``default_config`` seeds every tenant that registers without an
    explicit :class:`~repro.api.EngineConfig`; per-tenant configs may
    override (tenants under different configs simply land in different
    shape groups — the plan cache keeps them apart anyway).
    """

    def __init__(self, default_config: Optional[EngineConfig] = None,
                 latency_window: int = 4096):
        self.default_config = default_config or EngineConfig()
        self._latency_window = int(latency_window)
        self._sessions: Dict[str, TenantSession] = {}

    def register(self, tenant_id: str, dis: DIS,
                 config: Optional[EngineConfig] = None) -> TenantSession:
        """Create the tenant's engine session (plan + optimize now —
        compile lazily on first ingest). Re-registering a live tenant id
        raises — silently replacing a session mid-stream would orphan its
        queued requests."""
        tenant_id = str(tenant_id)
        if tenant_id in self._sessions:
            raise ValueError(f"tenant {tenant_id!r} is already registered")
        engine = KGEngine(dis, config=config or self.default_config)
        session = TenantSession(
            tenant_id=tenant_id, engine=engine,
            shape_key=engine.plan_signature,
            latencies=LatencyWindow(self._latency_window))
        self._sessions[tenant_id] = session
        return session

    def get(self, tenant_id: str) -> TenantSession:
        try:
            return self._sessions[str(tenant_id)]
        except KeyError:
            raise KeyError(f"unknown tenant {tenant_id!r} — register the "
                           "tenant's DIS before submitting") from None

    def __contains__(self, tenant_id: str) -> bool:
        return str(tenant_id) in self._sessions

    def __len__(self) -> int:
        return len(self._sessions)

    def sessions(self) -> Tuple[TenantSession, ...]:
        return tuple(self._sessions.values())

    # -- compile dedup -------------------------------------------------------
    def shapes(self) -> Dict[Tuple, int]:
        """shape key → tenant count."""
        out: Dict[Tuple, int] = {}
        for s in self._sessions.values():
            out[s.shape_key] = out.get(s.shape_key, 0) + 1
        return out

    def compiles(self) -> int:
        """Closures actually compiled across every tenant session —
        plan-cache hits and plan-store rehydrations excluded."""
        return sum(s.engine.builds for s in self._sessions.values())

    def compile_dedup(self) -> Dict[str, object]:
        """The K-compiles-for-T-tenants story as numbers: with T tenants
        over K shapes all inside one capacity bucket, ``compiles == K``
        and ``ratio == T / K``; extra bucket crossings show up as
        ``compiles`` beyond ``shapes``."""
        compiles = self.compiles()
        tenants = len(self._sessions)
        return {"tenants": tenants, "shapes": len(self.shapes()),
                "compiles": compiles,
                "ratio": (tenants / compiles) if compiles else 0.0}
