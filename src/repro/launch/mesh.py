"""Mesh construction for the production pod(s) and local testing.

Importing this module never touches jax device state — meshes are built by
FUNCTIONS so the dry-run can set ``XLA_FLAGS`` before first jax init.

Production target: TPU v5e pods, 256 chips each, mesh (data=16, model=16);
the multi-pod configuration adds a leading ``pod`` axis (2 pods = 512
chips). ``pod`` and ``data`` are both batch-parallel; FSDP weight sharding
stays *within* a pod so cross-pod ICI traffic is one gradient all-reduce
per step.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import numpy as np


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              devices: Optional[Sequence] = None) -> jax.sharding.Mesh:
    """jax.make_mesh with explicit Auto axis types and device slicing (the
    dry-run forces 512 host devices but the single-pod mesh uses 256)."""
    n = math.prod(shape)
    devices = list(devices if devices is not None else jax.devices())
    if len(devices) < n:
        raise ValueError(f"need {n} devices, have {len(devices)}")
    dev_array = np.asarray(devices[:n]).reshape(tuple(shape))
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:  # pre-0.5 JAX: every axis is Auto implicitly
        return jax.sharding.Mesh(dev_array, tuple(axes))
    return jax.sharding.Mesh(
        dev_array, tuple(axes),
        axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """The graded production mesh: (16,16) single pod / (2,16,16) two pods."""
    shape: Tuple[int, ...] = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(model: int = 1, data: Optional[int] = None
                    ) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests/examples)."""
    n = jax.device_count()
    data = data if data is not None else max(1, n // model)
    return make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link (~ per exchange direction)
HBM_BYTES = 16 * 2**30          # 16 GiB HBM per chip
