"""Mesh construction for the production pod(s) and local testing.

Importing this module never touches jax device state — meshes are built by
FUNCTIONS so the dry-run can set ``XLA_FLAGS`` before first jax init.

Production target: TPU v5e pods, 256 chips each, mesh (data=16, model=16);
the multi-pod configuration adds a leading ``pod`` axis (2 pods = 512
chips). ``pod`` and ``data`` are both batch-parallel; FSDP weight sharding
stays *within* a pod so cross-pod ICI traffic is one gradient all-reduce
per step.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              devices: Optional[Sequence] = None) -> jax.sharding.Mesh:
    """jax.make_mesh with explicit Auto axis types and device slicing (the
    dry-run forces 512 host devices but the single-pod mesh uses 256)."""
    n = math.prod(shape)
    devices = list(devices if devices is not None else jax.devices())
    if len(devices) < n:
        raise ValueError(f"need {n} devices, have {len(devices)}")
    dev_array = np.asarray(devices[:n]).reshape(tuple(shape))
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:  # pre-0.5 JAX: every axis is Auto implicitly
        return jax.sharding.Mesh(dev_array, tuple(axes))
    return jax.sharding.Mesh(
        dev_array, tuple(axes),
        axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """The graded production mesh: (16,16) single pod / (2,16,16) two pods."""
    shape: Tuple[int, ...] = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(model: int = 1, data: Optional[int] = None
                    ) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests/examples)."""
    n = jax.device_count()
    data = data if data is not None else max(1, n // model)
    return make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link (~ per exchange direction)
HBM_BYTES = 16 * 2**30          # 16 GiB HBM per chip


# ---------------------------------------------------------------------------
# measured-bandwidth collective calibration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Calibration:
    """Per-collective bandwidth model ``t = launch_s + wire_bytes / bw``.

    ``source`` records provenance: ``"static"`` = the v5e datasheet
    constants above (the cost model's default), ``"measured"`` = fitted
    from microbenchmarks on the live mesh by
    :func:`measure_collective_bandwidth`. The cost model
    (:func:`repro.plan.annotate.join_exchange_cost`) treats the two
    identically — only the numbers (and the plan-cache signature) differ.
    """
    all_gather_bw: float        # bytes/s of per-shard wire bytes
    all_to_all_bw: float        # bytes/s of per-shard wire bytes
    launch_s: float             # fixed per-collective launch cost
    source: str = "static"

    def signature(self) -> Tuple:
        """Hashable tag for plan-cache keys / store envelopes. Static
        calibrations share one tag; measured ones carry their numbers, so
        plans costed under different link speeds never collide."""
        if self.source == "static":
            return ("static",)
        return (self.source, round(self.all_gather_bw),
                round(self.all_to_all_bw), round(self.launch_s, 9))


def static_calibration() -> Calibration:
    """The documented-constant cost model as a :class:`Calibration`."""
    from repro.plan.annotate import COLLECTIVE_LAUNCH_S
    return Calibration(all_gather_bw=ICI_BW, all_to_all_bw=ICI_BW,
                       launch_s=COLLECTIVE_LAUNCH_S, source="static")


def _fit_line(wire_bytes: Sequence[float], seconds: Sequence[float]
              ) -> Tuple[float, float]:
    """Least-squares ``t = launch + bytes/bw`` -> (bw, launch)."""
    slope, intercept = np.polyfit(np.asarray(wire_bytes, dtype=np.float64),
                                  np.asarray(seconds, dtype=np.float64), 1)
    if not np.isfinite(slope) or slope <= 0.0:
        return float("nan"), float("nan")
    return 1.0 / float(slope), max(float(intercept), 0.0)


def _zeros(shape: Tuple[int, ...]):
    import jax.numpy as jnp  # deferred: see module docstring
    return jnp.zeros(shape, jnp.int32)


def _best_seconds(fn, x, repeats: int) -> float:
    fn(x)[0].block_until_ready()        # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(x)[0].block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def measure_collective_bandwidth(mesh: jax.sharding.Mesh, axis: str, *,
                                 payload_kib: Sequence[int] = (64, 256, 1024),
                                 repeats: int = 3) -> Calibration:
    """Microbenchmark ``all_gather`` / ``all_to_all`` over ``axis`` and fit
    the two-parameter model ``t = launch + wire_bytes / bw``.

    Wire bytes follow the cost model's convention — bytes *leaving one
    shard*: ``(n-1) · shard_bytes`` for all_gather, ``(n-1)/n · shard_bytes``
    for all_to_all. Degenerate fits (single-device axis, timer-noise-level
    payloads, non-monotone timings) fall back to the static datasheet
    calibration rather than poisoning the cost model with a garbage slope.
    """
    from jax import lax
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    n = int(mesh.shape[axis])
    if n < 2:
        return static_calibration()
    cols = 128

    def gather_body(x):
        return (lax.all_gather(x, axis, tiled=True),)

    def a2a_body(x):
        return (lax.all_to_all(x, axis, split_axis=0, concat_axis=0,
                               tiled=False),)

    gather = jax.jit(shard_map(gather_body, mesh, in_specs=P(axis),
                               out_specs=P(), check_vma=False))
    a2a = jax.jit(shard_map(a2a_body, mesh,
                            in_specs=P(axis, None, None),
                            out_specs=P(axis, None, None)))

    g_bytes, g_secs, a_bytes, a_secs = [], [], [], []
    for kib in payload_kib:
        shard_rows = max(1, (kib * 1024) // (cols * 4))
        x = _zeros((n * shard_rows, cols))
        g_bytes.append((n - 1) * shard_rows * cols * 4)
        g_secs.append(_best_seconds(gather, x, repeats))
        bucket_rows = max(1, shard_rows // n)
        xb = _zeros((n * n, bucket_rows, cols))
        a_bytes.append((n - 1) * bucket_rows * cols * 4)
        a_secs.append(_best_seconds(a2a, xb, repeats))

    g_bw, g_launch = _fit_line(g_bytes, g_secs)
    a_bw, a_launch = _fit_line(a_bytes, a_secs)
    if not (np.isfinite(g_bw) and np.isfinite(a_bw)):
        return static_calibration()
    return Calibration(all_gather_bw=g_bw, all_to_all_bw=a_bw,
                       launch_s=max(g_launch, a_launch), source="measured")


#: process-wide memo: one microbenchmark pass per (mesh population, axis)
_CALIBRATION_CACHE: Dict[Tuple, Calibration] = {}


def calibrate_mesh(mesh: jax.sharding.Mesh, axis: str, *,
                   payload_kib: Sequence[int] = (64, 256, 1024),
                   repeats: int = 3, force: bool = False) -> Calibration:
    """Session-start calibration entry point (memoized per process).

    Engines created with ``calibrate=True`` call this once per mesh; later
    engines on the same device population reuse the fit. When
    ``REPRO_CALIBRATION_OUT`` names a path, the fit is also dumped there as
    JSON (CI uploads it as a debugging artifact on failure).
    """
    devs = tuple(str(d) for d in np.ravel(mesh.devices))
    key = (axis, devs, tuple(payload_kib), repeats)
    if force or key not in _CALIBRATION_CACHE:
        _CALIBRATION_CACHE[key] = measure_collective_bandwidth(
            mesh, axis, payload_kib=payload_kib, repeats=repeats)
    cal = _CALIBRATION_CACHE[key]
    out = os.environ.get("REPRO_CALIBRATION_OUT")
    if out:
        payload = dict(dataclasses.asdict(cal), axis=axis,
                       n_shards=int(mesh.shape[axis]),
                       backend=jax.default_backend())
        with open(out, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
    return cal
