"""Abstract (ShapeDtypeStruct) inputs per (arch x shape x mesh) cell.

``build_cell`` returns ``(fn, args)`` such that
``jax.jit(fn).lower(*args)`` is the dry-run for that cell: every leaf of
``args`` is a weak-type-correct, sharded ShapeDtypeStruct — no device
allocation ever happens. The same builders power the roofline analysis
and the perf hillclimbs (a hillclimb edit is usually a rule override
passed through ``rules``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ArchConfig, ShapeSpec
from repro.distributed.sharding import (AxisRules, ParamSpec,
                                        abstract_params, spec_tree_map)
from repro.models import get_model
from repro.models.layers import ShardCtx
from repro.models.vlm import VIT_DIM
from repro.serve.decode import make_prefill, make_serve_step
from repro.train.optimizer import make_optimizer
from repro.train.train_step import make_train_step

PyTree = Any


# ---------------------------------------------------------------------------
# optimizer state specs (mirrors optimizer.init exactly)
# ---------------------------------------------------------------------------

def opt_state_specs(opt_name: str, param_specs: PyTree) -> PyTree:
    """ParamSpec tree for the optimizer state (same tree structure as
    ``make_optimizer(name).init(params)``), carrying logical axes so the
    state shards exactly like its parameter."""
    def f32(s: ParamSpec) -> ParamSpec:
        return ParamSpec(s.shape, s.logical_axes, jnp.float32, "zeros")

    if opt_name == "adamw":
        return {"mu": spec_tree_map(f32, param_specs),
                "nu": spec_tree_map(f32, param_specs),
                "master": spec_tree_map(f32, param_specs)}
    if opt_name == "adafactor":
        def per(s: ParamSpec):
            if len(s.shape) >= 2:
                return {"vr": ParamSpec(s.shape[:-1], s.logical_axes[:-1],
                                        jnp.float32, "zeros"),
                        "vc": ParamSpec(s.shape[:-2] + s.shape[-1:],
                                        s.logical_axes[:-2]
                                        + s.logical_axes[-1:],
                                        jnp.float32, "zeros")}
            return {"v": f32(s)}
        return {"v": spec_tree_map(per, param_specs)}
    raise KeyError(f"unknown optimizer {opt_name!r}")


# ---------------------------------------------------------------------------
# batch specs
# ---------------------------------------------------------------------------

def _sds(mesh: Optional[Mesh], rules: Optional[AxisRules], shape, dtype,
         *logical) -> jax.ShapeDtypeStruct:
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    sh = NamedSharding(mesh, rules.spec_for(tuple(logical)))
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)


def batch_specs(cfg: ArchConfig, shape: ShapeSpec, mesh: Optional[Mesh],
                rules: Optional[AxisRules], *, with_labels: bool
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Token (+frontend-stub) input specs for one global batch."""
    b, s = shape.global_batch, shape.seq_len
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.family == "vlm":
        text = s - cfg.n_prepend
        out["tokens"] = _sds(mesh, rules, (b, text), jnp.int32,
                             "batch", "seq")
        if with_labels:
            out["labels"] = _sds(mesh, rules, (b, text), jnp.int32,
                                 "batch", "seq")
        out["patches"] = _sds(mesh, rules, (b, cfg.n_prepend, VIT_DIM),
                              jnp.float32, "batch", "seq", None)
    elif cfg.family == "encdec":
        out["tokens"] = _sds(mesh, rules, (b, s), jnp.int32, "batch", "seq")
        if with_labels:
            out["labels"] = _sds(mesh, rules, (b, s), jnp.int32,
                                 "batch", "seq")
        out["frames"] = _sds(mesh, rules, (b, cfg.n_enc_frames, cfg.d_model),
                             jnp.float32, "batch", "seq", "embed")
    else:
        out["tokens"] = _sds(mesh, rules, (b, s), jnp.int32, "batch", "seq")
        if with_labels:
            out["labels"] = _sds(mesh, rules, (b, s), jnp.int32,
                                 "batch", "seq")
    return out


# ---------------------------------------------------------------------------
# cells
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Cell:
    """One dry-run cell: callable + abstract args (+ metadata)."""
    arch: str
    shape: str
    kind: str
    fn: Callable
    args: Tuple[PyTree, ...]
    n_microbatches: int = 1
    donate_argnums: Tuple[int, ...] = ()


def build_cell(cfg: ArchConfig, shape: ShapeSpec, mesh: Optional[Mesh],
               rules: Optional[AxisRules]) -> Cell:
    if not cfg.shape_supported(shape):
        raise ValueError(f"{cfg.name} does not support {shape.name}")
    ctx = None if mesh is None else ShardCtx(mesh, rules)
    model = get_model(cfg.family)
    params = abstract_params(model.param_specs(cfg), mesh, rules)

    if shape.kind == "train":
        n_shards = 1 if mesh is None else (
            mesh.shape.get("pod", 1) * mesh.shape.get("data", 1))
        n_mb = cfg.microbatches(shape, n_shards)
        opt = make_optimizer(cfg.optimizer)
        p_specs = model.param_specs(cfg)
        opt_spec_tree = opt_state_specs(cfg.optimizer, p_specs)
        use_ef = (cfg.grad_compress_pods and mesh is not None
                  and mesh.shape.get("pod", 1) > 1 and not cfg.fsdp
                  and not cfg.fsdp_pods)
        if use_ef:
            # POD-DECOUPLED step: shard_map manual over (pod, data) so
            # the backward produces per-rank gradients and the
            # hierarchical hook owns the WHOLE sync: reduce-scatter over
            # `data` (fast ICI) -> int8+EF quantize the 1/|data| shard ->
            # int16 psum over `pod` (the only DCI transfer) -> all-gather.
            # A naive quantized full-copy pod-psum moves MORE cross-pod
            # bytes than GSPMD's own hierarchical all-reduce (measured —
            # see EXPERIMENTS.md §Perf extras).
            from jax.sharding import PartitionSpec as P
            from repro.train.train_step import with_error_feedback
            n_inner = mesh.shape["data"]
            opt, hook = with_error_feedback(opt, n_inner)

            def _ef_len(s: ParamSpec) -> int:
                n = 1
                for d in s.shape:
                    n *= d
                return (n + n_inner - 1) // n_inner
            n_pods = mesh.shape["pod"]
            ef_specs = spec_tree_map(
                lambda s: ParamSpec((n_pods * n_inner * _ef_len(s),),
                                    ("ef_shard",), jnp.float32, "zeros"),
                p_specs)
            opt_spec_tree = {"opt": opt_spec_tree, "ef": ef_specs}
            rules = rules.with_overrides(("ef_shard", ("pod", "data")))
            rules_in = rules.with_overrides(("batch", None))
            ctx_in = ShardCtx(mesh, rules_in)
            inner0 = make_train_step(cfg, n_microbatches=n_mb,
                                     optimizer=opt, ctx=ctx_in,
                                     grad_compress=hook)

            def inner(params, opt_state, batch, step):
                ef = jax.tree_util.tree_map(lambda e: e.reshape(-1),
                                            opt_state["ef"])
                p2, o2, m = inner0(params, dict(opt_state, ef=ef), batch,
                                   step)
                o2 = dict(o2, ef=jax.tree_util.tree_map(
                    lambda e: e[None], o2["ef"]))
                return p2, o2, m

            rep = jax.tree_util.tree_map(lambda _: P(), p_specs)
            rep_opt = jax.tree_util.tree_map(
                lambda _: P(), opt_spec_tree["opt"],
                is_leaf=lambda x: isinstance(x, ParamSpec))
            ef_p = spec_tree_map(lambda _: P(("pod", "data")), p_specs)
            fn = shard_map(
                inner, mesh=mesh, axis_names={"pod", "data"},
                in_specs=(rep, {"opt": rep_opt, "ef": ef_p},
                          {k: P(("pod", "data")) for k in
                           batch_specs(cfg, shape, None, None,
                                       with_labels=True)}, P()),
                out_specs=(rep, {"opt": rep_opt, "ef": ef_p},
                           {"loss": P(), "grad_norm": P()}),
                check_vma=False)
        else:
            fn = make_train_step(cfg, n_microbatches=n_mb, optimizer=opt,
                                 ctx=ctx)
        opt_abs = abstract_params(opt_spec_tree, mesh, rules)
        batch = batch_specs(cfg, shape, mesh, rules, with_labels=True)
        step = _sds(mesh, rules, (), jnp.int32)
        return Cell(cfg.name, shape.name, "train", fn,
                    (params, opt_abs, batch, step), n_mb,
                    donate_argnums=(0, 1))

    if shape.kind == "prefill":
        batch = batch_specs(cfg, shape, mesh, rules, with_labels=False)
        fn = make_prefill(cfg, ctx)
        return Cell(cfg.name, shape.name, "prefill", fn, (params, batch))

    # decode: one token against a seq_len-deep cache/state
    cache = abstract_params(
        model.cache_specs(cfg, shape.global_batch, shape.seq_len),
        mesh, rules)
    tokens = _sds(mesh, rules, (shape.global_batch, 1), jnp.int32,
                  "batch", "seq")
    fn = make_serve_step(cfg, ctx)
    return Cell(cfg.name, shape.name, "decode", fn, (params, cache, tokens),
                donate_argnums=(1,))


def lower_cell(cell: Cell):
    """jit + AOT lower (no execution)."""
    fn = jax.jit(cell.fn, donate_argnums=cell.donate_argnums)
    return fn.lower(*cell.args)


# ---------------------------------------------------------------------------
# analytic parameter counts (roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------

def model_param_counts(cfg: ArchConfig) -> Dict[str, float]:
    """Total / active / non-embedding parameter counts from the spec tree.
    ``active`` scales expert leaves by top_k / n_experts (MoE); ``body``
    excludes vocab-axis leaves (the 6ND convention)."""
    specs = get_model(cfg.family).param_specs(cfg)
    leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    total = active = body = body_active = 0.0
    for s in leaves:
        n = 1.0
        for d in s.shape:
            n *= d
        frac = 1.0
        if cfg.n_experts and "expert" in (s.logical_axes or ()):
            frac = cfg.top_k / cfg.n_experts
        total += n
        active += n * frac
        if "vocab" not in (s.logical_axes or ()):
            body += n
            body_active += n * frac
    return {"total": total, "active": active,
            "body": body, "body_active": body_active}
