"""Post-SPMD HLO analysis: collective traffic + roofline terms.

``collective_bytes(text)`` parses a compiled (per-device, post-partition)
HLO module and sums **operand** bytes of every collective op, bucketed by
opcode — the numerator of the roofline collective term. Operand sizes are
resolved by first indexing every instruction's result type, then looking
up each collective's operand names.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_ELEM_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# one array type like bf16[8,128]{1,0} (layout/suffix optional)
_ARRAY_RE = re.compile(
    r"\b(" + "|".join(_ELEM_BYTES) + r")\[([0-9,]*)\]")

# an instruction line: %name = TYPE opcode(...)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)")


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _ARRAY_RE.finditer(type_str):
        elem = _ELEM_BYTES[m.group(1)]
        dims = m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += elem * n
    return total


@dataclasses.dataclass
class CollectiveStats:
    by_op: Dict[str, int]
    by_op_count: Dict[str, int]
    cross_pod_bytes: int = -1      # -1 = not classified (single pod)

    @property
    def total_bytes(self) -> int:
        return sum(self.by_op.values())

    def to_dict(self) -> Dict[str, object]:
        d = {"bytes_by_op": dict(self.by_op),
             "count_by_op": dict(self.by_op_count),
             "total_bytes": self.total_bytes}
        if self.cross_pod_bytes >= 0:
            d["cross_pod_bytes"] = self.cross_pod_bytes
        return d


# --- replica-group parsing (pod-boundary classification) -------------------

_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([^}]*(?:\},\{[^}]*)*)\}\}")


def groups_span_boundary(line: str, boundary: int) -> bool:
    """True if any replica group on this line contains device ids on both
    sides of ``boundary`` (pod 0 = ids < boundary). Unknown formats are
    conservatively treated as spanning."""
    m = _IOTA_RE.search(line)
    if m:
        import numpy as _np
        g, n = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        v = _np.arange(int(_np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(p) for p in m.group(4).split(",")]
            v = v.transpose(perm)
        groups = v.reshape(g, n)
        return bool(((groups < boundary).any(axis=1)
                     & (groups >= boundary).any(axis=1)).any())
    m = _EXPLICIT_RE.search(line)
    if m:
        for grp in m.group(1).split("},{"):
            ids = [int(x) for x in grp.split(",") if x.strip()]
            if ids and min(ids) < boundary <= max(ids):
                return True
        return False
    if "replica_groups={}" in line:
        return True                      # all devices, spans by definition
    return True


def collective_bytes(hlo_text: str,
                     pod_boundary: Optional[int] = None) -> CollectiveStats:
    """Sum operand bytes per collective opcode over a compiled module.
    ``pod_boundary``: classify collectives whose replica groups span the
    device-id boundary (cross-pod traffic over the slow DCI links)."""
    # pass 1: instruction name -> result bytes
    result_bytes: Dict[str, int] = {}
    lines = hlo_text.splitlines()
    for line in lines:
        m = _INSTR_RE.match(line)
        if m:
            result_bytes[m.group(1)] = _type_bytes(m.group(2))

    by_op: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    by_count: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    cross = 0
    opnd_re = re.compile(r"%?([\w.\-]+)")
    for line in lines:
        m = _INSTR_RE.match(line)
        if not m:
            continue
        opcode = m.group(3)
        base = None
        for c in _COLLECTIVES:
            if opcode == c or opcode.startswith(c + "-start"):
                base = c
                break
        if base is None:
            continue
        if opcode.endswith("-done"):
            continue                       # avoid double count of async pairs
        total = _operand_bytes(line, m.end(), result_bytes, opnd_re)
        by_op[base] += total
        by_count[base] += 1
        if pod_boundary is not None and \
                groups_span_boundary(line, pod_boundary):
            cross += total
    return CollectiveStats(by_op, by_count,
                           cross if pod_boundary is not None else -1)


def _operand_bytes(line: str, start: int, result_bytes: Dict[str, int],
                   opnd_re) -> int:
    """Sum result_bytes over the operand names of the instruction on
    ``line``; ``start`` points just past the opcode (so the instruction
    NAME — which also contains the opcode string — and tuple result types
    are never mistaken for the operand list)."""
    paren = line.find("(", start)
    if paren < 0:
        return 0
    depth, j = 0, paren
    while j < len(line):
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
            if depth == 0:
                break
        j += 1
    args = line[paren + 1:j]
    total = 0
    for om in opnd_re.finditer(args):
        name = om.group(1)
        if name in result_bytes:
            total += result_bytes[name]
    return total


# ---------------------------------------------------------------------------
# scan-aware correction
# ---------------------------------------------------------------------------

def while_trip_counts(hlo_text: str) -> List[int]:
    """Known trip counts of while loops (scan-over-layers), best effort."""
    return [int(m.group(1)) for m in
            re.finditer(r"trip_count[=:\s]+(\d+)", hlo_text)]


def collective_bytes_scaled(hlo_text: str) -> CollectiveStats:
    """Like :func:`collective_bytes` but multiplies collectives that live
    inside a while-loop body by the loop trip count (scan-over-layers
    executes its body L times; the static HLO lists it once).

    HLO text nests computations as separate blocks; we attribute a
    collective to a loop if its computation block is referenced as a
    while body with a known trip count."""
    # map computation name -> trip count (from while instrs)
    trip_re = re.compile(r'known_trip_count=\{n="?(\d+)"?\}')
    comp_trips: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        if " while(" in line or " while (" in line:
            bm = re.search(r"body=%?([\w.\-]+)", line)
            tm = trip_re.search(line)
            if bm:
                comp_trips[bm.group(1)] = int(tm.group(1)) if tm else 1
    # walk blocks; scale collectives inside while bodies
    result = collective_bytes(hlo_text)       # flat counts
    if not comp_trips:
        return result
    by_op = {c: 0 for c in _COLLECTIVES}
    by_count = {c: 0 for c in _COLLECTIVES}
    current_comp: Optional[str] = None
    comp_header = re.compile(r"^\s*%?([\w.\-]+)\s+\([^)]*\)\s*->")
    # rebuild result_bytes map (cheap)
    result_bytes: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            result_bytes[m.group(1)] = _type_bytes(m.group(2))
    opnd_re = re.compile(r"%?([\w.\-]+)")
    for line in hlo_text.splitlines():
        hm = comp_header.match(line)
        if hm and "=" not in line.split("->")[0]:
            current_comp = hm.group(1)
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        opcode = m.group(3)
        base = next((c for c in _COLLECTIVES
                     if opcode == c or opcode.startswith(c + "-start")), None)
        if base is None or opcode.endswith("-done"):
            continue
        scale = comp_trips.get(current_comp or "", 1)
        total = _operand_bytes(line, m.end(), result_bytes, opnd_re)
        by_op[base] += total * scale
        by_count[base] += scale
    return CollectiveStats(by_op, by_count)
