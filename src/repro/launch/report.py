"""Generate EXPERIMENTS.md tables from the dry-run / roofline artifacts."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..",
                    "..", "experiments")


def load_dir(dirname: str) -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(ROOT, dirname, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def dryrun_table(records: List[Dict]) -> str:
    head = ("| arch | shape | mesh | status | args+temp GiB/dev | "
            "collective MiB/step | compile s |\n"
            "|---|---|---|---|---|---|---|\n")
    rows = []
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"],
                                            r["mesh"])):
        if r.get("status") == "ok":
            mem = r["memory"]
            per = (mem.get("argument_size_in_bytes", 0)
                   + mem.get("temp_size_in_bytes", 0)) / 2**30
            coll = r["collectives"]["total_bytes"] / 2**20
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                        f"{per:.2f} | {coll:.1f} | "
                        f"{r.get('compile_seconds', 0):.0f} |")
        elif r.get("status") == "skip":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"skip ({r.get('reason', '')}) | — | — | — |")
        else:
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"ERROR | — | — | — |")
    return head + "\n".join(rows) + "\n"


def roofline_table(records: List[Dict]) -> str:
    head = ("| arch | shape | compute s | memory s | collective s | "
            "dominant | useful/HLO | roofline frac | lever |\n"
            "|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"])):
        if r.get("status") == "skip":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | skip |"
                        " — | — | — |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | |"
                        " |")
            continue
        t = r["terms_seconds"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.2e} | "
            f"{t['memory_s']:.2e} | {t['collective_s']:.2e} | "
            f"{r['dominant'].replace('_s', '')} | "
            f"{r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2%} | {r['suggestion'][:60]}… |")
    return head + "\n".join(rows) + "\n"


def bench_summary() -> str:
    out = []
    for name in ("group_a", "group_b", "table1", "motivating"):
        path = os.path.join(ROOT, "bench", f"{name}.json")
        if not os.path.exists(path):
            continue
        with open(path) as f:
            rows = json.load(f)
        if not rows:
            continue
        keys = list(rows[0])
        out.append(f"**{name}**\n")
        out.append("| " + " | ".join(keys) + " |")
        out.append("|" + "---|" * len(keys))
        for r in rows:
            out.append("| " + " | ".join(str(r.get(k, "")) for k in keys)
                       + " |")
        out.append("")
    return "\n".join(out) + "\n"


def inject(md_path: str) -> None:
    """Replace the marked blocks in EXPERIMENTS.md from artifacts."""
    with open(md_path) as f:
        text = f.read()

    def repl(tag: str, body: str, t: str) -> str:
        b, e = f"<!-- {tag}:BEGIN -->", f"<!-- {tag}:END -->"
        i, j = t.index(b) + len(b), t.index(e)
        return t[:i] + "\n" + body + t[j:]

    text = repl("DRYRUN", dryrun_table(load_dir("dryrun_scan")), text)
    text = repl("ROOFLINE", roofline_table(load_dir("roofline")), text)
    text = repl("BENCH", bench_summary(), text)
    with open(md_path, "w") as f:
        f.write(text)
    print(f"injected tables into {md_path}")


def main() -> None:
    import sys
    if "--inject" in sys.argv:
        md = os.path.join(ROOT, "..", "EXPERIMENTS.md")
        inject(os.path.abspath(md))
        return
    scans = load_dir("dryrun_scan")
    print(dryrun_table(scans))


if __name__ == "__main__":
    main()
