"""Multi-tenant streaming KG ingestion driver over the serve front door.

Simulates the production semantification service at CPU scale: T tenant
DISes spread over K structural shapes register with one
:class:`~repro.serve.FrontDoor`, then extension micro-batches (new
gene/sample rows) stream in round-robin and are folded into each tenant's
KG via the shared-plan ingest path — tenants of one shape share compiled
closures through the process-wide plan cache (K compiles for T tenants),
and the admission controller sheds load with typed ``Overloaded``
responses when the queue passes its watermarks. Reports per-request
latency quantiles (linear-interpolation percentiles — the shared
:func:`repro.serve.percentile` helper, NOT index arithmetic), compile
dedup, recompile stalls and shed counts from ``serve_stats()``.

With ``--mesh-shards N`` every tenant's sink duplicate elimination runs
through the shard_map collective path (requires N local devices, e.g.
``XLA_FLAGS=--xla_force_host_platform_device_count=N``). ``--tenants 1
--shapes 1`` recovers the historical single-session behaviour.

Usage::

    PYTHONPATH=src python -m repro.launch.kg_serve --rows 2000 \
        --tenants 8 --shapes 2 --batches 12 --batch-rows 128
    PYTHONPATH=src python -m repro.launch.serve --kg --rows 2000 ...
"""
from __future__ import annotations

import argparse
import time

from repro.api import EngineConfig
from repro.data.synthetic import (make_group_b_dis,
                                  make_group_b_extension_records)
from repro.serve import FrontDoor, Overloaded, percentile


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=4000,
                    help="seed rows per source")
    ap.add_argument("--tenants", type=int, default=4,
                    help="registered tenant sessions")
    ap.add_argument("--shapes", type=int, default=2,
                    help="distinct structural DIS shapes among tenants")
    ap.add_argument("--batches", type=int, default=16,
                    help="ingest micro-batches per tenant")
    ap.add_argument("--batch-rows", type=int, default=256)
    ap.add_argument("--flush-window", type=float, default=0.0,
                    help="micro-batch coalescing window in seconds")
    ap.add_argument("--max-queue", type=int, default=1024,
                    help="admission hard high-water (queued requests)")
    ap.add_argument("--engine", default="sdm")
    ap.add_argument("--dedup", default="hash")
    ap.add_argument("--mode", default="exact", choices=["exact", "bound"])
    ap.add_argument("--slack", type=float, default=1.0)
    ap.add_argument("--mesh-shards", type=int, default=0,
                    help="shard the sink δ over N devices (0 = off)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if not 1 <= args.shapes <= args.tenants:
        ap.error("--shapes must be in [1, --tenants]")

    mesh = None
    if args.mesh_shards:
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((args.mesh_shards,), ("data",))

    door = FrontDoor(EngineConfig(engine=args.engine, dedup=args.dedup,
                                  mode=args.mode, slack=args.slack,
                                  mesh=mesh),
                     flush_window=args.flush_window,
                     max_queue=args.max_queue)
    t0 = time.perf_counter()
    for t in range(args.tenants):
        # tenants of one shape share seed rows (identical structure +
        # dictionary codes → identical plan signature → one compile);
        # their live deltas below still differ per tenant
        shape = t % args.shapes
        dis = make_group_b_dis(args.rows, 0.6, seed=args.seed + shape)
        door.register(f"tenant{t}", dis)
    dedup = door.registry.compile_dedup()
    print(f"registered {dedup['tenants']} tenants over {dedup['shapes']} "
          f"shapes in {time.perf_counter() - t0:.2f}s")

    shed = 0
    tickets = []
    for b in range(args.batches):
        for t in range(args.tenants):
            recs = make_group_b_extension_records(
                args.batch_rows, seed=1000 + b * args.tenants + t)
            resp = door.submit(f"tenant{t}", recs)
            if isinstance(resp, Overloaded):
                shed += 1
                continue
            tickets.append(resp)
        flushed = door.pump(force=args.flush_window == 0.0)
        if flushed:
            last = tickets[-1].result(timeout=600)
            print(f"batch {b:3d}: tenant kg={last.kg_triples} triples "
                  f"{last.ingest_s * 1e3:7.1f}ms "
                  f"coalesced={last.batched_requests} "
                  f"recompiles={last.recompiles}")
    door.drain()

    st = door.serve_stats()
    lat = [tk.result(timeout=600).latency_s for tk in tickets]
    print(f"\ningested {sum(s['rows'] for s in st['per_tenant'].values())} "
          f"rows over {st['flushes']} flushes "
          f"({st['completed']} requests, {shed} shed): "
          f"p50={percentile(lat, 50) * 1e3:.1f}ms "
          f"p99={percentile(lat, 99) * 1e3:.1f}ms")
    print(f"compiles={st['compiles']} for {st['tenants']} tenants "
          f"(dedup ratio {st['compile_dedup_ratio']:.1f}x) "
          f"recompile_stalls={st['recompile_stalls']} "
          f"plan_cache_hits={st['plan_cache']['hits']} "
          f"sheds={st['admission']['sheds']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
