"""Streaming KG ingestion driver: micro-batches through one KGEngine session.

Simulates the production semantification loop at CPU scale: a seed
group-B DIS is planned once into a ``KGEngine`` session, then extension
micro-batches (new gene/sample rows) arrive and are folded in via
``engine.ingest`` — the session reuses its cached compiled plan inside a
capacity bucket and transparently recompiles (counted) when the stream
outgrows it. Reports per-batch latency, cumulative triples, recompile and
plan-cache counters. With ``--mesh-shards N`` the sink duplicate
elimination runs through the shard_map collective path (requires N local
devices, e.g. ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).

Usage::

    PYTHONPATH=src python -m repro.launch.kg_serve --rows 4000 \
        --batches 16 --batch-rows 256
    PYTHONPATH=src python -m repro.launch.serve --kg --rows 4000 ...
"""
from __future__ import annotations

import argparse
import time
from typing import List

from repro.api import EngineConfig, KGEngine
from repro.data.synthetic import (make_group_b_dis,
                                  make_group_b_extension_records)
from repro.relalg import Table


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=4000,
                    help="seed rows per source")
    ap.add_argument("--batches", type=int, default=16)
    ap.add_argument("--batch-rows", type=int, default=256)
    ap.add_argument("--engine", default="sdm")
    ap.add_argument("--dedup", default="hash")
    ap.add_argument("--mode", default="exact", choices=["exact", "bound"])
    ap.add_argument("--slack", type=float, default=1.0)
    ap.add_argument("--mesh-shards", type=int, default=0,
                    help="shard the sink δ over N devices (0 = off)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    mesh = None
    if args.mesh_shards:
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((args.mesh_shards,), ("data",))

    dis = make_group_b_dis(args.rows, 0.6, seed=args.seed)
    t0 = time.perf_counter()
    engine = KGEngine(dis, config=EngineConfig(
        engine=args.engine, dedup=args.dedup, mode=args.mode,
        slack=args.slack, mesh=mesh))
    kg, stats = engine.create_kg()
    print(f"seed: {stats['kg_triples']} triples in "
          f"{time.perf_counter() - t0:.2f}s "
          f"(plan cache hit={stats['plan_cache_hit']})")

    latencies: List[float] = []
    ingested = 0
    for b in range(args.batches):
        recs = make_group_b_extension_records(args.batch_rows, seed=1000 + b)
        deltas = {name: Table.from_records(r, engine.sources[name].attrs,
                                           engine.vocab)
                  for name, r in recs.items()}
        t0 = time.perf_counter()
        kg, stats = engine.ingest(deltas)
        latencies.append(time.perf_counter() - t0)
        ingested += 2 * args.batch_rows
        print(f"batch {b:3d}: {stats['kg_triples']} triples "
              f"{latencies[-1] * 1e3:7.1f}ms "
              f"recompiles={stats['recompiles']} "
              f"cache_hit={stats['plan_cache_hit']}")

    lat = sorted(latencies)
    st = engine.stats()
    print(f"\ningested {ingested} rows over {args.batches} batches: "
          f"p50={lat[len(lat) // 2] * 1e3:.1f}ms "
          f"p99={lat[min(len(lat) - 1, int(len(lat) * 0.99))] * 1e3:.1f}ms "
          f"steady={int(st['source_buckets']['gene'])}-row gene bucket")
    print(f"recompiles={st['recompiles']} "
          f"plan_cache_hits={st['plan_cache_hits']} "
          f"misses={st['plan_cache_misses']} "
          f"kg_triples={stats['kg_triples']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
