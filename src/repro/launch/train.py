"""End-to-end training driver: MapSDI data integration -> LM training.

The full production story in one process (shrunk to CPU scale with
``--reduced``):

1. Build a synthetic genomics DIS (volume/redundancy dials), run MapSDI
   (Rules 1-3 + RDFize) to create the deduplicated knowledge graph.
2. Linearize the KG into a token stream (:mod:`repro.data.pipeline`).
3. Train the selected architecture with pjit on a mesh, with sharded
   atomic checkpoints, injected failures + supervised restarts, and a
   straggler monitor rebalancing the data pipeline.

Usage (CPU smoke)::

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --reduced --steps 20 --batch 8 --seq 128 --ckpt /tmp/ckpt \
        --fail-at 7 --fail-at 13
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced_config
from repro.core.pipeline import mapsdi_create_kg
from repro.data.pipeline import KGTokenPipeline, linearize_kg
from repro.data.synthetic import make_group_a_dis
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.fault import (FailureInjector, RestartPolicy,
                                     StragglerMonitor, run_with_restarts)
from repro.distributed.sharding import init_params, param_shardings
from repro.launch.mesh import make_local_mesh
from repro.models import auto_rules, get_model
from repro.models.layers import ShardCtx
from repro.train.optimizer import make_optimizer
from repro.train.train_step import make_train_step


def build_dataset(cfg, *, rows: int, redundancy: float, seed: int
                  ) -> KGTokenPipeline:
    dis = make_group_a_dis(rows, redundancy, seed=seed)
    kg, stats = mapsdi_create_kg(dis)
    print(f"[mapsdi] raw={stats['raw_triples']} kg={stats['kg_triples']} "
          f"rows {stats['source_rows_before']}->{stats['source_rows_after']}"
          f" (rule1={stats['rule1']} rule3={stats['rule3']})")
    stream = linearize_kg(kg, cfg.vocab_size, seed=seed)
    return stream


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--rows", type=int, default=2000)
    ap.add_argument("--redundancy", type=float, default=0.75)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=5)
    ap.add_argument("--fail-at", type=int, action="append", default=[],
                    help="inject a simulated failure at this step")
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    if cfg.family in ("vlm", "encdec"):
        raise SystemExit("train driver covers token-only families; "
                         "see tests/test_archs.py for vlm/encdec steps")

    mesh = make_local_mesh(model=args.model_parallel)
    rules = auto_rules(cfg, mesh)
    ctx = ShardCtx(mesh, rules)
    model = get_model(cfg.family)

    # --- data: MapSDI KG -> token stream ------------------------------------
    stream = build_dataset(cfg, rows=args.rows, redundancy=args.redundancy,
                           seed=args.seed)
    pipe = KGTokenPipeline(stream, args.seq, args.batch)
    n_hosts = mesh.shape.get("data", 1)
    monitor = StragglerMonitor(n_hosts)

    # --- model / optimizer ---------------------------------------------------
    opt = make_optimizer(cfg.optimizer, lr=args.lr)
    specs = model.param_specs(cfg)
    shardings = param_shardings(specs, mesh, rules)
    train_step = make_train_step(cfg, optimizer=opt, ctx=ctx)
    jit_step = jax.jit(train_step, donate_argnums=(0, 1))

    manager = (CheckpointManager(args.ckpt, keep_n=3) if args.ckpt else None)
    injector = FailureInjector(schedule=tuple(args.fail_at))

    def init_state():
        params = init_params(specs, jax.random.PRNGKey(args.seed))
        params = jax.device_put(params, shardings)
        return params, opt.init(params)

    def loop(resume_attempt: Optional[int]):
        params, opt_state = init_state()
        start = 0
        if manager is not None and manager.latest_step() is not None:
            (params, opt_state), extra = manager.restore(
                (params, opt_state))
            start = int(extra.get("step", manager.latest_step())) + 1
            print(f"[restore] resumed from step {start - 1}")
        losses = []
        for step in range(start, args.steps):
            injector.maybe_fail(step)
            t0 = time.perf_counter()
            batch = {k: jnp.asarray(v)
                     for k, v in pipe.batch(step).items()}
            params, opt_state, metrics = jit_step(
                params, opt_state, batch, jnp.asarray(step, jnp.int32))
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            monitor.observe([dt] * n_hosts)   # single-host: uniform
            losses.append(loss)
            if manager is not None and (step + 1) % args.ckpt_every == 0:
                manager.save(step, (params, opt_state),
                             extra={"step": step})
            if step % max(1, args.steps // 10) == 0:
                print(f"[step {step:4d}] loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"{dt*1e3:.0f}ms")
        if manager is not None:
            manager.save(args.steps - 1, (params, opt_state),
                         extra={"step": args.steps - 1})
            manager.wait()
        return losses

    policy = RestartPolicy(max_restarts=max(3, len(args.fail_at) + 1))
    losses, report = run_with_restarts(loop, policy)
    if report.restarts:
        print(f"[fault] survived {report.restarts} injected failures: "
              f"{[f[1] for f in report.failures]}")
    if monitor.stragglers():
        pipe.rebalance(monitor.shard_weights())
        print(f"[straggler] rebalanced: {monitor.shard_weights()}")
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    ok = losses[-1] < losses[0]
    print("loss decreased" if ok else "WARNING: loss did not decrease")
    if manager is not None:
        manager.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
