import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
# Must precede any jax import (same contract as dryrun.py).

"""Roofline analysis per (arch x shape) on the single-pod mesh.

Three terms, all **per device** (the compiled module after SPMD
partitioning is the per-device program, so ``cost_analysis()`` and the
collective parse are already per-chip):

    compute    = HLO_FLOPs / peak_FLOP/s        (197e12, bf16 v5e)
    memory     = HLO_bytes / HBM_bw             (819e9 B/s)
    collective = collective_operand_bytes / ICI (50e9 B/s per link)

**Depth extrapolation.** XLA's cost analysis counts a while-loop body
once, and fully unrolling an 88-layer model on this 1-core container
takes ~10 min/cell. Instead we compile the *unrolled* program at two
small depths (L0, L1) — every cost is exactly affine in depth
(homogeneous layer stacks; params, grad all-reduce, optimizer update all
affine in L) — and extrapolate to the real depth:

    f(L) = f(L0) + (f(L1) - f(L0)) / (L1 - L0) * (L - L0)

For structured stacks the depth unit is one *period* (gemma3: 6-layer
local/global cycle; zamba2: one shared+6-mamba group). The extrapolation
is validated against a full-depth unrolled compile in
``tests/test_roofline.py`` (qwen3: <2%% error).

Residual known undercount: the blockwise-attention kv scan is partially
unrolled (cap 32 blocks), so ``long_500k`` decode attention FLOPs are
counted at 32/512 of true — decode cells are memory-bound by orders of
magnitude, so the dominant term is unaffected; the MODEL_FLOPS column
flags it.
"""

import argparse
import dataclasses
import json
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ARCH_IDS, SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "roofline")


# ---------------------------------------------------------------------------
# depth schedule
# ---------------------------------------------------------------------------

def depth_points(cfg) -> Tuple[int, int, int]:
    """(L0, L1, L_full) in layers, respecting the structural period."""
    if cfg.local_global:                      # gemma3: 6-layer cycle
        p = cfg.local_global + 1
        return p, 2 * p, cfg.n_layers
    if cfg.shared_attn_every:                 # zamba2: 6-mamba groups
        p = cfg.shared_attn_every
        return p, 2 * p, cfg.n_layers
    return 4, 8, cfg.n_layers


def _extract(rec: Dict) -> Dict[str, float]:
    c = rec["cost"]
    return {
        "flops": float(c.get("flops", 0.0)),
        "bytes": float(c.get("bytes accessed", 0.0)),
        "transcendentals": float(c.get("transcendentals", 0.0)),
        "coll_bytes": float(rec["collectives"]["total_bytes"]),
        "temp_bytes": float(rec["memory"].get("temp_size_in_bytes", 0)),
        "arg_bytes": float(rec["memory"].get("argument_size_in_bytes", 0)),
    }


def extrapolate(f0: Dict[str, float], f1: Dict[str, float],
                l0: int, l1: int, l: int) -> Dict[str, float]:
    out = {}
    for k in f0:
        slope = (f1[k] - f0[k]) / (l1 - l0)
        out[k] = f0[k] + slope * (l - l0)
    return out


# ---------------------------------------------------------------------------
# analysis
# ---------------------------------------------------------------------------

def model_flops(cfg, shape, n_devices: int, params: Dict[str, float]
                ) -> float:
    """Useful FLOPs per device per step: 6·N·D train, 2·N·D inference
    (N = active non-embedding params, D = tokens this step)."""
    n = params["body_active"]
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        mult = 6.0
    elif shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        mult = 2.0
    else:                                    # decode: one token per row
        d = shape.global_batch
        mult = 2.0
    return mult * n * d / n_devices


def analyze_cell(arch: str, shape_name: str, *, mesh: str = "single",
                 rule_overrides=(), cfg_overrides: Optional[Dict] = None
                 ) -> Dict[str, object]:
    """Two reduced-depth unrolled compiles -> extrapolated roofline terms."""
    from repro.launch.dryrun import run_cell   # sets XLA_FLAGS on import

    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    if not cfg.shape_supported(shape):
        return {"arch": arch, "shape": shape_name, "mesh": mesh,
                "status": "skip"}
    l0, l1, lf = depth_points(cfg)
    base_over = dict(cfg_overrides or {})
    # Cost is microbatch-count invariant (same total tokens per step), but
    # unrolling a 16-deep grad-accum loop multiplies compile time ~16x;
    # compile the cost build with n_mb=1 (memory comes from the
    # production scan build in §Dry-run, which keeps the real n_mb).
    base_over.setdefault("microbatch_seq_tokens", 1 << 62)
    rec0 = run_cell(arch, shape_name, mesh, unroll=True,
                    cfg_overrides={**base_over, "n_layers": l0},
                    rule_overrides=rule_overrides)
    rec1 = run_cell(arch, shape_name, mesh, unroll=True,
                    cfg_overrides={**base_over, "n_layers": l1},
                    rule_overrides=rule_overrides)
    f = extrapolate(_extract(rec0), _extract(rec1), l0, l1, lf)

    n_dev = rec0["n_devices"]
    # param counts at FULL depth (cheap, no compile)
    from repro.launch.specs import model_param_counts
    params = model_param_counts(cfg)

    terms = {
        "compute_s": f["flops"] / PEAK_FLOPS_BF16,
        "memory_s": f["bytes"] / HBM_BW,
        "collective_s": f["coll_bytes"] / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape, n_dev, params)
    bound_s = max(terms.values())
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh, "status": "ok",
        "kind": shape.kind, "n_devices": n_dev,
        "depths": [l0, l1, lf],
        "hlo_flops": f["flops"], "hlo_bytes": f["bytes"],
        "collective_bytes": f["coll_bytes"],
        "terms_seconds": terms,
        "dominant": dominant,
        "model_flops": mf,
        "useful_flops_ratio": (mf / f["flops"]) if f["flops"] else 0.0,
        "roofline_fraction": (
            (mf / PEAK_FLOPS_BF16) / bound_s if bound_s else 0.0),
        "params": params,
        "compile_seconds": rec0["compile_seconds"] + rec1["compile_seconds"],
        "suggestion": _suggest(dominant, terms, shape),
    }
    return rec


def _suggest(dominant: str, terms: Dict[str, float], shape) -> str:
    c, m, k = (terms["compute_s"], terms["memory_s"],
               terms["collective_s"])
    if dominant == "compute_s":
        return ("compute-bound: cut remat recompute / cast accumulations "
                "to bf16; beyond that this cell is at the FLOP roofline")
    if dominant == "memory_s":
        if shape.kind == "decode":
            return ("HBM-bound (weight+cache streaming): shrink the KV/state"
                    " working set (wider batch amortizes weights; quantize "
                    "cache; window/local layers skip far blocks)")
        return ("HBM-bound: fuse attention (Pallas flash path), bigger "
                "matmul tiles, avoid f32 round-trips on the residual")
    return ("collective-bound: reshard (move TP off the hot axis), overlap "
            "collectives with compute, int8-compress cross-pod grads")


def save_record(rec: Dict[str, object], out_dir: str = OUT_DIR) -> str:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir,
                        f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return path


# ---------------------------------------------------------------------------
# table generation (EXPERIMENTS.md §Roofline)
# ---------------------------------------------------------------------------

def markdown_table(records: List[Dict]) -> str:
    head = ("| arch | shape | compute s | memory s | collective s | "
            "dominant | useful/HLO | roofline frac |\n"
            "|---|---|---|---|---|---|---|---|\n")
    rows = []
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"])):
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"skip | — | — |")
            continue
        t = r["terms_seconds"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | "
            f"{r['dominant'].replace('_s', '')} | "
            f"{r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2%} |")
    return head + "\n".join(rows) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args(argv)
    archs = ARCH_IDS if args.arch == "all" else (args.arch,)
    shapes = tuple(SHAPES) if args.shape == "all" else (args.shape,)
    recs = []
    for arch in archs:
        for shape in shapes:
            try:
                rec = analyze_cell(arch, shape)
            except Exception as e:
                import traceback
                rec = {"arch": arch, "shape": shape, "mesh": "single",
                       "status": "error", "error": str(e),
                       "traceback": traceback.format_exc()}
                print(f"[FAIL] {arch} x {shape}: {e}")
            save_record(rec, args.out)
            recs.append(rec)
            if rec["status"] == "ok":
                t = rec["terms_seconds"]
                print(f"[ok] {arch} x {shape}: "
                      f"C={t['compute_s']:.2e}s M={t['memory_s']:.2e}s "
                      f"K={t['collective_s']:.2e}s -> {rec['dominant']} "
                      f"(useful {rec['useful_flops_ratio']:.2f}, "
                      f"roofline {rec['roofline_fraction']:.1%})")
    print()
    print(markdown_table(recs))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
