"""Batched serving driver: continuous-batching decode over a small model.

Simulates the production serving loop at CPU scale: a request queue with
Poisson-ish arrivals, a prefill stage that admits requests into free
cache slots, and a batched decode loop (one ``serve_step`` advances every
active slot by one token). Reports throughput + per-request latency.

Usage::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --requests 16 --slots 4 --prompt-len 32 --gen-len 16

``--kg`` switches to the knowledge-graph ingestion loop instead: a
``KGEngine`` session served micro-batches of source extensions
(:mod:`repro.launch.kg_serve` — same session API as the benchmarks).
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced_config
from repro.distributed.sharding import init_params
from repro.models import get_model
from repro.serve.decode import make_prefill, make_serve_step


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else list(argv)
    if "--kg" in argv:   # KG-session serving loop (repro.launch.kg_serve)
        from . import kg_serve
        return kg_serve.main([a for a in argv if a != "--kg"])
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4,
                    help="concurrent decode slots (batch size)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = reduced_config(get_config(args.arch))
    if cfg.family in ("vlm", "encdec"):
        raise SystemExit("serve driver covers token-only families")
    model = get_model(cfg.family)
    rng = np.random.default_rng(args.seed)

    params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
    prefill = jax.jit(make_prefill(cfg))
    step_fn = jax.jit(make_serve_step(cfg))

    B, S = args.slots, args.prompt_len
    prompts = rng.integers(0, cfg.vocab_size, (args.requests, S))
    queue: List[int] = list(range(args.requests))
    done: Dict[int, List[int]] = {}
    t_start = time.perf_counter()

    # slot state: one batched cache; slot i serves request slot_req[i]
    slot_req = [-1] * B
    remaining = [0] * B
    cache = None
    latency: Dict[int, float] = {}
    t_admit: Dict[int, float] = {}
    n_tokens = 0

    def admit_wave() -> Optional[jax.Array]:
        """Fill all free slots with queued prompts, one batched prefill."""
        nonlocal cache
        free = [i for i in range(B) if slot_req[i] < 0]
        if not free or not queue:
            return None
        take = [queue.pop(0) for _ in free[:len(queue)]]
        batch_tokens = np.stack([prompts[r] for r in take] +
                                [prompts[take[-1]]] * (len(free) - len(take)))
        logits, new_cache = prefill(
            params, {"tokens": jnp.asarray(batch_tokens, jnp.int32)})
        # pad caches to max_len once (prefill caches are prompt-length)
        def grow(x):
            if x.ndim >= 4 and x.shape[-2] == S:
                pad = [(0, 0)] * x.ndim
                pad[-2] = (0, args.gen_len)
                return jnp.pad(x, pad)
            return x
        new_cache = jax.tree_util.tree_map(grow, new_cache)
        if cache is None:
            cache = new_cache
        else:  # merge admitted slots into the live cache
            sel = jnp.zeros((B,), bool).at[jnp.asarray(free)].set(True)
            def mix(old, new):
                if old.ndim == 0:
                    return old
                b_axis = 0 if old.shape[0] == B else 1
                shape = [1] * old.ndim
                shape[b_axis] = B
                return jnp.where(sel.reshape(shape), new, old)
            cache = jax.tree_util.tree_map(mix, cache, new_cache)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        now = time.perf_counter()
        for j, slot in enumerate(free[:len(take)]):
            slot_req[slot] = take[j]
            remaining[slot] = args.gen_len
            done[take[j]] = []
            t_admit[take[j]] = now
        return tok

    tok = admit_wave()
    while any(r >= 0 for r in slot_req):
        logits, cache = step_fn(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        toks = np.asarray(tok)[:, 0]
        now = time.perf_counter()
        for i in range(B):
            r = slot_req[i]
            if r < 0:
                continue
            done[r].append(int(toks[i]))
            n_tokens += 1
            remaining[i] -= 1
            if remaining[i] == 0:
                latency[r] = now - t_admit[r]
                slot_req[i] = -1
        if queue and any(r < 0 for r in slot_req):
            new_tok = admit_wave()
            if new_tok is not None:
                sel = jnp.asarray([remaining[i] > 0 and slot_req[i] >= 0
                                   for i in range(B)])
                tok = jnp.where(sel[:, None], tok, new_tok)

    dt = time.perf_counter() - t_start
    lat = sorted(latency.values())
    print(f"served {len(done)} requests / {n_tokens} tokens in {dt:.2f}s "
          f"({n_tokens / dt:.1f} tok/s)")
    print(f"latency p50={lat[len(lat)//2]*1e3:.0f}ms "
          f"p99={lat[int(len(lat)*0.99)]*1e3:.0f}ms")
    assert all(len(v) == args.gen_len for v in done.values())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
