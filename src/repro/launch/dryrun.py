import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first init, and the dry-run needs 512 placeholder host devices
# to build the production meshes. Never set this in conftest/pyproject —
# smoke tests and benches must keep seeing one device.

"""Multi-pod AOT dry-run: ``.lower().compile()`` the full matrix.

For every (architecture x supported input shape x mesh) cell this script
builds abstract sharded inputs (:mod:`repro.launch.specs`), lowers the
appropriate step function (train_step / prefill / serve_step), compiles
it for the production mesh, and records:

* ``memory_analysis()``  — per-device argument/output/temp bytes (fits?)
* ``cost_analysis()``    — HLO FLOPs + HBM bytes for §Roofline
* collective operand bytes by opcode (parsed from the compiled module)

Artifacts land in ``experiments/dryrun/<arch>__<shape>__<mesh>.json`` and
are consumed by ``launch/roofline.py`` and ``benchmarks/roofline.py``.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun             # full matrix
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
        --shape train_4k --mesh single --print-hlo
"""
import argparse
import json
import time
import traceback
from typing import Dict, List, Optional


from repro.configs.base import ARCH_IDS, SHAPES, get_config
from repro.launch import mesh as mesh_lib
from repro.launch.hlo_analysis import collective_bytes_scaled
from repro.launch.specs import build_cell, lower_cell, model_param_counts
from repro.models import auto_rules

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

MESHES = ("single", "multi")


def _mesh_for(name: str):
    return mesh_lib.make_production_mesh(multi_pod=(name == "multi"))


def run_cell(arch: str, shape_name: str, mesh_name: str,
             *, print_hlo: bool = False, keep_hlo: bool = False,
             rule_overrides=(), unroll: bool = True,
             cfg_overrides: Optional[Dict[str, object]] = None
             ) -> Dict[str, object]:
    """One dry-run cell. ``unroll=True`` (default) unrolls layer scans so
    ``cost_analysis`` counts every layer (XLA tallies while bodies once);
    ``unroll=False`` compiles the production scan-over-layers program."""
    import dataclasses as _dc
    cfg = get_config(arch)
    if unroll:
        cfg = _dc.replace(cfg, unroll_layers=True)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    rec: Dict[str, object] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "status": "skip", "unrolled": unroll,
    }
    if not cfg.shape_supported(shape):
        rec["reason"] = ("no sub-quadratic path"
                         if shape_name == "long_500k" else "no decode path")
        return rec
    mesh = _mesh_for(mesh_name)
    rules = auto_rules(cfg, mesh, shape)
    if rule_overrides:
        rules = rules.with_overrides(*rule_overrides)
    t0 = time.perf_counter()
    cell = build_cell(cfg, shape, mesh, rules)
    lowered = lower_cell(cell)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    text = compiled.as_text()
    coll = collective_bytes_scaled(text)

    rec.update({
        "status": "ok",
        "n_devices": mesh.devices.size,
        "n_microbatches": cell.n_microbatches,
        "lower_seconds": round(t1 - t0, 3),
        "compile_seconds": round(t2 - t1, 3),
        "memory": {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
            if hasattr(mem, k)
        },
        "cost": {k: float(v) for k, v in dict(cost or {}).items()
                 if k in ("flops", "transcendentals", "bytes accessed",
                          "optimal_seconds")},
        "collectives": coll.to_dict(),
        "params": model_param_counts(cfg),
    })
    if keep_hlo:
        rec["hlo_text"] = text
    if print_hlo:
        print(text[:20000])
    return rec


def save_record(rec: Dict[str, object], out_dir: str = OUT_DIR) -> str:
    os.makedirs(out_dir, exist_ok=True)
    rec = {k: v for k, v in rec.items() if k != "hlo_text"}
    path = os.path.join(
        out_dir, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return path


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=("single", "multi",
                                                       "both"))
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--print-hlo", action="store_true")
    ap.add_argument("--stop-on-error", action="store_true")
    ap.add_argument("--production-scan", action="store_true",
                    help="compile the rolled scan-over-layers program "
                         "(production HLO) instead of the cost-accurate "
                         "unrolled variant")
    args = ap.parse_args(argv)

    archs = ARCH_IDS if args.arch == "all" else (args.arch,)
    shapes = tuple(SHAPES) if args.shape == "all" else (args.shape,)
    meshes = MESHES if args.mesh == "both" else (args.mesh,)
    if args.production_scan:           # keep unrolled + scan records apart
        args.out = args.out.rstrip("/") + "_scan"

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                tag = f"{arch} x {shape} x {mesh_name}"
                try:
                    rec = run_cell(arch, shape, mesh_name,
                                   print_hlo=args.print_hlo,
                                   unroll=not args.production_scan)
                except Exception as e:   # record and continue
                    failures += 1
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "error": str(e),
                           "traceback": traceback.format_exc()}
                    print(f"[FAIL] {tag}: {e}")
                    if args.stop_on_error:
                        save_record(rec, args.out)
                        raise
                save_record(rec, args.out)
                if rec["status"] == "ok":
                    mem = rec["memory"]
                    per_dev = (mem.get("argument_size_in_bytes", 0)
                               + mem.get("temp_size_in_bytes", 0))
                    print(f"[ok]   {tag}: args+temp/dev = "
                          f"{per_dev / 2**30:.2f} GiB, "
                          f"flops/dev = {rec['cost'].get('flops', 0):.3e}, "
                          f"coll = {rec['collectives']['total_bytes']/2**20:.1f}"
                          f" MiB ({rec['compile_seconds']:.0f}s compile)")
                elif rec["status"] == "skip":
                    print(f"[skip] {tag}: {rec['reason']}")
    print(f"dry-run complete; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
