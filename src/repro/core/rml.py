"""Parser/serializer for the RML subset MapSDI consumes.

The JSON form mirrors RML structure (rml:logicalSource, rr:subjectMap with
rr:template + rr:class, rr:predicateObjectMap with rml:reference /
rr:template / rr:constant objects, and rr:joinCondition +
rr:parentTriplesMap), e.g.::

    {
      "name": "TripleMap1",
      "source": "genes",
      "subject": {"template": "http://project-iasis.eu/Gene/{ENSG}",
                  "class": "iasis:Gene"},
      "poms": [
        {"predicate": "iasis:geneName", "object": {"reference": "SYMBOL"}},
        {"predicate": "iasis:locatedIn",
         "object": {"parentTriplesMap": "TripleMap2",
                    "joinCondition": {"child": "Genename",
                                      "parent": "Genename"}}}
      ]
    }

``parse_dis`` builds a full :class:`DIS` from ``{"sources": ..., "maps":
...}`` where each source is ``{"attrs": [...], "records": [...]}``.
"""
from __future__ import annotations

import json
import re
from typing import Dict, List, Mapping, Optional, Sequence

from repro.relalg import Table, Vocab

from .schema import (DIS, PredicateObjectMap, RefObjectMap, Selection,
                     TermMap, TripleMap)

_TEMPLATE_VAR = re.compile(r"\{([^{}]+)\}")


def parse_term_map(obj: Mapping) -> TermMap:
    if "reference" in obj:
        return TermMap(kind="reference", attr=obj["reference"])
    if "template" in obj:
        tmpl = obj["template"]
        vars_ = _TEMPLATE_VAR.findall(tmpl)
        if len(vars_) != 1:
            raise ValueError(
                f"only single-placeholder templates supported, got {tmpl!r}")
        canonical = _TEMPLATE_VAR.sub("{}", tmpl)
        return TermMap(kind="template", attr=vars_[0], template=canonical)
    if "constant" in obj:
        return TermMap(kind="constant", constant=obj["constant"])
    raise ValueError(f"cannot parse term map {obj!r}")


def parse_selection(obj: Mapping) -> Selection:
    if "eq" in obj:
        return Selection(attr=obj["attr"], op="eq", value=obj["eq"])
    if "neq" in obj:
        return Selection(attr=obj["attr"], op="neq", value=obj["neq"])
    if obj.get("notnull"):
        return Selection(attr=obj["attr"], op="notnull")
    raise ValueError(f"cannot parse selection {obj!r}")


def parse_triple_map(obj: Mapping) -> TripleMap:
    subj_obj = dict(obj["subject"])
    subject_class = subj_obj.pop("class", None)
    subject = parse_term_map(subj_obj)
    poms = []
    for pom in obj.get("poms", ()):
        if "parentTriplesMap" in pom.get("object", {}):
            jc = pom["object"]["joinCondition"]
            o = RefObjectMap(parent_map=pom["object"]["parentTriplesMap"],
                             child_attr=jc["child"], parent_attr=jc["parent"])
        else:
            o = parse_term_map(pom["object"])
        poms.append(PredicateObjectMap(predicate=pom["predicate"], object=o))
    selections = tuple(parse_selection(s) for s in obj.get("selections", ()))
    return TripleMap(name=obj["name"], source=obj["source"], subject=subject,
                     subject_class=subject_class, poms=tuple(poms),
                     selections=selections)


def parse_dis(obj: Mapping, vocab: Optional[Vocab] = None,
              capacity_slack: float = 1.0) -> DIS:
    """Build a DIS from the JSON form (sources with inline records)."""
    vocab = vocab or Vocab()
    sources: Dict[str, Table] = {}
    for name, src in obj["sources"].items():
        attrs = list(src["attrs"])
        records = src.get("records", [])
        cap = max(1, int(len(records) * capacity_slack))
        sources[name] = Table.from_records(records, attrs, vocab, cap)
    maps = [parse_triple_map(m) for m in obj["maps"]]
    null_code = vocab.intern(None) if any(
        rec.get(a) is None for src in obj["sources"].values()
        for rec in src.get("records", []) for a in src["attrs"]) else None
    dis = DIS(sources=sources, maps=maps, vocab=vocab, null_code=null_code)
    # pre-register templates and σ comparison codes deterministically
    for m in maps:
        if m.subject.kind == "template":
            dis.template_id(m.subject.template)
        for p in m.poms:
            if isinstance(p.object, TermMap) and p.object.kind == "template":
                dis.template_id(p.object.template)
        for sel in m.selections:
            if sel.op in ("eq", "neq"):
                vocab.intern(sel.value)
    return dis


def load_dis(path: str, **kw) -> DIS:
    with open(path) as f:
        return parse_dis(json.load(f), **kw)


# -- serialization (triple maps only; sources are data) ----------------------

def term_map_to_json(t: TermMap) -> Dict:
    if t.kind == "reference":
        return {"reference": t.attr}
    if t.kind == "template":
        return {"template": t.template.replace("{}", "{" + t.attr + "}")}
    return {"constant": t.constant}


def triple_map_to_json(m: TripleMap) -> Dict:
    subj = term_map_to_json(m.subject)
    if m.subject_class:
        subj["class"] = m.subject_class
    poms: List[Dict] = []
    for p in m.poms:
        if isinstance(p.object, RefObjectMap):
            obj = {"parentTriplesMap": p.object.parent_map,
                   "joinCondition": {"child": p.object.child_attr,
                                     "parent": p.object.parent_attr}}
        else:
            obj = term_map_to_json(p.object)
        poms.append({"predicate": p.predicate, "object": obj})
    out = {"name": m.name, "source": m.source, "subject": subj, "poms": poms}
    if m.selections:
        out["selections"] = [
            {"attr": s.attr, "notnull": True} if s.op == "notnull"
            else {"attr": s.attr, s.op: s.value} for s in m.selections]
    return out


def dump_maps(maps: Sequence[TripleMap]) -> str:
    return json.dumps([triple_map_to_json(m) for m in maps], indent=2)
