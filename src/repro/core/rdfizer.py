"""The semantification engine: evaluates triple maps into device triples.

``RDFizer`` compiles a DIS into a jit-compatible closure
``sources -> (kg_triples, raw_count)``. Two engine modes mirror the paper's
two studied engines:

* ``"rmlmapper"`` — blind generation: every map emits every triple
  (duplicates included); duplicate elimination happens once at the sink.
* ``"sdm"`` — duplicate-aware: each map's output is deduplicated as it is
  produced (the SDM-RDFizer strategy), so the sink-level dedup sees far
  fewer rows.

A triple is a row of the 5-column table ``(s_t, s_v, p, o_t, o_v)`` — see
:mod:`repro.core.schema` for term encoding.

Both engines' duplicate elimination (the per-map SDM dedup and the sink δ)
goes through the shared relalg strategies: ``dedup="hash"`` (default) runs
the rowhash-based single-key-sort path, ``dedup="lex"`` the K-key
lexicographic path; results are bit-identical.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.relalg import (PAD_ID, Table, distinct, equi_join, project_as)
from repro.relalg.ops import compact

from .schema import (DIS, RDF_TYPE, RefObjectMap, TMPL_CONSTANT, TermMap,
                     TRIPLE_ATTRS, TripleMap)

Engine = str  # 'rmlmapper' | 'sdm'


def _round_cap(n: int, mult: int = 8) -> int:
    return max(mult, ((int(n) + mult - 1) // mult) * mult)


def plan_join_caps(dis: DIS) -> Dict[Tuple[str, int], int]:
    """Exact output capacity per (map, pom_index) join — host-side planning,
    the analogue of cardinality estimation in a query optimizer."""
    caps: Dict[Tuple[str, int], int] = {}
    for tm in dis.maps:
        child = dis.sources[tm.source]
        for i, pom in enumerate(tm.poms):
            if not isinstance(pom.object, RefObjectMap):
                continue
            parent_tm = dis.map_by_name(pom.object.parent_map)
            parent = dis.sources[parent_tm.source]
            c = np.asarray(child.column(pom.object.child_attr))[
                :int(child.count)]
            p = np.asarray(parent.column(pom.object.parent_attr))[
                :int(parent.count)]
            vals, counts = np.unique(p, return_counts=True)
            if len(vals) == 0 or len(c) == 0:   # empty side => empty join
                caps[(tm.name, i)] = _round_cap(0)
                continue
            idx = np.searchsorted(vals, c)
            idx_c = np.clip(idx, 0, len(vals) - 1)
            match = vals[idx_c] == c
            total = int(counts[idx_c][match].sum())
            caps[(tm.name, i)] = _round_cap(total)
    return caps


class RDFizer:
    """Compiled evaluator for ``RDFize(DIS)``. Structure (maps, templates,
    capacities) is static; source *extensions* are the runtime argument, so
    the closure can be jitted and re-run as sources change."""

    def __init__(self, dis: DIS, engine: Engine = "rmlmapper",
                 join_caps: Optional[Dict[Tuple[str, int], int]] = None,
                 dedup: Optional[str] = None):
        if engine not in ("rmlmapper", "sdm"):
            raise ValueError(f"unknown engine {engine!r}")
        self.dis = dis
        self.engine = engine
        self.dedup = dedup  # δ strategy: 'lex' | 'hash' | None (default)
        self.join_caps = plan_join_caps(dis) if join_caps is None else join_caps
        self.rdf_type_code = dis.vocab.intern(RDF_TYPE)
        # pre-intern every constant so tracing is side-effect free
        self._pred = {p.predicate: dis.vocab.intern(p.predicate)
                      for m in dis.maps for p in m.poms}
        self._class = {m.subject_class: dis.vocab.intern(m.subject_class)
                       for m in dis.maps if m.subject_class}
        self._const = {p.object.constant: dis.vocab.intern(p.object.constant)
                       for m in dis.maps for p in m.poms
                       if isinstance(p.object, TermMap)
                       and p.object.kind == "constant"}
        self._subject_tmpl = {m.name: self._term_tmpl(m.subject)
                              for m in dis.maps}

    # -- static helpers ------------------------------------------------------
    def _term_tmpl(self, t: TermMap) -> int:
        from .schema import TMPL_LITERAL
        if t.kind == "reference":
            return TMPL_LITERAL
        if t.kind == "constant":
            return TMPL_CONSTANT
        return self.dis.template_id(t.template)

    def _null_ok(self, col: jax.Array) -> jax.Array:
        if self.dis.null_code is None:
            return jnp.ones_like(col, dtype=bool)
        return col != jnp.int32(self.dis.null_code)

    # -- evaluation ----------------------------------------------------------
    def _term_cols(self, t: TermMap, table: Table
                   ) -> Tuple[int, jax.Array, jax.Array]:
        """(tmpl_id, value column, validity) for a non-join term map."""
        cap = table.capacity
        if t.kind == "constant":
            code = self._const.get(t.constant)
            if code is None:
                code = self.dis.vocab.intern(t.constant)
            col = jnp.full((cap,), jnp.int32(code))
            return TMPL_CONSTANT, col, jnp.ones((cap,), dtype=bool)
        col = table.column(t.attr)
        return self._term_tmpl(t), col, self._null_ok(col)

    def _block(self, s_t: int, s_v: jax.Array, p: int, o_t: int,
               o_v: jax.Array, mask: jax.Array) -> Tuple[jax.Array, jax.Array]:
        cap = s_v.shape[0]
        data = jnp.stack([
            jnp.full((cap,), jnp.int32(s_t)), s_v.astype(jnp.int32),
            jnp.full((cap,), jnp.int32(p)),
            jnp.full((cap,), jnp.int32(o_t)), o_v.astype(jnp.int32),
        ], axis=1)
        return data, mask

    def eval_map(self, tm: TripleMap, sources: Dict[str, Table]) -> Table:
        """All triples produced by one triple map (bag semantics)."""
        table = sources[tm.source]
        s_t = self._subject_tmpl[tm.name]
        s_v = table.column(tm.subject.attr) if tm.subject.attr else None
        if s_v is None:  # constant subject (legal but unusual)
            code = self.dis.vocab.intern(tm.subject.constant)
            s_v = jnp.full((table.capacity,), jnp.int32(code))
        s_ok = table.valid_mask & self._null_ok(s_v)

        blocks: List[Tuple[jax.Array, jax.Array]] = []
        if tm.subject_class:
            cls = self._class[tm.subject_class]
            blocks.append(self._block(
                s_t, s_v, self.rdf_type_code, TMPL_CONSTANT,
                jnp.full((table.capacity,), jnp.int32(cls)), s_ok))

        for i, pom in enumerate(tm.poms):
            p_code = self._pred[pom.predicate]
            if isinstance(pom.object, RefObjectMap):
                blocks.append(self._join_block(tm, i, pom, p_code, sources))
            else:
                o_t, o_v, o_ok = self._term_cols(pom.object, table)
                blocks.append(self._block(s_t, s_v, p_code, o_t, o_v,
                                          s_ok & o_ok))

        if not blocks:  # a map with neither class nor POMs emits nothing
            return Table.empty(TRIPLE_ATTRS, 8)
        data = jnp.concatenate([b[0] for b in blocks], axis=0)
        mask = jnp.concatenate([b[1] for b in blocks], axis=0)
        data, count = compact(data, mask)
        return Table(data=data, count=count, attrs=TRIPLE_ATTRS)

    def _join_block(self, tm: TripleMap, pom_idx: int, pom, p_code: int,
                    sources: Dict[str, Table]):
        rom: RefObjectMap = pom.object
        parent_tm = self.dis.map_by_name(rom.parent_map)
        child = sources[tm.source]
        parent = sources[parent_tm.source]
        # pull only what the join needs from the parent, under reserved names
        parent_proj = project_as(parent, [
            (parent_tm.subject.attr, "__ps"), (rom.parent_attr, "__pk")])
        cap = self.join_caps.get((tm.name, pom_idx),
                                 _round_cap(child.capacity * 4))
        joined, _total = equi_join(child, parent_proj, rom.child_attr,
                                   "__pk", out_capacity=cap)
        s_v = joined.column(tm.subject.attr)
        o_v = joined.column("__ps")
        s_t = self._subject_tmpl[tm.name]
        o_t = self._subject_tmpl[parent_tm.name]
        mask = joined.valid_mask & self._null_ok(s_v) & self._null_ok(o_v)
        return self._block(s_t, s_v, p_code, o_t, o_v, mask)

    def __call__(self, sources: Optional[Dict[str, Table]] = None
                 ) -> Tuple[Table, jax.Array]:
        """Evaluate all maps; returns (deduplicated KG, raw triple count).

        ``raw`` counts the triples materialized *before* the sink dedup —
        the quantity the paper's motivating example blames (2,049,442,714
        raw vs 102,549 distinct).
        """
        sources = self.dis.sources if sources is None else sources
        per_map = [self.eval_map(tm, sources) for tm in self.dis.maps]
        if self.engine == "sdm":
            per_map = [distinct(t, dedup=self.dedup) for t in per_map]
        raw = jnp.sum(jnp.stack([t.count for t in per_map]))
        data = jnp.concatenate([t.data for t in per_map], axis=0)
        mask = jnp.concatenate([t.valid_mask for t in per_map])
        data, count = compact(data, mask)
        kg = distinct(Table(data=data, count=count, attrs=TRIPLE_ATTRS),
                      dedup=self.dedup)
        return kg, raw


def rdfize(dis: DIS, engine: Engine = "rmlmapper",
           dedup: Optional[str] = None) -> Tuple[Table, int]:
    """Eager convenience wrapper: ``RDFize(DIS)`` -> (KG, raw count)."""
    kg, raw = RDFizer(dis, engine, dedup=dedup)()
    return kg, int(raw)


# -- host-side sink (strings only at the edge) -------------------------------

def triples_to_ntriples(kg: Table, dis: DIS) -> List[str]:
    """Decode device triples to N-Triples-ish text lines (host sink)."""
    inv_tmpl = {v: k for k, v in dis.templates.items()}
    out = []
    for s_t, s_v, p, o_t, o_v in kg.to_codes():
        out.append(f"{_term(inv_tmpl, dis, s_t, s_v)} "
                   f"<{dis.vocab.decode(p)}> "
                   f"{_term(inv_tmpl, dis, o_t, o_v)} .")
    return out


def _term(inv_tmpl, dis: DIS, t: int, v: int) -> str:
    from .schema import TMPL_CONSTANT as TC, TMPL_LITERAL as TL
    val = dis.vocab.decode(v)
    if t == TL:
        return f'"{val}"'
    if t == TC:
        return f"<{val}>"
    return f"<{inv_tmpl[int(t)].format(val)}>"
