"""The semantification engine: evaluates triple maps into device triples.

``RDFizer`` compiles a DIS into a jit-compatible closure
``sources -> (kg_triples, raw_count)``. Two engine modes mirror the paper's
two studied engines:

* ``"rmlmapper"`` — blind generation: every map emits every triple
  (duplicates included); duplicate elimination happens once at the sink.
* ``"sdm"`` — duplicate-aware: each map's output is deduplicated as it is
  produced (the SDM-RDFizer strategy), so the sink-level dedup sees far
  fewer rows.

A triple is a row of the 5-column table ``(s_t, s_v, p, o_t, o_v)`` — see
:mod:`repro.core.schema` for term encoding.

The interior is the plan executor (:mod:`repro.plan.compile`): the DIS is
lowered to the logical IR and compiled to ONE jitted closure; the RDFizer
itself only provides the ``EmitTriples`` semantics (term columns, null and
σ masks, block assembly). Tracing is side-effect free by construction —
``__init__`` pre-interns every constant a trace could need and the lookup
helpers *raise* instead of interning.

Both engines' duplicate elimination (the per-map SDM dedup and the sink δ)
goes through the shared relalg strategies: ``dedup="hash"`` (default) runs
the rowhash-based single-key-sort path, ``dedup="lex"`` the K-key
lexicographic path; results are bit-identical.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.relalg import Table, round_cap
from repro.relalg.guard import host_get, host_int
from repro.relalg.ops import compact

from .schema import (DIS, RDF_TYPE, RefObjectMap, TMPL_CONSTANT, TermMap,
                     TRIPLE_ATTRS, TripleMap)

Engine = str  # 'rmlmapper' | 'sdm'


def plan_join_caps(dis: DIS) -> Dict[Tuple[str, int], int]:
    """Exact output capacity per (map, pom_index) join — host-side planning,
    the analogue of cardinality estimation in a query optimizer. (The plan
    subsystem's :func:`repro.plan.annotate.annotate` generalizes this to a
    capacity for every IR node; the counting kernel is shared.)"""
    from repro.plan.annotate import join_match_total
    caps: Dict[Tuple[str, int], int] = {}
    for tm in dis.maps:
        child = dis.sources[tm.source]
        for i, pom in enumerate(tm.poms):
            if not isinstance(pom.object, RefObjectMap):
                continue
            parent_tm = dis.map_by_name(pom.object.parent_map)
            parent = dis.sources[parent_tm.source]
            c = host_get(child.column(pom.object.child_attr))[
                :host_int(child.count)]
            p = host_get(parent.column(pom.object.parent_attr))[
                :host_int(parent.count)]
            caps[(tm.name, i)] = round_cap(join_match_total(c, p))
    return caps


class RDFizer:
    """Compiled evaluator for ``RDFize(DIS)``. Structure (maps, templates,
    capacities) is static; source *extensions* are the runtime argument, so
    the closure can be jitted and re-run as sources change."""

    def __init__(self, dis: DIS, engine: Engine = "rmlmapper",
                 join_caps: Optional[Dict[Tuple[str, int], int]] = None,
                 dedup: Optional[str] = None):
        if engine not in ("rmlmapper", "sdm"):
            raise ValueError(f"unknown engine {engine!r}")
        self.dis = dis
        self.engine = engine
        self.dedup = dedup  # δ strategy: 'lex' | 'hash' | None (default)
        self.join_caps = plan_join_caps(dis) if join_caps is None else join_caps
        self.rdf_type_code = dis.vocab.intern(RDF_TYPE)
        # pre-intern EVERY constant a trace could touch, so tracing is
        # side-effect free (the lookups below raise instead of interning)
        self._pred = {p.predicate: dis.vocab.intern(p.predicate)
                      for m in dis.maps for p in m.poms}
        self._class = {m.subject_class: dis.vocab.intern(m.subject_class)
                       for m in dis.maps if m.subject_class}
        self._const = {p.object.constant: dis.vocab.intern(p.object.constant)
                       for m in dis.maps for p in m.poms
                       if isinstance(p.object, TermMap)
                       and p.object.kind == "constant"}
        self._subj_const = {m.subject.constant:
                            dis.vocab.intern(m.subject.constant)
                            for m in dis.maps if m.subject.kind == "constant"}
        self._sel = {sel.value: dis.vocab.intern(sel.value)
                     for m in dis.maps for sel in m.selections
                     if sel.op in ("eq", "neq")}
        self._subject_tmpl = {m.name: self._term_tmpl(m.subject)
                              for m in dis.maps}
        # pre-register every object template id too — template_id mutates
        # dis.templates on a new template, which must not happen mid-trace
        self._tmpl_ids = {t: self._term_tmpl(t) for m in dis.maps
                          for t in [m.subject] + [p.object for p in m.poms
                                                  if isinstance(p.object,
                                                                TermMap)]}
        self._plan_caps = None  # (plan, node caps), built lazily
        self._compiled = None   # jitted sources -> (kg, raw), built lazily

    # -- static helpers ------------------------------------------------------
    def _term_tmpl(self, t: TermMap) -> int:
        from .schema import TMPL_LITERAL
        if t.kind == "reference":
            return TMPL_LITERAL
        if t.kind == "constant":
            return TMPL_CONSTANT
        return self.dis.template_id(t.template)

    def _code(self, table: Dict, value, what: str) -> int:
        code = table.get(value)
        if code is None:
            raise RuntimeError(
                f"{what} {value!r} was not pre-interned; tracing must be "
                "side-effect free — register it on the DIS before building "
                "the RDFizer")
        return code

    def _null_ok(self, col: jax.Array) -> jax.Array:
        if self.dis.null_code is None:
            return jnp.ones_like(col, dtype=bool)
        return col != jnp.int32(self.dis.null_code)

    # -- evaluation ----------------------------------------------------------
    def _term_cols(self, t: TermMap, table: Table
                   ) -> Tuple[int, jax.Array, jax.Array]:
        """(tmpl_id, value column, validity) for a non-join term map."""
        cap = table.capacity
        if t.kind == "constant":
            code = self._code(self._const, t.constant, "constant")
            col = jnp.full((cap,), jnp.int32(code))
            return TMPL_CONSTANT, col, jnp.ones((cap,), dtype=bool)
        col = table.column(t.attr)
        tmpl = self._tmpl_ids.get(t)
        if tmpl is None:
            raise RuntimeError(
                f"term map {t!r} was not pre-registered; tracing must be "
                "side-effect free — build the RDFizer over a DIS that "
                "contains this map")
        return tmpl, col, self._null_ok(col)

    def _selection_mask(self, tm: TripleMap, table: Table) -> jax.Array:
        """σ mask of the map's explicit selections over ``table`` (which may
        be the source relation or a join output carrying its attrs)."""
        mask = jnp.ones((table.capacity,), dtype=bool)
        for sel in tm.selections:
            col = table.column(sel.attr)
            if sel.op == "notnull":
                if self.dis.null_code is not None:
                    mask &= col != jnp.int32(self.dis.null_code)
            elif sel.op == "eq":
                mask &= col == jnp.int32(self._code(self._sel, sel.value,
                                                    "selection value"))
            else:
                mask &= col != jnp.int32(self._code(self._sel, sel.value,
                                                    "selection value"))
        return mask

    def _block(self, s_t: int, s_v: jax.Array, p: int, o_t: int,
               o_v: jax.Array, mask: jax.Array) -> Tuple[jax.Array, jax.Array]:
        cap = s_v.shape[0]
        data = jnp.stack([
            jnp.full((cap,), jnp.int32(s_t)), s_v.astype(jnp.int32),
            jnp.full((cap,), jnp.int32(p)),
            jnp.full((cap,), jnp.int32(o_t)), o_v.astype(jnp.int32),
        ], axis=1)
        return data, mask

    def emit_triples(self, tm: TripleMap, table: Table,
                     joins: Dict[int, Table]) -> Table:
        """All triples of one map (bag semantics). ``table`` is the map's
        relation; ``joins[i]`` is the pre-joined table for join POM ``i``
        (child attrs + ``__ps`` = parent subject)."""
        s_t = self._subject_tmpl[tm.name]
        if tm.subject.attr:
            s_v = table.column(tm.subject.attr)
        else:  # constant subject (legal but unusual)
            code = self._code(self._subj_const, tm.subject.constant,
                              "subject constant")
            s_v = jnp.full((table.capacity,), jnp.int32(code))
        s_ok = table.valid_mask & self._null_ok(s_v) & \
            self._selection_mask(tm, table)

        blocks: List[Tuple[jax.Array, jax.Array]] = []
        if tm.subject_class:
            cls = self._class[tm.subject_class]
            blocks.append(self._block(
                s_t, s_v, self.rdf_type_code, TMPL_CONSTANT,
                jnp.full((table.capacity,), jnp.int32(cls)), s_ok))

        for i, pom in enumerate(tm.poms):
            p_code = self._pred[pom.predicate]
            if isinstance(pom.object, RefObjectMap):
                joined = joins[i]
                parent_tm = self.dis.map_by_name(pom.object.parent_map)
                if tm.subject.attr:
                    s_vj = joined.column(tm.subject.attr)
                else:  # constant child subject
                    s_vj = jnp.full((joined.capacity,), jnp.int32(self._code(
                        self._subj_const, tm.subject.constant,
                        "subject constant")))
                if parent_tm.subject.attr:
                    o_v = joined.column("__ps")
                else:  # constant parent subject (not carried by the ⋈)
                    o_v = jnp.full((joined.capacity,), jnp.int32(self._code(
                        self._subj_const, parent_tm.subject.constant,
                        "subject constant")))
                mask = joined.valid_mask & self._null_ok(s_vj) & \
                    self._null_ok(o_v) & self._selection_mask(tm, joined)
                blocks.append(self._block(
                    s_t, s_vj, p_code, self._subject_tmpl[parent_tm.name],
                    o_v, mask))
            else:
                o_t, o_v, o_ok = self._term_cols(pom.object, table)
                blocks.append(self._block(s_t, s_v, p_code, o_t, o_v,
                                          s_ok & o_ok))

        if not blocks:  # a map with neither class nor POMs emits nothing
            return Table.empty(TRIPLE_ATTRS, 8)
        data = jnp.concatenate([b[0] for b in blocks], axis=0)
        mask = jnp.concatenate([b[1] for b in blocks], axis=0)
        data, count = compact(data, mask)
        return Table(data=data, count=count, attrs=TRIPLE_ATTRS)

    # -- plan construction ---------------------------------------------------
    def _build_plan(self):
        if self._plan_caps is None:
            from repro.plan import lower
            plan = lower(self.dis)
            caps = {}
            for tm in plan.maps:
                for i, pom in enumerate(tm.poms):
                    if isinstance(pom.object, RefObjectMap):
                        node = plan.join_node(tm, i)
                        cap = self.join_caps.get((tm.name, i))
                        if cap is not None:
                            caps[node] = cap
            self._plan_caps = (plan, caps)
        return self._plan_caps

    def eval_map(self, tm: TripleMap, sources: Dict[str, Table]) -> Table:
        """All triples produced by one triple map (bag semantics)."""
        from repro.plan.compile import execute_node
        plan, caps = self._build_plan()
        return execute_node(plan.emit_node(tm), sources, {}, emitter=self,
                            dedup=self.dedup, caps=caps)

    def __call__(self, sources: Optional[Dict[str, Table]] = None
                 ) -> Tuple[Table, jax.Array]:
        """Evaluate all maps; returns (deduplicated KG, raw triple count).

        ``raw`` counts the triples materialized *before* the sink dedup —
        the quantity the paper's motivating example blames (2,049,442,714
        raw vs 102,549 distinct).
        """
        from repro.plan.compile import compile_plan
        if self._compiled is None:
            plan, caps = self._build_plan()
            self._compiled = compile_plan(plan, self, engine=self.engine,
                                          dedup=self.dedup, caps=caps)
        sources = self.dis.sources if sources is None else sources
        return self._compiled(sources)


def rdfize(dis: DIS, engine: Engine = "rmlmapper",
           dedup: Optional[str] = None) -> Tuple[Table, int]:
    """DEPRECATED eager wrapper: ``RDFize(DIS)`` -> (KG, raw count).

    .. deprecated:: removal target — goes away together with the
       ``repro.core.pipeline`` shims (``make_planned_fn``,
       ``make_mapsdi_fn``) once the ``repro.api`` surface (``KGEngine`` +
       ``EngineConfig``) has been the documented entry point for two
       releases.

    Delegates to a :class:`repro.api.KGEngine` session with
    ``optimize=False`` (blind evaluation of the un-rewritten rules — the
    semantics ``raw`` has always measured), so repeated rdfize calls over
    structurally-identical DISes share one cached closure. Use
    ``KGEngine(dis, config=EngineConfig(engine=..., dedup=...,
    optimize=False))`` directly for session state (ingestion, stats)."""
    from repro.api import EngineConfig, KGEngine
    from .pipeline import _warn_once
    _warn_once("rdfize",
               "KGEngine(dis, config=EngineConfig(optimize=False)).run()")
    config = EngineConfig(engine=engine, dedup=dedup, optimize=False)
    kg, raw = KGEngine(dis, config=config).run()
    return kg, host_int(raw)


# -- host-side sink (strings only at the edge) -------------------------------

def triples_to_ntriples(kg: Table, dis: DIS) -> List[str]:
    """Decode device triples to N-Triples-ish text lines (host sink)."""
    inv_tmpl = {v: k for k, v in dis.templates.items()}
    out = []
    for s_t, s_v, p, o_t, o_v in kg.to_codes():
        out.append(f"{_term(inv_tmpl, dis, s_t, s_v)} "
                   f"<{dis.vocab.decode(p)}> "
                   f"{_term(inv_tmpl, dis, o_t, o_v)} .")
    return out


def _term(inv_tmpl, dis: DIS, t: int, v: int) -> str:
    from .schema import TMPL_CONSTANT as TC, TMPL_LITERAL as TL
    val = dis.vocab.decode(v)
    if t == TL:
        return f'"{val}"'
    if t == TC:
        return f"<{val}>"
    return f"<{inv_tmpl[int(t)].format(val)}>"
