"""Static analysis of mapping rules — the knowledge MapSDI extracts.

The paper's framework "extracts from the mapping rules information related to
the attributes that are used from each file" and detects rules that can be
merged. This module computes:

* :func:`referenced_attrs` — for every triple map, the attributes its
  evaluation touches in its own source (subject attr, object reference/
  template attrs, child join attrs) **plus** the attributes other maps pull
  from it via join conditions (its subject attr and the parent join attrs) —
  the set ``Z̄`` of the Rule-2 formalization.
* :func:`merge_groups` — maximal groups of join-free maps with equal heads
  (same subject template/class and same (predicate, object-signature) multi-
  set) over possibly different sources — the Rule-3 precondition.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Set, Tuple

from .schema import DIS, RefObjectMap, TermMap, TripleMap


def own_referenced_attrs(tm: TripleMap) -> Set[str]:
    """Attributes of ``tm.source`` used by ``tm`` itself."""
    attrs: Set[str] = set()
    if tm.subject.referenced_attr:
        attrs.add(tm.subject.referenced_attr)
    for pom in tm.poms:
        if isinstance(pom.object, RefObjectMap):
            attrs.add(pom.object.child_attr)
        elif pom.object.referenced_attr:
            attrs.add(pom.object.referenced_attr)
    for sel in tm.selections:
        attrs.add(sel.attr)
    return attrs


def incoming_join_attrs(dis: DIS, tm: TripleMap) -> Set[str]:
    """Attributes of ``tm.source`` that OTHER maps need from ``tm`` as a
    join parent: its subject attr + every parent join attr."""
    attrs: Set[str] = set()
    for other in dis.maps:
        for pom in other.poms:
            if isinstance(pom.object, RefObjectMap) and \
                    pom.object.parent_map == tm.name:
                attrs.add(pom.object.parent_attr)
                if tm.subject.referenced_attr:
                    attrs.add(tm.subject.referenced_attr)
    return attrs


def referenced_attrs(dis: DIS) -> Dict[str, Set[str]]:
    """map name -> full attribute set needed from its source (own + incoming)."""
    return {tm.name: own_referenced_attrs(tm) | incoming_join_attrs(dis, tm)
            for tm in dis.maps}


def head_signature(tm: TripleMap) -> Tuple:
    """Rule-3 equivalence key: subject template/class + sorted
    (predicate, object signature) tuple. Maps with joins or σ selections
    never merge (σ predicates reference source-specific attrs)."""
    if tm.has_join or tm.selections:
        return ("__nomerge__", tm.name)
    pom_sigs = tuple(sorted(
        (p.predicate,) + p.object.signature() for p in tm.poms))
    return (tm.subject.signature(), tm.subject_class, pom_sigs)


def merge_groups(dis: DIS) -> List[List[TripleMap]]:
    """Groups of >=2 maps sharing a head — candidates for Rule 3."""
    groups: Dict[Tuple, List[TripleMap]] = defaultdict(list)
    for tm in dis.maps:
        groups[head_signature(tm)].append(tm)
    return [g for key, g in groups.items()
            if len(g) >= 2 and key[0] != "__nomerge__"]


def sorted_reference_poms(tm: TripleMap) -> List[Tuple[int, TermMap]]:
    """Reference-kind POMs in canonical (predicate, signature) order, with
    their original indices — used to align attrs across merged maps."""
    entries = [(i, p) for i, p in enumerate(tm.poms)
               if isinstance(p.object, TermMap)]
    entries.sort(key=lambda e: (e[1].predicate,) + e[1].object.signature())
    return [(i, p.object) for i, p in entries]
