"""Scaled-up MapSDI: the paper's dedup lifted onto a TPU-pod mesh.

The core primitive is :func:`repartition_by_key` — hash-partition a
shard's rows on a column subset and exchange them with one ``all_to_all``
so equal keys co-locate. Two consumers:

* **global duplicate elimination** (``key_cols=None``: the hash covers the
  whole row) over row-sharded tables in one collective pass:

      local δ  →  rowhash → hash-repartition (all_to_all)  →  local δ

  Equal rows hash identically, so after repartition every duplicate group
  lives on exactly one shard and the second local distinct is globally
  correct. Crucially the *first* local distinct happens **before** the
  collective — projection/dedup pushdown applied to the network: the
  all_to_all moves already-minimized data (the same insight as Rule 1,
  with the ICI links playing the role of the RDFizer).
* **repartition-by-join-key ⋈ exchange** (``key_cols=(key,)``): both join
  sides partitioned on the key so each shard joins only its key range —
  the ``join_exchange="repartition"`` strategy of
  :func:`repro.plan.mesh.compile_mesh_plan`, which wins over the
  all_gather parent exchange when the parent side is large relative to
  ICI bandwidth.

Everything is fixed-shape: each shard holds ``cap_local`` rows, each
outgoing bucket ``cap_bucket = ceil(cap_local * slack / n_shards)`` rows.
Bucket overflow is detected and returned as a flag (the planner can re-run
with more slack); with the pre-dedup + a mixing hash, ``slack = 1``
overflows only on adversarial data.
"""
from __future__ import annotations

import functools
from collections import OrderedDict
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.kernels.radix_partition import radix_partition
from repro.kernels.rowhash import rowhash
from repro.relalg import PAD_ID, Table
from repro.relalg.ops import compact, dedup_rows


# ---------------------------------------------------------------------------
# shard-local body (runs inside shard_map)
# ---------------------------------------------------------------------------

def _partition_local(data: jax.Array, count: jax.Array, n_shards: int,
                     cap_bucket: int, use_pallas: Optional[bool],
                     key_cols: Optional[Tuple[int, ...]] = None
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Group this shard's valid rows into per-target-shard buckets.

    The target shard is ``rowhash(row[key_cols]) % n_shards``
    (``key_cols=None`` hashes the whole row — the global-δ partition);
    hashing a *subset* is what repartitions a relation by join key, so
    equal keys land on one shard. Returns (buckets
    [n_shards, cap_bucket, K], bucket_counts [n_shards], overflowed scalar
    bool).

    Backed by the radix-partition kernel package (one-pass histogram →
    prefix-sum → scatter; Pallas on TPU, jnp oracle elsewhere), which is
    bit-identical to the historical :func:`_partition_local_sorted` — the
    sort-based body kept as the differential-test/benchmark reference.
    """
    return radix_partition(
        data, count, n_buckets=n_shards, cap_bucket=cap_bucket,
        key_cols=None if key_cols is None else tuple(key_cols),
        use_pallas=use_pallas)


def _partition_local_sorted(data: jax.Array, count: jax.Array, n_shards: int,
                            cap_bucket: int, use_pallas: Optional[bool],
                            key_cols: Optional[Tuple[int, ...]] = None
                            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Historical sort-based bucketization (stable ``lax.sort`` on the
    target + ``searchsorted`` boundaries + scatter). Superseded by the
    radix kernel in :func:`_partition_local`; retained as the oracle the
    differential tests and ``benchmarks/partition.py`` compare against.
    """
    cap_local, k = data.shape
    valid = jnp.arange(cap_local, dtype=jnp.int32) < count
    data = jnp.where(valid[:, None], data, jnp.int32(PAD_ID))

    keyed = data if key_cols is None else data[:, jnp.asarray(key_cols)]
    h = rowhash(keyed, use_pallas=use_pallas)
    target = jnp.where(valid, (h % jnp.uint32(n_shards)).astype(jnp.int32),
                       jnp.int32(n_shards))  # invalid rows -> sentinel bucket

    # group rows by target: sort (target, row-id) and gather
    order_key, order = lax.sort(
        (target, jnp.arange(cap_local, dtype=jnp.int32)), num_keys=1)
    rows_sorted = data[order]

    # bucket boundaries via searchsorted over the sorted targets
    shard_ids = jnp.arange(n_shards, dtype=jnp.int32)
    starts = jnp.searchsorted(order_key, shard_ids, side="left")
    ends = jnp.searchsorted(order_key, shard_ids, side="right")
    counts = (ends - starts).astype(jnp.int32)
    overflow = jnp.any(counts > cap_bucket)

    pos_within = jnp.arange(cap_local, dtype=jnp.int32) - \
        starts[jnp.clip(order_key, 0, n_shards - 1)]
    ok = (order_key < n_shards) & (pos_within < cap_bucket)
    dest = jnp.where(ok, order_key * cap_bucket + pos_within,
                     n_shards * cap_bucket)
    buckets = jnp.full((n_shards * cap_bucket, k), jnp.int32(PAD_ID))
    buckets = buckets.at[dest].set(rows_sorted, mode="drop")
    return (buckets.reshape(n_shards, cap_bucket, k),
            jnp.minimum(counts, cap_bucket), overflow)


def pack_u16_pairs(data: jax.Array) -> jax.Array:
    """[N, K] int32 codes (all in [0, 65535]) -> [N, ceil(K/2)] int32.

    Halves collective payload when the planner knows every column's
    dictionary fits 16 bits (checked host-side from the vocab)."""
    n, k = data.shape
    if k % 2:
        data = jnp.concatenate(
            [data, jnp.zeros((n, 1), jnp.int32)], axis=1)
        k += 1
    lo = data[:, 0::2].astype(jnp.uint32) & jnp.uint32(0xFFFF)
    hi = data[:, 1::2].astype(jnp.uint32) & jnp.uint32(0xFFFF)
    return (lo | (hi << jnp.uint32(16))).astype(jnp.int32)


def unpack_u16_pairs(packed: jax.Array, k: int) -> jax.Array:
    """Inverse of :func:`pack_u16_pairs` (original column count ``k``)."""
    u = packed.astype(jnp.uint32)
    lo = (u & jnp.uint32(0xFFFF)).astype(jnp.int32)
    hi = ((u >> jnp.uint32(16)) & jnp.uint32(0xFFFF)).astype(jnp.int32)
    out = jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], -1)
    return out[:, :k]


def repartition_by_key(data: jax.Array, count: jax.Array, *,
                       axis: str, n_shards: int, cap_bucket: int,
                       key_cols: Optional[Tuple[int, ...]] = None,
                       use_pallas: Optional[bool] = None,
                       pack_u16: bool = False
                       ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Hash-repartition this shard's valid rows by ``key_cols``.

    The reusable exchange primitive behind every mesh-plan collective:
    callable from *inside* any ``shard_map`` body over ``axis``. Rows are
    hashed on ``key_cols`` (``None`` = all columns), grouped into
    per-target buckets of ``cap_bucket`` rows, exchanged with one
    ``all_to_all``, and compacted. Takes this shard's ``data
    [cap_local, k]`` / scalar ``count`` and returns ``(data
    [n_shards * cap_bucket, k], count scalar, overflow scalar)`` — the rows
    whose key hashes to this shard.

    Because equal keys land on one shard, a local δ afterwards is a global
    δ when ``key_cols=None`` (every copy of a row shares its hash — the
    :func:`repartition_distinct_local` sink), and a local ⋈ on the key
    afterwards is exactly that shard's slice of the global ⋈ (the
    ``join_exchange="repartition"`` strategy of
    :func:`repro.plan.mesh.compile_mesh_plan`). ``overflow`` is True iff
    some outgoing bucket exceeded ``cap_bucket`` and rows were dropped —
    a *correctness* flag the caller must surface (the engine recompiles
    with safe bucket capacities; ``cap_bucket >= cap_local`` can never
    overflow, since a shard sends at most its own rows to one target).
    """
    _TRACE_COUNTS["repartition"] += 1  # trace-time side effect: each
    # (re)trace of a shard body that exchanges rows ticks the guard counter
    # tests and the engine benchmark use to assert closure reuse
    count = count.reshape(())
    k_cols = data.shape[1]
    # 1. bucket by key hash
    buckets, bcounts, overflow = _partition_local(
        data, count, n_shards, cap_bucket, use_pallas, key_cols)
    # 2. exchange buckets; shard j receives every shard's bucket j
    if pack_u16:   # §Perf hillclimb 3: halve the wire bytes
        buckets = pack_u16_pairs(
            buckets.reshape(n_shards * cap_bucket, k_cols)
        ).reshape(n_shards, cap_bucket, -1)
    recv = lax.all_to_all(buckets, axis, split_axis=0, concat_axis=0,
                          tiled=False)
    if pack_u16:
        recv = unpack_u16_pairs(
            recv.reshape(n_shards * cap_bucket, -1), k_cols
        ).reshape(n_shards, cap_bucket, k_cols)
    recv_counts = lax.all_to_all(bcounts.reshape(n_shards, 1), axis,
                                 split_axis=0, concat_axis=0).reshape(-1)
    overflow = lax.pmax(overflow, axis)
    # 3. flatten + compact (validity tracked by counts, so u16 packing of
    # PAD rows round-trips harmlessly — they are re-masked here)
    cap_bucket_total = n_shards * cap_bucket
    flat = recv.reshape(cap_bucket_total, -1)
    row_in_bucket = jnp.arange(cap_bucket_total, dtype=jnp.int32) % cap_bucket
    bucket_of_row = jnp.arange(cap_bucket_total, dtype=jnp.int32) // cap_bucket
    valid = row_in_bucket < recv_counts[bucket_of_row]
    flat, n = compact(jnp.where(valid[:, None], flat, jnp.int32(PAD_ID)),
                      valid)
    return flat, n, overflow


def repartition_distinct_local(data: jax.Array, count: jax.Array, *,
                               axis: str, n_shards: int, cap_bucket: int,
                               use_pallas: Optional[bool] = None,
                               pack_u16: bool = False,
                               dedup: Optional[str] = None
                               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-shard body: local δ -> hash partition -> all_to_all -> local δ.

    The plan-level global-δ primitive: callable from *inside* any
    ``shard_map`` body over ``axis`` — both :func:`make_repartition_distinct`
    (the standalone collective closure) and the fused mesh plan compiler
    (:func:`repro.plan.mesh.compile_mesh_plan`, where it runs as the plan's
    sink instead of a host-side post-pass) consume it. Takes this shard's
    ``data [cap_local, k]`` / scalar ``count`` and returns
    ``(data [n_shards * cap_bucket, k], count [1], overflow [1])`` — the
    globally-deduplicated rows that hash to this shard. The exchange itself
    is :func:`repartition_by_key` over all columns.

    Both local δ passes go through :func:`repro.relalg.ops.dedup_rows`, so
    the single-device and distributed paths share one implementation and one
    ``dedup`` strategy ("lex" | "hash" | None = engine default).
    """
    count = count.reshape(())
    # 1. dedup BEFORE the collective (pushdown to the network)
    data, count = dedup_rows(data, count, dedup, use_pallas=use_pallas)
    # 2. hash-repartition so every duplicate group lands on one shard
    flat, n, overflow = repartition_by_key(
        data, count, axis=axis, n_shards=n_shards, cap_bucket=cap_bucket,
        key_cols=None, use_pallas=use_pallas, pack_u16=pack_u16)
    # 3. local δ = global δ
    flat, n = dedup_rows(flat, n, dedup, use_pallas=use_pallas)
    return flat, n.reshape(1), overflow.reshape(1)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

# trace-count guard: how many times the shard_map body has been traced in
# this process — reuse of a cached closure keeps this flat
_TRACE_COUNTS = {"repartition": 0}

# (mesh devices, axis, shapes, strategy) -> (run, out cap per shard): the
# compiled-closure cache the KGEngine session consumes, so repeated
# distributed δ calls over same-bucket shapes never rebuild or re-trace
# (small LRU — each entry pins a jitted collective program)
_CLOSURE_CACHE: "OrderedDict[Tuple, Tuple]" = OrderedDict()
_CLOSURE_CACHE_MAX = 32


def repartition_trace_count() -> int:
    """Process-wide count of shard-body traces (the reuse guard)."""
    return _TRACE_COUNTS["repartition"]


def sink_bucket_cap(cap_local: int, n_shards: int, slack: float = 1.0) -> int:
    """Per-target-shard bucket capacity for the hash repartition.

    A Poisson tail bound: a mixing hash spreads rows ~uniformly, so bucket
    occupancy ≈ Poisson(m) with ``m = cap_local / n_shards``, and
    ``m + 6·sqrt(m) + 8`` bounds the max bucket far tighter than a blanket
    2× at large m. ``slack`` multiplies the bound; overflow is still
    detected and flagged for a re-run. Shared by the standalone collective
    closure and the fused mesh-plan sink."""
    m = cap_local / n_shards
    return max(8, int(np.ceil((m + 6.0 * np.sqrt(m) + 8) * slack)))


def _closure_key(mesh: Mesh, axis: str, cap_local: int, k: int, slack: float,
                 use_pallas: Optional[bool], pack_u16: bool,
                 dedup: Optional[str]) -> Tuple:
    devices = tuple(d.id for d in np.asarray(mesh.devices).flat)
    return (devices, tuple(mesh.shape.items()), axis, cap_local, k, slack,
            use_pallas, pack_u16, dedup)


def make_repartition_distinct(mesh: Mesh, axis: str, cap_local: int, k: int,
                              slack: float = 1.0,
                              use_pallas: Optional[bool] = None,
                              pack_u16: bool = False,
                              dedup: Optional[str] = None,
                              cache: bool = True):
    """Build the jitted global-distinct over a row-sharded matrix.

    Input:  data [n_shards * cap_local, k] sharded P(axis, None),
            counts [n_shards] sharded P(axis).
    Output: data [n_shards * out_cap_local, k] (same sharding), counts,
            overflow flag (replicated bool).

    ``pack_u16``: the caller asserts every dictionary code fits 16 bits
    (host-side vocab check); the all_to_all then moves ceil(k/2) words per
    row instead of k.

    Bucket capacity is a Poisson tail bound — a mixing hash spreads rows
    ~uniformly, so occupancy ≈ Poisson(m), m = cap_local / n_shards, and
    ``m + 6·sqrt(m) + 8`` bounds the max bucket far tighter than a
    blanket 2× at large m (``slack`` multiplies the bound; overflow is
    still detected and flagged for a re-run).

    ``cache=True`` (default) memoizes the built closure on (mesh, axis,
    shapes, strategy), so repeated calls — e.g. every ``KGEngine.ingest``
    within one capacity bucket — reuse one jitted program;
    :func:`repartition_trace_count` observes the reuse.
    """
    key = _closure_key(mesh, axis, cap_local, k, slack, use_pallas,
                       pack_u16, dedup)
    if cache:
        hit = _CLOSURE_CACHE.get(key)
        if hit is not None:
            _CLOSURE_CACHE.move_to_end(key)
            return hit
    n_shards = mesh.shape[axis]
    cap_bucket = sink_bucket_cap(cap_local, n_shards, slack)

    body = functools.partial(repartition_distinct_local, axis=axis,
                             n_shards=n_shards, cap_bucket=cap_bucket,
                             use_pallas=use_pallas, pack_u16=pack_u16,
                             dedup=dedup)
    fn = shard_map(body, mesh=mesh,
                       in_specs=(P(axis, None), P(axis)),
                       out_specs=(P(axis, None), P(axis), P(axis)))

    @jax.jit
    def run(data: jax.Array, counts: jax.Array):
        out, n, overflow = fn(data, counts)
        return out, n, jnp.any(overflow)

    result = (run, cap_bucket * n_shards)  # out cap per shard
    if cache:
        _CLOSURE_CACHE[key] = result
        while len(_CLOSURE_CACHE) > _CLOSURE_CACHE_MAX:
            _CLOSURE_CACHE.popitem(last=False)
    return result


def shard_table(table: Table, mesh: Mesh, axis: str,
                cap_local: Optional[int] = None
                ) -> Tuple[jax.Array, jax.Array, int]:
    """Round-robin-block distribute a host table's valid rows across the
    ``axis`` shards; returns (data, counts, cap_local).

    ``cap_local`` overrides the exact-fit per-shard capacity — the engine
    passes a :func:`repro.relalg.bucket_cap` bucket derived from the static
    table capacity so the downstream collective closure is shape-stable
    across ingests."""
    n_shards = mesh.shape[axis]
    rows = np.asarray(table.data)[:int(table.count)]
    per = int(np.ceil(max(1, len(rows)) / n_shards))
    if cap_local is None:
        cap_local = max(8, ((per + 7) // 8) * 8)
    elif cap_local < per:
        raise ValueError(f"cap_local {cap_local} < {per} rows per shard")
    data = np.full((n_shards * cap_local, table.n_attrs), PAD_ID, np.int32)
    counts = np.zeros((n_shards,), np.int32)
    for s in range(n_shards):
        chunk = rows[s * per:(s + 1) * per]
        data[s * cap_local:s * cap_local + len(chunk)] = chunk
        counts[s] = len(chunk)
    sharding = NamedSharding(mesh, P(axis, None))
    return (jax.device_put(data, sharding),
            jax.device_put(counts, NamedSharding(mesh, P(axis))),
            cap_local)


def unshard_rows(data: jax.Array, counts: jax.Array, cap_local: int
                 ) -> np.ndarray:
    """Gather valid rows from all shards back to host (tests/sinks)."""
    data = np.asarray(data)
    counts = np.asarray(counts)
    parts = [data[s * cap_local:s * cap_local + counts[s]]
             for s in range(len(counts))]
    return np.concatenate(parts, axis=0) if parts else data[:0]


def distributed_distinct_table(table: Table, mesh: Mesh, axis: str = "data",
                               slack: float = 1.0,
                               use_pallas: Optional[bool] = None,
                               pack_u16: Optional[bool] = None,
                               dedup: Optional[str] = None,
                               cap_local: Optional[int] = None
                               ) -> Tuple[Table, bool]:
    """Convenience end-to-end: shard -> global distinct -> gather.

    ``pack_u16=None`` auto-enables payload packing when every valid code
    fits 16 bits (the host knows the dictionary). ``dedup`` picks the
    shard-local δ strategy (shared with the single-device path).
    ``cap_local`` pins the per-shard capacity (see :func:`shard_table`) so
    repeated calls reuse one cached collective closure."""
    if pack_u16 is None:
        rows_np = np.asarray(table.data)[:int(table.count)]
        pack_u16 = bool(rows_np.size == 0
                        or (rows_np.min() >= 0 and rows_np.max() < 65536))
    data, counts, cap_local = shard_table(table, mesh, axis, cap_local)
    run, out_cap_local = make_repartition_distinct(
        mesh, axis, cap_local, table.n_attrs, slack, use_pallas,
        pack_u16=pack_u16, dedup=dedup)
    out, n, overflow = run(data, counts)
    rows = unshard_rows(out, n, out_cap_local)
    return (Table.from_codes(rows, table.attrs),
            bool(overflow))
