"""The paper's baseline: the traditional ("T-") framework.

Schema-level integration first (blind evaluation of all mapping rules), then
data-level integration (global duplicate elimination + cleaning) — the two
separated steps of the motivating example (Fig. 1). No pre-processing of the
sources happens; whatever duplicates the sources contain are materialized as
RDF triples and only removed at the sink.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

from repro.relalg import Table

from .rdfizer import Engine, RDFizer
from .schema import DIS


def t_framework_create_kg(dis: DIS, engine: Engine = "rmlmapper",
                          dedup: Optional[str] = None
                          ) -> Tuple[Table, Dict[str, int]]:
    """RDFize the untransformed DIS; returns (KG, stats)."""
    rdfizer = RDFizer(dis, engine, dedup=dedup)
    kg, raw = rdfizer()
    return kg, {
        "raw_triples": int(raw),
        "kg_triples": int(kg.count),
        "source_rows": {k: int(v.count) for k, v in dis.sources.items()},
    }


def make_t_framework_fn(dis: DIS, engine: Engine = "rmlmapper",
                        dedup: Optional[str] = None):
    """jit-friendly closure (sources pytree -> (kg, raw)) for benchmarking."""
    rdfizer = RDFizer(dis, engine, dedup=dedup)

    def fn(sources: Optional[Dict[str, Table]] = None):
        return rdfizer(sources if sources is not None else dis.sources)

    return fn
