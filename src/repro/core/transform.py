"""MapSDI Transformation Rules 1–3 and the fixpoint driver.

Rewrites ``DIS_G = <O, S, M>`` into ``DIS'_G = <O, S', M'>`` with
``RDFize(DIS) == RDFize(DIS')`` (set semantics) and less work for the
semantification engine:

* Rule 1 (projection of attributes) — join-free maps get a projected +
  deduplicated copy of their source restricted to the referenced attrs.
* Rule 2 (pushing projections into joins) — the same projection applied to
  the child and parent sources of join conditions, keeping the ``Z̄`` set
  (head attrs + join attrs) of the formalization.
* Rule 3 (merging sources with equivalent attributes) — join-free maps with
  equal heads over different sources are merged: project each source to the
  referenced attrs under canonical role names, union, dedup; the maps
  collapse into one.

Two fixpoint drivers share that rule set:

* :func:`apply_mapsdi` (the default) plans **symbolically**: the DIS is
  lowered to the logical IR (:mod:`repro.plan`), Rules 1–3 + selection
  pushdown + CSE run as pure rewrites with ZERO device work and zero host
  syncs, and the final plan is materialized once — one jitted evaluation
  with shared subplans computed once, then one ``shrink_to_fit`` per new
  source. This is the paper's "until a fixed point over S' and M'" loop
  without ever materializing an intermediate state.
* :func:`apply_mapsdi_eager` is the historical driver: each rewrite
  materializes + shrinks its sources (host sync) every iteration. It is
  kept as the benchmark baseline (``benchmarks/planner.py``) and as an
  independent oracle for the planner's property tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from repro.relalg import Table, distinct, project_as, round_cap, \
    shrink_to_fit, union
from repro.relalg.guard import host_int

from .analyze import (merge_groups, referenced_attrs, sorted_reference_poms)
from .schema import DIS, PredicateObjectMap, RefObjectMap, TripleMap

__all__ = [
    "TransformStats", "apply_mapsdi", "apply_mapsdi_eager", "apply_merge",
    "apply_projection", "plan_mapsdi", "round_cap", "shrink_to_fit",
]


@dataclasses.dataclass
class TransformStats:
    rule1_applications: int = 0
    rule2_applications: int = 0
    rule3_merges: int = 0
    sigma_pushdowns: int = 0
    cse_shared_subplans: int = 0
    source_rows_before: Dict[str, int] = dataclasses.field(default_factory=dict)
    source_rows_after: Dict[str, int] = dataclasses.field(default_factory=dict)


# ---------------------------------------------------------------------------
# Rules 1 & 2: projection (+dedup) pushdown (eager form)
# ---------------------------------------------------------------------------

def apply_projection(dis: DIS, stats: Optional[TransformStats] = None,
                     dedup: Optional[str] = None) -> DIS:
    """Rules 1 and 2. Each map's source is replaced by
    ``δ(π_{referenced}(S))``; identical (source, attr-set) projections are
    shared between maps. Maps are rewritten in place (attr names survive,
    so only ``TripleMap.source`` changes). ``dedup`` picks the δ strategy
    (``"lex"`` | ``"hash"``; None = engine default)."""
    needed = referenced_attrs(dis)
    out = dis.copy()
    shared: Dict[Tuple[str, Tuple[str, ...]], str] = {}
    new_maps: List[TripleMap] = []
    for tm in dis.maps:
        attrs = tuple(sorted(needed[tm.name]))
        src = dis.sources[tm.source]
        if tm.source in dis.preprocessed and attrs == tuple(sorted(src.attrs)):
            new_maps.append(tm)  # already in projected+dedup'd form
            continue
        key = (tm.source, attrs)
        if key not in shared:
            proj = distinct(project_as(src, [(a, a) for a in attrs]),
                            dedup=dedup)
            proj = shrink_to_fit(proj)
            name = f"{tm.source}__pi_" + "_".join(attrs)
            out.sources[name] = proj
            out.preprocessed.add(name)
            shared[key] = name
            if stats is not None:
                if tm.has_join:
                    stats.rule2_applications += 1
                else:
                    stats.rule1_applications += 1
        new_maps.append(dataclasses.replace(tm, source=shared[key]))
    out.maps = new_maps
    # drop now-unreferenced originals
    used = {m.source for m in out.maps}
    out.sources = {k: v for k, v in out.sources.items() if k in used}
    return out


# ---------------------------------------------------------------------------
# Rule 3: merging sources with equivalent attributes (eager form)
# ---------------------------------------------------------------------------

def _join_parents(dis: DIS) -> Set[str]:
    return {p.object.parent_map for m in dis.maps for p in m.poms
            if isinstance(p.object, RefObjectMap)}


def apply_merge(dis: DIS, stats: Optional[TransformStats] = None,
                dedup: Optional[str] = None) -> DIS:
    """Rule 3 on every mergeable group. Maps that serve as join parents are
    conservatively kept separate (their names are referenced by other maps).
    Canonical role attrs are ``__m0`` (subject) and ``__m{i}`` for the i-th
    (predicate-sorted) non-constant object reference. ``dedup`` picks the
    δ strategy for the merged-source set-union."""
    parents = _join_parents(dis)
    out = dis.copy()
    merged_any = False
    for gi, group in enumerate(merge_groups(dis)):
        group = [tm for tm in group if tm.name not in parents]
        if len(group) < 2:
            continue
        lead = group[0]
        canon_poms: List[PredicateObjectMap] = []
        r_nonconst = 0
        for idx, term in sorted_reference_poms(lead):
            pom = lead.poms[idx]
            if term.kind == "constant":
                canon_poms.append(pom)
            else:
                r_nonconst += 1
                canon_poms.append(PredicateObjectMap(
                    predicate=pom.predicate,
                    object=dataclasses.replace(term,
                                               attr=f"__m{r_nonconst}")))

        # project every member source to the role schema, union + dedup
        merged: Optional[Table] = None
        for tm in group:
            spec: List[Tuple[str, str]] = []
            if tm.subject.referenced_attr:
                spec.append((tm.subject.referenced_attr, "__m0"))
            r_nonconst = 0
            for idx, term in sorted_reference_poms(tm):
                if term.kind == "constant":
                    continue
                r_nonconst += 1
                spec.append((term.attr, f"__m{r_nonconst}"))
            part = project_as(dis.sources[tm.source], spec)
            merged = part if merged is None else union(merged, part)
        assert merged is not None
        merged = shrink_to_fit(distinct(merged, dedup=dedup))
        merged_name = f"merged_{gi}_" + "_".join(tm.name for tm in group)

        subject = (dataclasses.replace(lead.subject, attr="__m0")
                   if lead.subject.referenced_attr else lead.subject)
        merged_map = TripleMap(
            name=f"TM_merged_{gi}", source=merged_name, subject=subject,
            subject_class=lead.subject_class, poms=tuple(canon_poms))

        out.sources[merged_name] = merged
        out.preprocessed.add(merged_name)
        group_names = {tm.name for tm in group}
        out.maps = [m for m in out.maps if m.name not in group_names]
        out.maps.append(merged_map)
        merged_any = True
        if stats is not None:
            stats.rule3_merges += 1
    if merged_any:
        used = {m.source for m in out.maps} | {
            out.map_by_name(p.object.parent_map).source
            for m in out.maps for p in m.poms
            if isinstance(p.object, RefObjectMap)}
        out.sources = {k: v for k, v in out.sources.items() if k in used}
    return out


# ---------------------------------------------------------------------------
# fixpoint drivers
# ---------------------------------------------------------------------------

def _dis_signature(dis: DIS) -> Tuple:
    from .rml import triple_map_to_json
    maps_sig = tuple(sorted(str(triple_map_to_json(m)) for m in dis.maps))
    src_sig = tuple(sorted((k, v.attrs, v.capacity, host_int(v.count))
                           for k, v in dis.sources.items()))
    return maps_sig, src_sig


def plan_mapsdi(dis: DIS, max_iters: int = 8,
                stats: Optional[TransformStats] = None, gate=None):
    """Symbolic fixpoint: lower the DIS and run the optimizer (Rules 1–3 +
    σ pushdown + CSE) to convergence. Pure host-side rewriting — no device
    work, no host syncs (tests run this under ``forbid_transfers``).
    Returns the optimized :class:`~repro.plan.lower.LogicalPlan`.
    ``gate`` is forwarded to :func:`repro.plan.optimize.optimize` (the
    rewrite-soundness hook)."""
    from repro.plan.lower import lower
    from repro.plan.optimize import optimize
    plan = lower(dis)
    pstats = optimize(plan, max_iters=max_iters, gate=gate)
    if stats is not None:
        stats.rule1_applications += pstats.rule1_applications
        stats.rule2_applications += pstats.rule2_applications
        stats.rule3_merges += pstats.rule3_merges
        stats.sigma_pushdowns += pstats.sigma_pushdowns
        stats.cse_shared_subplans += pstats.cse_shared_subplans
    return plan


def apply_mapsdi(dis: DIS, max_iters: int = 8,
                 stats: Optional[TransformStats] = None,
                 dedup: Optional[str] = None
                 ) -> Tuple[DIS, TransformStats]:
    """Rules 1–3 (+ σ pushdown, CSE) to a fixpoint, planner-backed: the
    fixpoint runs entirely on the symbolic plan and the result is
    materialized once at the end. ``dedup`` picks the δ strategy used by
    the single materialization."""
    from repro.plan.compile import materialize_plan
    stats = stats or TransformStats()
    plan = plan_mapsdi(dis, max_iters=max_iters, stats=stats)
    out, rows_after = materialize_plan(plan, dedup=dedup)
    stats.source_rows_before = {k: host_int(v.count)
                                for k, v in dis.sources.items()}
    stats.source_rows_after = rows_after
    return out, stats


def apply_mapsdi_eager(dis: DIS, max_iters: int = 8,
                       stats: Optional[TransformStats] = None,
                       dedup: Optional[str] = None
                       ) -> Tuple[DIS, TransformStats]:
    """The historical materializing fixpoint: every iteration rewrites and
    shrinks sources on device with host syncs in between. Baseline for
    ``benchmarks/planner.py`` and oracle for the planner tests."""
    stats = stats or TransformStats()
    stats.source_rows_before = {k: host_int(v.count)
                                for k, v in dis.sources.items()}
    cur = dis
    prev_sig = None
    for _ in range(max_iters):
        cur = apply_merge(cur, stats, dedup=dedup)
        cur = apply_projection(cur, stats, dedup=dedup)
        sig = _dis_signature(cur)
        if sig == prev_sig:
            break
        prev_sig = sig
    stats.source_rows_after = {k: host_int(v.count)
                               for k, v in cur.sources.items()}
    return cur, stats
