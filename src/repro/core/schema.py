"""Data model of a data integration system ``DIS_G = <O, S, M>``.

Mirrors the paper's §3 formalization: a unified schema ``O`` (classes and
properties derived from the mapping rules), sources ``S`` with signatures
(attribute sets) and extensions (:class:`~repro.relalg.Table`), and mapping
rules ``M`` expressed in an RML subset (triples maps with subject/predicate-
object maps and join conditions).

RDF terms on device are int32 pairs ``(tmpl_id, val_id)``:

* ``tmpl_id == TMPL_LITERAL`` (0): plain literal whose text is
  ``vocab.decode(val_id)`` — produced by ``rml:reference`` object maps.
* ``tmpl_id == TMPL_CONSTANT`` (1): constant IRI ``vocab.decode(val_id)`` —
  produced by ``rr:constant`` (and ``rr:class``/predicates).
* ``tmpl_id >= TMPL_BASE`` (2): IRI from an ``rr:template`` with a single
  placeholder; the IRI text is ``template.format(vocab.decode(val_id))``.

Two terms are equal iff their pairs are equal; distinct templates are assumed
not to collide textually (standard in RML practice).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple, Union

from repro.relalg import Table, Vocab

TMPL_LITERAL = 0
TMPL_CONSTANT = 1
TMPL_BASE = 2

RDF_TYPE = "rdf:type"

TRIPLE_ATTRS = ("s_t", "s_v", "p", "o_t", "o_v")


def map_by_name(maps, name: str) -> "TripleMap":
    """Look a triple map up by name in any map collection (shared by DIS
    and the planner's LogicalPlan)."""
    for m in maps:
        if m.name == name:
            return m
    raise KeyError(f"no triple map named {name!r}")


@dataclasses.dataclass(frozen=True)
class TermMap:
    """rr:subjectMap / rr:objectMap — one of reference/template/constant."""

    kind: str  # 'reference' | 'template' | 'constant'
    attr: Optional[str] = None        # for reference/template
    template: Optional[str] = None    # for template (single {placeholder})
    constant: Optional[object] = None  # for constant

    def __post_init__(self):
        if self.kind not in ("reference", "template", "constant"):
            raise ValueError(f"bad TermMap kind {self.kind!r}")
        if self.kind in ("reference", "template") and self.attr is None:
            raise ValueError(f"{self.kind} TermMap needs attr")
        if self.kind == "template" and self.template is None:
            raise ValueError("template TermMap needs template string")

    @property
    def referenced_attr(self) -> Optional[str]:
        return self.attr if self.kind in ("reference", "template") else None

    def signature(self) -> Tuple:
        """Merge-compatibility signature — attr *names* excluded (Rule 3
        merges maps whose attrs differ only in name)."""
        if self.kind == "reference":
            return ("reference",)
        if self.kind == "template":
            return ("template", self.template)
        return ("constant", self.constant)


@dataclasses.dataclass(frozen=True)
class RefObjectMap:
    """rr:parentTriplesMap + rr:joinCondition (single child==parent pair)."""

    parent_map: str
    child_attr: str
    parent_attr: str


@dataclasses.dataclass(frozen=True)
class Selection:
    """σ predicate on a map's logical source (the paper's selection of
    relevant entries). Filters every triple the map emits, including rows it
    contributes to joins as a parent."""

    attr: str
    op: str                          # 'eq' | 'neq' | 'notnull'
    value: Optional[object] = None   # for eq/neq; interned via the vocab

    def __post_init__(self):
        if self.op not in ("eq", "neq", "notnull"):
            raise ValueError(f"bad Selection op {self.op!r}")
        if self.op in ("eq", "neq") and self.value is None:
            raise ValueError(f"{self.op} Selection needs a value")


@dataclasses.dataclass(frozen=True)
class PredicateObjectMap:
    predicate: str
    object: Union[TermMap, RefObjectMap]

    @property
    def is_join(self) -> bool:
        return isinstance(self.object, RefObjectMap)


@dataclasses.dataclass(frozen=True)
class TripleMap:
    """One RML triples map (a GAV conjunctive rule in the paper's algebra)."""

    name: str
    source: str                      # key into DIS.sources
    subject: TermMap
    subject_class: Optional[str] = None   # rr:class -> (s, rdf:type, class)
    poms: Tuple[PredicateObjectMap, ...] = ()
    selections: Tuple[Selection, ...] = ()  # σ over the logical source

    @property
    def join_poms(self) -> List[PredicateObjectMap]:
        return [p for p in self.poms if p.is_join]

    @property
    def has_join(self) -> bool:
        return any(p.is_join for p in self.poms)


@dataclasses.dataclass
class DIS:
    """A data integration system: sources S (+extensions) and rules M.

    ``O`` (the unified schema) is implicit: ``classes()`` / ``properties()``
    enumerate the signature induced by the rules, as in GAV.
    """

    sources: Dict[str, Table]
    maps: List[TripleMap]
    vocab: Vocab
    templates: Dict[str, int] = dataclasses.field(default_factory=dict)
    null_code: Optional[int] = None
    # names of sources known to be projected+deduplicated already (MapSDI
    # provenance — makes the transformation rules idempotent)
    preprocessed: set = dataclasses.field(default_factory=set)
    # names of sources whose extension already satisfies the owning maps'
    # σ selections (set by the planner's materialization, where σ is pushed
    # below the final shrink; the eager driver never bakes σ, so its DIS'
    # keeps the join-time parent re-select)
    sigma_baked: set = dataclasses.field(default_factory=set)

    def template_id(self, template: str) -> int:
        tid = self.templates.get(template)
        if tid is None:
            tid = TMPL_BASE + len(self.templates)
            self.templates[template] = tid
        return tid

    def map_by_name(self, name: str) -> TripleMap:
        return map_by_name(self.maps, name)

    # -- unified schema O ---------------------------------------------------
    def classes(self) -> List[str]:
        return sorted({m.subject_class for m in self.maps if m.subject_class})

    def properties(self) -> List[str]:
        return sorted({p.predicate for m in self.maps for p in m.poms})

    def copy(self) -> "DIS":
        return DIS(sources=dict(self.sources), maps=list(self.maps),
                   vocab=self.vocab, templates=dict(self.templates),
                   null_code=self.null_code,
                   preprocessed=set(self.preprocessed),
                   sigma_baked=set(self.sigma_baked))
