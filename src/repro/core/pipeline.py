"""End-to-end MapSDI pipeline entry points — thin wrappers over the
session API.

The one front door is :class:`repro.api.KGEngine` (cached plans,
incremental ingestion, overflow-safe re-execution; see ``docs/engine.md``).
``mapsdi_create_kg`` remains the one-shot convenience (Fig. 2 in one call);
``make_planned_fn`` / ``make_mapsdi_fn`` are **deprecated** shims kept for
source compatibility — they delegate to a ``KGEngine`` session and warn
once per process. Unlike the historical closures, the shims inherit the
engine's overflow safety: re-running on grown extensions recompiles
instead of silently truncating.
"""
from __future__ import annotations

import warnings
from typing import Dict, Optional, Tuple

from repro.relalg import Table

from .rdfizer import Engine
from .schema import DIS
from .transform import apply_mapsdi

_WARNED: set = set()


def _warn_once(name: str, replacement: str) -> None:
    if name in _WARNED:
        return
    _WARNED.add(name)
    warnings.warn(
        f"{name} is deprecated; use repro.api.KGEngine — {replacement}",
        DeprecationWarning, stacklevel=3)


def mapsdi_create_kg(dis: DIS, engine: Engine = "sdm",
                     dedup: Optional[str] = None,
                     ) -> Tuple[Table, Dict[str, object]]:
    """Plan + execute once; returns (KG, stats incl. Table-1-style sizes).

    Delegates to a fresh :class:`repro.api.KGEngine` session, so repeated
    calls over structurally-identical DISes hit the shared plan cache: on
    a hit the capacity annotation (the host pass over the sources) and the
    closure compilation are skipped and no longer counted in
    ``preprocess_seconds`` — only the cheap symbolic re-plan that derives
    the cache key remains — and the stats carry the session's
    ``recompiles`` / ``plan_cache_hit`` counters. ``dedup`` selects the δ
    strategy (``"lex"`` | ``"hash"``) for both the planned Rule 1–3
    pre-processing and the engine sinks; None = engine default.
    """
    from repro.api import EngineConfig, KGEngine
    config = EngineConfig(engine=engine, dedup=dedup)
    return KGEngine(dis, config=config).create_kg()


def make_planned_fn(dis: DIS, engine: Engine = "sdm",
                    dedup: Optional[str] = None):
    """DEPRECATED: use ``KGEngine(dis).run`` (or ``.ingest``).

    .. deprecated:: removal target — this shim goes away together with the
       other ``repro.core.pipeline``/``rdfize`` compatibility wrappers once
       the ``repro.api`` surface (``KGEngine`` + ``EngineConfig``) has been
       the documented entry point for two releases; no in-repo caller uses
       it outside its own tests.

    Returns ``(fn, plan)`` where ``fn(raw_sources) -> (kg, raw)`` executes
    the session's cached closure — steady-state re-execution over
    *untransformed* source extensions. Via the engine, the closure is now
    overflow-safe: extensions that outgrow the plan-time capacities trigger
    one transparent recompile instead of silent truncation."""
    _warn_once("make_planned_fn",
               "engine = KGEngine(dis); engine.run(sources)")
    from repro.api import EngineConfig, KGEngine
    eng = KGEngine(dis, config=EngineConfig(engine=engine, dedup=dedup))
    return eng.run, eng.plan


def make_mapsdi_fn(dis: DIS, engine: Engine = "sdm",
                   dedup: Optional[str] = None):
    """DEPRECATED: use ``apply_mapsdi`` + ``KGEngine`` (or just
    ``KGEngine(dis)``).

    .. deprecated:: removal target — scheduled for deletion with
       ``make_planned_fn`` and ``rdfize`` (see the note there); migrate to
       ``apply_mapsdi`` + ``KGEngine(dis2, config=EngineConfig(...))``.

    Pre-transform once (planning + one materialization), return a semantify
    closure over the *transformed* sources — the historical steady-state
    shape, where pre-processed extensions exist as concrete tables (e.g. to
    be shipped to another pod)."""
    _warn_once("make_mapsdi_fn",
               "dis2, _ = apply_mapsdi(dis); engine = KGEngine(dis2)")
    from repro.api import EngineConfig, KGEngine
    dis2, _ = apply_mapsdi(dis, dedup=dedup)
    eng = KGEngine(dis2, config=EngineConfig(engine=engine, dedup=dedup))

    def fn(sources: Optional[Dict[str, Table]] = None):
        return eng.run(dis2.sources if sources is None else sources)

    return fn, dis2
