"""End-to-end MapSDI pipeline: transform the DIS, then semantify.

``mapsdi_create_kg`` = the full framework of Fig. 2: extract knowledge from
the mapping rules, project/dedup/merge the sources (Rules 1–3 to fixpoint),
rewrite the rules, then hand the minimized ``DIS'`` to the RDFizer.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

import jax

from repro.relalg import Table

from .rdfizer import Engine, RDFizer
from .schema import DIS
from .transform import TransformStats, apply_mapsdi


def mapsdi_create_kg(dis: DIS, engine: Engine = "sdm",
                     dedup: Optional[str] = None,
                     ) -> Tuple[Table, Dict[str, object]]:
    """Pre-process + RDFize; returns (KG, stats incl. Table-1-style sizes).

    ``dedup`` selects the δ strategy (``"lex"`` | ``"hash"``) for both the
    Rule 1–3 pre-processing and the RDFizer sinks; None = engine default.
    """
    t0 = time.perf_counter()
    dis2, tstats = apply_mapsdi(dis, dedup=dedup)
    t1 = time.perf_counter()
    rdfizer = RDFizer(dis2, engine, dedup=dedup)
    kg, raw = rdfizer()
    kg.data.block_until_ready()
    t2 = time.perf_counter()
    return kg, {
        "raw_triples": int(raw),
        "kg_triples": int(kg.count),
        "preprocess_seconds": t1 - t0,
        "semantify_seconds": t2 - t1,
        "source_rows_before": tstats.source_rows_before,
        "source_rows_after": tstats.source_rows_after,
        "rule1": tstats.rule1_applications,
        "rule2": tstats.rule2_applications,
        "rule3": tstats.rule3_merges,
    }


def make_mapsdi_fn(dis: DIS, engine: Engine = "sdm",
                   dedup: Optional[str] = None):
    """Pre-transform once (planning), return jit-friendly semantify closure
    over the *transformed* sources — what steady-state re-execution runs."""
    dis2, _ = apply_mapsdi(dis, dedup=dedup)
    rdfizer = RDFizer(dis2, engine, dedup=dedup)

    def fn(sources: Optional[Dict[str, Table]] = None):
        return rdfizer(sources if sources is not None else dis2.sources)

    return fn, dis2
