"""End-to-end MapSDI pipeline: plan the DIS, then execute one closure.

``mapsdi_create_kg`` = the full framework of Fig. 2, planner-backed:
extract knowledge from the mapping rules, run Rules 1–3 (+ σ pushdown +
CSE) as symbolic rewrites, size every buffer at plan time, and lower the
optimized DAG — pre-processing *and* semantification — to ONE jitted
``sources -> (KG, raw)`` closure. No intermediate source is ever
materialized; the only host work is planning.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

from repro.relalg import Table
from repro.relalg.guard import host_int

from .rdfizer import Engine, RDFizer
from .schema import DIS
from .transform import TransformStats, apply_mapsdi, plan_mapsdi


def _planned_closure(dis: DIS, engine: Engine, dedup: Optional[str],
                     stats: Optional[TransformStats] = None):
    """(symbolic fixpoint, annotate, compile) -> (fn, plan, counts)."""
    from repro.plan.annotate import annotate
    from repro.plan.compile import compile_plan
    plan = plan_mapsdi(dis, stats=stats)
    counts, caps = annotate(plan)
    view = dataclasses.replace(dis.copy(), maps=plan.maps)
    emitter = RDFizer(view, engine, join_caps={}, dedup=dedup)
    fn = compile_plan(plan, emitter, engine=engine, dedup=dedup, caps=caps)
    return fn, plan, counts


def mapsdi_create_kg(dis: DIS, engine: Engine = "sdm",
                     dedup: Optional[str] = None,
                     ) -> Tuple[Table, Dict[str, object]]:
    """Plan + execute; returns (KG, stats incl. Table-1-style sizes).

    ``dedup`` selects the δ strategy (``"lex"`` | ``"hash"``) for both the
    planned Rule 1–3 pre-processing and the engine sinks; None = engine
    default. ``source_rows_after`` reports the plan-time cardinality of
    each map's pre-processed relation (the paper's Table-1 reduced sizes)
    even though those relations only ever exist inside the fused closure.
    """
    from repro.plan.compile import input_names
    t0 = time.perf_counter()
    tstats = TransformStats()
    fn, plan, counts = _planned_closure(dis, engine, dedup, tstats)
    names = input_names(plan)
    rows_after = {names[tm.name]: counts[plan.inputs[tm.name]]
                  for tm in plan.maps}
    t1 = time.perf_counter()
    kg, raw = fn(dis.sources)
    kg.data.block_until_ready()
    t2 = time.perf_counter()
    return kg, {
        "raw_triples": host_int(raw),
        "kg_triples": host_int(kg.count),
        "preprocess_seconds": t1 - t0,   # planning: sync-free fixpoint +
                                         # one host read per source (annotate)
        "semantify_seconds": t2 - t1,    # the single fused closure
        "source_rows_before": {k: host_int(v.count)
                               for k, v in dis.sources.items()},
        "source_rows_after": rows_after,
        "rule1": tstats.rule1_applications,
        "rule2": tstats.rule2_applications,
        "rule3": tstats.rule3_merges,
        "sigma": tstats.sigma_pushdowns,
        "cse_shared": tstats.cse_shared_subplans,
    }


def make_planned_fn(dis: DIS, engine: Engine = "sdm",
                    dedup: Optional[str] = None):
    """Plan once, return the jitted ``raw sources -> (kg, raw)`` closure —
    steady-state re-execution over *untransformed* source extensions, with
    pre-processing fused into the program.

    Buffers are sized from the planning-time extension (exact). Re-running
    on extensions where more rows survive some operator than at plan time
    silently truncates, like join-cap overflow — re-plan when sources
    grow (recompile-on-overflow is a ROADMAP item)."""
    fn, plan, _counts = _planned_closure(dis, engine, dedup)
    return fn, plan


def make_mapsdi_fn(dis: DIS, engine: Engine = "sdm",
                   dedup: Optional[str] = None):
    """Pre-transform once (planning + one materialization), return a
    jit-friendly semantify closure over the *transformed* sources — the
    historical steady-state shape, where pre-processed extensions exist as
    concrete tables (e.g. to be shipped to another pod)."""
    dis2, _ = apply_mapsdi(dis, dedup=dedup)
    rdfizer = RDFizer(dis2, engine, dedup=dedup)

    def fn(sources: Optional[Dict[str, Table]] = None):
        return rdfizer(sources if sources is not None else dis2.sources)

    return fn, dis2
