"""MapSDI — the paper's contribution: mapping-rule-driven semantic data
integration with relational pre-processing (Rules 1-3), an RDFizer engine,
the T-framework baseline, and the pod-scale distributed dedup."""
from .schema import (DIS, PredicateObjectMap, RDF_TYPE, RefObjectMap,
                     Selection, TMPL_BASE, TMPL_CONSTANT, TMPL_LITERAL,
                     TermMap, TRIPLE_ATTRS, TripleMap)
from .rml import dump_maps, load_dis, parse_dis, parse_triple_map
from .analyze import merge_groups, referenced_attrs
from .transform import TransformStats, apply_mapsdi, apply_mapsdi_eager, \
    apply_merge, apply_projection, plan_mapsdi, shrink_to_fit
from .rdfizer import RDFizer, plan_join_caps, rdfize, triples_to_ntriples
from .tframework import make_t_framework_fn, t_framework_create_kg
from .pipeline import make_mapsdi_fn, make_planned_fn, mapsdi_create_kg

__all__ = [
    "DIS", "PredicateObjectMap", "RDF_TYPE", "RefObjectMap", "Selection",
    "TMPL_BASE", "TMPL_CONSTANT", "TMPL_LITERAL", "TermMap", "TRIPLE_ATTRS",
    "TripleMap", "dump_maps", "load_dis", "parse_dis", "parse_triple_map",
    "merge_groups", "referenced_attrs", "TransformStats", "apply_mapsdi",
    "apply_mapsdi_eager", "apply_merge", "apply_projection", "plan_mapsdi",
    "shrink_to_fit", "RDFizer", "plan_join_caps", "rdfize",
    "triples_to_ntriples", "make_t_framework_fn", "t_framework_create_kg",
    "make_mapsdi_fn", "make_planned_fn", "mapsdi_create_kg",
]
