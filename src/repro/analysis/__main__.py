"""``python -m repro.analysis`` — run the static passes from the shell.

Subcommands::

    python -m repro.analysis dis spec.json [--engine E] [--audit] [-v]
    python -m repro.analysis demo [--join] [--engine E] [--audit] [-v]
    python -m repro.analysis store [--root PATH]

``dis`` loads a DIS JSON spec (:func:`repro.core.rml.load_dis`), plans it
through the soundness-gated optimizer, verifies the optimized plan
against its exact annotations and prints the annotated dump with the
verdict; ``--audit`` additionally lowers the single-device closure and
audits its jaxpr. ``demo`` does the same on a built-in synthetic DIS
(``--join`` picks the two-map join spec). ``store`` integrity- and
shape-checks every entry of a persistent plan store without adopting any
executable. Exit status is non-zero iff any check failed.
"""
from __future__ import annotations

import argparse
import sys


def _check_dis(dis, engine: str, audit: bool, verbose: bool) -> int:
    from repro.core.rdfizer import RDFizer
    from repro.plan.annotate import annotate
    from repro.plan.explain import dump_plan
    from repro.plan.lower import lower

    from .audit import audit_closure
    from .soundness import RewriteSoundnessError, checked_optimize
    from .verify import verify_plan

    plan = lower(dis)
    try:
        checked_optimize(plan)
    except RewriteSoundnessError as e:
        print(e)
        return 1
    counts, caps = annotate(plan, mode="exact", sources=dis.sources)
    report = verify_plan(plan, engine, counts=counts, caps=caps)
    if verbose:
        print(dump_plan(plan, engine, counts=counts, caps=caps,
                        schemas=report.schemas, verdict=report.describe()))
    else:
        print(report.describe())
    status = 0 if report.ok else 1
    if audit and report.ok:
        from repro.plan.compile import abstract_sources, compile_plan
        emitter = RDFizer(dis, engine, join_caps={},
                          dedup="hash" if engine == "sdm" else None)
        fn = compile_plan(plan, emitter, engine=engine, caps=caps)
        audit_report = audit_closure(fn, (abstract_sources(dis.sources),),
                                     plan=plan, engine=engine,
                                     single_device=True)
        print(audit_report.describe())
        status = status or (0 if audit_report.ok else 1)
    return status


def _check_store(root) -> int:
    import os

    from repro.api.store import (PlanStore, default_store_root,
                                 read_container)
    store = PlanStore(root or default_store_root())
    required = ("node_count", "engine", "mode", "counts", "caps",
                "build_seconds")
    bad = 0
    entries = sorted(store._entry_files())
    for path in entries:
        name = os.path.basename(path)
        try:
            header, payloads = read_container(path)
            meta = header.get("meta", {})
            missing = [k for k in required if k not in meta]
            if missing:
                raise ValueError(f"meta missing keys {missing}")
            for field in ("counts", "caps"):
                pairs = meta[field]
                idxs = [i for i, _ in pairs]
                if any(i >= int(meta["node_count"]) or i < 0 for i in idxs):
                    raise ValueError(
                        f"{field} node index out of range "
                        f"(node_count={meta['node_count']})")
                if len(set(idxs)) != len(idxs):
                    raise ValueError(f"duplicate node index in {field}")
                if any(int(v) < 0 for _, v in pairs):
                    raise ValueError(f"negative value in {field}")
            if not payloads:
                raise ValueError("entry has no executable payloads")
            print(f"{name}  ok  ({len(payloads)} payload(s), "
                  f"{int(meta['node_count'])} nodes)")
        except Exception as e:
            bad += 1
            print(f"{name}  INVALID ({e})")
    print(f"{len(entries)} entr{'y' if len(entries) == 1 else 'ies'}, "
          f"{bad} invalid")
    return 1 if bad else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("dis", help="verify a DIS JSON spec end to end")
    p.add_argument("spec", help="path to the DIS JSON file")
    p.add_argument("--engine", choices=("rmlmapper", "sdm"),
                   default="rmlmapper")
    p.add_argument("--audit", action="store_true",
                   help="also audit the lowered closure's jaxpr")
    p.add_argument("-v", "--verbose", action="store_true",
                   help="print the fully annotated plan dump")

    p = sub.add_parser("demo", help="verify a built-in synthetic DIS")
    p.add_argument("--join", action="store_true",
                   help="use the two-map join spec instead of group B")
    p.add_argument("--engine", choices=("rmlmapper", "sdm"),
                   default="rmlmapper")
    p.add_argument("--audit", action="store_true")
    p.add_argument("-v", "--verbose", action="store_true")

    p = sub.add_parser("store", help="integrity-check a plan store")
    p.add_argument("--root", default=None)

    args = ap.parse_args(argv)
    if args.cmd == "store":
        return _check_store(args.root)
    if args.cmd == "dis":
        from repro.core.rml import load_dis
        dis = load_dis(args.spec)
    else:
        from repro.data.synthetic import fig5_join_dis, make_group_b_dis
        dis = fig5_join_dis() if args.join else \
            make_group_b_dis(48, 0.6, seed=0)
    return _check_dis(dis, args.engine, args.audit, args.verbose)


if __name__ == "__main__":
    sys.exit(main())
