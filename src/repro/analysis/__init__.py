"""Static plan verification: the compile-time complement of the
differential test harness. Three passes (see ``docs/analysis.md``):

1. :func:`verify_plan` — schema-typed IR checking over the plan DAG;
2. :func:`soundness_gate` / :func:`checked_optimize` — per-rewrite
   lossless-precondition gates over the optimizer fixpoint;
3. :func:`audit_closure` — jaxpr collective/transfer/dtype audit of the
   lowered closure, cross-checked against the annotated exchange plan.

``python -m repro.analysis`` exposes the passes as a CLI over a DIS JSON
spec, the built-in demo DIS, or a persistent plan store.
"""
from .audit import (AuditReport, ClosureAuditError, audit_closure,
                    expected_collectives, expected_query_collectives)
from .soundness import (CONTRACTS, RewriteSoundnessError, checked_optimize,
                        soundness_gate)
from .verify import (Diagnostic, NodeSchema, PlanVerificationError,
                     VerifyReport, verify_plan, verify_query_plan)

__all__ = [
    "AuditReport", "ClosureAuditError", "audit_closure",
    "expected_collectives", "expected_query_collectives", "CONTRACTS",
    "RewriteSoundnessError", "checked_optimize", "soundness_gate",
    "Diagnostic", "NodeSchema", "PlanVerificationError", "VerifyReport",
    "verify_plan", "verify_query_plan",
]
