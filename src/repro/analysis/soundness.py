"""Pass 2 — rewrite-soundness gates over the optimizer fixpoint.

Each ``optimize.py`` rewrite declares its lossless precondition in
:data:`CONTRACTS`; :func:`soundness_gate` plugs into the ``gate=`` hook of
:func:`repro.plan.optimize.optimize` and asserts, after every pass that
changed the plan, (a) the pass-specific schema-equivalence condition and
(b) the generic structural invariants (:func:`~repro.analysis.verify
.verify_plan` minus the hash-consing checks, which only hold after CSE).
A violation raises :class:`RewriteSoundnessError` **naming the offending
rewrite** — a planner bug surfaces at plan time, not as a bit-mismatch
deep inside a differential run.

The conditions mirror the paper's losslessness argument:

* Rules 1 & 2 (``push_projections``) never *invent* columns — the new
  input projects a subset of the old schema that still covers every
  referenced attribute, so ``δ(π_Z̄(R))`` loses no triple-relevant data.
* Rule 3 (``merge_maps``) must put merged maps in the canonical role
  schema (``__m0`` subject, ``__m{i}`` for the i-th predicate-sorted
  non-constant object) so equal heads really do read equal columns.
* σ-pushdown (``push_selections``) is a pure filter: the relation schema
  is preserved exactly; only rows that could never emit a triple go.
* CSE (``cse``) is sharing only: every input must remain *structurally*
  equal to its pre-pass value, and the maps untouched.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.core.analyze import referenced_attrs, sorted_reference_poms
from repro.plan.ir import Node
from repro.plan.lower import LogicalPlan
from repro.plan.optimize import PlanStats, optimize

from .verify import Diagnostic, verify_plan

#: pass name -> the lossless precondition it promises (rendered in error
#: messages and in docs/analysis.md)
CONTRACTS: Dict[str, str] = {
    "merge_maps": (
        "Rule 3: merged maps use the canonical role schema (__m0 subject, "
        "__m{i} for the i-th predicate-sorted non-constant object) and "
        "their merged input provides every role column"),
    "push_projections": (
        "Rules 1 & 2: a rewritten input's schema is a subset of the old "
        "schema that still covers every attribute the map references"),
    "push_selections": (
        "σ-pushdown: the input schema is preserved exactly — only "
        "triple-irrelevant rows are filtered"),
    "cse": (
        "CSE: pure sharing — every input stays structurally equal to its "
        "pre-pass value and the maps are untouched"),
}


class RewriteSoundnessError(ValueError):
    """A rewrite violated its declared precondition; ``.rewrite`` names
    the offending pass, ``.diagnostics`` holds the findings."""

    def __init__(self, rewrite: str, diagnostics: List[Diagnostic]):
        contract = CONTRACTS.get(rewrite, "(no declared contract)")
        lines = [f"rewrite {rewrite!r} violated its soundness contract",
                 f"  contract: {contract}"]
        lines += [f"  {d}" for d in diagnostics]
        super().__init__("\n".join(lines))
        self.rewrite = rewrite
        self.diagnostics = diagnostics


class _MapsView:
    def __init__(self, maps):
        self.maps = maps


def _check_push_projections(before, plan: LogicalPlan,
                            out: List[Diagnostic]) -> None:
    maps_before, inputs_before = before
    if maps_before != plan.maps:
        out.append(Diagnostic(
            "rewrite", "push_projections",
            "pass modified the triple maps — it may only rewrite inputs"))
        return
    needed = referenced_attrs(_MapsView(plan.maps))
    for tm in plan.maps:
        old, new = inputs_before.get(tm.name), plan.inputs.get(tm.name)
        if new is None or old is None or new == old:
            continue
        old_attrs, new_attrs = set(old.attrs), set(new.attrs)
        missing = needed[tm.name] - new_attrs
        if missing:
            out.append(Diagnostic(
                "rewrite", f"map {tm.name!r}",
                f"projection dropped referenced attrs {sorted(missing)}"))
        invented = new_attrs - old_attrs
        if invented:
            out.append(Diagnostic(
                "rewrite", f"map {tm.name!r}",
                f"projection invented attrs {sorted(invented)} absent "
                "from the original schema"))


def _check_push_selections(before, plan: LogicalPlan,
                           out: List[Diagnostic]) -> None:
    maps_before, inputs_before = before
    if maps_before != plan.maps:
        out.append(Diagnostic(
            "rewrite", "push_selections",
            "pass modified the triple maps — it may only add σ filters"))
        return
    for tm in plan.maps:
        old, new = inputs_before.get(tm.name), plan.inputs.get(tm.name)
        if new is None or old is None or new == old:
            continue
        if tuple(new.attrs) != tuple(old.attrs):
            out.append(Diagnostic(
                "rewrite", f"map {tm.name!r}",
                f"σ-pushdown changed the schema {tuple(old.attrs)} -> "
                f"{tuple(new.attrs)} — a filter must be schema-preserving"
            ))


def _check_merge_maps(before, plan: LogicalPlan,
                      out: List[Diagnostic]) -> None:
    maps_before, _ = before
    old_names = {m.name for m in maps_before}
    for tm in plan.maps:
        if tm.name in old_names:
            continue
        # a freshly merged map: canonical role schema
        sub = tm.subject.referenced_attr
        if sub is not None and sub != "__m0":
            out.append(Diagnostic(
                "rewrite", f"map {tm.name!r}",
                f"merged subject reads {sub!r}, not the canonical '__m0'"))
        want = 0
        for idx, term in sorted_reference_poms(tm):
            if term.kind == "constant":
                continue
            want += 1
            if term.attr != f"__m{want}":
                out.append(Diagnostic(
                    "rewrite", f"map {tm.name!r}",
                    f"merged POM #{idx} reads {term.attr!r}, not the "
                    f"canonical '__m{want}'"))
        node = plan.inputs.get(tm.name)
        if node is None:
            out.append(Diagnostic(
                "rewrite", f"map {tm.name!r}",
                "merged map has no input relation"))
            continue
        roles = {f"__m{i}" for i in range(want + 1)} if sub else \
            {f"__m{i}" for i in range(1, want + 1)}
        missing = roles - set(node.attrs)
        if missing:
            out.append(Diagnostic(
                "rewrite", f"map {tm.name!r}",
                f"merged input lacks role columns {sorted(missing)}"))


def _check_cse(before, plan: LogicalPlan, out: List[Diagnostic]) -> None:
    maps_before, inputs_before = before
    if maps_before != plan.maps:
        out.append(Diagnostic("rewrite", "cse",
                              "CSE modified the triple maps"))
    if set(inputs_before) != set(plan.inputs):
        out.append(Diagnostic(
            "rewrite", "cse",
            f"CSE changed the input set {sorted(inputs_before)} -> "
            f"{sorted(plan.inputs)}"))
        return
    for name, old in inputs_before.items():
        if plan.inputs[name] != old:
            out.append(Diagnostic(
                "rewrite", f"map {name!r}",
                "CSE changed the input's structure — it may only re-share "
                "equal subplans"))


_PASS_CHECKS = {
    "merge_maps": _check_merge_maps,
    "push_projections": _check_push_projections,
    "push_selections": _check_push_selections,
    "cse": _check_cse,
}


def soundness_gate(name: str,
                   before: Tuple[List, Dict[str, Node]],
                   plan: LogicalPlan) -> None:
    """The ``gate=`` callback for :func:`repro.plan.optimize.optimize`:
    assert pass ``name``'s contract over the (maps, inputs) snapshot taken
    before it ran. Raises :class:`RewriteSoundnessError` on violation."""
    out: List[Diagnostic] = []
    check = _PASS_CHECKS.get(name)
    if check is None:
        out.append(Diagnostic(
            "rewrite", name,
            "unknown rewrite pass — no soundness contract declared"))
    else:
        check(before, plan, out)
    # generic structural invariants; hash-consing form only holds post-CSE
    report = verify_plan(plan, check_cse=(name == "cse"))
    out.extend(report.errors())
    if out:
        raise RewriteSoundnessError(name, out)


def checked_optimize(plan: LogicalPlan, max_iters: int = 8,
                     stats: Optional[PlanStats] = None) -> PlanStats:
    """:func:`repro.plan.optimize.optimize` with every rewrite gated by
    :func:`soundness_gate`."""
    return optimize(plan, max_iters=max_iters, stats=stats,
                    gate=soundness_gate)
