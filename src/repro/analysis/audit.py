"""Pass 3 — the jaxpr auditor (``audit_closure``).

Inspect a lowered closure's jaxpr *without executing it* and assert the
device-residency invariants the runtime ``forbid_transfers`` ledger can
only observe dynamically:

* **zero host callbacks / transfers** — no ``*_callback``, ``infeed`` /
  ``outfeed``, or ``device_put`` equation anywhere in the (recursively
  walked) jaxpr;
* **collective accounting** — the number of ``all_to_all`` /
  ``all_gather`` equations must match what the annotated exchange plan
  implies (:func:`expected_collectives`): a ``repartition`` ⋈ contributes
  one key-exchange per *undeduplicated side* (each lowering to 2
  ``all_to_all`` eqns — row payload + bucket counts), a ``gather`` ⋈ one
  broadcast per undeduplicated parent (2 ``all_gather`` eqns), plus the
  plan's global-δ and sink exchanges (see the table in
  ``docs/analysis.md``). Extra collectives mean the mesh lowering
  diverged from the plan the cost model priced; missing ones mean a
  shard is computing on data it never received.
* **dtype stability** — no unintended 64-bit promotion: every value in
  the closure is int32/uint32/bool by construction, so a wide dtype
  means an accidental x64 upcast that silently doubles exchange bytes.

The auditor works on the *pre-AOT* jitted closure (``jax.make_jaxpr``
traces through ``jit``); serialized AOT executables are covered because
they are lowered from the very closure audited here.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax

from repro.plan.ir import Distinct, EquiJoin, Node, iter_nodes
from repro.plan.lower import LogicalPlan

from .verify import Diagnostic

#: jaxpr equation names that execute on (or round-trip through) the host
HOST_CALLBACK_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "callback", "debug_callback",
    "infeed", "outfeed",
})
#: equation names that move data between host and device mid-closure
TRANSFER_PRIMITIVES = frozenset({"device_put", "transfer_to_host"})
#: the collectives the mesh lowering is allowed to use
COLLECTIVE_PRIMITIVES = ("all_gather", "all_to_all", "pmax", "psum",
                        "ppermute")

#: eqn fan-out per exchange site: one key-repartition lowers to 2
#: ``all_to_all`` (row payload + per-bucket counts), one table gather to
#: 2 ``all_gather`` (rows + counts) — measured, and pinned by tests
EQNS_PER_REPARTITION = 2
EQNS_PER_GATHER = 2


@dataclasses.dataclass
class AuditReport:
    """Outcome of one ``audit_closure`` run."""

    primitive_counts: Dict[str, int]
    collectives: Dict[str, int]
    expected: Optional[Dict[str, int]]
    host_callbacks: Tuple[str, ...]
    transfers: Tuple[str, ...]
    promotions: Tuple[str, ...]
    diagnostics: List[Diagnostic]

    @property
    def ok(self) -> bool:
        return not self.diagnostics

    def describe(self) -> str:
        coll = ", ".join(f"{k}={v}" for k, v in
                         sorted(self.collectives.items())) or "none"
        head = f"audit: {'ok' if self.ok else 'FAILED'} (collectives: {coll}"
        if self.expected is not None:
            exp = ", ".join(f"{k}={v}" for k, v in
                            sorted(self.expected.items()))
            head += f"; expected: {exp}"
        lines = [head + ")"]
        lines += [f"  {d}" for d in self.diagnostics]
        return "\n".join(lines)

    def raise_for_status(self) -> "AuditReport":
        if not self.ok:
            raise ClosureAuditError(self)
        return self


class ClosureAuditError(ValueError):
    """A lowered closure failed the static audit; ``.report`` has it."""

    def __init__(self, report: AuditReport):
        super().__init__(report.describe())
        self.report = report


def _walk_jaxpr(jaxpr, counter: Counter) -> Counter:
    """Count every equation's primitive, recursing into sub-jaxprs held
    in equation params (pjit/shard_map/scan/cond bodies)."""
    for eqn in jaxpr.eqns:
        counter[eqn.primitive.name] += 1
        for value in eqn.params.values():
            for x in (value if isinstance(value, (list, tuple))
                      else (value,)):
                inner = getattr(x, "jaxpr", None)
                if inner is not None and hasattr(inner, "eqns"):
                    _walk_jaxpr(inner, counter)     # ClosedJaxpr
                elif hasattr(x, "eqns"):
                    _walk_jaxpr(x, counter)         # raw Jaxpr
    return counter


def _wide_outvars(jaxpr, out: List[str], seen: set) -> None:
    for eqn in jaxpr.eqns:
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            dtype = getattr(aval, "dtype", None)
            if dtype is not None and dtype.itemsize > 4:
                key = f"{eqn.primitive.name} -> {dtype}"
                if key not in seen:
                    seen.add(key)
                    out.append(key)
        for value in eqn.params.values():
            for x in (value if isinstance(value, (list, tuple))
                      else (value,)):
                inner = getattr(x, "jaxpr", None)
                if inner is not None and hasattr(inner, "eqns"):
                    _wide_outvars(inner, out, seen)
                elif hasattr(x, "eqns"):
                    _wide_outvars(x, out, seen)


def expected_collectives(plan: LogicalPlan, engine: str = "rmlmapper",
                         n_shards: int = 1,
                         exchanges: Optional[Mapping[Node, object]] = None,
                         single_device: bool = False) -> Dict[str, int]:
    """Collective eqn counts the annotated exchange plan implies.

    Mirrors ``compile_mesh_plan``'s memoization exactly: repartition ⋈
    sides dedupe on ``(side_node, key)``, gathers on the parent node, the
    per-value global-δ exchanges are gated on ``n_shards > 1``, the sdm
    sink runs one per-map rowhash exchange (``n_shards > 1``) while the
    rmlmapper fused sink always repartitions (once, even on one shard).
    ``single_device=True`` describes the meshless ``compile_plan`` path,
    which must contain no collectives at all.
    """
    if single_device:
        return {"all_gather": 0, "all_to_all": 0}
    strategies = {node: getattr(x, "strategy", x)
                  for node, x in (exchanges or {}).items()}
    repart_sides: set = set()
    gather_parents: set = set()
    distincts: set = set()
    emit_nodes = plan.emits()
    for emit in emit_nodes:
        for node in iter_nodes(emit):
            if isinstance(node, EquiJoin):
                if strategies.get(node) == "repartition":
                    repart_sides.add((node.left, node.left_key))
                    repart_sides.add((node.right, node.right_key))
                else:
                    gather_parents.add(node.right)
            elif isinstance(node, Distinct):
                distincts.add(node)
    sites = len(repart_sides)
    if n_shards > 1:
        sites += len(distincts)
        if engine == "sdm":
            sites += len(emit_nodes)
    if engine != "sdm":
        sites += 1  # fused rowhash sink exchange, unconditional
    return {"all_gather": EQNS_PER_GATHER * len(gather_parents),
            "all_to_all": EQNS_PER_REPARTITION * sites}


def expected_query_collectives(plan, n_shards: int = 1,
                               exchanges: Optional[Mapping[Node, object]]
                               = None,
                               single_device: bool = False
                               ) -> Dict[str, int]:
    """Collective eqn counts a fused query closure
    (:func:`repro.query.mesh.compile_query_mesh`) implies — the query-DAG
    sibling of :func:`expected_collectives`: same per-site fan-out and
    memoization (repartition ⋈ sides dedupe on ``(side_node, key)``,
    gathers on the parent node, every δ — including the root — is one
    rowhash exchange when ``n_shards > 1``), no emitter/sink terms.
    ``plan`` is duck-typed via ``emits()`` (a
    :class:`repro.query.lower.QueryPlan`)."""
    if single_device:
        return {"all_gather": 0, "all_to_all": 0}
    strategies = {node: getattr(x, "strategy", x)
                  for node, x in (exchanges or {}).items()}
    repart_sides: set = set()
    gather_parents: set = set()
    distincts: set = set()
    for root in plan.emits():
        for node in iter_nodes(root):
            if isinstance(node, EquiJoin):
                if strategies.get(node) == "repartition":
                    repart_sides.add((node.left, node.left_key))
                    repart_sides.add((node.right, node.right_key))
                else:
                    gather_parents.add(node.right)
            elif isinstance(node, Distinct):
                distincts.add(node)
    sites = len(repart_sides)
    if n_shards > 1:
        sites += len(distincts)
    return {"all_gather": EQNS_PER_GATHER * len(gather_parents),
            "all_to_all": EQNS_PER_REPARTITION * sites}


def audit_closure(fn, abstract_args: Sequence, *,
                  plan: Optional[LogicalPlan] = None,
                  engine: str = "rmlmapper", n_shards: int = 1,
                  exchanges: Optional[Mapping[Node, object]] = None,
                  single_device: bool = False,
                  expected_counts: Optional[Dict[str, int]] = None
                  ) -> AuditReport:
    """Trace ``fn`` over ``abstract_args`` (ShapeDtypeStructs — nothing
    executes) and audit the jaxpr. With ``plan`` given, the observed
    collective counts are cross-checked against
    :func:`expected_collectives`; ``expected_counts`` supplies the
    expectation directly instead (the query path passes
    :func:`expected_query_collectives`); without either only the
    residency and dtype invariants are asserted. Returns an
    :class:`AuditReport`."""
    jaxpr = jax.make_jaxpr(fn)(*abstract_args)
    counts = dict(_walk_jaxpr(jaxpr.jaxpr, Counter()))
    diags: List[Diagnostic] = []

    callbacks = tuple(sorted(
        name for name in counts
        if name in HOST_CALLBACK_PRIMITIVES or name.endswith("_callback")))
    for name in callbacks:
        diags.append(Diagnostic(
            "host-callback", name,
            f"{counts[name]} host-callback eqn(s) in the closure — the "
            "plan must be device-resident end to end"))
    transfers = tuple(sorted(
        name for name in counts if name in TRANSFER_PRIMITIVES))
    for name in transfers:
        diags.append(Diagnostic(
            "host-transfer", name,
            f"{counts[name]} host/device transfer eqn(s) in the closure"))

    promotions: List[str] = []
    _wide_outvars(jaxpr.jaxpr, promotions, set())
    for p in promotions:
        diags.append(Diagnostic(
            "dtype-promotion", p,
            "64-bit value in a closure that is int32/bool by "
            "construction — an accidental x64 promotion"))

    collectives = {name: counts.get(name, 0)
                   for name in ("all_gather", "all_to_all")}
    expected = expected_counts
    if expected is None and plan is not None:
        expected = expected_collectives(plan, engine, n_shards,
                                        exchanges=exchanges,
                                        single_device=single_device)
    if expected is not None:
        for name in sorted(set(expected) | set(collectives)):
            want, got = expected.get(name, 0), collectives.get(name, 0)
            if want != got:
                diags.append(Diagnostic(
                    "collective-mismatch", name,
                    f"closure contains {got} {name} eqn(s) but the "
                    f"annotated exchange plan implies {want}"))
        if single_device:
            stray = {k: v for k, v in counts.items()
                     if k in COLLECTIVE_PRIMITIVES and v}
            for name, v in sorted(stray.items()):
                diags.append(Diagnostic(
                    "collective-mismatch", name,
                    f"single-device plan contains {v} {name} eqn(s) — "
                    "it must lower collective-free"))
    return AuditReport(primitive_counts=counts, collectives=collectives,
                       expected=expected, host_callbacks=callbacks,
                       transfers=transfers,
                       promotions=tuple(promotions), diagnostics=diags)
