"""Pass 1 — the schema-typed IR verifier (``verify_plan``).

Bottom-up schema/type inference over the plan DAG: every node gets an
inferred :class:`NodeSchema` (column set + per-column dtype, propagated
from the source extensions), and a battery of structural checks rejects
malformed plans with *named* diagnostics instead of letting them surface
as shape errors deep inside an XLA trace — or worse, as a silently wrong
KG. The checks (see ``docs/analysis.md`` for the full invariant table):

* **references** — ``Project``/``Select``/``EquiJoin`` columns must exist
  in the child schema; join keys must agree on dtype; ``Union`` children
  must share one attribute set; ``Scan`` attrs must match the source.
* **semantification** — every ``EmitTriples`` term map must resolve
  against its input schema, each join POM must have a matching ⋈ carrying
  the reserved ``__ps``/``__pk`` columns, and a map that can emit nothing
  (no class, no POMs) is flagged.
* **annotations** — plan-time counts must be monotone under the algebra
  (σ/π/δ never grow their child, ∪ is bounded by its inputs' sum) and
  capacities must be consistent (a buffer must hold its planned rows; a
  node's cap must not exceed what its parents can produce). Shard-local
  capacities (``annotate_local``) are checked mode-aware: a post-exchange
  δ block may legitimately exceed its child's *local* cap (rows
  redistribute), so only the redistribution-free relations are compared.
* **shape** — cycles (a frozen dataclass DAG can still be made cyclic
  through ``object.__setattr__``) and non-canonical forms CSE relies on
  (nested/unsorted/duplicated σ, ``Distinct(Distinct)``, unary ∪, equal
  subplans left as distinct objects).

``verify_plan`` returns a :class:`VerifyReport`; callers that want the
raise-on-failure contract use :meth:`VerifyReport.raise_for_status`
(:class:`PlanVerificationError` carries the report).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.core.schema import RefObjectMap, TermMap
from repro.plan.ir import (ColEq, Distinct, EmitTriples, EquiJoin, Node,
                           Project, Scan, Select, Union)
from repro.plan.lower import LogicalPlan

#: dtype every Table column carries by construction
#: (:meth:`repro.relalg.Table.from_codes` forces int32)
DEFAULT_DTYPE = np.dtype(np.int32)


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One named verifier finding: ``code`` is the stable machine-readable
    diagnostic name tests and tools key on, ``where`` locates the node.

    ``severity`` is ``"error"`` (fails verification) or ``"warning"``
    (reported, but a plan carrying only warnings still verifies — e.g. a
    degenerate triples map that legitimately emits zero triples)."""

    code: str
    where: str
    message: str
    severity: str = "error"

    def __str__(self) -> str:
        tag = self.code if self.severity == "error" else f"{self.code}/warn"
        return f"[{tag}] {self.where}: {self.message}"


@dataclasses.dataclass(frozen=True)
class NodeSchema:
    """Inferred output schema of one node: ordered columns + dtypes."""

    attrs: Tuple[str, ...]
    dtypes: Tuple[np.dtype, ...]

    def dtype_of(self, attr: str) -> Optional[np.dtype]:
        try:
            return self.dtypes[self.attrs.index(attr)]
        except ValueError:
            return None

    def describe(self) -> str:
        if all(dt == DEFAULT_DTYPE for dt in self.dtypes):
            return ",".join(self.attrs)
        return ",".join(f"{a}:{dt}" for a, dt in zip(self.attrs, self.dtypes))


@dataclasses.dataclass
class VerifyReport:
    """Outcome of one ``verify_plan`` run."""

    diagnostics: List[Diagnostic]
    schemas: Dict[Node, NodeSchema]
    nodes_checked: int = 0

    @property
    def ok(self) -> bool:
        return not self.errors()

    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "error"]

    def codes(self) -> Tuple[str, ...]:
        return tuple(sorted({d.code for d in self.diagnostics}))

    def describe(self) -> str:
        if self.ok:
            n_warn = len(self.diagnostics)
            suffix = f", {n_warn} warning(s)" if n_warn else ""
            lines = [f"verify: ok ({self.nodes_checked} nodes{suffix})"]
            lines += [f"  {d}" for d in self.diagnostics]
            return "\n".join(lines)
        lines = [f"verify: FAILED ({len(self.errors())} diagnostic(s) "
                 f"over {self.nodes_checked} nodes)"]
        lines += [f"  {d}" for d in self.diagnostics]
        return "\n".join(lines)

    def raise_for_status(self) -> "VerifyReport":
        if not self.ok:
            raise PlanVerificationError(self)
        return self


class PlanVerificationError(ValueError):
    """A plan failed static verification; ``.report`` has the findings."""

    def __init__(self, report: VerifyReport):
        super().__init__(report.describe())
        self.report = report


def _label(node: Node) -> str:
    from repro.plan.explain import _label as lab
    return lab(node)


# ---------------------------------------------------------------------------
# traversal
# ---------------------------------------------------------------------------

def _postorder(roots: List[Node], out: List[Diagnostic]
               ) -> Optional[List[Node]]:
    """Iterative post-order over unique node *objects*, with an on-path
    set so a cyclic DAG — impossible through the public constructors,
    reachable via ``object.__setattr__`` or a buggy rewrite — reports
    ``cycle`` instead of recursing forever. All bookkeeping is by
    ``id()``: even structural ``__hash__`` diverges on a cyclic node, so
    nothing may hash a node before acyclicity is established. Returns
    ``None`` when a cycle was found (no safe order exists)."""
    order: List[Node] = []
    done: set = set()
    on_path: set = set()
    for root in roots:
        stack: List[Tuple[Node, bool]] = [(root, False)]
        while stack:
            node, expanded = stack.pop()
            if expanded:
                on_path.discard(id(node))
                if id(node) not in done:
                    done.add(id(node))
                    order.append(node)
                continue
            if id(node) in done:
                continue
            if id(node) in on_path:
                out.append(Diagnostic(
                    "cycle", _label(node),
                    "plan DAG contains a cycle through this node"))
                return None
            on_path.add(id(node))
            stack.append((node, True))
            for child in node.children():
                stack.append((child, False))
    return order


# ---------------------------------------------------------------------------
# schema inference + structural checks
# ---------------------------------------------------------------------------

def _infer(node: Node, schemas: Dict[Node, NodeSchema],
           sources: Mapping[str, object], out: List[Diagnostic]) -> None:
    """Infer ``schemas[node]`` from its children (already inferred) and
    append reference/arity/dtype diagnostics. Inference is best-effort on
    error so one bad column does not cascade into spurious findings."""
    where = _label(node)

    def schema_of(child: Node) -> NodeSchema:
        return schemas[child]

    if isinstance(node, Scan):
        dtype = DEFAULT_DTYPE
        src = sources.get(node.source)
        if src is None:
            if sources:
                out.append(Diagnostic(
                    "unknown-source", where,
                    f"scans source {node.source!r} which is not among the "
                    f"extensions {sorted(sources)}"))
        else:
            dtype = np.dtype(src.data.dtype)
            if tuple(src.attrs) != tuple(node.scan_attrs):
                out.append(Diagnostic(
                    "scan-schema-drift", where,
                    f"scan attrs {node.scan_attrs} != source extension "
                    f"attrs {tuple(src.attrs)}"))
        schemas[node] = NodeSchema(node.scan_attrs,
                                   (dtype,) * len(node.scan_attrs))
        return

    if isinstance(node, Project):
        child = schema_of(node.child)
        if not node.spec:
            out.append(Diagnostic("empty-projection", where,
                                  "projection with an empty column spec"))
        seen_dst: Dict[str, str] = {}
        dtypes = []
        for src_attr, dst in node.spec:
            if src_attr not in child.attrs:
                out.append(Diagnostic(
                    "unknown-column", where,
                    f"projects {src_attr!r} which is not in the child "
                    f"schema [{child.describe()}]"))
            if dst in seen_dst:
                out.append(Diagnostic(
                    "duplicate-column", where,
                    f"output column {dst!r} produced twice"))
            seen_dst[dst] = src_attr
            dtypes.append(child.dtype_of(src_attr) or DEFAULT_DTYPE)
        schemas[node] = NodeSchema(node.attrs, tuple(dtypes))
        return

    if isinstance(node, Select):
        child = schema_of(node.child)
        for p in node.preds:
            if p.attr not in child.attrs:
                out.append(Diagnostic(
                    "unknown-column", where,
                    f"σ predicate references {p.attr!r} which is not in "
                    f"the child schema [{child.describe()}]"))
        schemas[node] = child
        return

    if isinstance(node, ColEq):
        child = schema_of(node.child)
        for attr in (node.left_attr, node.right_attr):
            if attr not in child.attrs:
                out.append(Diagnostic(
                    "unknown-column", where,
                    f"σ= references {attr!r} which is not in the child "
                    f"schema [{child.describe()}]"))
        lt = child.dtype_of(node.left_attr)
        rt = child.dtype_of(node.right_attr)
        if lt is not None and rt is not None and lt != rt:
            out.append(Diagnostic(
                "coleq-dtype", where,
                f"σ= column dtypes differ: {node.left_attr}:{lt} vs "
                f"{node.right_attr}:{rt}"))
        schemas[node] = child
        return

    if isinstance(node, Distinct):
        schemas[node] = schema_of(node.child)
        return

    if isinstance(node, Union):
        first = schema_of(node.inputs[0]) if node.inputs else \
            NodeSchema((), ())
        for c in node.inputs[1:]:
            cs = schema_of(c)
            if set(cs.attrs) != set(first.attrs) or \
                    len(cs.attrs) != len(first.attrs):
                out.append(Diagnostic(
                    "union-arity", where,
                    f"∪ input schema [{cs.describe()}] does not match the "
                    f"first input's [{first.describe()}]"))
        schemas[node] = first
        return

    if isinstance(node, EquiJoin):
        left, right = schema_of(node.left), schema_of(node.right)
        for key, side, name in ((node.left_key, left, "left"),
                                (node.right_key, right, "right")):
            if key not in side.attrs:
                out.append(Diagnostic(
                    "unknown-column", where,
                    f"{name} join key {key!r} is not in the {name} schema "
                    f"[{side.describe()}]"))
        lk, rk = left.dtype_of(node.left_key), right.dtype_of(node.right_key)
        if lk is not None and rk is not None and lk != rk:
            out.append(Diagnostic(
                "join-key-dtype", where,
                f"join key dtypes differ: {node.left_key}:{lk} vs "
                f"{node.right_key}:{rk}"))
        schemas[node] = NodeSchema(node.attrs,
                                   left.dtypes + right.dtypes)
        return

    if isinstance(node, EmitTriples):
        schemas[node] = NodeSchema(node.attrs,
                                   (DEFAULT_DTYPE,) * len(node.attrs))
        return

    out.append(Diagnostic("unknown-node", where,
                          f"unrecognized node type {type(node).__name__}"))
    schemas[node] = NodeSchema((), ())


def _check_canonical(node: Node, out: List[Diagnostic]) -> None:
    """Canonical-form invariants the optimizer's CSE (hash-consing)
    depends on: equal relations must be *structurally* equal, which only
    holds if σ is flattened/sorted/deduplicated (``make_select``), δ is
    not stacked, and ∪ is genuinely n-ary."""
    where = _label(node)
    if isinstance(node, Select):
        if not node.preds:
            out.append(Diagnostic("non-canonical", where,
                                  "σ with an empty predicate set"))
        if isinstance(node.child, Select):
            out.append(Diagnostic(
                "non-canonical", where,
                "nested σ(σ(..)) — make_select flattens these"))
        key = [(p.attr, p.op, p.code if p.code is not None else -1)
               for p in node.preds]
        if key != sorted(key):
            out.append(Diagnostic(
                "non-canonical", where,
                "σ predicates are not in canonical sorted order"))
        if len(set(node.preds)) != len(node.preds):
            out.append(Diagnostic("non-canonical", where,
                                  "σ carries duplicate predicates"))
    elif isinstance(node, ColEq):
        if node.left_attr > node.right_attr:
            out.append(Diagnostic(
                "non-canonical", where,
                "σ= attr pair is not in canonical sorted order — "
                "make_coleq orders it"))
    elif isinstance(node, Distinct):
        if isinstance(node.child, Distinct):
            out.append(Diagnostic("non-canonical", where,
                                  "δ(δ(..)) — the inner δ is redundant"))
    elif isinstance(node, Union):
        if len(node.inputs) < 2:
            out.append(Diagnostic(
                "non-canonical", where,
                f"∪ with {len(node.inputs)} input(s) — must be n-ary"))


def _check_emit(node: EmitTriples, plan: LogicalPlan,
                schemas: Dict[Node, NodeSchema],
                out: List[Diagnostic]) -> None:
    tm = node.tm
    where = _label(node)
    input_schema = schemas[node.input]
    map_names = {m.name for m in plan.maps}

    def need(attr: Optional[str], schema: NodeSchema, what: str) -> None:
        if attr is not None and attr not in schema.attrs:
            out.append(Diagnostic(
                "emit-unresolved", where,
                f"{what} references {attr!r} which is not in the input "
                f"schema [{schema.describe()}]"))

    if tm.subject_class is None and not tm.poms:
        out.append(Diagnostic(
            "emit-empty", where,
            f"map {tm.name!r} has neither a subject class nor POMs — it "
            "resolves to nothing (emits zero triples)",
            severity="warning"))
    need(tm.subject.referenced_attr, input_schema, "subject term map")
    for sel in tm.selections:
        need(sel.attr, input_schema, "σ selection")

    join_nodes = dict(node.joins)
    want_joins = {i for i, pom in enumerate(tm.poms)
                  if isinstance(pom.object, RefObjectMap)}
    if set(join_nodes) != want_joins:
        out.append(Diagnostic(
            "emit-unresolved", where,
            f"join POM indices {sorted(want_joins)} do not match the "
            f"attached ⋈ nodes {sorted(join_nodes)}"))
    for i, pom in enumerate(tm.poms):
        obj = pom.object
        if isinstance(obj, RefObjectMap):
            if obj.parent_map not in map_names:
                out.append(Diagnostic(
                    "emit-unresolved", where,
                    f"join POM #{i} references parent map "
                    f"{obj.parent_map!r} which is not in the plan"))
                continue
            join = join_nodes.get(i)
            if join is None:
                continue
            joined = schemas[join]
            need(tm.subject.referenced_attr, joined,
                 f"join POM #{i} (child subject)")
            parent_tm = plan.map_by_name(obj.parent_map)
            if parent_tm.subject.referenced_attr is not None and \
                    "__ps" not in joined.attrs:
                out.append(Diagnostic(
                    "emit-unresolved", where,
                    f"join POM #{i}: ⋈ output lacks the reserved parent-"
                    "subject column '__ps'"))
            for sel in tm.selections:
                need(sel.attr, joined, f"join POM #{i} σ selection")
        elif isinstance(obj, TermMap):
            need(obj.referenced_attr, input_schema, f"POM #{i} object")


def _check_annotations(order: List[Node],
                       counts: Optional[Mapping[Node, int]],
                       caps: Optional[Mapping[Node, int]],
                       shard_local: bool, slack: float,
                       out: List[Diagnostic]) -> None:
    """Count monotonicity + capacity consistency (see module docstring).

    Count relations hold for BOTH annotate modes — exact counts obey the
    algebra and ``mode="bound"`` computes exactly these bounds. ⋈ uses
    ``max(|L|·|R|, |L|+|R|)`` because bound mode applies the FK heuristic
    ``|L|+|R|``, which exceeds the true product when a side is empty.
    Capacity comparisons assume one monotone ``cap_fn`` sized the whole
    plan; shard-local caps skip every redistribution-crossing comparison
    (δ Poisson bounds, ∪ of differently-clamped slices)."""
    counts = counts or {}
    caps = caps or {}
    # with slack >= 1 a buffer must at least hold its planned count; a
    # deliberate under-sizing (slack < 1) only demands the slacked share
    hold = min(1.0, slack)

    def c(n: Node) -> Optional[int]:
        return counts.get(n)

    for node in order:
        where = _label(node)
        cnt, cap = counts.get(node), caps.get(node)
        if cnt is not None and cnt < 0:
            out.append(Diagnostic("capacity", where,
                                  f"negative planned count {cnt}"))
        if cap is not None and cap < 0:
            out.append(Diagnostic("capacity", where,
                                  f"negative planned capacity {cap}"))
        if cnt is not None:
            kids = [c(k) for k in node.children()]
            if isinstance(node, (Project, Select, ColEq, Distinct)) and \
                    kids and kids[0] is not None and cnt > kids[0]:
                out.append(Diagnostic(
                    "capacity", where,
                    f"count {cnt} exceeds its child's count {kids[0]} — "
                    "π/σ/δ can never grow a relation"))
            elif isinstance(node, Union) and all(k is not None
                                                 for k in kids):
                if cnt > sum(kids):
                    out.append(Diagnostic(
                        "capacity", where,
                        f"count {cnt} exceeds the sum of its inputs "
                        f"({sum(kids)})"))
            elif isinstance(node, EquiJoin) and all(k is not None
                                                    for k in kids):
                bound = max(kids[0] * kids[1], kids[0] + kids[1])
                if cnt > bound:
                    out.append(Diagnostic(
                        "capacity", where,
                        f"⋈ match total {cnt} exceeds every admissible "
                        f"bound ({bound})"))
        if cap is None:
            continue
        if not shard_local:
            if cnt is not None and cap < int(math.ceil(cnt * hold)):
                out.append(Diagnostic(
                    "capacity", where,
                    f"capacity {cap} cannot hold the node's own planned "
                    f"count {cnt}"))
            kid_caps = [caps.get(k) for k in node.children()]
            if isinstance(node, (Project, Select, ColEq, Distinct)) and \
                    kid_caps and kid_caps[0] is not None and \
                    cap > kid_caps[0]:
                out.append(Diagnostic(
                    "capacity", where,
                    f"capacity {cap} exceeds its child's capacity "
                    f"{kid_caps[0]} — more than the parent can produce"))
            elif isinstance(node, Union) and all(k is not None
                                                 for k in kid_caps):
                limit = 2 * sum(kid_caps) + 64
                if cap > limit:
                    out.append(Diagnostic(
                        "capacity", where,
                        f"capacity {cap} exceeds what the ∪ inputs can "
                        f"produce (≤ {limit})"))
        else:
            # shard-local caps: only π/σ stay below their child (δ and ⋈
            # redistribute rows across shards; ∪ mixes clamped slices)
            kid_caps = [caps.get(k) for k in node.children()]
            if isinstance(node, (Project, Select, ColEq)) and kid_caps and \
                    kid_caps[0] is not None and cap > kid_caps[0]:
                out.append(Diagnostic(
                    "capacity", where,
                    f"shard-local capacity {cap} exceeds its child's "
                    f"{kid_caps[0]} — π/σ never grow their block"))


def _check_cse(roots: List[Node], out: List[Diagnostic]) -> None:
    """After hash-consing, structurally-equal subplans must be the same
    object across the given roots (the executor memoizes by value, so
    aliasing is a missed-sharing bug, not a correctness one — but it
    breaks the canonical form every cache key assumes)."""
    by_value: Dict[Node, int] = {}
    stack = list(roots)
    seen_ids = set()
    while stack:
        n = stack.pop()
        if id(n) in seen_ids:
            continue
        seen_ids.add(id(n))
        prev = by_value.get(n)
        if prev is not None and prev != id(n):
            out.append(Diagnostic(
                "cse-alias", _label(n),
                "structurally-equal subplans are distinct objects — the "
                "plan is not in hash-consed (CSE) canonical form"))
        else:
            by_value[n] = id(n)
        stack.extend(n.children())


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def verify_plan(plan: LogicalPlan, engine: str = "rmlmapper", *,
                counts: Optional[Mapping[Node, int]] = None,
                caps: Optional[Mapping[Node, int]] = None,
                sources: Optional[Mapping[str, object]] = None,
                shard_local: bool = False, slack: float = 1.0,
                check_canonical: bool = True,
                check_cse: bool = True) -> VerifyReport:
    """Statically verify a lowered (and usually optimized) plan.

    Parameters mirror how the :class:`~repro.api.engine.KGEngine` calls
    it: ``counts``/``caps`` are the annotation pass's outputs (checked for
    consistency when given), ``sources`` the extensions to type against
    (default ``plan.dis.sources``; an empty mapping — e.g. a cache entry's
    slim plan — skips source-existence checks and types every column
    int32), ``shard_local=True`` relaxes the capacity comparisons that do
    not hold for per-shard buffers, and ``check_cse``/``check_canonical``
    gate the hash-consing invariants (off for un-optimized plans, whose
    inputs are never interned). Returns a :class:`VerifyReport`; use
    ``.raise_for_status()`` for the raising contract.
    """
    diags: List[Diagnostic] = []
    schemas: Dict[Node, NodeSchema] = {}
    sources = plan.dis.sources if sources is None else sources
    roots: List[Node] = list(plan.emits())
    roots.append(plan.sink(engine))
    order = _postorder(roots, diags)
    if order is None:        # cyclic: no safe inference order exists
        return VerifyReport(diags, schemas, nodes_checked=0)
    for node in order:
        _infer(node, schemas, sources, diags)
        if check_canonical:
            _check_canonical(node, diags)
        if isinstance(node, EmitTriples):
            _check_emit(node, plan, schemas, diags)
    _check_annotations(order, counts, caps, shard_local, slack, diags)
    if check_cse and check_canonical:
        _check_cse(list(plan.inputs.values()), diags)
    # the sink wraps fresh EmitTriples objects around the shared subtrees,
    # so emit-level findings can surface once per root — dedupe, keep order
    diags = list(dict.fromkeys(diags))
    return VerifyReport(diags, schemas, nodes_checked=len(order))


def verify_query_plan(plan, *,
                      counts: Optional[Mapping[Node, int]] = None,
                      caps: Optional[Mapping[Node, int]] = None,
                      sources: Optional[Mapping[str, object]] = None,
                      shard_local: bool = False,
                      slack: float = 1.0) -> VerifyReport:
    """Statically verify a lowered BGP query DAG
    (:class:`repro.query.lower.QueryPlan`, duck-typed via ``emits()``).

    Runs the same schema inference, canonical-form, CSE and annotation
    checks as :func:`verify_plan` over the query root — there is no
    emitter/sink, so the emit checks are replaced by one query-specific
    invariant: the root must be a δ (query results have set semantics; a
    non-δ root would leak bag duplicates into the answer). ``sources``
    defaults to empty (the KG scan is typed int32 without a table in
    hand); pass ``{KG_SOURCE: kg_table}`` to also check scan-schema drift.
    """
    diags: List[Diagnostic] = []
    schemas: Dict[Node, NodeSchema] = {}
    roots: List[Node] = list(plan.emits())
    for root in roots:
        if not isinstance(root, Distinct):
            diags.append(Diagnostic(
                "query-root", _label(root),
                f"query root is {type(root).__name__}, expected δ — "
                "answers must have set semantics"))
    order = _postorder(roots, diags)
    if order is None:        # cyclic: no safe inference order exists
        return VerifyReport(diags, schemas, nodes_checked=0)
    for node in order:
        _infer(node, schemas, sources or {}, diags)
        _check_canonical(node, diags)
        if isinstance(node, EmitTriples):
            diags.append(Diagnostic(
                "query-root", _label(node),
                "EmitTriples inside a query DAG — queries read the KG, "
                "they never semantify"))
    _check_annotations(order, counts, caps, shard_local, slack, diags)
    _check_cse(roots, diags)
    diags = list(dict.fromkeys(diags))
    return VerifyReport(diags, schemas, nodes_checked=len(order))
