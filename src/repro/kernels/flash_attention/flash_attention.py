"""Pallas TPU flash attention: online-softmax block attention.

Grid ``(B*H, num_q_blocks, num_k_blocks)`` with the k dimension sequential
("arbitrary") so the running max/denominator/accumulator live in VMEM
scratch across k steps. Per step the kernel touches one ``(block_q, D)`` q
tile and one ``(block_k, D)`` k/v tile — VMEM footprint is
``O(block_q·D + block_k·D + block_q·block_k)`` independent of sequence
length, vs the O(S²) score matrix XLA would materialize.

GQA is handled by the k/v BlockSpec index maps (q head -> kv head), causal
and sliding-window masking by absolute-position predicates; fully-masked
(q-block, k-block) pairs skip the MXU work entirely via ``pl.when``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import TPUCompilerParams

from .ref import MASK_VALUE


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_scr, l_scr, *,
               scale: float, block_q: int, block_k: int, causal: bool,
               window: int, kv_len: int, q_offset: int, num_kb: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, MASK_VALUE)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc[...] = jnp.zeros_like(acc)

    q_first = qi * block_q + q_offset          # absolute pos of first q row
    q_last = q_first + block_q - 1
    k_first = ki * block_k
    k_last = k_first + block_k - 1

    live = k_first < kv_len                    # padded kv tail
    if causal:
        live &= k_first <= q_last
    if window > 0:
        # the youngest pair in the block is (q_first, k_last); if even that
        # is older than the window, every pair is
        live &= k_last > q_first - window

    @pl.when(live)
    def _step():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        q_pos = q_first + lax.broadcasted_iota(jnp.int32,
                                               (block_q, block_k), 0)
        k_pos = k_first + lax.broadcasted_iota(jnp.int32,
                                               (block_q, block_k), 1)
        mask = k_pos < kv_len
        if causal:
            mask &= q_pos >= k_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, MASK_VALUE)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_prev + p.sum(axis=-1)
        m_scr[...] = m_new
        v = v_ref[...].astype(jnp.float32)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc[...] = acc[...] * alpha[:, None] + pv

    @pl.when(ki == num_kb - 1)
    def _finish():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "scale", "block_q",
                              "block_k", "kv_len", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: Optional[int] = None,
                           scale: Optional[float] = None,
                           kv_len: Optional[int] = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q [B,H,S,D], k/v [B,KH,Sk,D] -> [B,H,S,D]. Sequences are padded to
    block multiples; ``kv_len`` masks the padded tail (defaults to Sk)."""
    b, h, s_q, d = q.shape
    _, kh, s_k, _ = k.shape
    assert h % kh == 0
    group = h // kh
    scale_val = float(d ** -0.5 if scale is None else scale)
    kv_len_val = int(s_k if kv_len is None else kv_len)
    window_val = int(window or 0)

    # pad to block multiples
    sq_p = -(-s_q // block_q) * block_q
    sk_p = -(-s_k // block_k) * block_k
    if sq_p != s_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - s_q), (0, 0)))
    if sk_p != s_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, sk_p - s_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, sk_p - s_k), (0, 0)))

    qf = q.reshape(b * h, sq_p, d)
    kf = k.reshape(b * kh, sk_p, d)
    vf = v.reshape(b * kh, sk_p, d)
    num_qb = sq_p // block_q
    num_kb = sk_p // block_k

    def kv_index(bh, qi, ki):
        return (bh // h) * kh + (bh % h) // group, ki, 0

    kernel = functools.partial(
        _fa_kernel, scale=scale_val, block_q=block_q, block_k=block_k,
        causal=causal, window=window_val, kv_len=kv_len_val,
        q_offset=kv_len_val - s_q, num_kb=num_kb)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, num_qb, num_kb),
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((None, block_k, d), kv_index),
            pl.BlockSpec((None, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((None, block_q, d),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out[:, :s_q].reshape(b, h, s_q, d)
