"""Pure-jnp oracle for flash attention (causal / sliding-window / GQA).

Also the path the models take on CPU (the dry-run lowers this; XLA fuses it
reasonably). Shapes: q [B, H, S, D], k/v [B, KH, S, D] with H % KH == 0.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

MASK_VALUE = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: Optional[int] = None,
                  scale: Optional[float] = None,
                  kv_len: Optional[int] = None) -> jax.Array:
    b, h, s_q, d = q.shape
    _, kh, s_k, _ = k.shape
    assert h % kh == 0, (h, kh)
    group = h // kh
    scale = (d ** -0.5) if scale is None else scale

    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)

    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(s_q)[:, None]
    k_pos = jnp.arange(s_k)[None, :]
    # when s_q < s_k (decode), align q to the END of the kv timeline
    offset = (kv_len if kv_len is not None else s_k) - s_q
    q_abs = q_pos + offset
    mask = jnp.ones((s_q, s_k), dtype=bool)
    if causal:
        mask &= q_abs >= k_pos
    if window is not None and window > 0:
        mask &= (q_abs - k_pos) < window
    if kv_len is not None:
        mask &= k_pos < kv_len
    scores = jnp.where(mask[None, None], scores, MASK_VALUE)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
