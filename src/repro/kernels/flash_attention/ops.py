"""Dispatching wrapper: Pallas flash attention on TPU, jnp oracle on CPU."""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import pallas_interpret, resolve_use_pallas

from .flash_attention import flash_attention_pallas
from .ref import attention_ref


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None,
                    kv_len: Optional[int] = None,
                    use_pallas: Optional[bool] = None,
                    block_q: int = 128, block_k: int = 128) -> jax.Array:
    if resolve_use_pallas(use_pallas):
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, scale=scale,
            kv_len=kv_len, block_q=block_q, block_k=block_k,
            interpret=pallas_interpret())
    return attention_ref(q, k, v, causal=causal, window=window, scale=scale,
                         kv_len=kv_len)
