# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Shared backend gating for every kernel package's dispatcher.

Each ``ops.py`` dispatcher resolves its ``use_pallas=None`` default the
same way; the resolution lives here (instead of per-package ``_on_tpu``
copies) so the policy — and the CI interpret-mode override — is defined
exactly once:

* ``on_tpu()`` — the Pallas kernels target real TPUs; elsewhere the
  pure-jnp oracle is the faster *and* always-available path.
* ``REPRO_PALLAS_INTERPRET=1`` forces ``use_pallas=None`` to resolve True
  off-TPU too, running the kernel **bodies** through the Pallas
  interpreter (``pallas_call(interpret=True)``) — the CI leg that
  exercises the real kernel code on CPU runners instead of only the
  oracles. Explicit ``use_pallas=True/False`` is always honored.

No kernel subpackage is imported here: consumers import
``repro.kernels.<pkg>`` directly, which keeps this module dependency-free
(and cycle-free — relalg imports kernels, never the reverse).
"""
from __future__ import annotations

import os
from typing import Optional

import jax


def on_tpu() -> bool:
    """True iff the default jax backend is a real TPU."""
    return jax.default_backend() == "tpu"


def pallas_interpret_forced() -> bool:
    """True iff ``$REPRO_PALLAS_INTERPRET`` requests interpret-mode kernels
    (read per call: tests toggle it with ``monkeypatch.setenv``)."""
    return os.environ.get("REPRO_PALLAS_INTERPRET", "").strip() \
        not in ("", "0", "false", "no")


def resolve_use_pallas(use_pallas: Optional[bool]) -> bool:
    """The single ``use_pallas=None`` policy: kernels on TPU, oracles
    elsewhere — unless the interpret-mode env flag opts the kernel bodies
    in on CPU."""
    if use_pallas is None:
        return on_tpu() or pallas_interpret_forced()
    return bool(use_pallas)


def pallas_interpret() -> bool:
    """Whether a Pallas call taken off-TPU must run interpreted (always:
    only a real TPU executes compiled Mosaic)."""
    return not on_tpu()
