"""Dispatching wrappers: Pallas on TPU, oracle (or interpret mode) on CPU."""
from __future__ import annotations

from typing import Tuple

import jax

from repro.kernels import pallas_interpret, resolve_use_pallas

from .ref import hash_neighbor_flags_ref, rowhash_ref
from .rowhash import hash_neighbor_flags_pallas, rowhash_pallas


def rowhash(x: jax.Array, *, use_pallas: bool | None = None,
            block_n: int = 256) -> jax.Array:
    """[N, K] int32 -> [N] uint32 row hashes (kernel on TPU, ref elsewhere)."""
    if resolve_use_pallas(use_pallas):
        return rowhash_pallas(x, block_n=block_n,
                              interpret=pallas_interpret())
    return rowhash_ref(x)


def hash_neighbor_flags(rows: jax.Array, *, use_pallas: bool | None = None,
                        block_n: int = 256
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused (hash, keep, collide) over hash-sorted ``rows[N, K]``.

    Kernel on TPU, pure-jnp oracle elsewhere (the Pallas interpreter is far
    slower than the oracle for this memory-bound pass).
    """
    if resolve_use_pallas(use_pallas):
        return hash_neighbor_flags_pallas(rows, block_n=block_n,
                                          interpret=pallas_interpret())
    return hash_neighbor_flags_ref(rows)
