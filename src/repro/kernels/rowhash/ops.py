"""Dispatching wrapper: Pallas on TPU, oracle (or interpret mode) on CPU."""
from __future__ import annotations

import jax

from .ref import rowhash_ref
from .rowhash import rowhash_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def rowhash(x: jax.Array, *, use_pallas: bool | None = None,
            block_n: int = 256) -> jax.Array:
    """[N, K] int32 -> [N] uint32 row hashes (kernel on TPU, ref elsewhere)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return rowhash_pallas(x, block_n=block_n, interpret=not _on_tpu())
    return rowhash_ref(x)
