"""Pure-jnp oracles for the row-hash kernel family.

FNV/murmur-style 32-bit mixing hash over the columns of an int32 row
matrix, plus the fused hash+neighbor-flag pass used by hash-first
duplicate elimination. The hash is used in two places:

* distributed dedup — repartition rows so equal rows land on the same
  shard; collisions are harmless there (the local distinct re-checks full
  rows), but good mixing keeps buckets balanced;
* single-device hash-first δ — sort once on the 32-bit hash instead of a
  K-key lexicographic sort; collisions are detected (equal hash, unequal
  row) and trigger an exact fallback.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

# plain ints (NOT jnp arrays) so Pallas kernels can close over them
FNV_OFFSET = 2166136261
FNV_PRIME = 16777619
GOLDEN = 0x9E3779B9


def fmix32(x: jax.Array) -> jax.Array:
    """murmur3 finalizer — avalanche a uint32."""
    x = x ^ lax.shift_right_logical(x, jnp.uint32(16))
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ lax.shift_right_logical(x, jnp.uint32(13))
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ lax.shift_right_logical(x, jnp.uint32(16))
    return x


def rowhash_ref(x: jax.Array) -> jax.Array:
    """[N, K] int32 -> [N] uint32 row hashes."""
    assert x.ndim == 2
    n, k = x.shape
    h = jnp.full((n,), jnp.uint32(FNV_OFFSET), dtype=jnp.uint32)
    for col in range(k):
        salt = jnp.uint32((GOLDEN * (col + 1)) & 0xFFFFFFFF)
        v = fmix32(x[:, col].astype(jnp.uint32) + salt)
        h = (h ^ v) * jnp.uint32(FNV_PRIME)
    return fmix32(h)


def hash_neighbor_flags_ref(rows: jax.Array
                            ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused pass over hash-sorted rows: ``(hash, keep, collide)``.

    ``rows[N, K]`` must already be sorted by row hash. For each row i:

    * ``hash[i]``    — the 32-bit row hash (recomputed; one read of the row),
    * ``keep[i]``    — 1 iff row i differs from row i-1 in hash or content
                       (first occurrence of a duplicate run; row 0 always 1),
    * ``collide[i]`` — 1 iff hash[i] == hash[i-1] but the rows differ — a
                       genuine 32-bit collision that makes the neighbor
                       keep-mask inexact and forces the lex fallback.
    """
    assert rows.ndim == 2
    h = rowhash_ref(rows)
    prev_rows = jnp.roll(rows, 1, axis=0)
    prev_h = jnp.roll(h, 1)
    row_eq = jnp.all(rows == prev_rows, axis=1)
    hash_eq = h == prev_h
    keep = ~(hash_eq & row_eq)
    collide = hash_eq & ~row_eq
    keep = keep.at[0].set(True)
    collide = collide.at[0].set(False)
    return h, keep.astype(jnp.int32), collide.astype(jnp.int32)
