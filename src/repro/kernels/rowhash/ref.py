"""Pure-jnp oracle for the row-hash kernel.

FNV/murmur-style 32-bit mixing hash over the columns of an int32 row
matrix. Used by the distributed dedup to repartition rows so that equal
rows land on the same shard; collisions are harmless there (the local
distinct re-checks full rows), but good mixing keeps buckets balanced.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

# plain ints (NOT jnp arrays) so Pallas kernels can close over them
FNV_OFFSET = 2166136261
FNV_PRIME = 16777619
GOLDEN = 0x9E3779B9


def fmix32(x: jax.Array) -> jax.Array:
    """murmur3 finalizer — avalanche a uint32."""
    x = x ^ lax.shift_right_logical(x, jnp.uint32(16))
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ lax.shift_right_logical(x, jnp.uint32(13))
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ lax.shift_right_logical(x, jnp.uint32(16))
    return x


def rowhash_ref(x: jax.Array) -> jax.Array:
    """[N, K] int32 -> [N] uint32 row hashes."""
    assert x.ndim == 2
    n, k = x.shape
    h = jnp.full((n,), jnp.uint32(FNV_OFFSET), dtype=jnp.uint32)
    for col in range(k):
        salt = jnp.uint32((GOLDEN * (col + 1)) & 0xFFFFFFFF)
        v = fmix32(x[:, col].astype(jnp.uint32) + salt)
        h = (h ^ v) * jnp.uint32(FNV_PRIME)
    return fmix32(h)
