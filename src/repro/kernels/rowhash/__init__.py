from .ops import hash_neighbor_flags, rowhash
from .ref import hash_neighbor_flags_ref, rowhash_ref
from .rowhash import hash_neighbor_flags_pallas, rowhash_pallas

__all__ = [
    "hash_neighbor_flags", "hash_neighbor_flags_pallas",
    "hash_neighbor_flags_ref", "rowhash", "rowhash_ref", "rowhash_pallas",
]
