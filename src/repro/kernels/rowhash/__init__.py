from .ops import rowhash
from .ref import rowhash_ref
from .rowhash import rowhash_pallas

__all__ = ["rowhash", "rowhash_ref", "rowhash_pallas"]
