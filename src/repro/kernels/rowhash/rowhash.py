"""Pallas TPU kernel: 32-bit mixing hash over int32 rows.

One grid step processes a ``(block_n, K)`` tile resident in VMEM and writes
``block_n`` hashes. The K-column mix is unrolled (K is static and small for
relational rows), so the kernel is a single fused VPU pass over the tile —
one HBM read per element, one HBM write per row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .ref import FNV_OFFSET, FNV_PRIME, GOLDEN


def _fmix32(x):
    x = x ^ lax.shift_right_logical(x, jnp.uint32(16))
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ lax.shift_right_logical(x, jnp.uint32(13))
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ lax.shift_right_logical(x, jnp.uint32(16))
    return x


def _rowhash_kernel(x_ref, o_ref, *, k: int):
    x = x_ref[...].astype(jnp.uint32)          # [block_n, K] in VMEM
    h = jnp.full((x.shape[0],), jnp.uint32(FNV_OFFSET), dtype=jnp.uint32)
    for col in range(k):                        # static unroll over columns
        salt = jnp.uint32((GOLDEN * (col + 1)) & 0xFFFFFFFF)
        v = _fmix32(x[:, col] + salt)
        h = (h ^ v) * jnp.uint32(FNV_PRIME)
    o_ref[...] = _fmix32(h)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def rowhash_pallas(x: jax.Array, *, block_n: int = 256,
                   interpret: bool = False) -> jax.Array:
    """[N, K] int32 -> [N] uint32. N is padded to a block multiple."""
    n, k = x.shape
    n_pad = ((n + block_n - 1) // block_n) * block_n
    if n_pad != n:
        x = jnp.pad(x, ((0, n_pad - n), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rowhash_kernel, k=k),
        grid=(n_pad // block_n,),
        in_specs=[pl.BlockSpec((block_n, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.uint32),
        interpret=interpret,
    )(x)
    return out[:n]
