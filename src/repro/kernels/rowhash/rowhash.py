"""Pallas TPU kernels: 32-bit mixing hash over int32 rows, plus the fused
hash + sorted-neighbor-flag pass behind hash-first duplicate elimination.

One grid step processes a ``(block_n, K)`` tile resident in VMEM and writes
``block_n`` outputs. The K-column mix is unrolled (K is static and small for
relational rows), so each kernel is a single fused VPU pass over the tile —
one HBM read per element, one HBM write per output row.

``hash_neighbor_flags_pallas`` additionally compares every row with its
predecessor (the row above in hash-sorted order): the tile-internal shift is
a VMEM roll, and each tile's first row compares against a per-block boundary
row gathered outside the kernel, so hash, neighbor compare and keep-mask all
happen in one pass without re-reading the matrix.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .ref import FNV_OFFSET, FNV_PRIME, GOLDEN


def _fmix32(x):
    x = x ^ lax.shift_right_logical(x, jnp.uint32(16))
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ lax.shift_right_logical(x, jnp.uint32(13))
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ lax.shift_right_logical(x, jnp.uint32(16))
    return x


def _row_hashes(x: jax.Array, k: int) -> jax.Array:
    """Hash the rows of a [*, K] uint32 tile (static unroll over columns)."""
    h = jnp.full((x.shape[0],), jnp.uint32(FNV_OFFSET), dtype=jnp.uint32)
    for col in range(k):
        salt = jnp.uint32((GOLDEN * (col + 1)) & 0xFFFFFFFF)
        v = _fmix32(x[:, col] + salt)
        h = (h ^ v) * jnp.uint32(FNV_PRIME)
    return _fmix32(h)


def _rowhash_kernel(x_ref, o_ref, *, k: int):
    o_ref[...] = _row_hashes(x_ref[...].astype(jnp.uint32), k)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def rowhash_pallas(x: jax.Array, *, block_n: int = 256,
                   interpret: bool = False) -> jax.Array:
    """[N, K] int32 -> [N] uint32. N is padded to a block multiple."""
    n, k = x.shape
    n_pad = ((n + block_n - 1) // block_n) * block_n
    if n_pad != n:
        x = jnp.pad(x, ((0, n_pad - n), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rowhash_kernel, k=k),
        grid=(n_pad // block_n,),
        in_specs=[pl.BlockSpec((block_n, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.uint32),
        interpret=interpret,
    )(x)
    return out[:n]


def _hash_flags_kernel(x_ref, b_ref, h_ref, keep_ref, coll_ref, *, k: int):
    x = x_ref[...].astype(jnp.uint32)          # [block_n, K] in VMEM
    b = b_ref[...].astype(jnp.uint32)          # [1, K] boundary (prev block's
    #                                            last row; row 0 for block 0)
    h = _row_hashes(x, k)
    hb = _row_hashes(b, k)                      # [1]
    idx = lax.broadcasted_iota(jnp.int32, (x.shape[0], 1), 0)[:, 0]
    first_in_tile = idx == 0
    prev_rows = jnp.where(first_in_tile[:, None],
                          jnp.broadcast_to(b, x.shape),
                          jnp.roll(x, 1, axis=0))
    prev_h = jnp.where(first_in_tile, jnp.broadcast_to(hb, h.shape),
                       jnp.roll(h, 1))
    row_eq = jnp.all(x == prev_rows, axis=1)
    hash_eq = h == prev_h
    keep = ~(hash_eq & row_eq)
    coll = hash_eq & ~row_eq
    # the very first row of the whole matrix has no predecessor
    global_first = (pl.program_id(0) == 0) & first_in_tile
    keep = keep | global_first
    coll = coll & ~global_first
    h_ref[...] = h
    keep_ref[...] = keep.astype(jnp.int32)
    coll_ref[...] = coll.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def hash_neighbor_flags_pallas(rows: jax.Array, *, block_n: int = 256,
                               interpret: bool = False
                               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused flags over hash-sorted ``rows[N, K]``: ``(hash, keep, collide)``.

    ``keep[i]`` is 1 iff row i differs from row i-1 (hash or content) — the
    first-occurrence mask of a duplicate run. ``collide[i]`` is 1 iff the
    hashes match but the rows differ (a genuine 32-bit collision). Semantics
    match :func:`repro.kernels.rowhash.ref.hash_neighbor_flags_ref`.
    """
    n, k = rows.shape
    n_pad = ((n + block_n - 1) // block_n) * block_n
    if n_pad != n:
        rows = jnp.pad(rows, ((0, n_pad - n), (0, 0)))
    n_blocks = n_pad // block_n
    # boundary[i] = last row of block i-1 (block 0 gets row 0: the kernel
    # overrides the global first row anyway)
    last_of_block = rows[block_n - 1::block_n]
    boundary = jnp.concatenate([rows[:1], last_of_block[:n_blocks - 1]],
                               axis=0)
    h, keep, coll = pl.pallas_call(
        functools.partial(_hash_flags_kernel, k=k),
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((block_n, k), lambda i: (i, 0)),
                  pl.BlockSpec((1, k), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((block_n,), lambda i: (i,)),
                   pl.BlockSpec((block_n,), lambda i: (i,)),
                   pl.BlockSpec((block_n,), lambda i: (i,))),
        out_shape=(jax.ShapeDtypeStruct((n_pad,), jnp.uint32),
                   jax.ShapeDtypeStruct((n_pad,), jnp.int32),
                   jax.ShapeDtypeStruct((n_pad,), jnp.int32)),
        interpret=interpret,
    )(rows, boundary)
    return h[:n], keep[:n], coll[:n]
