"""Dispatching wrapper: Pallas radix partition on TPU, oracle elsewhere.

On top of the shared backend gate (``repro.kernels.resolve_use_pallas``)
this dispatcher applies a *feasibility* gate: the kernel keeps the whole
bucketed output VMEM-resident and unrolls a per-bucket copy loop, so it
only pays off (and only fits) for moderate bucket counts and output
footprints. Infeasible shapes silently use the oracle — the two are
bit-identical, so callers never observe which path ran.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.kernels import pallas_interpret, resolve_use_pallas

from .radix_partition import radix_partition_pallas
from .ref import radix_partition_ref

#: kernel feasibility bounds (beyond them the oracle is used)
MAX_BUCKETS = 64
MAX_VMEM_OUT_BYTES = 6 * 2**20


def kernel_feasible(n: int, k: int, n_buckets: int, cap_bucket: int,
                    block_n: int = 256) -> bool:
    """True iff the Pallas kernel supports this shape.

    Power-of-two bucket count >= 2 (the kernel's modulo is a bit mask),
    bounded bucket fan-out (per-bucket copy is unrolled), and the resident
    output block must fit comfortably in VMEM.
    """
    if n == 0 or k == 0:
        return False
    if n_buckets < 2 or n_buckets & (n_buckets - 1) or n_buckets > MAX_BUCKETS:
        return False
    out_bytes = (n_buckets * cap_bucket + block_n) * k * 4
    return out_bytes + 2 * block_n * k * 4 <= MAX_VMEM_OUT_BYTES


def radix_partition(data: jax.Array, count: jax.Array, *,
                    n_buckets: int, cap_bucket: int,
                    key_cols: Optional[Tuple[int, ...]] = None,
                    order_preserving: bool = False,
                    use_pallas: Optional[bool] = None,
                    block_n: int = 256
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Partition ``data[cap_local, K]``'s first ``count`` rows into
    ``n_buckets`` hash buckets of ``cap_bucket`` rows each.

    Returns ``(buckets [n_buckets, cap_bucket, K], counts [n_buckets],
    overflow)`` with rows in original relative order inside each bucket,
    PAD elsewhere, counts clamped, and ``overflow`` raised (never silent)
    when a bucket's true occupancy exceeds ``cap_bucket``.
    """
    n, k = data.shape
    if (resolve_use_pallas(use_pallas)
            and kernel_feasible(n, k, n_buckets, cap_bucket, block_n)):
        return radix_partition_pallas(
            data, count, n_buckets=n_buckets, cap_bucket=cap_bucket,
            key_cols=None if key_cols is None else tuple(key_cols),
            order_preserving=order_preserving, block_n=block_n,
            interpret=pallas_interpret())
    return radix_partition_ref(
        data, count, n_buckets=n_buckets, cap_bucket=cap_bucket,
        key_cols=None if key_cols is None else tuple(key_cols),
        order_preserving=order_preserving)
