"""Pallas TPU kernel: one-pass radix partition of coded rows into
fixed-capacity hash buckets.

The sequential TPU grid walks ``(block_n, K)`` row tiles while the whole
bucketed output block stays VMEM-resident (constant index map → the block
is "revisited" every step and written back to HBM once at the end).  Each
step:

1. hashes the tile's key columns (same unrolled FNV/murmur mix as the
   rowhash kernel) and derives a bucket target per row — ``h &
   (n_buckets-1)`` in exchange mode, ``h >> (32-log2 n_buckets)`` in
   order-preserving mode; rows past ``count`` get a sentinel target;
2. groups the tile's rows by bucket *without a sort*: an exclusive
   per-bucket rank plus an in-tile bucket offset (both computed with small
   one-hot matmuls on the MXU) form a complete permutation of the tile,
   applied as a ``[block_n, block_n]`` one-hot matmul.  int32 row payloads
   ride through the f32 MXU as two 16-bit limbs (exact: each output slot
   has exactly one source row and limbs are < 2^16) and are recombined;
3. copies each bucket's now-contiguous run from the tile scratch into its
   region of the resident output with a masked dynamic-slice blend.  The
   per-bucket running totals live in the SMEM counts output (doubling as
   the cross-tile histogram), so slice starts are SMEM-sourced scalars.
   A row whose bucket is already at capacity is simply never written —
   overflow shows up in the (unclamped) counts, never as corruption.

Within a bucket rows keep their original order (rank is a stable running
count), so the result is bit-identical to the oracle and to the historical
stable-sort bucketization it replaces.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.rowhash.ref import FNV_OFFSET, FNV_PRIME, GOLDEN

from .ref import PAD_ID, bucket_shift

_F32 = jnp.float32
_HIGHEST = lax.Precision.HIGHEST


def _fmix32(x):
    x = x ^ lax.shift_right_logical(x, jnp.uint32(16))
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ lax.shift_right_logical(x, jnp.uint32(13))
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ lax.shift_right_logical(x, jnp.uint32(16))
    return x


def _mm(a, b):
    """Exact small-int matmul through the MXU."""
    return lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                           precision=_HIGHEST,
                           preferred_element_type=_F32)


def _radix_partition_kernel(count_ref, x_ref, o_ref, counts_ref, tile_ref,
                            ts_ref, *, n_buckets: int, cap_bucket: int,
                            block_n: int, key_cols: Tuple[int, ...],
                            shift: Optional[int]):
    i = pl.program_id(0)
    nb1 = n_buckets + 1  # + sentinel bucket for invalid rows

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.full(o_ref.shape, PAD_ID, jnp.int32)
        tile_ref[...] = jnp.full(tile_ref.shape, PAD_ID, jnp.int32)
        for b in range(n_buckets):
            counts_ref[b] = 0

    x = x_ref[...]                                        # [block_n, K]
    ridx = i * block_n + lax.broadcasted_iota(jnp.int32, (block_n, 1), 0)
    valid = ridx < count_ref[0, 0]
    masked = jnp.where(valid, x, jnp.int32(PAD_ID))

    # --- bucket targets (column-unrolled row hash, as in rowhash) ---
    h = jnp.full((block_n, 1), jnp.uint32(FNV_OFFSET), dtype=jnp.uint32)
    for j, col in enumerate(key_cols):
        salt = jnp.uint32((GOLDEN * (j + 1)) & 0xFFFFFFFF)
        v = _fmix32(masked[:, col:col + 1].astype(jnp.uint32) + salt)
        h = (h ^ v) * jnp.uint32(FNV_PRIME)
    h = _fmix32(h)
    if shift is None:
        t = (h & jnp.uint32(n_buckets - 1)).astype(jnp.int32)
    else:
        t = lax.shift_right_logical(h, jnp.uint32(shift)).astype(jnp.int32)
    t = jnp.where(valid, t, jnp.int32(n_buckets))         # [block_n, 1]

    # --- in-tile grouping permutation (histogram → rank → one-hot) ---
    onehot = (t == lax.broadcasted_iota(jnp.int32, (block_n, nb1), 1)
              ).astype(_F32)                              # [block_n, nb1]
    tile_counts = _mm(jnp.ones((1, block_n), _F32), onehot)        # [1, nb1]
    upper = (lax.broadcasted_iota(_F32, (nb1, nb1), 0)
             < lax.broadcasted_iota(_F32, (nb1, nb1), 1)).astype(_F32)
    tile_offset = _mm(tile_counts, upper)                 # excl. cumsum
    lower = (lax.broadcasted_iota(_F32, (block_n, block_n), 0)
             > lax.broadcasted_iota(_F32, (block_n, block_n), 1)
             ).astype(_F32)
    excl = _mm(lower, onehot)            # same-bucket predecessors per row
    rank = jnp.sum(excl * onehot, axis=1, keepdims=True)  # [block_n, 1]
    base = lax.dot_general(onehot, tile_offset, (((1,), (1,)), ((), ())),
                           precision=_HIGHEST,
                           preferred_element_type=_F32)   # [block_n, 1]
    dest = base + rank  # complete permutation of 0..block_n-1

    # apply P[d, j] = (dest_j == d) via two 16-bit-limb matmuls
    pt = (dest == lax.broadcasted_iota(_F32, (block_n, block_n), 1)
          ).astype(_F32)                                  # [j, d]
    m_u = masked.astype(jnp.uint32)
    hi = lax.shift_right_logical(m_u, jnp.uint32(16)).astype(_F32)
    lo = (m_u & jnp.uint32(0xFFFF)).astype(_F32)
    phi = lax.dot_general(pt, hi, (((0,), (0,)), ((), ())),
                          precision=_HIGHEST, preferred_element_type=_F32)
    plo = lax.dot_general(pt, lo, (((0,), (0,)), ((), ())),
                          precision=_HIGHEST, preferred_element_type=_F32)
    perm = (lax.shift_left(phi.astype(jnp.uint32), jnp.uint32(16))
            | plo.astype(jnp.uint32)).astype(jnp.int32)
    tile_ref[0:block_n, :] = perm

    # --- per-bucket blend-copy into the resident output ---
    off = lax.broadcasted_iota(jnp.int32, (block_n, 1), 0)
    ts_ref[0] = 0
    for b in range(n_buckets):
        ts = ts_ref[0]                       # in-tile start of bucket b
        base_b = counts_ref[b]               # rows already placed in b
        cnt_b = jnp.sum(onehot[:, b:b + 1]).astype(jnp.int32)
        start = b * cap_bucket + jnp.minimum(base_b, cap_bucket)
        src = tile_ref[pl.ds(ts, block_n), :]
        keep = (off < cnt_b) & (base_b + off < cap_bucket)
        cur = o_ref[pl.ds(start, block_n), :]
        o_ref[pl.ds(start, block_n), :] = jnp.where(keep, src, cur)
        counts_ref[b] = base_b + cnt_b
        ts_ref[0] = ts + cnt_b


@functools.partial(jax.jit, static_argnames=(
    "n_buckets", "cap_bucket", "key_cols", "order_preserving", "block_n",
    "interpret"))
def radix_partition_pallas(data: jax.Array, count: jax.Array, *,
                           n_buckets: int, cap_bucket: int,
                           key_cols: Optional[Tuple[int, ...]] = None,
                           order_preserving: bool = False,
                           block_n: int = 256, interpret: bool = False
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Kernel-backed twin of :func:`.ref.radix_partition_ref`.

    ``n_buckets`` must be a power of two (the exchange-mode modulo is a
    mask; the dispatcher falls back to the oracle otherwise). Returns
    ``(buckets [n_buckets, cap_bucket, K], clamped counts, overflow)``.
    """
    n, k = data.shape
    if n_buckets & (n_buckets - 1) or n_buckets < 2:
        raise ValueError(f"kernel needs a power-of-two bucket count >= 2, "
                         f"got {n_buckets}")
    cols = tuple(range(k)) if key_cols is None else tuple(key_cols)
    shift = bucket_shift(n_buckets) if order_preserving else None
    n_pad = max(((n + block_n - 1) // block_n) * block_n, block_n)
    if n_pad != n:
        data = jnp.pad(data, ((0, n_pad - n), (0, 0)),
                       constant_values=PAD_ID)
    out_rows = n_buckets * cap_bucket + block_n  # slack for clamped writes
    flat, raw = pl.pallas_call(
        functools.partial(_radix_partition_kernel, n_buckets=n_buckets,
                          cap_bucket=cap_bucket, block_n=block_n,
                          key_cols=cols, shift=shift),
        grid=(n_pad // block_n,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec((block_n, k), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((out_rows, k), lambda i: (0, 0)),
                   pl.BlockSpec(memory_space=pltpu.SMEM)),
        out_shape=(jax.ShapeDtypeStruct((out_rows, k), jnp.int32),
                   jax.ShapeDtypeStruct((n_buckets,), jnp.int32)),
        scratch_shapes=[pltpu.VMEM((2 * block_n, k), jnp.int32),
                        pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(jnp.asarray(count, jnp.int32).reshape(1, 1), data)
    buckets = flat[:n_buckets * cap_bucket].reshape(n_buckets, cap_bucket, k)
    return (buckets, jnp.minimum(raw, cap_bucket),
            jnp.any(raw > cap_bucket))
