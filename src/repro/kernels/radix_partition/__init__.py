"""Radix partition of coded rows into fixed-capacity hash buckets.

``ops.radix_partition`` is the entry point; ``ref`` holds the pure-jnp
oracle and ``radix_partition`` the Pallas TPU kernel. Used by the
all_to_all join exchange / global-δ repartition
(:mod:`repro.core.distributed`) and by the bucketed hash-δ path
(:func:`repro.relalg.ops.distinct_rows_hashed`).
"""
from .ops import kernel_feasible, radix_partition
from .radix_partition import radix_partition_pallas
from .ref import bucket_shift, bucket_targets_ref, radix_partition_ref

__all__ = [
    "bucket_shift",
    "bucket_targets_ref",
    "kernel_feasible",
    "radix_partition",
    "radix_partition_pallas",
    "radix_partition_ref",
]
