"""Pure-jnp oracle for the radix partition: histogram → exclusive
prefix-sum → scatter, with output semantics bit-identical to the historical
sort-based bucketization in ``repro.core.distributed`` (stable within-bucket
order = original row order; overflowing rows dropped with the flag raised,
never silently).

Two bucketization modes share one pipeline:

* ``order_preserving=False`` (default) — ``target = rowhash(row) %
  n_buckets``: the exchange mode. This is *the* shard-assignment function of
  ``repartition_by_key``, so the kernel, the oracle and the old sort path
  must (and do) agree bit-for-bit on which shard every row travels to.
* ``order_preserving=True`` — ``target = rowhash(row) >> (32 - log2
  n_buckets)`` (``n_buckets`` a power of two): bucket index = the hash's
  top bits, so concatenating the buckets in index order yields rows in
  globally non-decreasing hash order. The δ partition stage
  (:func:`repro.relalg.ops.distinct_rows_hashed`) needs exactly this —
  a per-bucket hash sort then reproduces the single global hash sort's
  row order, keeping the hash-δ output canonical.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.rowhash.ref import rowhash_ref

#: padding sentinel — must equal :data:`repro.relalg.PAD_ID` (kernels may
#: not import relalg: relalg already imports kernels). Pinned by a test.
PAD_ID = 2**31 - 1


def bucket_shift(n_buckets: int) -> int:
    """Top-bits shift for ``order_preserving`` mode; validates the
    power-of-two requirement."""
    bits = int(n_buckets).bit_length() - 1
    if n_buckets != 1 << bits:
        raise ValueError(f"order-preserving radix partition needs a "
                         f"power-of-two bucket count, got {n_buckets}")
    return 32 - bits


def bucket_targets_ref(data: jax.Array, count: jax.Array, n_buckets: int,
                       key_cols: Optional[Tuple[int, ...]] = None,
                       order_preserving: bool = False
                       ) -> Tuple[jax.Array, jax.Array]:
    """(masked data, per-row target bucket) — invalid rows are forced to
    PAD rows and get the sentinel target ``n_buckets``."""
    cap_local, _ = data.shape
    valid = jnp.arange(cap_local, dtype=jnp.int32) < count
    masked = jnp.where(valid[:, None], data, jnp.int32(PAD_ID))
    keyed = masked if key_cols is None else masked[:, jnp.asarray(key_cols)]
    h = rowhash_ref(keyed)
    if order_preserving:
        t = (h >> jnp.uint32(bucket_shift(n_buckets))).astype(jnp.int32)
    else:
        t = (h % jnp.uint32(n_buckets)).astype(jnp.int32)
    return masked, jnp.where(valid, t, jnp.int32(n_buckets))


def radix_partition_ref(data: jax.Array, count: jax.Array, *,
                        n_buckets: int, cap_bucket: int,
                        key_cols: Optional[Tuple[int, ...]] = None,
                        order_preserving: bool = False
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Partition ``data[cap_local, K]``'s ``count`` valid rows into
    ``n_buckets`` fixed-capacity buckets by key hash.

    Returns ``(buckets [n_buckets, cap_bucket, K], counts [n_buckets],
    overflow scalar bool)``: rows within a bucket keep their original
    relative order, unused bucket slots are PAD rows, ``counts`` are
    clamped to ``cap_bucket``, and ``overflow`` is True iff any bucket's
    true occupancy exceeded ``cap_bucket`` (the dropped-rows flag the
    caller must surface — rows are never dropped silently).
    """
    _, k = data.shape
    masked, target = bucket_targets_ref(data, count, n_buckets, key_cols,
                                        order_preserving)
    onehot = (target[:, None]
              == jnp.arange(n_buckets, dtype=jnp.int32)[None, :]
              ).astype(jnp.int32)
    # exclusive running count of same-bucket predecessors = the row's slot
    rank = jnp.sum((jnp.cumsum(onehot, axis=0) - onehot) * onehot, axis=1)
    counts = jnp.sum(onehot, axis=0)
    overflow = jnp.any(counts > cap_bucket)
    ok = (target < n_buckets) & (rank < cap_bucket)
    dest = jnp.where(ok, target * cap_bucket + rank,
                     jnp.int32(n_buckets * cap_bucket))
    flat = jnp.full((n_buckets * cap_bucket, k), jnp.int32(PAD_ID))
    flat = flat.at[dest].set(masked, mode="drop")
    return (flat.reshape(n_buckets, cap_bucket, k),
            jnp.minimum(counts, cap_bucket), overflow)
