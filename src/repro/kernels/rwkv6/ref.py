"""Oracles for the RWKV6 (Finch) time-mix recurrence.

Per head (key/value dim N): data-dependent per-channel decay ``w_t`` and
bonus ``u``::

    S_{t+1} = diag(w_t) S_t + k_t v_t^T
    y_t     = (S_t + diag(u) k_t v_t^T)^T r_t

``rwkv6_scan_ref`` is the exact per-token ``lax.scan`` oracle.
``rwkv6_chunked`` is the chunk-parallel matrix form used as the model's
compute path: intra-chunk work is batched matmuls (MXU-shaped, FLOPs fully
visible to HLO cost analysis), inter-chunk state is a log-depth
``associative_scan`` — no sequential while-loop over tokens.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax



def rwkv6_scan_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                   u: jax.Array, state: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """r/k/v/w: [B,H,T,N] (w = decay in (0,1)), u: [H,N].
    Returns (y [B,H,T,N], final state [B,H,N,N])."""
    b, h, t, n = r.shape
    if state is None:
        state = jnp.zeros((b, h, n, n), jnp.float32)

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp  # each [B,H,N]
        kv = k_t[..., :, None] * v_t[..., None, :]          # [B,H,N,N]
        y = jnp.einsum("bhi,bhij->bhj",
                       r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, y

    xs = tuple(jnp.moveaxis(x.astype(jnp.float32), 2, 0) for x in (r, k, v, w))
    state, ys = lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 2).astype(r.dtype), state


def _chunk_body(r, k, v, w, u):
    """One chunk, all matrix ops. r/k/v/w: [L,N] f32.

    The intra-chunk exponent ``cum_excl[t] - cum[s]`` is ≤ 0 for every
    s < t (cum is non-increasing), so computing it as an explicit [L,L,N]
    log-space difference is unconditionally stable — no clamping, exact
    w.r.t. the scan oracle. XLA fuses the exp into the reduction."""
    l, n = r.shape
    lw = jnp.log(w)
    cum = jnp.cumsum(lw, axis=0)                 # inclusive  [L,N]
    cum_excl = cum - lw                          # exclusive
    diff = cum_excl[:, None, :] - cum[None, :, :]      # [L,L,N], ≤0 for s<t
    scores = jnp.einsum("tsn,tn,sn->ts", jnp.exp(diff), r, k)
    mask = jnp.tril(jnp.ones((l, l), bool), k=-1)
    scores = jnp.where(mask, scores, 0.0)
    bonus = jnp.sum(r * u * k, axis=-1)          # diag(u) k_t v_t^T term
    y = scores @ v + bonus[:, None] * v
    # chunk-level state transition (D, M): S_out = diag(D) S_in + M
    d_tot = jnp.exp(cum[-1])                     # [N]
    m = (k * jnp.exp(cum[-1][None, :] - cum)).T @ v   # [N,N]
    return y, d_tot, m


def rwkv6_chunked(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                  u: jax.Array, state: Optional[jax.Array] = None,
                  chunk: int = 32) -> Tuple[jax.Array, jax.Array]:
    """Chunk-parallel RWKV6 (same signature/semantics as the scan oracle)."""
    b, h, t, n = r.shape
    if state is None:
        state = jnp.zeros((b, h, n, n), jnp.float32)
    # keep log(w) finite when w underflows to 0 (decay saturated anyway)
    w = jnp.maximum(w, 1e-30)
    pad = (-t) % chunk
    if pad:
        r, k, v = (jnp.pad(x, ((0, 0),) * 2 + ((0, pad), (0, 0)))
                   for x in (r, k, v))
        w = jnp.pad(w, ((0, 0),) * 2 + ((0, pad), (0, 0)),
                    constant_values=1.0)
    tc = (t + pad) // chunk

    def per_head(r, k, v, w, u, s0):
        rc, kc, vc, wc = (x.reshape(tc, chunk, n).astype(jnp.float32)
                          for x in (r, k, v, w))
        # chunk summaries for the associative inter-chunk scan
        y0, d, m = jax.vmap(
            lambda a, b_, c, d_: _chunk_body(a, b_, c, d_, u)
        )(rc, kc, vc, wc)

        def combine(x1, x2):
            d1, m1 = x1
            d2, m2 = x2
            return d1 * d2, d2[..., :, None] * m1 + m2

        d_sc, m_sc = lax.associative_scan(combine, (d, m), axis=0)
        # state entering chunk c: scan result of chunks < c, applied to s0
        d_in = jnp.concatenate([jnp.ones((1, n)), d_sc[:-1]], axis=0)
        m_in = jnp.concatenate([jnp.zeros((1, n, n)), m_sc[:-1]], axis=0)
        s_in = d_in[:, :, None] * s0[None] + m_in      # [tc,N,N]
        # inter-chunk contribution (y0 already has intra + bonus)
        lw = jnp.log(wc)
        cum_excl = jnp.cumsum(lw, axis=1) - lw
        q_t = rc * jnp.exp(cum_excl)
        y = y0 + jnp.einsum("cln,cnm->clm", q_t, s_in)
        s_fin = d_sc[-1][:, None] * s0 + m_sc[-1]
        return y.reshape(tc * chunk, n), s_fin

    y, s_fin = jax.vmap(jax.vmap(per_head))(
        r, k, v, w, jnp.broadcast_to(u, (b, h, n)), state)
    return y[:, :, :t].astype(r.dtype), s_fin
