"""Pallas TPU kernel for the RWKV6 time-mix recurrence (chunked).

Grid ``(B*H, T/L)`` with the chunk dimension sequential; the [N,N]
recurrent state lives in VMEM scratch across chunk steps so it never
round-trips HBM. Per chunk the math is the same matrix form as
``ref.rwkv6_chunked`` (exact log-space intra-chunk scores — stable for any
decay), so HBM traffic is one read of r/k/v/w and one write of y per token.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import TPUCompilerParams


def _rwkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, fs_ref,
                  state, *, num_chunks: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    r = r_ref[...].astype(jnp.float32)   # [L,N]
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)   # [N]
    l, n = r.shape

    lw = jnp.log(jnp.maximum(w, 1e-30))
    cum = jnp.cumsum(lw, axis=0)
    cum_excl = cum - lw
    diff = cum_excl[:, None, :] - cum[None, :, :]       # [L,L,N] <= 0
    mask = jnp.tril(jnp.ones((l, l), dtype=bool), k=-1)
    diff = jnp.where(mask[:, :, None], diff, -1e30)
    scores = jnp.einsum("tsn,tn,sn->ts", jnp.exp(diff), r, k)
    bonus = jnp.sum(r * u[None, :] * k, axis=-1)

    q_t = r * jnp.exp(cum_excl)
    s_in = state[...]
    y = scores @ v + bonus[:, None] * v + q_t @ s_in
    o_ref[...] = y.astype(o_ref.dtype)

    d_tot = jnp.exp(cum[-1])
    m = (k * jnp.exp(cum[-1][None, :] - cum)).T @ v
    state[...] = d_tot[:, None] * s_in + m

    @pl.when(c == num_chunks - 1)
    def _finish():
        fs_ref[...] = state[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_pallas(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                 u: jax.Array, *, chunk: int = 32,
                 interpret: bool = False):
    """r/k/v/w: [B,H,T,N]; u: [H,N] -> (y [B,H,T,N], state [B,H,N,N]).
    T must be a chunk multiple (the ops wrapper pads)."""
    b, h, t, n = r.shape
    assert t % chunk == 0, (t, chunk)
    num_chunks = t // chunk
    rf, kf, vf, wf = (x.reshape(b * h, t, n) for x in (r, k, v, w))

    def x_spec():
        return pl.BlockSpec((None, chunk, n), lambda bh, c: (bh, c, 0))

    y, fs = pl.pallas_call(
        functools.partial(_rwkv6_kernel, num_chunks=num_chunks),
        grid=(b * h, num_chunks),
        in_specs=[x_spec(), x_spec(), x_spec(), x_spec(),
                  pl.BlockSpec((None, n), lambda bh, c: (bh % h, 0))],
        out_specs=[x_spec(),
                   pl.BlockSpec((None, n, n), lambda bh, c: (bh, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((b * h, t, n), r.dtype),
                   jax.ShapeDtypeStruct((b * h, n, n), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(rf, kf, vf, wf, u)
    return (y.reshape(b, h, t, n), fs.reshape(b, h, n, n))
