"""Dispatching wrapper for the RWKV6 recurrence."""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from .ref import rwkv6_chunked, rwkv6_scan_ref
from .rwkv6 import rwkv6_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def rwkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
          u: jax.Array, state: Optional[jax.Array] = None, *,
          chunk: int = 32, use_pallas: Optional[bool] = None
          ) -> Tuple[jax.Array, jax.Array]:
    """RWKV6 time mix. Returns (y, final_state). The Pallas path handles
    the zero-initial-state (train/prefill) case; carried-state calls
    (decode) use the chunked jnp path."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas and state is None and r.shape[2] % chunk == 0:
        return rwkv6_pallas(r, k, v, w, u, chunk=chunk,
                            interpret=not _on_tpu())
    return rwkv6_chunked(r, k, v, w, u, state, chunk=chunk)
