"""Dispatching wrapper for the RWKV6 recurrence."""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.kernels import pallas_interpret, resolve_use_pallas

from .ref import rwkv6_chunked
from .rwkv6 import rwkv6_pallas


def rwkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
          u: jax.Array, state: Optional[jax.Array] = None, *,
          chunk: int = 32, use_pallas: Optional[bool] = None
          ) -> Tuple[jax.Array, jax.Array]:
    """RWKV6 time mix. Returns (y, final_state). The Pallas path handles
    the zero-initial-state (train/prefill) case; carried-state calls
    (decode) use the chunked jnp path."""
    use_pallas = resolve_use_pallas(use_pallas)
    if use_pallas and state is None and r.shape[2] % chunk == 0:
        return rwkv6_pallas(r, k, v, w, u, chunk=chunk,
                            interpret=pallas_interpret())
    return rwkv6_chunked(r, k, v, w, u, state, chunk=chunk)
