from .ops import rwkv6
from .ref import rwkv6_chunked, rwkv6_scan_ref
from .rwkv6 import rwkv6_pallas

__all__ = ["rwkv6", "rwkv6_chunked", "rwkv6_scan_ref", "rwkv6_pallas"]
