"""Dispatching wrapper for the Mamba2 SSD recurrence."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import pallas_interpret, resolve_use_pallas

from .mamba2 import mamba2_ssd_pallas
from .ref import ssd_chunked


def mamba2_ssd(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
               c: jax.Array, state: Optional[jax.Array] = None, *,
               chunk: int = 64, use_pallas: Optional[bool] = None
               ) -> Tuple[jax.Array, jax.Array]:
    """Mamba2 SSD. x [B,H,T,P]; dt [B,H,T]; a [H]; b/c [B,T,N]. The Pallas
    path handles the zero-initial-state (train/prefill) case; carried-state
    calls (decode) use the chunked jnp path."""
    use_pallas = resolve_use_pallas(use_pallas)
    if use_pallas and state is None and x.shape[2] % chunk == 0:
        la = dt.astype(jnp.float32) * a.astype(jnp.float32)[None, :, None]
        xdt = (x.astype(jnp.float32)
               * dt.astype(jnp.float32)[..., None]).astype(x.dtype)
        return mamba2_ssd_pallas(xdt, la, b, c, chunk=chunk,
                                 interpret=pallas_interpret())
    return ssd_chunked(x, dt, a, b, c, state, chunk=chunk)
