from .ops import mamba2_ssd
from .ref import ssd_chunked, ssd_scan_ref

__all__ = ["mamba2_ssd", "ssd_chunked", "ssd_scan_ref"]
