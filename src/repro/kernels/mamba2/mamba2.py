"""Pallas TPU kernel for the Mamba2 SSD chunk scan.

Same scheme as the rwkv6 kernel: grid ``(B*H, T/L)`` with the chunk
dimension sequential; the [N, P] recurrent state lives in VMEM scratch
across chunk steps. Per chunk: two MXU matmuls for the intra-chunk scores
and output, one for the state delta — HBM traffic is one read of
x·dt / decay / B / C and one write of y per token, state never leaves
VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import TPUCompilerParams


def _ssd_kernel(xdt_ref, la_ref, b_ref, c_ref, o_ref, fs_ref, state, *,
                num_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    xdt = xdt_ref[...].astype(jnp.float32)   # [L,P]
    la = la_ref[...].astype(jnp.float32)     # [L]
    b = b_ref[...].astype(jnp.float32)       # [L,N]
    c = c_ref[...].astype(jnp.float32)       # [L,N]
    l = xdt.shape[0]

    cum = jnp.cumsum(la)
    diff = cum[:, None] - cum[None, :]
    mask = jnp.tril(jnp.ones((l, l), dtype=bool))
    decay = jnp.where(mask, jnp.exp(jnp.where(mask, diff, 0.0)), 0.0)
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * decay
    s_in = state[...]                         # [N,P]
    q = c * jnp.exp(cum)[:, None]
    y = (jax.lax.dot_general(scores, xdt, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
         + jax.lax.dot_general(q, s_in, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32))
    o_ref[...] = y.astype(o_ref.dtype)

    bw = b * jnp.exp(cum[-1] - cum)[:, None]
    delta = jax.lax.dot_general(bw, xdt, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    state[...] = jnp.exp(cum[-1]) * s_in + delta

    @pl.when(ci == num_chunks - 1)
    def _finish():
        fs_ref[...] = state[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba2_ssd_pallas(xdt: jax.Array, la: jax.Array, b: jax.Array,
                      c: jax.Array, *, chunk: int = 64,
                      interpret: bool = False):
    """xdt [B,H,T,P] (= x*dt); la [B,H,T] (= dt*A); b/c [B,T,N].
    Returns (y [B,H,T,P], state [B,H,N,P]). T must be a chunk multiple."""
    bb, h, t, p = xdt.shape
    n = b.shape[-1]
    assert t % chunk == 0, (t, chunk)
    num_chunks = t // chunk
    xf = xdt.reshape(bb * h, t, p)
    lf = la.reshape(bb * h, t)

    y, fs = pl.pallas_call(
        functools.partial(_ssd_kernel, num_chunks=num_chunks),
        grid=(bb * h, num_chunks),
        in_specs=[
            pl.BlockSpec((None, chunk, p), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, chunk), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((None, chunk, n), lambda bh, ci: (bh // h, ci, 0)),
            pl.BlockSpec((None, chunk, n), lambda bh, ci: (bh // h, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, p), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, n, p), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((bb * h, t, p), xdt.dtype),
                   jax.ShapeDtypeStruct((bb * h, n, p), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xf, lf, b, c)
    return y.reshape(bb, h, t, p), fs.reshape(bb, h, n, p)
