"""Oracles for the Mamba2 SSD (state-space dual) recurrence.

Per head (headdim P, state N), scalar decay per step ``a_t = exp(dt_t A)``::

    h_t = a_t h_{t-1} + B_t (dt_t x_t)^T        h: [N, P]
    y_t = C_t^T h_t

``ssd_scan_ref`` is the exact per-token oracle; ``ssd_chunked`` is the
chunk-parallel matrix form (intra-chunk batched matmuls on the MXU +
log-depth associative scan across chunks) used as the model compute path.
B/C are shared across the heads of a group (ngroups=1 here): [B, T, N].
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def ssd_scan_ref(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                 c: jax.Array, state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """x [B,H,T,P]; dt [B,H,T]; a (log-decay coef A) [H]; b/c [B,T,N].
    Returns (y [B,H,T,P], final state [B,H,N,P])."""
    bb, h, t, p = x.shape
    n = b.shape[-1]
    if state is None:
        state = jnp.zeros((bb, h, n, p), jnp.float32)
    la = dt.astype(jnp.float32) * a.astype(jnp.float32)[None, :, None]
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]

    def step(s, inp):
        xdt_t, la_t, b_t, c_t = inp      # [B,H,P], [B,H], [B,N], [B,N]
        s = (jnp.exp(la_t)[..., None, None] * s
             + b_t[:, None, :, None] * xdt_t[:, :, None, :])
        y = jnp.einsum("bn,bhnp->bhp", c_t, s)
        return s, y

    xs = (jnp.moveaxis(xdt, 2, 0), jnp.moveaxis(la, 2, 0),
          jnp.moveaxis(b.astype(jnp.float32), 1, 0),
          jnp.moveaxis(c.astype(jnp.float32), 1, 0))
    state, ys = lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 2).astype(x.dtype), state


def _chunk_body(xdt, la, b, c):
    """One chunk: xdt [L,P], la [L], b/c [L,N] (f32). Returns
    (y_intra [L,P], decay_tot scalar, state_delta [N,P], q [L,N])."""
    l = xdt.shape[0]
    cum = jnp.cumsum(la)                               # inclusive [L]
    # intra-chunk scores: s<=t, weight exp(cum[t]-cum[s])
    diff = cum[:, None] - cum[None, :]                 # [L,L]
    mask = jnp.tril(jnp.ones((l, l), bool))
    decay = jnp.where(mask, jnp.exp(jnp.where(mask, diff, 0.0)), 0.0)
    scores = (c @ b.T) * decay
    y = scores @ xdt
    # chunk-state transition: h_out = exp(cum[-1]) h_in + delta
    delta = (b * jnp.exp(cum[-1] - cum)[:, None]).T @ xdt   # [N,P]
    q = c * jnp.exp(cum)[:, None]                      # reads h_in
    return y, jnp.exp(cum[-1]), delta, q


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                c: jax.Array, state: Optional[jax.Array] = None,
                chunk: int = 64) -> Tuple[jax.Array, jax.Array]:
    """Chunk-parallel SSD; same signature/semantics as the scan oracle."""
    bb, h, t, p = x.shape
    n = b.shape[-1]
    if state is None:
        state = jnp.zeros((bb, h, n, p), jnp.float32)
    pad = (-t) % chunk
    la = dt.astype(jnp.float32) * a.astype(jnp.float32)[None, :, None]
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]
    if pad:
        xdt = jnp.pad(xdt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        la = jnp.pad(la, ((0, 0), (0, 0), (0, pad)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    tc = (t + pad) // chunk

    def per_head(xdt, la, b, c, s0):
        # [T,P],[T],[T,N],[T,N],[N,P]
        xc = xdt.reshape(tc, chunk, p)
        lc = la.reshape(tc, chunk)
        bc = b.astype(jnp.float32).reshape(tc, chunk, n)
        cc = c.astype(jnp.float32).reshape(tc, chunk, n)
        y0, d, delta, q = jax.vmap(_chunk_body)(xc, lc, bc, cc)

        def combine(s1, s2):
            d1, m1 = s1
            d2, m2 = s2
            return d1 * d2, d2[..., None, None] * m1 + m2

        d_sc, m_sc = lax.associative_scan(combine, (d, delta), axis=0)
        d_in = jnp.concatenate([jnp.ones((1,)), d_sc[:-1]])
        m_in = jnp.concatenate([jnp.zeros((1, n, p)), m_sc[:-1]])
        h_in = d_in[:, None, None] * s0[None] + m_in       # [tc,N,P]
        y = y0 + jnp.einsum("cln,cnp->clp", q, h_in)
        s_fin = d_sc[-1] * s0 + m_sc[-1]
        return y.reshape(tc * chunk, p), s_fin

    y, s_fin = jax.vmap(  # over batch
        jax.vmap(per_head, in_axes=(0, 0, None, None, 0))  # over heads
    )(xdt, la, b, c, state)
    return y[:, :, :t].astype(x.dtype), s_fin
