"""Sharded, atomic, async checkpointing with elastic reshard-on-restore.

Layout (one directory per step; the write is crash-safe because the
directory is materialized under a ``.tmp`` name and ``os.rename``'d —
readers never observe a partial checkpoint)::

    ckpt_root/
      step_00000100/
        manifest.json       tree structure, per-leaf shape/dtype/logical axes
        arrays.npz          leaf data keyed by flattened tree path
      LATEST                text file: "step_00000100"

Elastic restore: the manifest stores *logical* metadata, never mesh axes,
so a checkpoint written on a ``(data=16, model=16)`` mesh restores onto
``(data=8, model=4)`` (or one CPU) by re-`device_put`ting each leaf with
the target sharding — the logical->mesh mapping is recomputed at restore
time from the target AxisRules. On a real multi-controller pod each host
would write only its addressable shards; this single-controller
implementation gathers leaves with ``np.asarray`` (fully-addressable
arrays) and keeps the same on-disk format.

Async mode hands the serialized host copy to a writer thread: the train
loop continues while the previous step flushes (standard
checkpoint-overlap trick; the copy is taken synchronously so donation and
in-place updates cannot race the writer).
"""
from __future__ import annotations

import dataclasses
import json
import os
import queue
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any

_SEP = "/"


# ---------------------------------------------------------------------------
# tree <-> flat dict of numpy leaves
# ---------------------------------------------------------------------------

def _flatten_with_paths(tree: PyTree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = _SEP.join(_path_elem(p) for p in path)
        out.append((key, leaf))
    return out


def _path_elem(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return str(p.name)
    return str(p)


def _treedef_of(tree: PyTree):
    return jax.tree_util.tree_structure(tree)


def _host_copy(tree: PyTree) -> Dict[str, np.ndarray]:
    """Synchronous device->host gather (the only blocking part of async)."""
    out = {}
    for key, leaf in _flatten_with_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        out[key] = arr
    return out


# ---------------------------------------------------------------------------
# save / restore primitives
# ---------------------------------------------------------------------------

def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:08d}")


def save_checkpoint(root: str, step: int, tree: PyTree,
                    extra: Optional[Dict[str, Any]] = None) -> str:
    """Atomic synchronous save; returns the final directory path."""
    host = _host_copy(tree)
    return _write_host_copy(root, step, host, _manifest_for(tree, step, extra))


def _manifest_for(tree: PyTree, step: int,
                  extra: Optional[Dict[str, Any]]) -> Dict[str, Any]:
    leaves = _flatten_with_paths(tree)
    return {
        "step": step,
        "format": 1,
        "leaves": {k: {"shape": list(np.shape(v)),
                       "dtype": str(np.asarray(jax.device_get(v)).dtype
                                    if hasattr(v, "dtype") else
                                    np.asarray(v).dtype)}
                   for k, v in leaves},
        "extra": extra or {},
    }


def _write_host_copy(root: str, step: int, host: Dict[str, np.ndarray],
                     manifest: Dict[str, Any]) -> str:
    final = _step_dir(root, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{k: v for k, v in host.items()})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)              # atomic publish
    latest_tmp = os.path.join(root, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(final))
    os.replace(latest_tmp, os.path.join(root, "LATEST"))
    return final


def latest_step(root: str) -> Optional[int]:
    path = os.path.join(root, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(root, name)):
        return None
    return int(name.split("_")[-1])


def all_steps(root: str) -> List[int]:
    if not os.path.isdir(root):
        return []
    out = []
    for name in os.listdir(root):
        if name.startswith("step_") and not name.endswith(".tmp") \
                and os.path.isdir(os.path.join(root, name)):
            out.append(int(name.split("_")[-1]))
    return sorted(out)


def restore_checkpoint(root: str, like: PyTree, step: Optional[int] = None,
                       shardings: Optional[PyTree] = None
                       ) -> Tuple[PyTree, Dict[str, Any]]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings`` (same structure, NamedSharding
    leaves) triggers elastic resharding via device_put; with ``None`` the
    leaves come back as committed numpy->jnp arrays on the default device.
    Returns (tree, manifest['extra'])."""
    step = latest_step(root) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {root}")
    d = _step_dir(root, step)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))

    flat_like = _flatten_with_paths(like)
    treedef = _treedef_of(like)
    shard_leaves = (None if shardings is None else
                    [s for _, s in _flatten_with_paths(shardings)])

    leaves = []
    for i, (key, ref) in enumerate(flat_like):
        if key not in data:
            raise KeyError(f"checkpoint {d} missing leaf {key!r}")
        arr = data[key]
        want_shape = tuple(ref.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"leaf {key}: checkpoint shape {arr.shape} "
                             f"!= expected {want_shape}")
        want_dtype = np.dtype(ref.dtype)
        if arr.dtype != want_dtype:
            # npz round-trips ml_dtypes (bf16/f8) as raw void bytes
            if arr.dtype.kind == "V" and \
                    arr.dtype.itemsize == want_dtype.itemsize:
                arr = arr.view(want_dtype)
            else:
                arr = arr.astype(want_dtype)
        if shard_leaves is not None:
            leaves.append(jax.device_put(arr, shard_leaves[i]))
        else:
            leaves.append(jax.device_put(arr))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, manifest.get("extra", {})


# ---------------------------------------------------------------------------
# manager (async writer + retention)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CheckpointManager:
    """Retention + async writes. ``save`` blocks only for the host copy."""

    root: str
    keep_n: int = 3
    async_write: bool = True

    def __post_init__(self):
        os.makedirs(self.root, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue()
        self._err: List[BaseException] = []
        self._thread: Optional[threading.Thread] = None
        if self.async_write:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    # -- writer thread ------------------------------------------------------
    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host, manifest = item
            try:
                _write_host_copy(self.root, step, host, manifest)
                self._gc()
            except BaseException as e:   # surfaced on next save/wait
                self._err.append(e)
            finally:
                self._q.task_done()

    def _raise_pending(self):
        if self._err:
            raise RuntimeError("async checkpoint write failed") \
                from self._err.pop(0)

    def _gc(self):
        steps = all_steps(self.root)
        for s in steps[:-self.keep_n] if self.keep_n else []:
            shutil.rmtree(_step_dir(self.root, s), ignore_errors=True)

    # -- public API ----------------------------------------------------------
    def save(self, step: int, tree: PyTree,
             extra: Optional[Dict[str, Any]] = None) -> None:
        self._raise_pending()
        manifest = _manifest_for(tree, step, extra)
        host = _host_copy(tree)          # synchronous: donation-safe
        if self.async_write:
            self._q.put((step, host, manifest))
        else:
            _write_host_copy(self.root, step, host, manifest)
            self._gc()

    def wait(self) -> None:
        if self.async_write:
            self._q.join()
        self._raise_pending()

    def close(self) -> None:
        if self._thread is not None:
            self._q.join()
            self._q.put(None)
            self._thread.join()
            self._thread = None
        self._raise_pending()

    def latest_step(self) -> Optional[int]:
        return latest_step(self.root)

    def all_steps(self) -> List[int]:
        return all_steps(self.root)

    def restore(self, like: PyTree, step: Optional[int] = None,
                shardings: Optional[PyTree] = None):
        self.wait()
        return restore_checkpoint(self.root, like, step, shardings)
