from .sharding import (AxisRules, ParamSpec, abstract_params, init_params,
                       logical_sharding, param_shardings, spec_tree_map,
                       DEFAULT_RULES, FSDP_RULES)

__all__ = [
    "AxisRules", "ParamSpec", "abstract_params", "init_params",
    "logical_sharding", "param_shardings", "spec_tree_map",
    "DEFAULT_RULES", "FSDP_RULES",
]
