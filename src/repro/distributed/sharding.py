"""Logical-axis sharding: ParamSpec trees -> NamedShardings.

Model definitions never name mesh axes. Every parameter is declared as a
:class:`ParamSpec` carrying *logical* axis names (``("layers", "embed",
"ffn")`` ...); an :class:`AxisRules` table maps logical names to mesh axes
(MaxText-style), so the same model runs data-parallel, tensor-parallel,
FSDP, or any mix by swapping rule tables — the foundation of the dry-run
matrix and of the §Perf hillclimbs (a hillclimb step is usually one rule
edit).

Conventions:

* a logical axis mapped to ``None`` is replicated;
* a logical axis may map to a *tuple* of mesh axes (e.g. batch ->
  ``("pod", "data")``);
* rules are ordered: the first rule whose mesh axes are all still unused
  by the current parameter wins (prevents double-sharding one mesh axis).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declaration of one parameter: shape + dtype + logical axes + init."""

    shape: Tuple[int, ...]
    logical_axes: Tuple[Optional[str], ...]
    dtype: jnp.dtype = jnp.bfloat16
    init: str = "normal"          # 'normal' | 'zeros' | 'ones' | 'scaled'
    init_scale: float = 0.02

    def __post_init__(self):
        if len(self.shape) != len(self.logical_axes):
            raise ValueError(
                f"shape {self.shape} vs logical_axes {self.logical_axes}")

    def abstract(self, sharding=None) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype, sharding=sharding)

    def materialize(self, key: jax.Array) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        scale = self.init_scale
        if self.init == "scaled":  # 1/sqrt(fan_in) on the last axis
            fan_in = self.shape[-1] if len(self.shape) else 1
            scale = float(fan_in) ** -0.5
        return (jax.random.normal(key, self.shape, jnp.float32)
                * scale).astype(self.dtype)


def spec_tree_map(fn: Callable[[ParamSpec], object], specs):
    """tree_map over a pytree of ParamSpecs (dataclass leaves)."""
    return jax.tree_util.tree_map(
        fn, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def init_params(specs, rng: jax.Array):
    """Materialize a ParamSpec tree into arrays (smoke tests / examples)."""
    leaves, treedef = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(rng, len(leaves))
    return jax.tree_util.tree_unflatten(
        treedef, [s.materialize(k) for s, k in zip(leaves, keys)])


def abstract_params(specs, mesh: Optional[Mesh] = None,
                    rules: Optional["AxisRules"] = None):
    """ShapeDtypeStruct tree (optionally sharded) — the dry-run input."""
    if mesh is None:
        return spec_tree_map(lambda s: s.abstract(), specs)
    assert rules is not None
    return spec_tree_map(
        lambda s: s.abstract(NamedSharding(mesh, rules.spec_for(s))), specs)


# ---------------------------------------------------------------------------
# axis rules
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Ordered (logical_axis -> mesh axes) table."""

    rules: Tuple[Tuple[str, MeshAxes], ...]

    def with_overrides(self, *overrides: Tuple[str, MeshAxes]) -> "AxisRules":
        """New table with ``overrides`` taking precedence (prepended)."""
        return AxisRules(tuple(overrides) + self.rules)

    def candidates(self, logical: str) -> Sequence[MeshAxes]:
        return [m for l, m in self.rules if l == logical]

    def spec_for(self, spec_or_axes) -> P:
        """PartitionSpec for a ParamSpec (or raw logical-axes tuple)."""
        axes = (spec_or_axes.logical_axes
                if isinstance(spec_or_axes, ParamSpec) else spec_or_axes)
        used: set = set()
        out = []
        for logical in axes:
            assigned: MeshAxes = None
            if logical is not None:
                for mesh_axes in self.candidates(logical):
                    if mesh_axes is None:
                        assigned = None
                        break
                    tup = ((mesh_axes,) if isinstance(mesh_axes, str)
                           else tuple(mesh_axes))
                    if not (set(tup) & used):
                        assigned = tup if len(tup) > 1 else tup[0]
                        used.update(tup)
                        break
            out.append(assigned)
        # trim trailing Nones (canonical PartitionSpec form)
        while out and out[-1] is None:
            out.pop()
        return P(*out)


def logical_sharding(mesh: Mesh, rules: AxisRules,
                     *logical_axes: Optional[str]) -> NamedSharding:
    """NamedSharding for an activation given its logical axes."""
    return NamedSharding(mesh, rules.spec_for(tuple(logical_axes)))


def param_shardings(specs, mesh: Mesh, rules: AxisRules):
    """Tree of NamedShardings matching a ParamSpec tree."""
    return spec_tree_map(
        lambda s: NamedSharding(mesh, rules.spec_for(s)), specs)


# ---------------------------------------------------------------------------
# standard rule tables
# ---------------------------------------------------------------------------
#
# Logical axes used by the model zoo:
#   batch       input batch                  -> (pod, data)
#   seq         sequence (activations)       -> None (or model under SP)
#   embed       d_model / residual stream    -> None (or data under FSDP)
#   heads       q heads                      -> model
#   kv_heads    k/v heads                    -> model
#   head_dim    per-head dim                 -> None
#   ffn         MLP hidden                   -> model
#   vocab       embedding/unembedding rows   -> model
#   expert      MoE expert dim               -> model
#   expert_ffn  per-expert hidden            -> None (or data under FSDP)
#   layers      scan-stacked layer dim       -> None (never sharded)
#   conv/state  small recurrent dims         -> None

DEFAULT_RULES = AxisRules((
    ("batch", ("pod", "data")),
    ("batch", "data"),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("ffn", "model"),
    ("vocab", "model"),
    ("expert", "model"),
    ("seq", None),
    ("embed", None),
    ("expert_ffn", None),
))

# FSDP: parameters additionally sharded over the within-pod data axis on a
# non-"model" dim; XLA inserts the per-layer all-gather. Used by >=20B
# configs where params+optimizer would not fit otherwise.
FSDP_RULES = DEFAULT_RULES.with_overrides(
    ("embed", "data"),
    ("expert_ffn", "data"),
)


def batch_sharding(mesh: Mesh, rules: AxisRules) -> NamedSharding:
    return logical_sharding(mesh, rules, "batch", "seq")


def make_rules(fsdp: bool = False,
               overrides: Sequence[Tuple[str, MeshAxes]] = ()) -> AxisRules:
    base = FSDP_RULES if fsdp else DEFAULT_RULES
    return base.with_overrides(*overrides) if overrides else base
