"""Fault tolerance: failure injection, restart policy, straggler watch.

On a real pod, failures arrive as lost hosts / ICI timeouts and the
runtime restarts the job from the last checkpoint, possibly on fewer
nodes (elastic). This module implements the *control plane* of that story
so it can be exercised end-to-end in tests and examples:

* :class:`FailureInjector` — deterministic (seeded) step-level failure
  schedule; raises :class:`SimulatedFailure` mid-loop.
* :class:`RestartPolicy` + :func:`run_with_restarts` — the supervisor:
  catches failures, restores from the latest checkpoint (optionally onto
  a *different* mesh via the ``remesh`` hook = elastic scaling), replays.
* :class:`StragglerMonitor` — per-host step-time EMA; hosts slower than
  ``threshold`` x median are flagged; :meth:`shard_weights` feeds the data
  pipeline so slow hosts receive proportionally fewer examples (straggler
  mitigation by load shedding rather than sync barriers).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np


class SimulatedFailure(RuntimeError):
    """Injected node failure (host lost, ICI timeout, preemption...)."""


# ---------------------------------------------------------------------------
# failure injection
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FailureInjector:
    """Raises at deterministic steps: either an explicit schedule or a
    seeded Bernoulli per step (probability ``p``). Each failure fires once
    — after a restart the same step passes (crash-consistency is the
    checkpoint's job, not the injector's)."""

    schedule: Sequence[int] = ()
    p: float = 0.0
    seed: int = 0
    max_failures: int = 10

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._fired: set = set()
        self._count = 0

    def maybe_fail(self, step: int) -> None:
        if self._count >= self.max_failures:
            return
        want = step in self.schedule
        if not want and self.p > 0.0 and step not in self._fired:
            # hash-seeded draw: deterministic per (seed, step)
            r = np.random.default_rng((self.seed, step)).random()
            want = r < self.p
        if want and step not in self._fired:
            self._fired.add(step)
            self._count += 1
            raise SimulatedFailure(f"injected failure at step {step}")


# ---------------------------------------------------------------------------
# restart supervisor
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RestartPolicy:
    max_restarts: int = 5
    backoff_seconds: float = 0.0      # real pods back off; tests use 0
    restore_on_start: bool = True


@dataclasses.dataclass
class RestartReport:
    restarts: int = 0
    failures: List[Tuple[int, str]] = dataclasses.field(default_factory=list)
    resumed_from: List[Optional[int]] = dataclasses.field(
        default_factory=list)


def run_with_restarts(loop: Callable[[Optional[int]], Any],
                      policy: RestartPolicy = RestartPolicy(),
                      on_restart: Optional[Callable[[int], None]] = None
                      ) -> Tuple[Any, RestartReport]:
    """Supervise ``loop(resume_step)``: run until it returns; on
    :class:`SimulatedFailure` invoke ``on_restart`` (e.g. remesh for
    elastic scaling) and call the loop again — it is responsible for
    restoring from its checkpoint manager. Raises after
    ``policy.max_restarts`` failures (the paged-in-human case)."""
    report = RestartReport()
    attempt = 0
    while True:
        try:
            result = loop(None if attempt == 0 else attempt)
            return result, report
        except SimulatedFailure as e:
            attempt += 1
            report.restarts += 1
            report.failures.append((attempt, str(e)))
            if attempt > policy.max_restarts:
                raise
            if policy.backoff_seconds:
                time.sleep(policy.backoff_seconds)
            if on_restart is not None:
                on_restart(attempt)


# ---------------------------------------------------------------------------
# straggler monitor
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StragglerMonitor:
    """EMA of per-host step durations; flags and down-weights stragglers.

    ``observe`` is called with per-host wall times for one step (on a real
    pod these come from the per-host heartbeat); ``stragglers()`` returns
    hosts whose EMA exceeds ``threshold`` x the median EMA; and
    ``shard_weights()`` converts inverse EMAs into data-shard weights the
    pipeline uses to rebalance (slow host -> fewer rows)."""

    n_hosts: int
    alpha: float = 0.2
    threshold: float = 1.5

    def __post_init__(self):
        self._ema = np.zeros(self.n_hosts, dtype=np.float64)
        self._seen = np.zeros(self.n_hosts, dtype=bool)

    def observe(self, times: Sequence[float]) -> None:
        t = np.asarray(times, dtype=np.float64)
        if t.shape != (self.n_hosts,):
            raise ValueError(f"expected {self.n_hosts} host times")
        fresh = ~self._seen
        self._ema[fresh] = t[fresh]
        self._ema[~fresh] = (self.alpha * t[~fresh]
                             + (1 - self.alpha) * self._ema[~fresh])
        self._seen[:] = True

    @property
    def ema(self) -> np.ndarray:
        return self._ema.copy()

    def stragglers(self) -> List[int]:
        if not self._seen.any():
            return []
        med = float(np.median(self._ema[self._seen]))
        if med <= 0:
            return []
        return [i for i in range(self.n_hosts)
                if self._seen[i] and self._ema[i] > self.threshold * med]

    def shard_weights(self) -> np.ndarray:
        """Data-pipeline weights proportional to host speed (1/ema),
        normalized to sum to n_hosts (weight 1.0 = fair share)."""
        if not self._seen.all() or (self._ema <= 0).any():
            return np.ones(self.n_hosts)
        inv = 1.0 / self._ema
        return inv * (self.n_hosts / inv.sum())
