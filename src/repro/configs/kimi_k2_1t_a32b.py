"""Kimi K2 — trillion-parameter MoE, 384 experts top-8 (+1 shared).
[arXiv:2501.kimi2 paper-table; unverified]
61L d_model=7168 64H (GQA kv=8) expert d_ff=2048 vocab=163840.

~1.03T total / ~32B active. Training at this scale REQUIRES the
multi-pod mesh: params alone are 2 TB in bf16 — fsdp_pods shards them
over (pod, data) x model = 512 ways (4 GB/chip). Adafactor keeps the
optimizer state factored; bf16 gradient accumulation halves the grad
buffer. The single-pod dry-run still compiles — its memory_analysis
documents the overflow (see EXPERIMENTS.md)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab_size=163840, d_head=112,
    n_experts=384, top_k=8, n_shared_experts=1, capacity_factor=1.25,
    moe_impl="local",
    optimizer="adafactor", fsdp=True, fsdp_pods=True, remat="full",
    seq_shard_activations=True,
    microbatch_seq_tokens=1 << 16,
)
