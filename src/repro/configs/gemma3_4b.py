"""Gemma3 4B — dense GQA, 5:1 local:global attention, 128k context.
[hf:google/gemma-3-*; unverified] 34L d_model=2560 8H (GQA kv=4)
d_ff=10240 vocab=262144. Local layers use a 1024-token sliding window;
every 6th layer is global. Sub-quadratic overall (only 6 global layers
hold full-context KV) => runs the long_500k cell."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
    d_ff=10240, vocab_size=262144, d_head=256,
    local_global=5, window_size=1024, tied_embeddings=True,
    banded_local=True,
    rope_theta=1e6,
    optimizer="adamw", fsdp=True, remat="full",
    supports_long_context=True,
)
