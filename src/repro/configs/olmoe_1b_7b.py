"""OLMoE 1B-7B — MoE, 64 experts top-8. [arXiv:2409.02060; hf]
16L d_model=2048 16H (GQA kv=16) expert d_ff=1024 vocab=50304."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab_size=50304, d_head=128,
    n_experts=64, top_k=8, capacity_factor=1.25, moe_impl="local",
    optimizer="adamw", fsdp=False, remat="full",
    microbatch_seq_tokens=1 << 18,
)
