"""RWKV6 "Finch" 7B — attention-free, data-dependent decay.
[arXiv:2404.05892; hf] 32L d_model=4096 d_ff=14336 vocab=65536.
Recurrent O(1)/token state => runs the long_500k cell."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b", family="rwkv",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=0,
    d_ff=14336, vocab_size=65536, d_head=64,
    ssm_head_dim=64, ssm_state=64,
    optimizer="adamw", fsdp=True, remat="full",
    supports_long_context=True,
)
