"""Whisper large-v3 — encoder-decoder, stub conv frontend.
[arXiv:2212.04356; unverified] 32L(enc)+32L(dec) d_model=1280 20H (MHA
kv=20) d_ff=5120 vocab=51866; input_specs provides 1500 precomputed frame
embeddings (the conv frontend output)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab_size=51866, d_head=64,
    n_enc_frames=1500,
    optimizer="adamw", fsdp=False, remat="full",
)
