"""Mistral Large 123B — dense GQA.
[hf:mistralai/Mistral-Large-Instruct-2407; unverified]
88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768.

At 123B: Adafactor (factored 2nd moment — AdamW state alone would be
~2 TB), FSDP over the data axis, sequence-sharded residual checkpoints,
64k-token microbatches (16-way grad accumulation at train_4k)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-large-123b", family="dense",
    n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8,
    d_ff=28672, vocab_size=32768, d_head=128,
    rope_theta=1e6,
    optimizer="adafactor", fsdp=True, remat="full",
    seq_shard_activations=True,
    microbatch_seq_tokens=1 << 16,
)
