from .base import (ArchConfig, ShapeSpec, SHAPES, get_config, list_archs,
                   reduced_config)

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "get_config", "list_archs",
           "reduced_config"]
