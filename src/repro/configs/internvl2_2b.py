"""InternVL2 2B — VLM: stub InternViT frontend + InternLM2-1.8B backbone.
[arXiv:2404.16821; hf] 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553; 256 precomputed patch embeddings prepended."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab_size=92553, d_head=128,
    n_prepend=256,
    optimizer="adamw", fsdp=False, remat="full",
)
