"""Zamba2 2.7B — Mamba2 backbone + weight-shared attention block.
[arXiv:2411.15242; hf] 54L d_model=2560 32H (kv=32) d_ff=10240
vocab=32000, ssm_state=64; shared attn+MLP block every 6 mamba layers.
SSM state is O(1)/token => runs the long_500k cell (the 9 shared-block
invocations hold full-context KV, 1:6 ratio)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab_size=32000, d_head=80,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, shared_attn_every=6,
    optimizer="adamw", fsdp=False, remat="full",
    supports_long_context=True,
)
