"""Architecture + shape configuration.

One :class:`ArchConfig` per assigned architecture lives in
``configs/<id>.py``; the four input-shape points are global
(:data:`SHAPES`). ``reduced_config`` shrinks any arch to a CPU-smoke-test
size *of the same family* (same block structure, tiny dims).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


def round_up(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


# ---------------------------------------------------------------------------
# input shapes (assigned; seq_len x global_batch)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# architecture config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | rwkv | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0               # 0 => d_model // n_heads

    # dense-family options
    qk_norm: bool = False
    rope_theta: float = 10000.0
    local_global: int = 0         # gemma3: N local layers per global layer
    window_size: int = 0          # sliding-window width for local layers
    # Period-structured scan: local layers use the banded kernel that only
    # COMPUTES the window band (the homogeneous scan must execute every kv
    # block because its per-layer window is traced). Train/apply path.
    banded_local: bool = False
    tied_embeddings: bool = False

    # moe
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # 'global': one global sort-dispatch (baseline; the sort and the
    # [E,C,D] buffer are GLOBAL, so GSPMD pays cross-shard traffic).
    # 'local': shard_map dispatch/combine — the sort stays inside each
    # data shard, expert matmuls run expert-sharded with zero comm, and
    # the combine is one masked psum over `model` (see §Perf hillclimb 1).
    moe_impl: str = "global"

    # ssm / rwkv / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    shared_attn_every: int = 0    # zamba2: shared attn block cadence

    # modality frontends (stubs: input_specs provides embeddings)
    n_prepend: int = 0            # vlm: patch embeddings prepended
    n_enc_frames: int = 0         # audio: encoder frames (enc-dec)

    # training / distribution defaults
    remat: str = "full"           # none | dots | full
    fsdp: bool = False
    fsdp_pods: bool = False       # FSDP across the pod axis too (>=500B)
    optimizer: str = "adamw"      # adamw | adafactor
    microbatch_seq_tokens: int = 1 << 22   # grad-accum sizing target
    seq_shard_activations: bool = False    # SP on residual checkpoints
    use_pallas: Optional[bool] = None      # None => auto (TPU yes, CPU no)
    # int8 error-feedback compression of the cross-pod gradient all-reduce
    # (valid when params are replicated across pods, i.e. not fsdp_pods)
    grad_compress_pods: bool = False
    # Unroll scans over layers (and partially over attention kv blocks).
    # Trade-off: O(L) HLO + slower compiles, but exact cost_analysis and
    # sometimes better XLA overlap scheduling. The dry-run flips this on
    # for roofline fidelity (while-loop bodies are otherwise counted once).
    unroll_layers: bool = False

    # long_500k applicability (sub-quadratic archs only)
    supports_long_context: bool = False
    # decode applicability (encoder-only archs would set False)
    supports_decode: bool = True

    def __post_init__(self):
        if self.d_head == 0 and self.n_heads:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        return round_up(self.vocab_size, 128)

    @property
    def d_inner(self) -> int:     # mamba2 inner width
        return self.ssm_expand * self.d_model

    def shape_supported(self, shape: ShapeSpec) -> bool:
        if shape.kind == "decode" and not self.supports_decode:
            return False
        if shape.name == "long_500k" and not self.supports_long_context:
            return False
        return True

    def microbatches(self, shape: ShapeSpec, n_data_shards: int) -> int:
        """Grad-accum steps so one microbatch holds <= the token target."""
        if shape.kind != "train":
            return 1
        total = shape.seq_len * shape.global_batch
        mb = max(1, total // self.microbatch_seq_tokens)
        # microbatch count must divide global_batch / data shards evenly
        per_shard = shape.global_batch // n_data_shards
        while per_shard % mb and mb > 1:
            mb -= 1
        return mb


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "rwkv6_7b", "internlm2_20b", "qwen3_1p7b", "gemma3_4b",
    "mistral_large_123b", "olmoe_1b_7b", "kimi_k2_1t_a32b",
    "internvl2_2b", "zamba2_2p7b", "whisper_large_v3",
)

_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}
_ALIASES.update({
    "rwkv6-7b": "rwkv6_7b", "internlm2-20b": "internlm2_20b",
    "qwen3-1.7b": "qwen3_1p7b", "gemma3-4b": "gemma3_4b",
    "mistral-large-123b": "mistral_large_123b", "olmoe-1b-7b": "olmoe_1b_7b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b", "internvl2-2b": "internvl2_2b",
    "zamba2-2.7b": "zamba2_2p7b", "whisper-large-v3": "whisper_large_v3",
})


def list_archs() -> Tuple[str, ...]:
    return ARCH_IDS


def get_config(arch: str) -> ArchConfig:
    mod_name = _ALIASES.get(arch, arch)
    if mod_name not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def reduced_config(cfg: ArchConfig) -> ArchConfig:
    """Same family/block structure at smoke-test scale."""
    n_heads = min(cfg.n_heads, 4) or 0
    n_kv = (max(1, n_heads // max(1, cfg.n_heads // max(cfg.n_kv_heads, 1)))
            if cfg.n_kv_heads else 0)
    d_head = 16
    reps = {
        "n_layers": min(cfg.n_layers, 4),
        "d_model": d_head * max(n_heads, 2),
        "n_heads": n_heads,
        "n_kv_heads": n_kv,
        "d_head": d_head,
        "d_ff": 128,
        "vocab_size": 256,
        "n_experts": min(cfg.n_experts, 8),
        "top_k": min(cfg.top_k, 2),
        "ssm_state": min(cfg.ssm_state, 16),
        "n_prepend": min(cfg.n_prepend, 8),
        "n_enc_frames": min(cfg.n_enc_frames, 16),
        "window_size": min(cfg.window_size, 32) if cfg.window_size else 0,
        "local_global": cfg.local_global,
        "shared_attn_every": min(cfg.shared_attn_every, 2)
        if cfg.shared_attn_every else 0,
        "remat": "none",
        "fsdp": False,
        "fsdp_pods": False,
        "microbatch_seq_tokens": 1 << 22,
        "use_pallas": False,
    }
    if cfg.shared_attn_every:   # zamba2: keep groups aligned
        reps["n_layers"] = reps["shared_attn_every"] * 2
    if cfg.local_global:
        reps["n_layers"] = cfg.local_global + 1
    return dataclasses.replace(cfg, **reps)
