"""The paper's own workload: the §4 experimental grid, as a config.

Group A: 4 volumes x 3 redundancy levels x 2 engines x 2 frameworks.
Group B: join experiments with 0/1/2 sources pre-deduplicated.
Row counts are scaled-down but keep the paper's ratios; benchmarks accept
a ``--scale`` multiplier to grow them toward the paper's 19.5M records.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class PaperConfig:
    # group A grid (fractions of the full dataset, per the paper)
    volumes: Sequence[float] = (0.25, 0.50, 0.75, 1.00)
    redundancies: Sequence[float] = (0.25, 0.50, 0.75)
    engines: Sequence[str] = ("rmlmapper", "sdm")
    base_rows: int = 20000          # rows at volume=1.0 (scaled testbed)
    n_noise_attrs: int = 8          # wide-source shape (paper: up to 39)
    timeout_seconds: float = 500.0  # the paper's timeout

    # group B
    group_b_rows: int = 8000
    group_b_redundancy: float = 0.75
    group_b_scenarios: Tuple[Tuple[bool, bool], ...] = (
        (False, False),   # (a) no dedup
        (True, False),    # (b) one source dedup'd
        (True, True),     # (c) both dedup'd
    )

    def rows_for_volume(self, v: float) -> int:
        return max(1, int(round(self.base_rows * v)))


CONFIG = PaperConfig()
