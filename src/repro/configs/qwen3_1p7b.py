"""Qwen3 1.7B — dense GQA + qk_norm. [hf:Qwen/Qwen3-8B family; hf]
28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=6144, vocab_size=151936, d_head=128,
    qk_norm=True, rope_theta=1e6, tied_embeddings=True,
    optimizer="adamw", fsdp=False, remat="full",
)
