from .optimizer import Optimizer, adamw, adafactor, make_optimizer
from .train_step import make_train_step, make_loss_fn

__all__ = ["Optimizer", "adamw", "adafactor", "make_optimizer",
           "make_train_step", "make_loss_fn"]
