"""Int8 error-feedback gradient compression for the cross-pod all-reduce.

At 2 pods the per-step cross-pod traffic of a dense sync is
``2 x params x 4B`` over the slow inter-pod links. This module quantizes
each gradient leaf to int8 (per-leaf max-abs scale) BEFORE the pod
all-reduce and keeps the quantization error in an error-feedback buffer
(added back the next step), which preserves convergence (Seide et al.;
Karimireddy et al.). Traffic drops 4x (fp32) / 2x (bf16 grads).

Implementation: the train step's gradients come out of pjit already
averaged over (data, model) *within* a pod; the compressed stage runs
under ``shard_map`` over the ``pod`` axis only (other axes stay auto), so
the only collective it owns is the pod-axis psum of int8 payloads
(accumulated in int32).
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from repro.compat import axis_size, shard_map
from jax.sharding import Mesh, PartitionSpec as P


def quantize_leaf(g: jax.Array, err: jax.Array
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """g + err -> (int8 payload, scale, new error)."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_err = gf - q.astype(jnp.float32) * scale
    return q, scale, new_err


def dequantize_leaf(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_buffers(params) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_allreduce(grads, err_buffers, *, axis: str = "pod"):
    """Per-pod body (inside shard_map over ``axis``): quantize+EF, psum the
    int16 payload over pods, dequantize with the mean scale."""
    n = axis_size(axis)

    def per_leaf(g, e):
        q, scale, new_e = quantize_leaf(g, e)
        # int16 payload: the sum of <=128 pods' int8 values cannot
        # overflow, and the wire carries 2 bytes/param instead of the 4
        # of an f32 all-reduce
        q_sum = lax.psum(q.astype(jnp.int16), axis)
        scale_mean = lax.pmean(scale, axis)
        return (q_sum.astype(jnp.float32) * scale_mean / n).astype(g.dtype), \
            new_e

    out = jax.tree_util.tree_map(per_leaf, grads, err_buffers)
    new_grads = jax.tree_util.tree_map(
        lambda pair: pair[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree_util.tree_map(
        lambda pair: pair[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, new_err


def hierarchical_compress_allreduce(grads, err_buffers, *,
                                    pod_axis: str = "pod",
                                    inner_axis: str = "data"):
    """Hierarchical compressed gradient sync (both axes manual):

        reduce-scatter over ``inner_axis`` (within-pod, fast ICI)
        -> int8+EF quantize the 1/|data|-sized shard
        -> int16 psum over ``pod_axis``  (the only cross-DCI transfer)
        -> dequantize -> all-gather over ``inner_axis``

    This matches XLA's own hierarchical all-reduce shape (RS -> cross-pod
    -> AG) but carries 2 B/param over the pod boundary instead of 4 — a
    naive full-copy quantized psum actually moves MORE cross-pod bytes
    than the hierarchy (measured; see EXPERIMENTS.md). The EF buffers live
    on the scattered shard: shape ceil(n / |data|) per leaf
    (:func:`init_scattered_error_buffers`)."""
    n_inner = axis_size(inner_axis)
    n_pods = axis_size(pod_axis)

    def per_leaf(g, e):
        flat = g.astype(jnp.float32).ravel()
        pad = (-flat.shape[0]) % n_inner
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
        shard = lax.psum_scatter(flat, inner_axis, scatter_dimension=0,
                                 tiled=True)            # [n_padded/|data|]
        q, scale, new_e = quantize_leaf(shard, e)
        q_sum = lax.psum(q.astype(jnp.int16), pod_axis)
        scale_mean = lax.pmean(scale, pod_axis)
        # /n_pods for the pod mean; /n_inner because the RS summed the
        # per-rank means over the (manual) data axis
        shard_out = (q_sum.astype(jnp.float32) * scale_mean
                     / (n_pods * n_inner))
        full = lax.all_gather(shard_out, inner_axis, axis=0, tiled=True)
        if pad:
            full = full[:-pad]
        return full.reshape(g.shape).astype(g.dtype), new_e

    out = jax.tree_util.tree_map(per_leaf, grads, err_buffers)
    new_grads = jax.tree_util.tree_map(
        lambda p: p[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree_util.tree_map(
        lambda p: p[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, new_err


def init_scattered_error_buffers(params, n_inner: int):
    """EF buffers matching the reduce-scattered shard of each leaf."""
    def per(p):
        n = 1
        for d in p.shape:
            n *= d
        return jnp.zeros(((n + n_inner - 1) // n_inner,), jnp.float32)
    return jax.tree_util.tree_map(per, params)


def make_pod_grad_compress(mesh: Mesh, param_specs_tree,
                           axis: str = "pod"):
    """Wrap :func:`compress_allreduce` in shard_map over the pod axis.

    ``param_specs_tree``: tree with the gradients' structure (values
    unused). Only the ``pod`` axis is manual inside the shard_map —
    gradients are replicated across pods (no fsdp_pods), so every in/out
    spec is P() w.r.t. ``pod``; the within-pod (data/model) shardings
    remain automatic and untouched."""
    body = functools.partial(compress_allreduce, axis=axis)
    specs = jax.tree_util.tree_map(lambda _: P(), param_specs_tree)

    def fn(grads, err):
        return shard_map(
            body, mesh=mesh,
            in_specs=(specs, specs), out_specs=(specs, specs),
            check_vma=False, axis_names=frozenset({axis}),
        )(grads, err)

    return fn
