"""Train step: loss, microbatched gradient accumulation, optimizer apply.

The step is family-agnostic: the loss closes over the arch config and the
family module's ``apply``. Gradient accumulation is a ``lax.scan`` over
microbatches (batch reshaped [n_mb, mb, S]) with an fp32 (or bf16, per
config) gradient accumulator — remat happens inside the model, so peak
activation memory is one microbatch deep.

Cross-pod gradient compression (int8 error feedback) hooks in between the
accumulation and the optimizer: see :mod:`repro.train.grad_compress` and
:func:`with_error_feedback`.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import get_model
from repro.models.layers import ShardCtx, softmax_xent
from .optimizer import Optimizer, make_optimizer

Batch = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def make_loss_fn(cfg, ctx: Optional[ShardCtx] = None) -> Callable:
    """(params, batch) -> scalar loss. Batch keys by family:
    dense/moe/rwkv/hybrid: tokens, labels [B,S] (+ loss_mask)
    vlm:   + patches [B,n_prepend,VIT_DIM]; labels cover text span only
    encdec: + frames [B,n_enc_frames,d_model]."""
    model = get_model(cfg.family)

    def loss_fn(params, batch):
        kwargs = {}
        if cfg.family == "vlm":
            kwargs["patches"] = batch["patches"]
        if cfg.family == "encdec":
            kwargs["frames"] = batch["frames"]
        logits = model.apply(cfg, params, batch["tokens"], ctx=ctx, **kwargs)
        labels = batch["labels"]
        if cfg.family == "vlm":  # logits cover patches + text; slice text
            logits = logits[:, cfg.n_prepend:]
        mask = batch.get("loss_mask")
        loss = softmax_xent(logits, labels, mask, cfg.vocab_size)
        if cfg.family == "moe":
            # lightweight router balance penalty on the embedding output
            loss = loss + 0.0  # per-layer aux loss folded in future work
        return loss

    return loss_fn


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def with_error_feedback(optimizer: Optimizer, n_inner: int,
                        pod_axis: str = "pod", inner_axis: str = "data"):
    """Wrap an optimizer + build the grad_compress hook for the
    hierarchical compressed gradient sync (RS over ``inner_axis`` ->
    int8+EF quantize -> int16 psum over ``pod_axis`` -> AG). The optimizer
    state becomes ``{"opt": ..., "ef": ...}`` with EF buffers on the
    reduce-scattered shard. The train step must run inside a shard_map
    where BOTH axes are manual (the pod-decoupled wrapper in
    :mod:`repro.launch.specs`). Requires pod-replicated, non-FSDP
    params."""
    from repro.train.grad_compress import (
        hierarchical_compress_allreduce, init_scattered_error_buffers)

    def init(params):
        return {"opt": optimizer.init(params),
                "ef": init_scattered_error_buffers(params, n_inner)}

    def update(grads, state, params, step):
        new_params, new_opt, gnorm = optimizer.update(
            grads, state["opt"], params, step)
        return new_params, dict(state, opt=new_opt), gnorm

    def hook(grads, opt_state):
        new_g, new_ef = hierarchical_compress_allreduce(
            grads, opt_state["ef"], pod_axis=pod_axis,
            inner_axis=inner_axis)
        return new_g, dict(opt_state, ef=new_ef)

    return Optimizer(init, update, optimizer.name + "+ef"), hook


def make_train_step(cfg, *, n_microbatches: int = 1,
                    optimizer: Optional[Optimizer] = None,
                    ctx: Optional[ShardCtx] = None,
                    accum_dtype=jnp.float32,
                    grad_compress: Optional[Callable] = None):
    """Returns ``train_step(params, opt_state, batch, step) ->
    (params, opt_state, metrics)`` (pure; jit/donate at the call site)."""
    optimizer = optimizer or make_optimizer(cfg.optimizer)
    loss_fn = make_loss_fn(cfg, ctx)
    grad_fn = jax.value_and_grad(loss_fn)

    def split_mb(x):
        return x.reshape((n_microbatches, x.shape[0] // n_microbatches)
                         + x.shape[1:])

    def train_step(params, opt_state, batch, step):
        if n_microbatches == 1:
            loss, grads = grad_fn(params, batch)
        else:
            mb_batch = jax.tree_util.tree_map(split_mb, batch)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)

            def accum(carry, mb):
                loss_acc, g_acc = carry
                loss, g = grad_fn(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(accum_dtype), g_acc, g)
                return (loss_acc + loss, g_acc), None

            (loss, grads), _ = lax.scan(
                accum, (jnp.zeros((), jnp.float32), zeros), mb_batch,
                # dry-run cost fidelity: XLA tallies while bodies once, so
                # the roofline build unrolls the accumulation loop too
                unroll=bool(getattr(cfg, "unroll_layers", False)))
            loss = loss / n_microbatches
            grads = jax.tree_util.tree_map(
                lambda g: (g / n_microbatches), grads)

        if grad_compress is not None:
            grads, opt_state = grad_compress(grads, opt_state)

        new_params, new_opt, gnorm = optimizer.update(
            grads, opt_state, params, step)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm}
        return new_params, new_opt, metrics

    return train_step
