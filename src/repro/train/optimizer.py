"""Optimizers as (init, update) pairs over parameter pytrees.

* ``adamw`` — fp32 first/second moments + fp32 master weights (the
  standard mixed-precision recipe; 16 bytes/param of state).
* ``adafactor`` — factored second moment for >=2D tensors (row+col
  accumulators), no momentum, no master copy: O(rows+cols) state. This is
  what lets the 123B/1T configs fit the per-chip HBM budget.

State lives in the same sharding as the parameters (tree-structure
preserved), so pjit shards it without extra annotation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """update(grads, state, params, step) -> (params, state, grad_norm)."""

    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], Tuple[Any, Any, jax.Array]]
    name: str = "opt"


def _tmap(f, *trees, **kw):
    return jax.tree_util.tree_map(f, *trees, **kw)


def _global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return _tmap(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                 grads), norm


def _wd_mask(path) -> bool:
    """No weight decay on norms / biases / 1-D params."""
    name = "/".join(str(k) for k in path)
    return not any(s in name for s in ("ln", "norm", "bias", "_b"))


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          clip_norm: float = 1.0) -> Optimizer:
    def init(params):
        return {
            "mu": _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "nu": _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "master": _tmap(lambda p: jnp.array(p, dtype=jnp.float32,
                                    copy=True), params),
        }

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        t = (step + 1).astype(jnp.float32)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t
        mu = _tmap(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                   state["mu"], grads)
        nu = _tmap(lambda v, g: b2 * v
                   + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                   state["nu"], grads)

        def stepf(path, w, m, v):
            upd = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay and _wd_mask(path):
                upd = upd + weight_decay * w
            return w - lr * upd

        master = jax.tree_util.tree_map_with_path(
            stepf, state["master"], mu, nu)
        new_params = _tmap(lambda w, p: w.astype(p.dtype), master, params)
        return new_params, {"mu": mu, "nu": nu, "master": master}, gnorm

    return Optimizer(init, update, "adamw")


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, no momentum, no master)
# ---------------------------------------------------------------------------

def adafactor(lr: float = 1e-3, decay: float = 0.8, eps: float = 1e-30,
              clip_norm: float = 1.0, weight_decay: float = 0.0
              ) -> Optimizer:
    def _factored(shape) -> bool:
        return len(shape) >= 2

    def init(params):
        def per(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"v": _tmap(per, params,
                           is_leaf=lambda x: hasattr(x, "shape"))}

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        t = (step + 1).astype(jnp.float32)
        beta = 1.0 - t ** (-decay)

        def per(path, w, g, v):
            gf = g.astype(jnp.float32)
            g2 = jnp.square(gf) + eps
            if _factored(g.shape):
                vr = beta * v["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * v["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(axis=-1)[..., None, None],
                                       eps))
                upd = gf * jax.lax.rsqrt(jnp.maximum(denom, eps))
                nv = {"vr": vr, "vc": vc}
            else:
                nv = {"v": beta * v["v"] + (1 - beta) * g2}
                upd = gf * jax.lax.rsqrt(jnp.maximum(nv["v"], eps))
            # relative-scale update clipping (Adafactor d=1)
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)))
            upd = upd / jnp.maximum(1.0, rms)
            wf = w.astype(jnp.float32)
            if weight_decay and _wd_mask(path):
                upd = upd + weight_decay * wf
            return (wf - lr * upd).astype(w.dtype), nv

        flat = jax.tree_util.tree_map_with_path(
            per, params, grads, state["v"],
            is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict))
        new_params = _tmap(lambda pair: pair[0], flat,
                           is_leaf=lambda x: isinstance(x, tuple))
        new_v = _tmap(lambda pair: pair[1], flat,
                      is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"v": new_v}, gnorm

    return Optimizer(init, update, "adafactor")


def make_optimizer(name: str, lr: float = 3e-4) -> Optimizer:
    if name == "adamw":
        return adamw(lr=lr)
    if name == "adafactor":
        return adafactor(lr=lr)
    raise KeyError(f"unknown optimizer {name!r}")
