"""Fused distributed execution: the whole plan inside one ``shard_map``.

``compile_mesh_plan`` is the mesh-aware sibling of
:func:`repro.plan.compile.compile_plan`: it lowers the optimized DAG to ONE
jitted closure whose body runs entirely inside a ``shard_map`` over row-
sharded sources — Scan reads this shard's row block, π/σ/∪ run on the
block, every interior δ is a *global* hash-repartition δ, every ⋈ moves its
inputs with one of two cost-modeled exchange strategies, ``EmitTriples``
semantifies the shard's rows, and the global sink δ runs fused on device
instead of as a gather-to-host post-pass. A distributed
``KGEngine.create_kg()``/``.ingest()`` therefore never materializes
intermediate triples on the host: the only host reads are the overflow
flags and the final (already-deduplicated) KG rows.

**Exact partition invariant.** Every relation node inside the body is an
exact *multiset* partition of its single-device value: Scans partition
rows, π/σ are row-wise, ∪ concatenates partitions, and an interior δ
repartitions by full-row hash (:func:`repro.core.distributed
.repartition_by_key`) so every copy of a row lands on one shard and the
local δ after the exchange is globally exact. Join exchanges preserve the
invariant on both sides, so per-shard ⋈ outputs and emit counts sum to the
single-device values — the mesh ``raw`` count (global per-map δ under
``sdm``, blind generation under ``rmlmapper``) is bit-identical to
:func:`compile_plan`'s, not just an upper bound.

**⋈ exchange strategies** (picked per join at plan time by the cost model
in :mod:`repro.plan.annotate`, threaded through ``exchanges``):

* ``gather`` — the parent side is ``all_gather``'ed across the axis
  (:func:`gather_table`) and each shard joins its child block against the
  full parent relation. One collective, shared across every ⋈ on the same
  parent node; wire bytes grow with the whole parent.
* ``repartition`` — both child and parent rows are hashed on the join key
  and exchanged with one ``all_to_all`` per side
  (:func:`repro.core.distributed.repartition_by_key`), so each shard joins
  only its key range. Wire bytes are ``(child + parent) / n_shards`` —
  the strategy that scales past the all_gather memory/bandwidth wall when
  the parent is large relative to ICI bandwidth.

Buffers are sized by SHARD-LOCAL capacities (``caps`` from
``annotate_local``, including the post-exchange Poisson bounds for
repartitioned δ/⋈ outputs); every capped node still reports a truncation
flag, every exchange reports its bucket-overflow flag, and the sink reports
its own, so ``KGEngine``'s recompile-on-overflow works per shard exactly as
on one device (``safe_exchange=True`` rebuilds with hard-safe bucket
capacities — ``cap_bucket = cap_local`` cannot overflow).
"""
from __future__ import annotations

from typing import Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.distributed import (repartition_by_key,
                                    repartition_distinct_local,
                                    sink_bucket_cap)
from repro.relalg import PAD_ID, Table
from repro.relalg.ops import _masked_data, compact, dedup_rows

from .compile import execute_node
from .ir import Node, Scan, iter_nodes
from .lower import LogicalPlan


def plan_scans(plan: LogicalPlan) -> Dict[str, Scan]:
    """The Scan node per source name reachable from the plan's emits —
    the sources the mesh closure must receive as sharded row blocks."""
    scans: Dict[str, Scan] = {}
    for emit in plan.emits():
        for node in iter_nodes(emit):
            if isinstance(node, Scan):
                scans[node.source] = node
    return scans


def gather_table(table: Table, axis: str, n_shards: int) -> Table:
    """All_gather a shard-local table into the full (replicated) relation.

    Concatenates every shard's valid rows and compacts. The slices are
    exact multiset partitions of the global relation (interior δ is a
    global repartition δ — see the module docstring), so the gathered
    table IS the single-device relation, duplicates included: no
    post-gather dedup, and ⋈ multiplicities (hence ``raw``) stay exact.
    Must run inside a ``shard_map`` body over ``axis``.
    """
    cap_local = table.capacity
    gdata = lax.all_gather(_masked_data(table), axis, axis=0, tiled=True)
    gcounts = lax.all_gather(table.count, axis)          # [n_shards]
    idx = jnp.arange(n_shards * cap_local, dtype=jnp.int32)
    valid = (idx % cap_local) < gcounts[idx // cap_local]
    data, count = compact(jnp.where(valid[:, None], gdata, jnp.int32(PAD_ID)),
                          valid)
    return Table(data=data, count=count, attrs=table.attrs)


def mesh_abstract_inputs(plan: LogicalPlan,
                         cap_locals: Mapping[str, int], n_shards: int,
                         mesh=None, axis: Optional[str] = None):
    """The abstract ``(datas, counts)`` input pytrees of a mesh closure —
    :class:`jax.ShapeDtypeStruct` leaves shaped exactly as
    :func:`repro.core.distributed.shard_table` lays the sources out.

    With ``mesh``/``axis`` given, every leaf additionally carries the
    ``NamedSharding`` the real shard blocks arrive with, so AOT lowering
    (``run.lower(*abstract).compile()``) bakes the same input layout the
    jitted path would infer — the persistent plan store serializes that
    executable with its shard layout (mesh shape/axis/device ids are part
    of the store key, so a different mesh can never rehydrate it)."""
    scans = plan_scans(plan)
    shard_d = shard_c = None
    if mesh is not None:
        from jax.sharding import NamedSharding
        shard_d = NamedSharding(mesh, P(axis, None))
        shard_c = NamedSharding(mesh, P(axis))
    datas = {name: jax.ShapeDtypeStruct(
                (n_shards * int(cap_locals[name]),
                 len(scans[name].scan_attrs)),
                jnp.int32, sharding=shard_d)
             for name in scans}
    counts = {name: jax.ShapeDtypeStruct((n_shards,), jnp.int32,
                                         sharding=shard_c)
              for name in scans}
    return datas, counts


def compile_mesh_plan(plan: LogicalPlan, emitter, mesh, axis: str,
                      engine: str = "rmlmapper", dedup: Optional[str] = None,
                      caps: Optional[Mapping[Node, int]] = None,
                      cap_locals: Optional[Mapping[str, int]] = None,
                      sink_slack: float = 1.0, pack_u16: bool = False,
                      jit: bool = True,
                      exchanges: Optional[Mapping[Node, object]] = None,
                      safe_exchange: bool = False):
    """Lower the DAG to one mesh-resident closure; returns
    ``(run, out_cap_local)``.

    ``run(datas, counts)`` takes the sharded sources —
    ``datas[name] [n_shards * cap_locals[name], k]`` placed ``P(axis,
    None)`` and ``counts[name] [n_shards]`` placed ``P(axis)`` (see
    :func:`repro.core.distributed.shard_table`) — and returns
    ``(kg_data, kg_counts, raw, overflowed, sink_overflowed)`` where
    ``kg_data [n_shards * out_cap_local, 5]`` / ``kg_counts [n_shards]``
    hold the globally-deduplicated KG still sharded over ``axis``, ``raw``
    is the total triple count before the sink δ (bit-identical to the
    single-device plan's — see the module docstring), ``overflowed`` is
    the any-shard capacity-truncation OR interior-exchange bucket-overflow
    flag (re-run a ``safe_exchange=True`` build) and ``sink_overflowed``
    the sink repartition bucket-overflow flag (re-run with more
    ``sink_slack``).

    ``caps`` are SHARD-LOCAL node capacities (``annotate_local``);
    ``exchanges`` maps ⋈ nodes to their strategy (a
    :class:`repro.plan.annotate.JoinExchange` or a plain
    ``"gather"``/``"repartition"`` string; unmapped joins gather);
    ``safe_exchange`` sizes every exchange bucket at the hard-safe
    ``cap_bucket = cap_local`` instead of the Poisson bound; ``pack_u16``
    asserts every dictionary code fits 16 bits so each all_to_all moves
    ceil(k/2) words per row.
    """
    n_shards = int(mesh.shape[axis])
    emit_nodes = plan.emits()
    scans = plan_scans(plan)
    cap_locals = {name: int(cap_locals[name]) for name in scans}
    strategies = {node: getattr(x, "strategy", x)
                  for node, x in (exchanges or {}).items()}

    def _bucket_cap(cap_local: int, slack: float = 1.0) -> int:
        if n_shards == 1 or safe_exchange:
            return cap_local    # a shard sends at most its own rows to one
            # target, so cap_bucket = cap_local can never overflow
        return min(cap_local, sink_bucket_cap(cap_local, n_shards, slack))

    def body(datas: Dict[str, jax.Array], counts: Dict[str, jax.Array]):
        sources = {name: Table(data=datas[name],
                               count=counts[name].reshape(()),
                               attrs=scan.scan_attrs)
                   for name, scan in scans.items()}
        gathered: Dict[Node, Table] = {}
        exchanged: Dict[Tuple[Node, str], Table] = {}
        flags = []
        sink_flags = []

        def exchange_table(side_node: Node, table: Table,
                           key_attr: str) -> Table:
            """Key-partition one ⋈ side (memoized per (node, key))."""
            hit = exchanged.get((side_node, key_attr))
            if hit is None:
                data, cnt, over = repartition_by_key(
                    _masked_data(table), table.count, axis=axis,
                    n_shards=n_shards,
                    cap_bucket=_bucket_cap(table.capacity),
                    key_cols=(table.attrs.index(key_attr),),
                    pack_u16=pack_u16)
                flags.append(over)
                hit = exchanged[(side_node, key_attr)] = Table(
                    data=data, count=cnt, attrs=table.attrs)
            return hit

        def join_exchange(node: Node, left: Table, right: Table):
            if strategies.get(node) == "repartition":
                return (exchange_table(node.left, left, node.left_key),
                        exchange_table(node.right, right, node.right_key))
            hit = gathered.get(node.right)
            if hit is None:
                hit = gathered[node.right] = gather_table(right, axis,
                                                          n_shards)
            return left, hit

        def global_distinct(table: Table, cap_bucket: int,
                            flag_list) -> Table:
            """Global δ: local δ -> rowhash repartition -> local δ.

            The pre-exchange δ minimizes wire traffic (Rule 1 applied to
            the ICI); the exchange co-locates every cross-shard copy, so
            the second local δ is globally exact and the output is an
            exact partition of the single-device relation. One shard needs
            no exchange; the bucket-overflow flag lands in ``flag_list``
            (``flags`` = safe-exchange rebuild, ``sink_flags`` =
            sink-slack rebuild)."""
            data, cnt = dedup_rows(_masked_data(table), table.count, dedup)
            if n_shards > 1:
                data, cnt, over = repartition_by_key(
                    data, cnt, axis=axis, n_shards=n_shards,
                    cap_bucket=cap_bucket, key_cols=None,
                    pack_u16=pack_u16)
                flag_list.append(over)
                data, cnt = dedup_rows(data, cnt, dedup)
            return Table(data=data, count=cnt, attrs=table.attrs)

        def distinct_global(node: Node, child: Table) -> Table:
            return global_distinct(child, _bucket_cap(child.capacity),
                                   flags)

        memo: Dict[Node, Table] = {}
        per_map = [execute_node(e, sources, memo, emitter, dedup, caps,
                                flags, join_exchange=join_exchange,
                                distinct_global=distinct_global)
                   for e in emit_nodes]
        if engine == "sdm":
            # global per-map δ — the single-device raw semantics. Every
            # map's surviving rows end up partitioned by the SAME full-row
            # hash, so the sink δ below collapses to one local δ (no
            # second exchange).
            per_map = [global_distinct(t, sink_bucket_cap(t.capacity,
                                                          n_shards,
                                                          sink_slack),
                                       sink_flags)
                       for t in per_map]
        raw = jnp.sum(jnp.stack([t.count for t in per_map]))

        data = jnp.concatenate([_masked_data(t) for t in per_map], axis=0)
        mask = jnp.concatenate([t.valid_mask for t in per_map])
        data, count = compact(data, mask)
        if engine == "sdm":
            # rows are rowhash-partitioned per map already: local δ = global
            kg_data, kg_count = dedup_rows(data, count, dedup)
            kg_count = kg_count.reshape(1)
            sink_over = (jnp.any(jnp.stack(sink_flags)) if sink_flags
                         else jnp.zeros((), dtype=bool)).reshape(1)
        else:
            # the fused sink δ: this shard's triples repartitioned by
            # rowhash so one local δ per shard is globally correct
            cap_bucket = sink_bucket_cap(data.shape[0], n_shards, sink_slack)
            kg_data, kg_count, sink_over = repartition_distinct_local(
                data, count, axis=axis, n_shards=n_shards,
                cap_bucket=cap_bucket, pack_u16=pack_u16, dedup=dedup)
        over = (jnp.any(jnp.stack(flags)) if flags
                else jnp.zeros((), dtype=bool))
        return (kg_data, kg_count, raw.reshape(1), over.reshape(1),
                sink_over)

    specs_data = {name: P(axis, None) for name in scans}
    specs_count = {name: P(axis) for name in scans}
    fn = shard_map(body, mesh=mesh, in_specs=(specs_data, specs_count),
                   out_specs=(P(axis, None), P(axis), P(axis), P(axis),
                              P(axis)))

    def run(datas: Dict[str, jax.Array], counts: Dict[str, jax.Array]):
        kg_data, kg_counts, raw, over, sink_over = fn(datas, counts)
        return (kg_data, kg_counts, jnp.sum(raw), jnp.any(over),
                jnp.any(sink_over))

    if jit:
        run = jax.jit(run)

    abstract = mesh_abstract_inputs(plan, cap_locals, n_shards)
    out_shape = jax.eval_shape(run, *abstract)[0]
    out_cap_local = out_shape.shape[0] // n_shards
    return run, out_cap_local
