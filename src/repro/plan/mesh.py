"""Fused distributed execution: the whole plan inside one ``shard_map``.

``compile_mesh_plan`` is the mesh-aware sibling of
:func:`repro.plan.compile.compile_plan`: it lowers the optimized DAG to ONE
jitted closure whose body runs entirely inside a ``shard_map`` over row-
sharded sources — Scan reads this shard's row block, π/σ/δ/∪ run on the
block, every ⋈ all_gathers (and deduplicates) the parent side so a sharded
child joins against the full parent relation, ``EmitTriples`` semantifies
the shard's rows, and the global sink δ is the fused
:func:`repro.core.distributed.repartition_distinct_local` collective
(local δ → rowhash partition → all_to_all → local δ) instead of a
gather-to-host post-pass. A distributed ``KGEngine.create_kg()``/
``.ingest()`` therefore never materializes intermediate triples on the
host: the only host reads are the overflow flags and the final
(already-deduplicated) KG rows.

Semantics versus the single-device plan:

* The KG row *set* is identical; the engine canonicalizes row order with
  one final δ over the gathered result, making the output bit-identical to
  :func:`compile_plan`'s (both paths end in the same δ kernel, whose output
  order depends only on the row set).
* Interior δ nodes (and the sdm per-map δ) deduplicate *per shard* —
  cross-shard duplicates survive until the global sink δ, so the mesh
  ``raw`` count is an upper bound on the single-device ``raw``.
* Gathered ⋈ parents are deduplicated after the all_gather (shard-local δ
  cannot see cross-shard copies). This keeps the exact-mode global join
  total a true per-shard output bound — the invariant
  :func:`repro.plan.annotate.annotate_local` relies on — and moves
  already-minimized rows over the network, Rule 1 applied to the ICI.

Buffers are sized by SHARD-LOCAL capacities (``caps`` from
``annotate_local``); every capped node still reports a truncation flag and
the sink reports its bucket-overflow flag, so ``KGEngine``'s
recompile-on-overflow works per shard exactly as on one device.
"""
from __future__ import annotations

from typing import Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.distributed import repartition_distinct_local, sink_bucket_cap
from repro.relalg import PAD_ID, Table, distinct
from repro.relalg.ops import _masked_data, compact, dedup_rows

from .compile import execute_node
from .ir import Node, Scan, iter_nodes
from .lower import LogicalPlan


def plan_scans(plan: LogicalPlan) -> Dict[str, Scan]:
    """The Scan node per source name reachable from the plan's emits —
    the sources the mesh closure must receive as sharded row blocks."""
    scans: Dict[str, Scan] = {}
    for emit in plan.emits():
        for node in iter_nodes(emit):
            if isinstance(node, Scan):
                scans[node.source] = node
    return scans


def gather_table(table: Table, axis: str, n_shards: int,
                 dedup: Optional[str] = None) -> Table:
    """All_gather a shard-local table into the full (replicated) relation.

    Concatenates every shard's valid rows, compacts, and deduplicates —
    shard-local δ cannot remove copies of a row living on two shards, and
    the join-capacity bound (see :func:`repro.plan.annotate.annotate_local`)
    needs the gathered parent side duplicate-free. Must run inside a
    ``shard_map`` body over ``axis``.
    """
    cap_local = table.capacity
    gdata = lax.all_gather(_masked_data(table), axis, axis=0, tiled=True)
    gcounts = lax.all_gather(table.count, axis)          # [n_shards]
    idx = jnp.arange(n_shards * cap_local, dtype=jnp.int32)
    valid = (idx % cap_local) < gcounts[idx // cap_local]
    data, count = compact(jnp.where(valid[:, None], gdata, jnp.int32(PAD_ID)),
                          valid)
    data, count = dedup_rows(data, count, dedup)
    return Table(data=data, count=count, attrs=table.attrs)


def compile_mesh_plan(plan: LogicalPlan, emitter, mesh, axis: str,
                      engine: str = "rmlmapper", dedup: Optional[str] = None,
                      caps: Optional[Mapping[Node, int]] = None,
                      cap_locals: Optional[Mapping[str, int]] = None,
                      sink_slack: float = 1.0, pack_u16: bool = False,
                      jit: bool = True):
    """Lower the DAG to one mesh-resident closure; returns
    ``(run, out_cap_local)``.

    ``run(datas, counts)`` takes the sharded sources —
    ``datas[name] [n_shards * cap_locals[name], k]`` placed ``P(axis,
    None)`` and ``counts[name] [n_shards]`` placed ``P(axis)`` (see
    :func:`repro.core.distributed.shard_table`) — and returns
    ``(kg_data, kg_counts, raw, overflowed, sink_overflowed)`` where
    ``kg_data [n_shards * out_cap_local, 5]`` / ``kg_counts [n_shards]``
    hold the globally-deduplicated KG still sharded over ``axis``, ``raw``
    is the total triple count before the sink δ (per-shard semantics — see
    the module docstring), ``overflowed`` is the any-shard any-node
    capacity-truncation flag and ``sink_overflowed`` the repartition
    bucket-overflow flag (re-run with more ``sink_slack``).

    ``caps`` are SHARD-LOCAL node capacities (``annotate_local``);
    ``pack_u16`` asserts every dictionary code fits 16 bits so the sink's
    all_to_all moves ceil(5/2) words per triple.
    """
    n_shards = int(mesh.shape[axis])
    emit_nodes = plan.emits()
    scans = plan_scans(plan)
    cap_locals = {name: int(cap_locals[name]) for name in scans}

    def body(datas: Dict[str, jax.Array], counts: Dict[str, jax.Array]):
        sources = {name: Table(data=datas[name],
                               count=counts[name].reshape(()),
                               attrs=scan.scan_attrs)
                   for name, scan in scans.items()}
        gathered: Dict[Node, Table] = {}

        def join_gather(right_node: Node, right: Table) -> Table:
            hit = gathered.get(right_node)
            if hit is None:
                hit = gathered[right_node] = gather_table(
                    right, axis, n_shards, dedup)
            return hit

        memo: Dict[Node, Table] = {}
        flags = []
        per_map = [execute_node(e, sources, memo, emitter, dedup, caps,
                                flags, join_gather=join_gather)
                   for e in emit_nodes]
        if engine == "sdm":
            per_map = [distinct(t, dedup=dedup) for t in per_map]
        raw = jnp.sum(jnp.stack([t.count for t in per_map]))

        data = jnp.concatenate([_masked_data(t) for t in per_map], axis=0)
        mask = jnp.concatenate([t.valid_mask for t in per_map])
        data, count = compact(data, mask)
        # the fused sink δ: this shard's triples repartitioned by rowhash so
        # one local δ per shard is globally correct — no host round-trip
        cap_bucket = sink_bucket_cap(data.shape[0], n_shards, sink_slack)
        kg_data, kg_count, sink_over = repartition_distinct_local(
            data, count, axis=axis, n_shards=n_shards, cap_bucket=cap_bucket,
            pack_u16=pack_u16, dedup=dedup)
        over = (jnp.any(jnp.stack(flags)) if flags
                else jnp.zeros((), dtype=bool))
        return (kg_data, kg_count, raw.reshape(1), over.reshape(1),
                sink_over)

    specs_data = {name: P(axis, None) for name in scans}
    specs_count = {name: P(axis) for name in scans}
    fn = shard_map(body, mesh=mesh, in_specs=(specs_data, specs_count),
                   out_specs=(P(axis, None), P(axis), P(axis), P(axis),
                              P(axis)))

    def run(datas: Dict[str, jax.Array], counts: Dict[str, jax.Array]):
        kg_data, kg_counts, raw, over, sink_over = fn(datas, counts)
        return (kg_data, kg_counts, jnp.sum(raw), jnp.any(over),
                jnp.any(sink_over))

    if jit:
        run = jax.jit(run)

    abstract = (
        {name: jax.ShapeDtypeStruct(
            (n_shards * cap_locals[name], len(scans[name].scan_attrs)),
            jnp.int32) for name in scans},
        {name: jax.ShapeDtypeStruct((n_shards,), jnp.int32)
         for name in scans},
    )
    out_shape = jax.eval_shape(run, *abstract)[0]
    out_cap_local = out_shape.shape[0] // n_shards
    return run, out_cap_local
