"""MapSDI logical-plan subsystem: IR, optimizing planner, compiler.

The paper defines pre-processing as relational-algebra rewrites; this
package makes that literal. ``lower`` turns a ``DIS`` into a logical plan
DAG, ``optimize`` runs Rules 1–3 plus selection pushdown and common-subplan
elimination as *symbolic* rewrites (zero device work), ``annotate`` sizes
every buffer at plan time, and ``compile_plan`` lowers the optimized DAG to
a single jitted ``sources -> (KG, raw)`` closure. See ``docs/planner.md``.
"""
from .ir import (Distinct, EmitTriples, EquiJoin, Node, Pred, Project, Scan,
                 Select, Union, fingerprint, intern, iter_nodes, make_select,
                 tree_size)
from .lower import LogicalPlan, lower, selection_preds
from .optimize import (PlanStats, cse, merge_maps, optimize,
                       push_projections, push_selections)
from .annotate import (JoinExchange, annotate, annotate_local,
                       join_exchange_cost, poisson_shard_bound)
from .compile import (compile_plan, execute_node, input_names,
                      materialize_plan)
from .mesh import compile_mesh_plan, plan_scans
from .explain import dump_plan, explain

__all__ = [
    "Distinct", "EmitTriples", "EquiJoin", "LogicalPlan", "Node",
    "JoinExchange", "PlanStats", "Pred", "Project", "Scan", "Select",
    "Union", "annotate",
    "annotate_local", "compile_mesh_plan", "compile_plan", "cse",
    "dump_plan", "execute_node", "explain",
    "fingerprint", "input_names", "intern", "iter_nodes",
    "join_exchange_cost", "lower",
    "make_select", "poisson_shard_bound",
    "materialize_plan", "merge_maps", "optimize", "plan_scans",
    "push_projections", "push_selections", "selection_preds", "tree_size",
]
