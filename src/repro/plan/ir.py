"""Logical relational-algebra IR for the MapSDI planner.

Nodes are immutable, hashable, and compared *structurally*: two plan
fragments that compute the same relation the same way are equal (and, after
:func:`intern`, identical objects). That single property carries most of the
optimizer:

* common-subplan elimination is hash-consing (:func:`intern`);
* the Rule 1–3 fixpoint terminates when a rewrite pass maps every node to an
  equal node;
* the executor memoizes on the node itself, so shared subtrees — including
  a join parent's relation reused by several child maps — are evaluated
  exactly once per run.

The node set mirrors the operators the paper's §3 algebra uses: ``Scan``
(a source extension), ``Project`` (π with rename), ``Select`` (σ),
``Distinct`` (δ), ``Union`` (∪, bag), ``EquiJoin`` (⋈ on one attr pair) and
``EmitTriples`` (semantification of one triple map — the only non-classical
operator, producing the 5-column triple relation).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Iterator, Optional, Sequence, Tuple

from repro.core.schema import TRIPLE_ATTRS, TripleMap


@dataclasses.dataclass(frozen=True)
class Pred:
    """One σ conjunct: ``attr <op> code`` over dictionary codes."""

    attr: str
    op: str                    # 'notnull' | 'eq' | 'neq'
    code: Optional[int] = None  # vocab code for eq/neq; null code for notnull

    def __post_init__(self):
        if self.op not in ("notnull", "eq", "neq"):
            raise ValueError(f"bad Pred op {self.op!r}")

    def describe(self) -> str:
        if self.op == "notnull":
            return f"{self.attr}≠∅"
        sym = "=" if self.op == "eq" else "≠"
        return f"{self.attr}{sym}#{self.code}"


class Node:
    """Base class for IR nodes. Subclasses are frozen dataclasses."""

    @property
    def attrs(self) -> Tuple[str, ...]:  # pragma: no cover - abstract
        raise NotImplementedError

    def children(self) -> Tuple["Node", ...]:
        return ()


@dataclasses.dataclass(frozen=True)
class Scan(Node):
    """A named source extension (leaf)."""

    source: str
    scan_attrs: Tuple[str, ...]

    @property
    def attrs(self) -> Tuple[str, ...]:
        return self.scan_attrs


@dataclasses.dataclass(frozen=True)
class Select(Node):
    """σ — keep rows satisfying every predicate (conjunction)."""

    child: Node
    preds: Tuple[Pred, ...]    # canonical: sorted, duplicate-free

    @property
    def attrs(self) -> Tuple[str, ...]:
        return self.child.attrs

    def children(self) -> Tuple[Node, ...]:
        return (self.child,)


@dataclasses.dataclass(frozen=True)
class Project(Node):
    """π with rename: ``spec`` is ``((src_attr, out_attr), ...)``."""

    child: Node
    spec: Tuple[Tuple[str, str], ...]

    @property
    def attrs(self) -> Tuple[str, ...]:
        return tuple(dst for _, dst in self.spec)

    def children(self) -> Tuple[Node, ...]:
        return (self.child,)


@dataclasses.dataclass(frozen=True)
class Distinct(Node):
    """δ — duplicate elimination (set semantics)."""

    child: Node

    @property
    def attrs(self) -> Tuple[str, ...]:
        return self.child.attrs

    def children(self) -> Tuple[Node, ...]:
        return (self.child,)


@dataclasses.dataclass(frozen=True)
class ColEq(Node):
    """σ= — keep rows whose ``left_attr`` column equals ``right_attr``.

    The column-vs-column counterpart of :class:`Select`'s column-vs-constant
    predicates. The query compiler (:mod:`repro.query`) needs it because a
    coded RDF term is a (template, value) column *pair* while
    :class:`EquiJoin` equates a single column pair: a BGP join on a shared
    variable joins on the value columns and then checks the template
    columns (and any further shared variables) with ``ColEq``. Attrs are
    kept in sorted order so structurally-equal filters hash-cons.
    """

    child: Node
    left_attr: str
    right_attr: str

    def __post_init__(self):
        if self.left_attr == self.right_attr:
            raise ValueError(f"ColEq on a single column {self.left_attr!r}")

    @property
    def attrs(self) -> Tuple[str, ...]:
        return self.child.attrs

    def children(self) -> Tuple[Node, ...]:
        return (self.child,)


def make_coleq(child: Node, left_attr: str, right_attr: str) -> Node:
    """Canonicalizing ``ColEq`` constructor: orders the attr pair so the
    commutative filter has one structural form."""
    if left_attr > right_attr:
        left_attr, right_attr = right_attr, left_attr
    return ColEq(child, left_attr, right_attr)


@dataclasses.dataclass(frozen=True)
class Union(Node):
    """∪ — n-ary bag union; children share an attr *set* (aligned by name
    to the first child's order at execution)."""

    inputs: Tuple[Node, ...]

    @property
    def attrs(self) -> Tuple[str, ...]:
        return self.inputs[0].attrs

    def children(self) -> Tuple[Node, ...]:
        return self.inputs


@dataclasses.dataclass(frozen=True)
class EquiJoin(Node):
    """⋈ — single-pair equi-join; output attrs follow
    :func:`repro.relalg.ops.equi_join` (left attrs, then right attrs with
    colliding names prefixed by ``right_suffix``)."""

    left: Node
    right: Node
    left_key: str
    right_key: str
    right_suffix: str = "r_"

    @property
    def attrs(self) -> Tuple[str, ...]:
        left_names = set(self.left.attrs)
        right = tuple((self.right_suffix + a) if a in left_names else a
                      for a in self.right.attrs)
        return self.left.attrs + right

    def children(self) -> Tuple[Node, ...]:
        return (self.left, self.right)


@dataclasses.dataclass(frozen=True)
class EmitTriples(Node):
    """Semantification of one triple map over its (pre-processed) relation.

    ``joins`` holds, per join-carrying POM index, the :class:`EquiJoin`
    feeding that POM; non-join POMs read ``input`` directly.
    """

    tm: TripleMap
    input: Node
    joins: Tuple[Tuple[int, EquiJoin], ...] = ()

    @property
    def attrs(self) -> Tuple[str, ...]:
        return TRIPLE_ATTRS

    def children(self) -> Tuple[Node, ...]:
        return (self.input,) + tuple(j for _, j in self.joins)


# ---------------------------------------------------------------------------
# traversal + hash-consing
# ---------------------------------------------------------------------------

def iter_nodes(root: Node) -> Iterator[Node]:
    """Post-order over *unique* nodes of a DAG."""
    seen: Dict[Node, bool] = {}

    def walk(n: Node):
        if n in seen:
            return
        seen[n] = True
        for c in n.children():
            yield from walk(c)
        yield n

    yield from walk(root)


def tree_size(root: Node) -> int:
    """Number of node *instances* counting repeats (no sharing)."""
    total = 1
    for c in root.children():
        total += tree_size(c)
    return total


def intern(node: Node, memo: Optional[Dict[Node, Node]] = None) -> Node:
    """Hash-cons: return a structurally-equal DAG where equal subtrees are
    the *same object*. ``memo`` shares the intern table across roots, which
    is what dedups common subplans across different triple maps."""
    memo = {} if memo is None else memo

    def go(n: Node) -> Node:
        hit = memo.get(n)
        if hit is not None:
            return hit
        if isinstance(n, Select):
            out: Node = Select(go(n.child), n.preds)
        elif isinstance(n, ColEq):
            out = ColEq(go(n.child), n.left_attr, n.right_attr)
        elif isinstance(n, Project):
            out = Project(go(n.child), n.spec)
        elif isinstance(n, Distinct):
            out = Distinct(go(n.child))
        elif isinstance(n, Union):
            out = Union(tuple(go(c) for c in n.inputs))
        elif isinstance(n, EquiJoin):
            out = EquiJoin(go(n.left), go(n.right), n.left_key, n.right_key,
                           n.right_suffix)
        elif isinstance(n, EmitTriples):
            out = EmitTriples(n.tm, go(n.input),
                              tuple((i, go(j)) for i, j in n.joins))
        else:
            out = n
        return memo.setdefault(out, out)

    return go(node)


def fingerprint(roots: Sequence[Node]) -> str:
    """Deterministic structural digest (sha1 hex) of a plan DAG.

    Two plans fingerprint equal iff they would compile to the same program
    over the same dictionary codes: node structure, σ predicate *codes*,
    π/⋈ attribute wiring, and — for :class:`EmitTriples` — the full triple
    map (templates, constants, selections as their source strings). Shared
    subtrees are serialized once, so the digest is DAG-shaped, stable
    across processes (no ``id()``/``hash()`` salting), and what the
    ``KGEngine`` plan cache keys on.
    """
    memo: Dict[Node, int] = {}
    lines: list = []

    def visit(n: Node) -> int:
        hit = memo.get(n)
        if hit is not None:
            return hit
        if isinstance(n, Scan):
            desc = f"scan {n.source} {n.scan_attrs}"
        elif isinstance(n, Select):
            preds = tuple((p.attr, p.op, p.code) for p in n.preds)
            desc = f"select {visit(n.child)} {preds}"
        elif isinstance(n, ColEq):
            desc = (f"coleq {visit(n.child)} "
                    f"{n.left_attr} {n.right_attr}")
        elif isinstance(n, Project):
            desc = f"project {visit(n.child)} {n.spec}"
        elif isinstance(n, Distinct):
            desc = f"distinct {visit(n.child)}"
        elif isinstance(n, Union):
            desc = f"union {tuple(visit(c) for c in n.inputs)}"
        elif isinstance(n, EquiJoin):
            desc = (f"join {visit(n.left)} {visit(n.right)} "
                    f"{n.left_key} {n.right_key} {n.right_suffix}")
        elif isinstance(n, EmitTriples):
            joins = tuple((i, visit(j)) for i, j in n.joins)
            desc = f"emit {visit(n.input)} {joins} {n.tm!r}"
        else:  # pragma: no cover - future node kinds must opt in explicitly
            raise TypeError(f"cannot fingerprint {type(n).__name__}")
        out = memo[n] = len(lines)
        lines.append(desc)
        return out

    for r in roots:
        visit(r)
    return hashlib.sha1("\n".join(lines).encode()).hexdigest()


def node_order(roots: Sequence[Node]) -> list:
    """Deterministic enumeration of a plan DAG's unique nodes.

    The visit order is exactly :func:`fingerprint`'s (post-order over
    ``children()``, shared subtrees once), so two processes whose plans
    fingerprint equal assign every node the same index — which is what
    lets the persistent plan store (:mod:`repro.api.store`) serialize
    node-keyed metadata (capacities, counts, ⋈ exchange decisions) as
    plain index lists and rehydrate them against a freshly lowered plan
    in another process.
    """
    seen: Dict[Node, bool] = {}
    out: list = []

    def visit(n: Node) -> None:
        if n in seen:
            return
        seen[n] = True
        for c in n.children():
            visit(c)
        out.append(n)

    for r in roots:
        visit(r)
    return out


def make_select(child: Node, preds: Tuple[Pred, ...]) -> Node:
    """σ constructor that canonicalizes (sort, dedup) and flattens a direct
    Select child; returns ``child`` unchanged for an empty predicate set."""
    if isinstance(child, Select):
        preds = preds + child.preds
        child = child.child
    uniq = tuple(sorted(set(preds), key=lambda p: (p.attr, p.op, p.code
                                                   if p.code is not None
                                                   else -1)))
    if not uniq:
        return child
    return Select(child, uniq)
