"""Lowering: a ``DIS`` becomes one logical-plan DAG.

``lower(dis)`` produces a :class:`LogicalPlan` whose per-map relation inputs
start as bare :class:`~repro.plan.ir.Scan` nodes; the optimizer then rewrites
those inputs symbolically (Rules 1–3 + σ pushdown + CSE) without touching a
single device array. ``plan.emits()`` / ``plan.sink(engine)`` extend the DAG
over semantification — join POMs become :class:`EquiJoin` nodes over the
*current* inputs, every map an :class:`EmitTriples`, and the whole KG is
``δ(∪ emits)`` — so one DAG covers pre-processing *and* semantification.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.core.schema import DIS, RefObjectMap, TripleMap, map_by_name

from .ir import (Distinct, EmitTriples, EquiJoin, Node, Pred, Project, Scan,
                 Select, Union, iter_nodes, make_select)


def selection_preds(dis: DIS, tm: TripleMap) -> Tuple[Pred, ...]:
    """The map's explicit σ selections as IR predicates (codes interned)."""
    preds: List[Pred] = []
    for sel in tm.selections:
        if sel.op == "notnull":
            if dis.null_code is None:
                continue
            preds.append(Pred(sel.attr, "notnull", dis.null_code))
        else:
            preds.append(Pred(sel.attr, sel.op, dis.vocab.intern(sel.value)))
    return tuple(preds)


@dataclasses.dataclass
class LogicalPlan:
    """Symbolic state of the planner: rewritten maps + per-map relations.

    ``inputs[name]`` is the relation the map named ``name`` semantifies;
    ``names`` remembers materialization names chosen during rewrites (e.g.
    Rule-3 merged sources). ``preprocessed`` carries the provenance flags of
    the source DIS so re-planning an already-minimized DIS is a no-op.
    """

    dis: DIS
    maps: List[TripleMap]
    inputs: Dict[str, Node]
    names: Dict[Node, str] = dataclasses.field(default_factory=dict)
    preprocessed: frozenset = frozenset()
    # sources whose extension already satisfies the owning maps' σ
    # selections (planner-materialized DIS' — σ was pushed below the
    # materialization; eager-materialized DIS' never bakes σ)
    sigma_baked: frozenset = frozenset()

    def map_by_name(self, name: str) -> TripleMap:
        return map_by_name(self.maps, name)

    # -- DAG construction over semantification ------------------------------
    def join_node(self, tm: TripleMap, pom_idx: int) -> EquiJoin:
        """⋈ feeding the join POM ``tm.poms[pom_idx]``: child relation
        against the parent relation projected to (subject, join key) under
        the reserved ``__ps``/``__pk`` names. Parent σ selections are
        applied here — unless the optimizer already sank them into the
        parent's relation (re-selecting an already-filtered table would
        cost a full compact per join per run)."""
        rom = tm.poms[pom_idx].object
        assert isinstance(rom, RefObjectMap)
        parent_tm = self.map_by_name(rom.parent_map)
        parent_in = self.inputs[parent_tm.name]
        if isinstance(parent_in, Scan) and \
                parent_in.source in self.sigma_baked:
            preds: Tuple[Pred, ...] = ()  # σ-baked provenance: the
            # materialized extension is already filtered, skip the
            # (idempotent) re-select and its full compact per join per run
        else:
            have = {p for n in iter_nodes(parent_in)
                    if isinstance(n, Select) for p in n.preds}
            preds = tuple(p for p in selection_preds(self.dis, parent_tm)
                          if p not in have)
        parent_in = make_select(parent_in, preds)
        spec = (((parent_tm.subject.attr, "__ps"),)
                if parent_tm.subject.attr else ()) + \
            ((rom.parent_attr, "__pk"),)
        right = Project(parent_in, spec)
        return EquiJoin(self.inputs[tm.name], right, rom.child_attr, "__pk")

    def emit_node(self, tm: TripleMap) -> EmitTriples:
        joins = tuple((i, self.join_node(tm, i))
                      for i, pom in enumerate(tm.poms)
                      if isinstance(pom.object, RefObjectMap))
        return EmitTriples(tm, self.inputs[tm.name], joins)

    def emits(self) -> List[EmitTriples]:
        return [self.emit_node(tm) for tm in self.maps]

    def sink(self, engine: str = "rmlmapper") -> Node:
        """The full-pipeline DAG: δ over the union of every map's triples
        (per-map δ first under the duplicate-aware ``"sdm"`` engine). A
        single-map sdm plan needs no sink δ on top of its per-map δ
        (δδ = δ). Must mirror the execution semantics in
        :func:`repro.plan.compile.compile_plan`."""
        outs: List[Node] = list(self.emits())
        if engine == "sdm":
            outs = [Distinct(e) for e in outs]
        merged = outs[0] if len(outs) == 1 else Union(tuple(outs))
        return merged if isinstance(merged, Distinct) else Distinct(merged)


def lower(dis: DIS) -> LogicalPlan:
    """``DIS -> LogicalPlan`` with identity (Scan) relation inputs."""
    inputs: Dict[str, Node] = {}
    for tm in dis.maps:
        src = dis.sources[tm.source]
        inputs[tm.name] = Scan(tm.source, tuple(src.attrs))
    return LogicalPlan(dis=dis, maps=list(dis.maps), inputs=inputs,
                       preprocessed=frozenset(dis.preprocessed),
                       sigma_baked=frozenset(dis.sigma_baked))
