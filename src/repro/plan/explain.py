"""``dump_plan`` / ``explain`` — human-readable plan trees.

Renders the full DAG (sink δ → ∪ → per-map emits → joins → relation
chains) as an indented text tree with per-node capacity/row annotations
from the annotation pass. Shared subtrees (CSE hits, join parents) print
once and show up as ``(shared #k)`` references afterwards, making the
common-subplan elimination visible. On a mesh, every ⋈ additionally shows
its cost-modeled exchange decision (gather vs repartition) and the
estimated per-device wire bytes of both strategies.
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional

from .annotate import JoinExchange, annotate, annotate_local
from .ir import (ColEq, Distinct, EmitTriples, EquiJoin, Node, Project,
                 Scan, Select, Union)
from .lower import LogicalPlan


def _label(node: Node) -> str:
    if isinstance(node, Scan):
        return f"scan {node.source}({', '.join(node.attrs)})"
    if isinstance(node, Project):
        cols = ", ".join(s if s == d else f"{s}→{d}" for s, d in node.spec)
        return f"π [{cols}]"
    if isinstance(node, Select):
        return "σ [" + " ∧ ".join(p.describe() for p in node.preds) + "]"
    if isinstance(node, ColEq):
        return f"σ= [{node.left_attr} = {node.right_attr}]"
    if isinstance(node, Distinct):
        return "δ"
    if isinstance(node, Union):
        return f"∪ ({len(node.inputs)} inputs)"
    if isinstance(node, EquiJoin):
        return f"⋈ {node.left_key}={node.right_key}"
    if isinstance(node, EmitTriples):
        n_joins = len(node.joins)
        extra = f", {n_joins} join{'s' if n_joins != 1 else ''}" \
            if n_joins else ""
        return f"emit[{node.tm.name}] ({len(node.tm.poms)} poms{extra})"
    return type(node).__name__


def _fmt_bytes(n: int) -> str:
    n = float(n)
    for unit in ("B", "KiB", "MiB", "GiB"):
        if n < 1024 or unit == "GiB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{int(n)}B"
        n /= 1024
    return f"{n:.1f}GiB"  # pragma: no cover - unreachable


def dump_plan(plan: LogicalPlan, engine: str = "rmlmapper",
              counts: Optional[Mapping[Node, int]] = None,
              caps: Optional[Mapping[Node, int]] = None,
              exchanges: Optional[Mapping[Node, JoinExchange]] = None,
              schemas: Optional[Mapping[Node, object]] = None,
              verdict: Optional[str] = None) -> str:
    """Text tree of the whole plan DAG with per-node annotations.

    ``exchanges`` (a mesh plan's per-⋈ decisions from ``annotate_local``)
    adds ``exchange=<strategy>`` plus the estimated per-device wire bytes
    of both strategies to every ⋈ line. ``schemas`` (the static
    verifier's per-node inference, ``repro.analysis.verify_plan(...)
    .schemas``) adds a ``cols=`` bit per node; ``verdict`` (e.g.
    ``report.describe()``) is printed as a header above the tree."""
    return dump_root(plan.sink(engine), counts=counts, caps=caps,
                     exchanges=exchanges, schemas=schemas, verdict=verdict)


def dump_root(root: Node,
              counts: Optional[Mapping[Node, int]] = None,
              caps: Optional[Mapping[Node, int]] = None,
              exchanges: Optional[Mapping[Node, JoinExchange]] = None,
              schemas: Optional[Mapping[Node, object]] = None,
              verdict: Optional[str] = None) -> str:
    """Root-generic body of :func:`dump_plan` — renders any IR DAG from
    its root node. Query plans (whose root is the answer δ rather than an
    engine sink) use this directly via ``KGEngine.explain_query``."""
    counts = counts or {}
    caps = caps or {}
    exchanges = exchanges or {}
    schemas = schemas or {}
    shared_ids: Dict[int, int] = {}
    seen_multi = _multi_referenced(root)
    lines: List[str] = []
    if verdict:
        lines.extend(verdict.splitlines())

    def annot(node: Node) -> str:
        bits = []
        schema = schemas.get(node)
        if schema is not None and not isinstance(node, Scan):
            bits.append(f"cols={schema.describe()}")
        if node in counts:
            bits.append(f"rows={counts[node]}")
        if node in caps:
            bits.append(f"cap={caps[node]}")
        exch = exchanges.get(node)
        if exch is not None:
            fanout = getattr(exch, "parent_fanout", 1)
            bits.append(f"exchange={exch.strategy}")
            # gather_bytes is the amortized per-⋈ share of the one shared
            # all_gather when several ⋈ reuse this parent's replica
            bits.append(f"gather≈{_fmt_bytes(exch.gather_bytes)}"
                        + (f" (÷{fanout} shared parent)" if fanout > 1
                           else ""))
            bits.append(f"all_to_all≈{_fmt_bytes(exch.repartition_bytes)}")
            bits.append(f"cost={getattr(exch, 'cost_source', 'static')}")
        return ("  [" + ", ".join(bits) + "]") if bits else ""

    def render(node: Node, prefix: str, is_last: bool, is_root: bool):
        branch = "" if is_root else ("└─ " if is_last else "├─ ")
        if id(node) in shared_ids:
            lines.append(f"{prefix}{branch}{_label(node)} "
                         f"(shared #{shared_ids[id(node)]})")
            return
        ref = ""
        if id(node) in seen_multi:
            shared_ids[id(node)] = len(shared_ids) + 1
            ref = f"  (#{shared_ids[id(node)]})"
        lines.append(f"{prefix}{branch}{_label(node)}{annot(node)}{ref}")
        kids = node.children()
        child_prefix = prefix if is_root else \
            prefix + ("   " if is_last else "│  ")
        for i, child in enumerate(kids):
            render(child, child_prefix, i == len(kids) - 1, False)

    render(root, "", True, True)
    return "\n".join(lines)


def _multi_referenced(root: Node) -> Dict[int, int]:
    # count references (not visits): a node with >1 incoming edge is shared
    refs: Dict[int, int] = {}
    stack: List[Node] = [root]
    visited = set()
    while stack:
        n = stack.pop()
        if id(n) in visited:
            continue
        visited.add(id(n))
        for c in n.children():
            refs[id(c)] = refs.get(id(c), 0) + 1
            stack.append(c)
    return {i: k for i, k in refs.items() if k > 1}


def explain(plan: LogicalPlan, engine: str = "rmlmapper",
            with_annotations: bool = True, n_shards: Optional[int] = None,
            join_exchange: str = "auto", calibration=None) -> str:
    """Convenience: annotate (host-side, exact) and dump the plan.

    With ``n_shards`` the annotation runs shard-locally
    (:func:`annotate_local`, per-shard source blocks derived from the
    plan's source capacities) and every ⋈ line shows the cost model's
    exchange decision under ``join_exchange`` plus the estimated wire
    bytes per strategy — what a mesh ``KGEngine`` session would compile.
    Each ⋈ line's ``cost=`` bit says whether those numbers came from the
    static datasheet constants or a measured
    :class:`repro.launch.mesh.Calibration` (pass one via ``calibration``).
    """
    if not with_annotations:
        return dump_plan(plan, engine)
    if n_shards is None:
        counts, caps = annotate(plan)
        return dump_plan(plan, engine, counts, caps)
    from repro.relalg.table import bucket_cap
    from .mesh import plan_scans
    cap_locals = {name: bucket_cap(-(-plan.dis.sources[name].capacity
                                     // n_shards))
                  for name in plan_scans(plan)}
    counts, caps, exchanges = annotate_local(
        plan, n_shards=n_shards, cap_locals=cap_locals,
        join_exchange=join_exchange, calibration=calibration)
    return dump_plan(plan, engine, counts, caps, exchanges)
