"""Compiling the optimized logical plan to device execution.

Two consumers:

* :func:`compile_plan` — the full pipeline: one jitted
  ``sources -> (KG, raw)`` closure executing pre-processing *and*
  semantification as a single XLA program. Shared subplans (CSE'd nodes,
  join parents) are evaluated once per call; nothing touches the host.
* :func:`materialize_plan` — the ``apply_mapsdi`` path: evaluate just the
  per-map relation inputs (one jitted call, shared subtrees computed once)
  and shrink the results into a concrete ``DIS'`` — the *only* host sync of
  the whole transformation, at the very end.

Execution is memoized on the structurally-hashable node itself, so equal
subtrees collapse even if a rewrite produced them as separate objects.
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Tuple

import dataclasses
import jax
import jax.numpy as jnp

from repro.core.schema import DIS
from repro.relalg import (PAD_ID, Table, distinct, equi_join, project,
                          project_as, round_cap, select_mask, shrink_to_fit)
from repro.relalg.guard import host_int
from repro.relalg.ops import _masked_data, compact

from .ir import (ColEq, Distinct, EmitTriples, EquiJoin, Node, Project,
                 Scan, Select, Union, iter_nodes)
from .lower import LogicalPlan, selection_preds


def _fit(table: Table, cap: Optional[int]) -> Table:
    """Re-buffer a compacted table at a plan-time capacity (device only)."""
    if cap is None or cap == table.capacity:
        return table
    if cap < table.capacity:
        return Table(data=table.data[:cap],
                     count=jnp.minimum(table.count, jnp.int32(cap)),
                     attrs=table.attrs)
    pad = jnp.full((cap - table.capacity, table.n_attrs), jnp.int32(PAD_ID))
    return Table(data=jnp.concatenate([table.data, pad], axis=0),
                 count=table.count, attrs=table.attrs)


def _pred_mask(table: Table, preds) -> jax.Array:
    mask = jnp.ones((table.capacity,), dtype=bool)
    for p in preds:
        col = table.column(p.attr)
        if p.op == "eq":
            mask &= col == jnp.int32(p.code)
        else:  # 'neq' / 'notnull' both exclude one code
            mask &= col != jnp.int32(p.code)
    return mask


def execute_node(node: Node, sources: Mapping[str, Table],
                 memo: Dict[Node, Table], emitter=None,
                 dedup: Optional[str] = None,
                 caps: Optional[Mapping[Node, int]] = None,
                 overflow: Optional[List[jax.Array]] = None, *,
                 join_exchange=None, distinct_global=None) -> Table:
    """Evaluate one DAG node (and, via ``memo``, each shared subtree once).

    When ``overflow`` is a list, every capped operator appends a scalar
    bool flag — "this node needed more rows than its plan-time capacity and
    was truncated" — exactly once per unique node. ``KGEngine`` reduces the
    flags to its recompile-on-overflow signal.

    ``join_exchange`` and ``distinct_global`` are the mesh hooks
    (:mod:`repro.plan.mesh`); single-device execution leaves them ``None``:

    * ``join_exchange(node, left, right) -> (left, right)`` runs before
      every ⋈ — the fused distributed plan either all_gathers the
      (shard-local) parent rows so a row-sharded child joins against the
      full parent relation, or hash-repartitions *both* sides by join key
      so each shard joins only its key range.
    * ``distinct_global(node, child) -> table`` replaces the local δ of a
      ``Distinct`` node — the mesh makes it a global hash-repartition δ,
      so every interior relation stays an exact multiset partition of its
      single-device value (what keeps the mesh ``raw`` count exact). The
      returned table is still fitted to the node's plan-time capacity and
      flagged on truncation here.
    """
    hit = memo.get(node)
    if hit is not None:
        return hit
    caps = caps or {}
    kw = dict(join_exchange=join_exchange, distinct_global=distinct_global)
    if isinstance(node, Scan):
        out = sources[node.source]
    elif isinstance(node, Project):
        child = execute_node(node.child, sources, memo, emitter, dedup, caps,
                             overflow, **kw)
        out = project_as(child, list(node.spec))
    elif isinstance(node, Select):
        child = execute_node(node.child, sources, memo, emitter, dedup, caps,
                             overflow, **kw)
        sel = select_mask(child, _pred_mask(child, node.preds))
        cap = caps.get(node)
        if overflow is not None and cap is not None:
            overflow.append(sel.count > jnp.int32(cap))
        out = _fit(sel, cap)
    elif isinstance(node, ColEq):
        child = execute_node(node.child, sources, memo, emitter, dedup, caps,
                             overflow, **kw)
        mask = child.column(node.left_attr) == child.column(node.right_attr)
        sel = select_mask(child, mask)
        cap = caps.get(node)
        if overflow is not None and cap is not None:
            overflow.append(sel.count > jnp.int32(cap))
        out = _fit(sel, cap)
    elif isinstance(node, Distinct):
        child = execute_node(node.child, sources, memo, emitter, dedup, caps,
                             overflow, **kw)
        dd = (distinct(child, dedup=dedup) if distinct_global is None
              else distinct_global(node, child))
        cap = caps.get(node)
        if overflow is not None and cap is not None:
            overflow.append(dd.count > jnp.int32(cap))
        out = _fit(dd, cap)
    elif isinstance(node, Union):
        parts = [execute_node(c, sources, memo, emitter, dedup, caps,
                              overflow, **kw)
                 for c in node.inputs]
        aligned = [parts[0]] + [project(p, parts[0].attrs) for p in parts[1:]]
        data = jnp.concatenate([_masked_data(p) for p in aligned], axis=0)
        keep = jnp.concatenate([p.valid_mask for p in aligned])
        data, count = compact(data, keep)
        out = Table(data=data, count=count, attrs=parts[0].attrs)
    elif isinstance(node, EquiJoin):
        left = execute_node(node.left, sources, memo, emitter, dedup, caps,
                            overflow, **kw)
        right = execute_node(node.right, sources, memo, emitter, dedup, caps,
                             overflow, **kw)
        if join_exchange is not None:
            left, right = join_exchange(node, left, right)
        cap = caps.get(node, round_cap(left.capacity * 4))
        out, total = equi_join(left, right, node.left_key, node.right_key,
                               out_capacity=cap,
                               right_suffix=node.right_suffix)
        if overflow is not None:
            overflow.append(total > jnp.int32(cap))
    elif isinstance(node, EmitTriples):
        if emitter is None:
            raise ValueError("EmitTriples node needs an emitter")
        table = execute_node(node.input, sources, memo, emitter, dedup, caps,
                             overflow, **kw)
        joins = {i: execute_node(j, sources, memo, emitter, dedup, caps,
                                 overflow, **kw)
                 for i, j in node.joins}
        out = emitter.emit_triples(node.tm, table, joins)
    else:
        raise TypeError(f"cannot execute node {type(node).__name__}")
    memo[node] = out
    return out


def abstract_sources(sources: Mapping[str, Table]) -> Dict[str, Table]:
    """The :class:`jax.ShapeDtypeStruct` skeleton of a source mapping —
    same pytree (Tables with their static attrs), no device buffers.

    What AOT lowering (``compile_plan(...).lower(abstract).compile()``)
    and ``jax.export`` trace against: the compiled program depends only on
    shapes/dtypes, and the plan-cache/store key pins those exactly (source
    buffer capacities are part of the key), so an executable lowered from
    this skeleton serves every same-key extension."""
    return {name: Table(data=jax.ShapeDtypeStruct(t.data.shape,
                                                  t.data.dtype),
                        count=jax.ShapeDtypeStruct(t.count.shape,
                                                   t.count.dtype),
                        attrs=t.attrs)
            for name, t in sources.items()}


def compile_plan(plan: LogicalPlan, emitter, engine: str = "rmlmapper",
                 dedup: Optional[str] = None,
                 caps: Optional[Mapping[Node, int]] = None, jit: bool = True,
                 report_overflow: bool = False):
    """Lower the DAG to one ``sources -> (kg, raw)`` closure (jitted by
    default). Mirrors the engine semantics: ``"sdm"`` deduplicates each
    map's output as it is produced, ``"rmlmapper"`` only at the sink; the
    sink δ runs in either mode. ``raw`` is the engine's materialized triple
    count before the sink δ.

    Capacities in ``caps`` are sized for the planning-time extension;
    re-running the closure on extensions where more rows survive a node
    than planned truncates (the ``equi_join`` overflow convention). With
    ``report_overflow=True`` the closure returns ``(kg, raw, overflowed)``
    where ``overflowed`` is a scalar bool — True iff any capped node was
    truncated — which is what lets ``KGEngine`` re-execute safely instead
    of silently truncating: re-plan (or let the engine recompile) when it
    fires.

    The engine/sink semantics below (per-map δ under sdm, δδ = δ for a
    single map, sink δ) must stay in lockstep with
    :meth:`LogicalPlan.sink`, which is what ``dump_plan``/``explain``
    display. The distributed sibling is
    :func:`repro.plan.mesh.compile_mesh_plan` (same DAG, one shard_map
    body, the sink δ fused as a repartition collective)."""
    emit_nodes = plan.emits()

    def fn(sources: Mapping[str, Table]):
        memo: Dict[Node, Table] = {}
        flags: Optional[List[jax.Array]] = [] if report_overflow else None
        per_map = [execute_node(e, sources, memo, emitter, dedup, caps,
                                flags)
                   for e in emit_nodes]
        if engine == "sdm":
            per_map = [distinct(t, dedup=dedup) for t in per_map]
        raw = jnp.sum(jnp.stack([t.count for t in per_map]))

        def done(kg: Table):
            if not report_overflow:
                return kg, raw
            over = (jnp.any(jnp.stack(flags)) if flags
                    else jnp.zeros((), dtype=bool))
            return kg, raw, over

        if engine == "sdm" and len(per_map) == 1:
            return done(per_map[0])     # δδ = δ: per-map δ IS the sink δ
        data = jnp.concatenate([t.data for t in per_map], axis=0)
        mask = jnp.concatenate([t.valid_mask for t in per_map])
        data, count = compact(data, mask)
        merged = Table(data=data, count=count, attrs=per_map[0].attrs)
        return done(distinct(merged, dedup=dedup))

    return jax.jit(fn) if jit else fn


# ---------------------------------------------------------------------------
# materialization (the apply_mapsdi back end)
# ---------------------------------------------------------------------------

def input_names(plan: LogicalPlan) -> Dict[str, str]:
    """Deterministic materialization name per map: Rule-3 merges keep their
    recorded ``merged_*`` label, δπ(σ) chains derive ``src__pi_attrs`` (+
    ``__sigma``), untouched scans keep the source name."""
    names: Dict[str, str] = {}
    node_name: Dict[Node, str] = {}
    used: Dict[str, Node] = {}
    for tm in plan.maps:
        node = plan.inputs[tm.name]
        if node in node_name:
            names[tm.name] = node_name[node]
            continue
        if isinstance(node, Scan):
            name = node.source
        elif node in plan.names:
            name = plan.names[node]
        else:
            scans = sorted({n.source for n in iter_nodes(node)
                            if isinstance(n, Scan)})
            base = scans[0] if len(scans) == 1 else "plan"
            name = f"{base}__pi_" + "_".join(node.attrs)
            if any(isinstance(n, Select) for n in iter_nodes(node)):
                name += "__sigma"
        k, candidate = 0, name
        while candidate in used and used[candidate] != node:
            k += 1
            candidate = f"{name}_{k}"
        used[candidate] = node
        node_name[node] = candidate
        names[tm.name] = candidate
    return names


def materialize_plan(plan: LogicalPlan, dedup: Optional[str] = None
                     ) -> Tuple[DIS, Dict[str, int]]:
    """Evaluate the plan's relation inputs into a concrete ``DIS'``.

    All device work happens in ONE jitted call with shared subtrees
    evaluated once; the host syncs exactly once per new source, at the end
    (``shrink_to_fit``), mirroring the paper's pre-processed files.
    """
    dis = plan.dis
    names = input_names(plan)
    ordered: List[Node] = []
    for tm in plan.maps:
        node = plan.inputs[tm.name]
        if node not in ordered and not isinstance(node, Scan):
            ordered.append(node)

    tables: Dict[Node, Table] = {}
    if ordered:
        def run(sources):
            memo: Dict[Node, Table] = {}
            return [execute_node(n, sources, memo, dedup=dedup)
                    for n in ordered]
        for node, table in zip(ordered, jax.jit(run)(dis.sources)):
            tables[node] = table

    sources: Dict[str, Table] = {}
    preprocessed = set()
    sigma_baked: Dict[str, bool] = {}
    rows_after: Dict[str, int] = {}
    new_maps = []
    for tm in plan.maps:
        node, name = plan.inputs[tm.name], names[tm.name]
        if name not in sources:
            if isinstance(node, Scan):
                sources[name] = dis.sources[node.source]
                if node.source in plan.preprocessed:
                    preprocessed.add(name)
            else:
                sources[name] = shrink_to_fit(tables[node])  # the host sync
                preprocessed.add(name)
            rows_after[name] = host_int(sources[name].count)
        # σ-baked provenance: the materialized extension carries the map's
        # σ selections iff they were pushed into the materialized subtree
        # (or the source was already flagged). A source shared by several
        # maps is baked only if it is baked for every one of them.
        if isinstance(node, Scan):
            ok = node.source in plan.sigma_baked
        else:
            have = {p for n in iter_nodes(node)
                    if isinstance(n, Select) for p in n.preds}
            ok = all(p in have for p in selection_preds(dis, tm))
        sigma_baked[name] = sigma_baked.get(name, True) and ok
        new_maps.append(tm if tm.source == name
                        else dataclasses.replace(tm, source=name))

    out = dis.copy()
    out.sources = sources
    out.maps = new_maps
    out.preprocessed = preprocessed
    out.sigma_baked = {name for name, ok in sigma_baked.items() if ok}
    return out, rows_after
