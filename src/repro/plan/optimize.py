"""The optimizing planner: MapSDI Rules 1–3 as pure symbolic rewrites,
plus selection pushdown (the paper's σ) and common-subplan elimination.

Every pass maps ``plan.inputs`` / ``plan.maps`` to new immutable values —
no device work, no host syncs (``tests/test_planner.py`` runs the whole
fixpoint under ``forbid_transfers``). The correspondence to the paper:

* :func:`push_projections` — Rules 1 & 2: each map's relation becomes
  ``δ(π_Z̄(R))`` with ``Z̄`` = referenced attrs (own + incoming join attrs).
* :func:`merge_maps` — Rule 3: join-free maps with equal heads collapse
  into one map over ``δ(∪ π_roles(R_i))``.
* :func:`push_selections` — σ: null-filters and constant-equality
  predicates implied by the term maps (and any explicit ``selections``)
  sink through δ/π/∪ to sit directly on the scans.
* :func:`cse` — hash-consing: arbitrary equal subplans (not just identical
  ``(source, attrs)`` projections) become one shared node, across maps and
  across join parents.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Set, Tuple

from repro.core.analyze import merge_groups, referenced_attrs, \
    sorted_reference_poms
from repro.core.schema import (PredicateObjectMap, RefObjectMap, TermMap,
                               TripleMap)

from .ir import (Distinct, Node, Pred, Project, Scan, Select, Union,
                 intern, make_select, tree_size)
from .lower import LogicalPlan, selection_preds


@dataclasses.dataclass
class PlanStats:
    """Rewrite counters; mirrors TransformStats' rule accounting."""

    rule1_applications: int = 0
    rule2_applications: int = 0
    rule3_merges: int = 0
    sigma_pushdowns: int = 0
    cse_shared_subplans: int = 0


class _MapsView:
    """Duck-typed DIS for the analysis helpers (they only read ``.maps``)."""

    def __init__(self, maps: List[TripleMap]):
        self.maps = maps


def _join_parents(maps: List[TripleMap]) -> Set[str]:
    return {p.object.parent_map for m in maps for p in m.poms
            if isinstance(p.object, RefObjectMap)}


# ---------------------------------------------------------------------------
# Rules 1 & 2 — projection pushdown
# ---------------------------------------------------------------------------

def push_projections(plan: LogicalPlan, stats: PlanStats) -> None:
    """Each map's relation becomes ``δ(π_attrs(R))``; already-canonical
    inputs (a δ with exactly the needed attrs, or a Scan of a source the
    DIS marks pre-processed) are left alone, which makes the pass — and the
    fixpoint — idempotent."""
    needed = referenced_attrs(_MapsView(plan.maps))
    created: Dict[Node, None] = {}
    for tm in plan.maps:
        attrs = tuple(sorted(needed[tm.name]))
        node = plan.inputs[tm.name]
        if isinstance(node, Distinct) and \
                tuple(sorted(node.attrs)) == attrs:
            continue
        if isinstance(node, Scan) and node.source in plan.preprocessed and \
                attrs == tuple(sorted(node.attrs)):
            continue
        new = Distinct(Project(node, tuple((a, a) for a in attrs)))
        plan.inputs[tm.name] = new
        if new not in created:
            created[new] = None
            if tm.has_join:
                stats.rule2_applications += 1
            else:
                stats.rule1_applications += 1


# ---------------------------------------------------------------------------
# Rule 3 — merging sources with equivalent attributes
# ---------------------------------------------------------------------------

def merge_maps(plan: LogicalPlan, stats: PlanStats) -> None:
    """Every mergeable group collapses to one map over
    ``δ(∪_i π_roles(R_i))``. Join parents stay separate (their names are
    referenced by other maps); canonical role attrs are ``__m0`` (subject)
    and ``__m{i}`` for the i-th predicate-sorted object reference."""
    parents = _join_parents(plan.maps)
    for gi, group in enumerate(merge_groups(_MapsView(plan.maps))):
        group = [tm for tm in group if tm.name not in parents]
        if len(group) < 2:
            continue
        lead = group[0]
        canon_poms: List[PredicateObjectMap] = []
        r_nonconst = 0
        for idx, term in sorted_reference_poms(lead):
            pom = lead.poms[idx]
            if term.kind == "constant":
                canon_poms.append(pom)
            else:
                r_nonconst += 1
                canon_poms.append(PredicateObjectMap(
                    predicate=pom.predicate,
                    object=dataclasses.replace(term,
                                               attr=f"__m{r_nonconst}")))

        parts: List[Node] = []
        for tm in group:
            spec: List[Tuple[str, str]] = []
            if tm.subject.referenced_attr:
                spec.append((tm.subject.referenced_attr, "__m0"))
            r_nonconst = 0
            for idx, term in sorted_reference_poms(tm):
                if term.kind == "constant":
                    continue
                spec.append((term.attr, f"__m{r_nonconst + 1}"))
                r_nonconst += 1
            parts.append(Project(plan.inputs[tm.name], tuple(spec)))
        merged = Distinct(parts[0] if len(parts) == 1 else
                          Union(tuple(parts)))
        merged_name = f"merged_{gi}_" + "_".join(tm.name for tm in group)

        subject = (dataclasses.replace(lead.subject, attr="__m0")
                   if lead.subject.referenced_attr else lead.subject)
        merged_map = TripleMap(
            name=f"TM_merged_{gi}", source=merged_name, subject=subject,
            subject_class=lead.subject_class, poms=tuple(canon_poms))

        group_names = {tm.name for tm in group}
        plan.maps = [m for m in plan.maps if m.name not in group_names]
        plan.maps.append(merged_map)
        for name in group_names:
            plan.inputs.pop(name, None)
        plan.inputs[merged_map.name] = merged
        plan.names[merged] = merged_name
        stats.rule3_merges += 1


# ---------------------------------------------------------------------------
# σ — selection pushdown (the paper's "selects relevant entries")
# ---------------------------------------------------------------------------

def _required_preds(plan: LogicalPlan, tm: TripleMap,
                    parents: Set[str]) -> Tuple[Pred, ...]:
    """Predicates implied by the term maps that suppress *every* triple the
    map (and every join against it) would emit — exactly the rows σ may
    remove from the logical source without changing the KG."""
    preds: List[Pred] = list(selection_preds(plan.dis, tm))
    null = plan.dis.null_code
    if null is not None:
        # every block of a map is masked by subject validity, and joins
        # against it null-mask the parent subject too
        if tm.subject.referenced_attr:
            preds.append(Pred(tm.subject.referenced_attr, "notnull", null))
        # single-block map: the lone object's null-mask is also universal —
        # but not for join parents, whose rows feed other maps' joins
        if (tm.name not in parents and tm.subject_class is None
                and len(tm.poms) == 1):
            obj = tm.poms[0].object
            if isinstance(obj, TermMap) and obj.referenced_attr:
                preds.append(Pred(obj.referenced_attr, "notnull", null))
    return tuple(preds)


def _sink_preds(node: Node, preds: Tuple[Pred, ...]) -> Node:
    """Push σ predicates through δ/π/∪ until they sit on the scans."""
    if not preds:
        return node
    if isinstance(node, (Scan, Select)):
        return make_select(node, preds)
    if isinstance(node, Distinct):
        return Distinct(_sink_preds(node.child, preds))   # σδ = δσ
    if isinstance(node, Project):
        back = {dst: src for src, dst in node.spec}
        if any(p.attr not in back for p in preds):
            return make_select(node, preds)               # rename lost — stop
        renamed = tuple(dataclasses.replace(p, attr=back[p.attr])
                        for p in preds)
        return Project(_sink_preds(node.child, renamed), node.spec)
    if isinstance(node, Union):
        return Union(tuple(_sink_preds(c, preds) for c in node.inputs))
    return make_select(node, preds)


def push_selections(plan: LogicalPlan, stats: PlanStats) -> None:
    parents = _join_parents(plan.maps)
    for tm in plan.maps:
        node = plan.inputs[tm.name]
        if isinstance(node, Scan) and node.source in plan.preprocessed:
            continue  # σ already baked into the pre-processed extension
        preds = tuple(p for p in _required_preds(plan, tm, parents)
                      if p.attr in node.attrs)
        new = _sink_preds(node, preds)
        if new != node:
            plan.inputs[tm.name] = new
            stats.sigma_pushdowns += 1


# ---------------------------------------------------------------------------
# common-subplan elimination + the driving fixpoint
# ---------------------------------------------------------------------------

def cse(plan: LogicalPlan, stats: PlanStats) -> None:
    """Hash-cons every input relation so equal subplans are one object;
    records how many node instances the sharing saves."""
    memo: Dict[Node, Node] = {}
    for name in list(plan.inputs):
        plan.inputs[name] = intern(plan.inputs[name], memo)
    plan.names = {intern(n, memo): label for n, label in plan.names.items()}
    instances = sum(tree_size(n) for n in plan.inputs.values())
    stats.cse_shared_subplans = instances - len(
        {id(n) for root in plan.inputs.values() for n in _iter_ids(root)})


def _iter_ids(root: Node):
    seen = set()
    stack = [root]
    while stack:
        n = stack.pop()
        if id(n) in seen:
            continue
        seen.add(id(n))
        stack.extend(n.children())
        yield n


def optimize(plan: LogicalPlan, max_iters: int = 8,
             stats: Optional[PlanStats] = None,
             gate: Optional[Callable[
                 [str, Tuple[List[TripleMap], Dict[str, Node]], LogicalPlan],
                 None]] = None) -> PlanStats:
    """Run all rewrite passes to a fixpoint (paper: "until a fixed point
    over S' and M' is reached"), then hash-cons. Purely symbolic.

    ``gate``, when given, is called as ``gate(pass_name, (maps_before,
    inputs_before), plan)`` after every pass *that changed the plan* —
    the hook point for ``repro.analysis.soundness.soundness_gate``, which
    asserts each rewrite's lossless precondition and names the offending
    pass on violation."""
    stats = stats if stats is not None else PlanStats()

    def run(name, pass_fn):
        before = ((list(plan.maps), dict(plan.inputs))
                  if gate is not None else None)
        pass_fn(plan, stats)
        if gate is not None and (before[0] != plan.maps or
                                 before[1] != plan.inputs):
            gate(name, before, plan)

    for _ in range(max_iters):
        sig = (tuple(plan.maps), dict(plan.inputs))
        run("merge_maps", merge_maps)
        run("push_projections", push_projections)
        run("push_selections", push_selections)
        if (tuple(plan.maps), plan.inputs) == sig:
            break
    run("cse", cse)
    return stats
