"""Plan-time cardinality annotation — ``plan_join_caps`` generalized to a
per-node capacity on the whole IR.

Two modes (the ROADMAP's ``annotate(mode="bound")`` item):

* ``mode="exact"`` (default) evaluates every *relation* node of the
  optimized DAG on the host (numpy, exact — the planning-time analogue of a
  cardinality estimator with perfect statistics). One host materialization
  per scanned source; capacities are exact for the planning extension.
* ``mode="bound"`` sizes every node from *structural upper bounds* with no
  host pass at all: a Scan is bounded by its buffer capacity (static pytree
  metadata — no device read), π/σ/δ by their child, ∪ by the sum of its
  inputs. An ⋈ is the one operator whose true bound (|L|·|R|) is useless in
  practice, so it gets the FK-join heuristic ``|L| + |R|``; the compiled
  closure's overflow flag plus the engine's recompile-on-overflow make the
  heuristic safe (see ``docs/engine.md``).

``annotate(plan)`` returns ``(counts, caps)``:

* ``counts[node]`` — row count (exact or bound) of the node's output
  (``EquiJoin`` nodes get their match total, the quantity ``plan_join_caps``
  computed per (map, pom)).
* ``caps[node]``   — ``cap_fn(ceil(count * slack))``, the static buffer
  capacity the compiler sizes that node's output with. ``cap_fn`` defaults
  to :func:`round_cap` (exact fit); the ``KGEngine`` passes
  :func:`repro.relalg.table.bucket_cap` so structurally-identical plans
  over same-bucket extensions share one compiled closure.

``sources`` overrides the extensions to annotate against (default:
``plan.dis.sources``) — the engine re-annotates against its *current*
session sources after ingestion.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Mapping, Optional, Tuple

import numpy as np

from repro.relalg.table import Table, round_cap

from .ir import (ColEq, Distinct, EmitTriples, EquiJoin, Node, Project,
                 Scan, Select, Union)
from .lower import LogicalPlan

Rows = Tuple[np.ndarray, Tuple[str, ...]]  # valid rows [n, k] + attr names

#: per-collective launch overhead (seconds) the exchange cost model adds on
#: top of wire time — the tie-breaker that keeps tiny relations on the
#: single-collective gather plan instead of the two-exchange repartition
#: (~dispatch latency of one ICI collective; crossover therefore sits near
#: ``launch · ICI_BW ≈ 100 KiB`` of parent bytes per device)
COLLECTIVE_LAUNCH_S = 2e-6

JOIN_EXCHANGES = ("gather", "repartition", "auto")


def poisson_shard_bound(total: int, n_shards: int) -> int:
    """Expected per-shard share of ``total`` hash-partitioned rows plus a
    Poisson tail: ``m + 6·sqrt(m) + 8`` with ``m = total / n_shards``,
    clamped to ``total`` (one shard can never receive more than everything,
    and on one shard the exchange is the identity). The same bound
    :func:`repro.core.distributed.sink_bucket_cap` uses for the sink's
    buckets, applied to post-exchange *node* buffers; skew beyond the tail
    is caught by the runtime overflow flag and answered with a
    safe-capacity recompile (see ``annotate_local``)."""
    total = int(total)
    if n_shards <= 1:
        return total
    m = total / n_shards
    return min(total, int(math.ceil(m + 6.0 * math.sqrt(m) + 8)))


@dataclasses.dataclass(frozen=True)
class JoinExchange:
    """Per-⋈ exchange decision + the cost-model terms behind it.

    ``*_bytes`` are the estimated per-device wire bytes of each strategy
    (computed from the *static buffer capacities* that actually cross the
    ICI — fixed shapes, padding included — not from row counts);
    ``*_seconds`` add the per-collective launch overhead. Produced by
    :func:`join_exchange_cost` / ``annotate_local``, consumed by
    :func:`repro.plan.mesh.compile_mesh_plan` and rendered by
    ``explain``/``dump_plan``.

    ``parent_fanout`` is the number of ⋈ sites sharing this join's parent
    node. The fused mesh closure all_gathers a parent ONCE and reuses the
    replica at every ⋈ on the same parent (``compile_mesh_plan`` memoizes
    per parent node), so the gather figures here are the per-⋈ AMORTIZED
    share — total gather cost ÷ fanout — and the total is recovered as
    ``gather_seconds · parent_fanout``. ``repartition_*`` stay per-⋈ (each
    ⋈'s child side is its own exchange).
    """

    strategy: str               # "gather" | "repartition"
    gather_bytes: int
    repartition_bytes: int
    gather_seconds: float
    repartition_seconds: float
    cost_source: str = "static"  # "static" | "measured" bandwidth numbers
    parent_fanout: int = 1       # ⋈ sites sharing the gathered parent


def join_exchange_cost(child_cap_local: int, child_cols: int,
                       parent_cap_local: int, parent_cols: int,
                       n_shards: int, strategy: str = "auto",
                       word_bytes: int = 4,
                       calibration=None,
                       parent_fanout: int = 1) -> JoinExchange:
    """Price the two ⋈ exchange strategies and pick one.

    Inputs are the SHARD-LOCAL buffer capacities (rows) and widths
    (columns) of the child and parent relations — the fixed shapes the
    collectives move. Per device, over a ``n_shards``-way axis:

    * ``gather``      — the parent block is ``all_gather``'ed: receive
      ``(n-1) · parent_cap_local · parent_cols`` words (one collective;
      the gathered parent is shared by every ⋈ on the same parent node).
    * ``repartition`` — both sides are hash-partitioned on the join key
      and exchanged: receive ``(n-1)`` buckets of
      ``min(cap_local, sink_bucket_cap(cap_local, n))`` rows per side (two
      collectives) — the same clamp ``compile_mesh_plan`` allocates with,
      so the estimate prices the buffers that actually cross the wire.

    Wire seconds default to the v5e ICI bandwidth from
    :mod:`repro.launch.mesh` plus :data:`COLLECTIVE_LAUNCH_S` per
    collective; passing a :class:`repro.launch.mesh.Calibration` (e.g. the
    session-start microbenchmark fit from
    :func:`repro.launch.mesh.calibrate_mesh`) prices each collective with
    its *measured* bandwidth and launch constant instead — the decision
    rule is unchanged, only the numbers (and the reported ``cost_source``)
    differ. Repartition therefore wins exactly when the parent side is
    large relative to the child (the all_gather wall), and loses on small
    relations where the per-bucket Poisson padding and the extra collective
    dominate. ``strategy`` forces the choice (``"gather"`` /
    ``"repartition"``) or lets the model decide (``"auto"``); one shard
    always gathers under ``"auto"`` (both strategies are the identity, the
    gather plan is the cheaper program).

    ``parent_fanout`` > 1 declares that this many ⋈ sites share the parent
    node: the runtime all_gather is memoized per parent
    (``compile_mesh_plan`` gathers once, every sharing ⋈ reuses the
    replica), so the gather bytes/seconds — wire time AND the one launch —
    are amortized over the fan-out before the ``"auto"`` comparison.
    Without the amortization a parent gathered once was billed
    ``parent_fanout`` times, flipping ``auto`` to ``repartition`` on plans
    where the shared gather is actually cheaper (each sharing ⋈ would pay
    its own child+parent repartition). ``repartition_*`` are never
    amortized (each ⋈'s exchange buckets are its own collectives).
    """
    from repro.core.distributed import sink_bucket_cap
    from repro.launch.mesh import ICI_BW
    if strategy not in JOIN_EXCHANGES:
        raise ValueError(f"unknown join exchange {strategy!r} "
                         f"(expected one of {JOIN_EXCHANGES})")
    if calibration is None:
        gather_bw = a2a_bw = ICI_BW
        launch_s = COLLECTIVE_LAUNCH_S
        cost_source = "static"
    else:
        gather_bw = calibration.all_gather_bw
        a2a_bw = calibration.all_to_all_bw
        launch_s = calibration.launch_s
        cost_source = calibration.source
    n = max(1, int(n_shards))

    def bucket(cap_local: int) -> int:
        return min(int(cap_local), sink_bucket_cap(int(cap_local), n))

    fanout = max(1, int(parent_fanout))
    gather_total = (n - 1) * int(parent_cap_local) * parent_cols * word_bytes
    # the amortized per-⋈ share of the one shared all_gather (ceil so the
    # shares still sum to at least the total)
    gather_bytes = -(-gather_total // fanout)
    rep_rows = (bucket(child_cap_local) * child_cols
                + bucket(parent_cap_local) * parent_cols)
    repartition_bytes = (n - 1) * rep_rows * word_bytes
    gather_s = (gather_total / gather_bw + 1 * launch_s) / fanout
    repartition_s = repartition_bytes / a2a_bw + 2 * launch_s
    if strategy == "auto":
        strategy = ("repartition" if n > 1 and repartition_s < gather_s
                    else "gather")
    return JoinExchange(strategy=strategy, gather_bytes=gather_bytes,
                        repartition_bytes=repartition_bytes,
                        gather_seconds=gather_s,
                        repartition_seconds=repartition_s,
                        cost_source=cost_source,
                        parent_fanout=fanout)


def parent_fanouts(joins) -> Dict[Node, int]:
    """How many ⋈ sites share each parent node — the amortization divisor
    :func:`join_exchange_cost` prices the shared all_gather with. Keyed by
    the parent node itself (structural hash), exactly the key
    ``compile_mesh_plan`` memoizes the gathered replica under, so the
    pricing groups precisely the joins the runtime lets share one
    collective."""
    fanout: Dict[Node, int] = {}
    for join in joins:
        fanout[join.right] = fanout.get(join.right, 0) + 1
    return fanout


def _eval_rows(node: Node, sources: Mapping[str, Table],
               memo: Dict[Node, Rows]) -> Rows:
    hit = memo.get(node)
    if hit is not None:
        return hit
    if isinstance(node, Scan):
        table = sources[node.source]
        rows: np.ndarray = table.to_codes()
        attrs = tuple(table.attrs)
    elif isinstance(node, Project):
        child, cattrs = _eval_rows(node.child, sources, memo)
        idx = [cattrs.index(a) for a, _ in node.spec]
        rows, attrs = child[:, idx], node.attrs
    elif isinstance(node, Select):
        child, cattrs = _eval_rows(node.child, sources, memo)
        keep = np.ones(len(child), dtype=bool)
        for p in node.preds:
            col = child[:, cattrs.index(p.attr)]
            if p.op == "eq":
                keep &= col == p.code
            else:  # 'neq' and 'notnull' both exclude one code
                keep &= col != p.code
        rows, attrs = child[keep], cattrs
    elif isinstance(node, ColEq):
        child, cattrs = _eval_rows(node.child, sources, memo)
        keep = (child[:, cattrs.index(node.left_attr)]
                == child[:, cattrs.index(node.right_attr)])
        rows, attrs = child[keep], cattrs
    elif isinstance(node, Distinct):
        child, cattrs = _eval_rows(node.child, sources, memo)
        rows, attrs = np.unique(child, axis=0), cattrs
    elif isinstance(node, EquiJoin):
        # materialized exact join — the creation path only ever needs the
        # match *total* (joins feed EmitTriples directly), but query DAGs
        # stack π/δ/ColEq on top of ⋈, so exact annotation needs the rows
        left, lattrs = _eval_rows(node.left, sources, memo)
        right, rattrs = _eval_rows(node.right, sources, memo)
        lk = left[:, lattrs.index(node.left_key)]
        rk = right[:, rattrs.index(node.right_key)]
        order = np.argsort(rk, kind="stable")
        rs = rk[order]
        lo = np.searchsorted(rs, lk, side="left")
        hi = np.searchsorted(rs, lk, side="right")
        match = hi - lo
        total = int(match.sum())
        li = np.repeat(np.arange(len(lk)), match)
        starts = np.repeat(np.cumsum(match) - match, match)
        ri = order[np.repeat(lo, match) + np.arange(total) - starts]
        rows = np.concatenate(
            [left[li], right[ri]], axis=1) if total else np.zeros(
            (0, left.shape[1] + right.shape[1]), dtype=left.dtype)
        attrs = node.attrs
    elif isinstance(node, Union):
        parts = []
        attrs = node.attrs
        for c in node.inputs:
            crows, cattrs = _eval_rows(c, sources, memo)
            parts.append(crows[:, [cattrs.index(a) for a in attrs]])
        rows = np.concatenate(parts, axis=0)
    else:
        raise TypeError(f"not a relation node: {type(node).__name__}")
    memo[node] = (rows, attrs)
    return rows, attrs


def join_match_total(lk: np.ndarray, rk: np.ndarray) -> int:
    """Exact equi-join output cardinality for two key columns — the
    estimation kernel shared with ``plan_join_caps``."""
    vals, counts = np.unique(rk, return_counts=True)
    if len(vals) == 0 or len(lk) == 0:
        return 0
    idx = np.clip(np.searchsorted(vals, lk), 0, len(vals) - 1)
    match = vals[idx] == lk
    return int(counts[idx][match].sum())


def _join_total(node: EquiJoin, sources: Mapping[str, Table],
                memo: Dict[Node, Rows]) -> int:
    left, lattrs = _eval_rows(node.left, sources, memo)
    right, rattrs = _eval_rows(node.right, sources, memo)
    return join_match_total(left[:, lattrs.index(node.left_key)],
                            right[:, rattrs.index(node.right_key)])


def _bound(node: Node, sources: Mapping[str, Table],
           memo: Dict[Node, int]) -> int:
    """Structural upper bound on a node's output rows — static shape
    metadata only, zero device *and* host reads."""
    hit = memo.get(node)
    if hit is not None:
        return hit
    if isinstance(node, Scan):
        out = sources[node.source].capacity
    elif isinstance(node, (Project, Select, ColEq, Distinct)):
        out = _bound(node.children()[0], sources, memo)
    elif isinstance(node, Union):
        out = sum(_bound(c, sources, memo) for c in node.inputs)
    elif isinstance(node, EquiJoin):
        # FK-join heuristic, NOT a true bound (that is |L|·|R|); the
        # runtime overflow flag + recompile-on-overflow covers the gap
        out = _bound(node.left, sources, memo) + \
            _bound(node.right, sources, memo)
    else:
        raise TypeError(f"not a relation node: {type(node).__name__}")
    memo[node] = out
    return out


def annotate(plan: LogicalPlan, mode: str = "exact", slack: float = 1.0,
             cap_fn: Callable[[int], int] = round_cap,
             sources: Optional[Mapping[str, Table]] = None,
             ) -> Tuple[Dict[Node, int], Dict[Node, int]]:
    """(counts, capacities) for every relation and join node reachable from
    the plan's emits — exact (one host read per scanned source) or
    structural bounds (no host pass); see the module docstring."""
    if mode not in ("exact", "bound"):
        raise ValueError(f"unknown annotate mode {mode!r}")
    sources = plan.dis.sources if sources is None else sources
    counts: Dict[Node, int] = {}
    if mode == "bound":
        bmemo: Dict[Node, int] = {}

        def count_of(node: Node) -> int:
            return _bound(node, sources, bmemo)

        def join_of(join: EquiJoin) -> int:
            return _bound(join, sources, bmemo)
    else:
        memo: Dict[Node, Rows] = {}

        def count_of(node: Node) -> int:
            return len(_eval_rows(node, sources, memo)[0])

        def join_of(join: EquiJoin) -> int:
            return _join_total(join, sources, memo)

    for emit in plan.emits():
        assert isinstance(emit, EmitTriples)
        for node in _relation_nodes(emit.input):
            if node not in counts:
                counts[node] = count_of(node)
        for _, join in emit.joins:
            for side in (join.left, join.right):
                for node in _relation_nodes(side):
                    if node not in counts:
                        counts[node] = count_of(node)
            if join not in counts:
                counts[join] = join_of(join)
    caps = {node: cap_fn(int(math.ceil(c * slack)))
            for node, c in counts.items()}
    return counts, caps


def annotate_local(plan: LogicalPlan, n_shards: int,
                   cap_locals: Mapping[str, int], mode: str = "exact",
                   slack: float = 1.0,
                   cap_fn: Callable[[int], int] = round_cap,
                   sources: Optional[Mapping[str, Table]] = None,
                   join_exchange: str = "gather",
                   safe_exchange: bool = False,
                   calibration=None,
                   ) -> Tuple[Dict[Node, int], Dict[Node, int],
                              Dict[Node, JoinExchange]]:
    """Shard-local (counts, capacities, exchanges) for the fused mesh
    closure.

    The fused distributed plan (:mod:`repro.plan.mesh`) runs every node on
    *per-shard row blocks*: a Scan sees at most ``cap_locals[source]`` rows,
    and every downstream buffer only needs to hold that shard's slice. This
    sizes those buffers and picks the exchange strategy per ⋈:

    * ``counts`` are the GLOBAL counts of :func:`annotate` (exact or bound
      mode) — what the engine's Table-1-style stats report.
    * ``caps[node]`` are SHARD-LOCAL: ``min(global count, structural local
      bound)`` where the local bound walks the subtree with Scans clamped
      to ``cap_locals`` (π/σ bounded by their child, ∪ by the sum).
    * ``exchanges[join]`` is the :class:`JoinExchange` decision of
      :func:`join_exchange_cost` under the ``join_exchange`` knob
      (``"gather"`` | ``"repartition"`` | ``"auto"``), priced from the
      already-computed shard-local caps of the child and parent relations —
      under the static datasheet constants, or under a measured
      :class:`repro.launch.mesh.Calibration` when one is passed. Joins
      sharing one parent node (CSE-shared subplans) share one runtime
      all_gather, so each ⋈'s gather price is the amortized
      total-÷-fan-out share (:func:`parent_fanouts`) — per-⋈ pricing in
      isolation would bill the shared collective once per ⋈ and flip
      ``auto`` to ``repartition`` on plans where the shared gather wins.

    **Post-exchange bounds.** The mesh executes every interior δ as a
    global hash-repartition (all copies of a row share its rowhash, so a
    local δ after the exchange is globally exact — what makes the mesh
    ``raw`` count match single-device semantics). A shard's post-exchange
    δ block therefore holds the globally-distinct rows *hashing to it* —
    bounded by :func:`poisson_shard_bound` of the global distinct count,
    NOT by the subtree's pre-exchange slice (a shard can receive more rows
    than its own slice held). The local-bound walk accordingly treats δ as
    a redistribution point; π/σ/∪ above it inherit the post-exchange
    bound. A repartitioned ⋈ is sized the same way from its global match
    total: each shard joins one hash range of the key space, expected
    ``total / n_shards`` matches plus the tail.

    Every bound of the ``safe_exchange=False`` default is exact *in
    expectation* but not adversarially: key/hash skew past the Poisson
    tail trips the runtime overflow flag, and the engine rebuilds once
    with ``safe_exchange=True`` — post-exchange caps grow to the full
    global counts (a true bound: one shard can never hold more than
    everything), so recompile-on-overflow terminates after exactly one
    recompile, exactly as on one device. Gather-strategy ⋈ caps keep the
    global total in ``"exact"`` mode (each shard's child slice is an exact
    sub-multiset of the global child, so its matches against the fully
    gathered parent are a subset of the global matches) and the FK
    heuristic (shard-local left + global right) in ``"bound"`` mode.
    """
    if join_exchange not in JOIN_EXCHANGES:
        raise ValueError(f"unknown join exchange {join_exchange!r} "
                         f"(expected one of {JOIN_EXCHANGES})")
    counts, _ = annotate(plan, mode=mode, slack=slack, cap_fn=cap_fn,
                         sources=sources)
    lmemo: Dict[Node, int] = {}

    def local_bound(node: Node) -> int:
        hit = lmemo.get(node)
        if hit is not None:
            return hit
        if isinstance(node, Scan):
            out = int(cap_locals[node.source])
        elif isinstance(node, Distinct):
            # executed as a global hash-repartition: the shard holds the
            # distinct rows hashing to it, not its pre-exchange slice
            out = (counts[node] if safe_exchange
                   else poisson_shard_bound(counts[node], n_shards))
        elif isinstance(node, (Project, Select, ColEq)):
            out = local_bound(node.children()[0])
        elif isinstance(node, Union):
            out = sum(local_bound(c) for c in node.inputs)
        else:
            raise TypeError(f"not a relation node: {type(node).__name__}")
        lmemo[node] = out
        return out

    caps: Dict[Node, int] = {}
    joins = []
    for node, c in counts.items():
        if isinstance(node, EquiJoin):
            joins.append(node)
            continue
        caps[node] = cap_fn(int(math.ceil(min(c, local_bound(node))
                                          * slack)))
    exchanges: Dict[Node, JoinExchange] = {}
    fanout = parent_fanouts(joins)
    for node in joins:
        c = counts[node]
        exch = join_exchange_cost(
            caps[node.left], len(node.left.attrs),
            caps[node.right], len(node.right.attrs),
            n_shards, strategy=join_exchange, calibration=calibration,
            parent_fanout=fanout[node.right])
        exchanges[node] = exch
        if exch.strategy == "repartition":
            local = c if safe_exchange else poisson_shard_bound(c, n_shards)
        elif mode == "exact":
            local = c
        else:
            local = min(c, local_bound(node.left) + counts[node.right])
        caps[node] = cap_fn(int(math.ceil(local * slack)))
    return counts, caps, exchanges


def _relation_nodes(root: Node):
    stack, seen = [root], set()
    while stack:
        n = stack.pop()
        if n in seen or isinstance(n, (EquiJoin, EmitTriples)):
            continue
        seen.add(n)
        stack.extend(n.children())
        yield n
