"""Plan-time cardinality annotation — ``plan_join_caps`` generalized to a
per-node capacity on the whole IR.

``annotate(plan)`` evaluates every *relation* node of the optimized DAG on
the host (numpy, exact — the planning-time analogue of a cardinality
estimator with perfect statistics) and returns ``(counts, caps)``:

* ``counts[node]`` — exact valid-row count of the node's output for the
  planning-time source extensions (``EquiJoin`` nodes get their exact match
  total, the quantity ``plan_join_caps`` computed per (map, pom)).
* ``caps[node]``   — ``round_cap(count)``, the static buffer capacity the
  compiler sizes that node's output with.

This is the only place the planned pipeline reads source data before
execution: one host materialization per scanned source, all downstream
arithmetic in numpy. Capacities are exact for the planning extension; like
join caps before, re-running the compiled closure on *larger* extensions is
the caller's overflow risk.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.relalg.table import round_cap

from .ir import (Distinct, EmitTriples, EquiJoin, Node, Project, Scan,
                 Select, Union)
from .lower import LogicalPlan

Rows = Tuple[np.ndarray, Tuple[str, ...]]  # valid rows [n, k] + attr names


def _eval_rows(node: Node, plan: LogicalPlan,
               memo: Dict[Node, Rows]) -> Rows:
    hit = memo.get(node)
    if hit is not None:
        return hit
    if isinstance(node, Scan):
        table = plan.dis.sources[node.source]
        rows: np.ndarray = table.to_codes()
        attrs = tuple(table.attrs)
    elif isinstance(node, Project):
        child, cattrs = _eval_rows(node.child, plan, memo)
        idx = [cattrs.index(a) for a, _ in node.spec]
        rows, attrs = child[:, idx], node.attrs
    elif isinstance(node, Select):
        child, cattrs = _eval_rows(node.child, plan, memo)
        keep = np.ones(len(child), dtype=bool)
        for p in node.preds:
            col = child[:, cattrs.index(p.attr)]
            if p.op == "eq":
                keep &= col == p.code
            else:  # 'neq' and 'notnull' both exclude one code
                keep &= col != p.code
        rows, attrs = child[keep], cattrs
    elif isinstance(node, Distinct):
        child, cattrs = _eval_rows(node.child, plan, memo)
        rows, attrs = np.unique(child, axis=0), cattrs
    elif isinstance(node, Union):
        parts: List[np.ndarray] = []
        attrs = node.attrs
        for c in node.inputs:
            crows, cattrs = _eval_rows(c, plan, memo)
            parts.append(crows[:, [cattrs.index(a) for a in attrs]])
        rows = np.concatenate(parts, axis=0)
    else:
        raise TypeError(f"not a relation node: {type(node).__name__}")
    memo[node] = (rows, attrs)
    return rows, attrs


def join_match_total(lk: np.ndarray, rk: np.ndarray) -> int:
    """Exact equi-join output cardinality for two key columns — the
    estimation kernel shared with ``plan_join_caps``."""
    vals, counts = np.unique(rk, return_counts=True)
    if len(vals) == 0 or len(lk) == 0:
        return 0
    idx = np.clip(np.searchsorted(vals, lk), 0, len(vals) - 1)
    match = vals[idx] == lk
    return int(counts[idx][match].sum())


def _join_total(node: EquiJoin, plan: LogicalPlan,
                memo: Dict[Node, Rows]) -> int:
    left, lattrs = _eval_rows(node.left, plan, memo)
    right, rattrs = _eval_rows(node.right, plan, memo)
    return join_match_total(left[:, lattrs.index(node.left_key)],
                            right[:, rattrs.index(node.right_key)])


def annotate(plan: LogicalPlan
             ) -> Tuple[Dict[Node, int], Dict[Node, int]]:
    """Exact (counts, capacities) for every relation and join node reachable
    from the plan's emits. One host read per scanned source."""
    memo: Dict[Node, Rows] = {}
    counts: Dict[Node, int] = {}
    for emit in plan.emits():
        assert isinstance(emit, EmitTriples)
        for node in _relation_nodes(emit.input):
            if node not in counts:
                counts[node] = len(_eval_rows(node, plan, memo)[0])
        for _, join in emit.joins:
            for side in (join.left, join.right):
                for node in _relation_nodes(side):
                    if node not in counts:
                        counts[node] = len(_eval_rows(node, plan, memo)[0])
            if join not in counts:
                counts[join] = _join_total(join, plan, memo)
    caps = {node: round_cap(c) for node, c in counts.items()}
    return counts, caps


def _relation_nodes(root: Node):
    stack, seen = [root], set()
    while stack:
        n = stack.pop()
        if n in seen or isinstance(n, (EquiJoin, EmitTriples)):
            continue
        seen.add(n)
        stack.extend(n.children())
        yield n
