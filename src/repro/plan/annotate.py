"""Plan-time cardinality annotation — ``plan_join_caps`` generalized to a
per-node capacity on the whole IR.

Two modes (the ROADMAP's ``annotate(mode="bound")`` item):

* ``mode="exact"`` (default) evaluates every *relation* node of the
  optimized DAG on the host (numpy, exact — the planning-time analogue of a
  cardinality estimator with perfect statistics). One host materialization
  per scanned source; capacities are exact for the planning extension.
* ``mode="bound"`` sizes every node from *structural upper bounds* with no
  host pass at all: a Scan is bounded by its buffer capacity (static pytree
  metadata — no device read), π/σ/δ by their child, ∪ by the sum of its
  inputs. An ⋈ is the one operator whose true bound (|L|·|R|) is useless in
  practice, so it gets the FK-join heuristic ``|L| + |R|``; the compiled
  closure's overflow flag plus the engine's recompile-on-overflow make the
  heuristic safe (see ``docs/engine.md``).

``annotate(plan)`` returns ``(counts, caps)``:

* ``counts[node]`` — row count (exact or bound) of the node's output
  (``EquiJoin`` nodes get their match total, the quantity ``plan_join_caps``
  computed per (map, pom)).
* ``caps[node]``   — ``cap_fn(ceil(count * slack))``, the static buffer
  capacity the compiler sizes that node's output with. ``cap_fn`` defaults
  to :func:`round_cap` (exact fit); the ``KGEngine`` passes
  :func:`repro.relalg.table.bucket_cap` so structurally-identical plans
  over same-bucket extensions share one compiled closure.

``sources`` overrides the extensions to annotate against (default:
``plan.dis.sources``) — the engine re-annotates against its *current*
session sources after ingestion.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, Mapping, Optional, Tuple

import numpy as np

from repro.relalg.table import Table, round_cap

from .ir import (Distinct, EmitTriples, EquiJoin, Node, Project, Scan,
                 Select, Union)
from .lower import LogicalPlan

Rows = Tuple[np.ndarray, Tuple[str, ...]]  # valid rows [n, k] + attr names


def _eval_rows(node: Node, sources: Mapping[str, Table],
               memo: Dict[Node, Rows]) -> Rows:
    hit = memo.get(node)
    if hit is not None:
        return hit
    if isinstance(node, Scan):
        table = sources[node.source]
        rows: np.ndarray = table.to_codes()
        attrs = tuple(table.attrs)
    elif isinstance(node, Project):
        child, cattrs = _eval_rows(node.child, sources, memo)
        idx = [cattrs.index(a) for a, _ in node.spec]
        rows, attrs = child[:, idx], node.attrs
    elif isinstance(node, Select):
        child, cattrs = _eval_rows(node.child, sources, memo)
        keep = np.ones(len(child), dtype=bool)
        for p in node.preds:
            col = child[:, cattrs.index(p.attr)]
            if p.op == "eq":
                keep &= col == p.code
            else:  # 'neq' and 'notnull' both exclude one code
                keep &= col != p.code
        rows, attrs = child[keep], cattrs
    elif isinstance(node, Distinct):
        child, cattrs = _eval_rows(node.child, sources, memo)
        rows, attrs = np.unique(child, axis=0), cattrs
    elif isinstance(node, Union):
        parts = []
        attrs = node.attrs
        for c in node.inputs:
            crows, cattrs = _eval_rows(c, sources, memo)
            parts.append(crows[:, [cattrs.index(a) for a in attrs]])
        rows = np.concatenate(parts, axis=0)
    else:
        raise TypeError(f"not a relation node: {type(node).__name__}")
    memo[node] = (rows, attrs)
    return rows, attrs


def join_match_total(lk: np.ndarray, rk: np.ndarray) -> int:
    """Exact equi-join output cardinality for two key columns — the
    estimation kernel shared with ``plan_join_caps``."""
    vals, counts = np.unique(rk, return_counts=True)
    if len(vals) == 0 or len(lk) == 0:
        return 0
    idx = np.clip(np.searchsorted(vals, lk), 0, len(vals) - 1)
    match = vals[idx] == lk
    return int(counts[idx][match].sum())


def _join_total(node: EquiJoin, sources: Mapping[str, Table],
                memo: Dict[Node, Rows]) -> int:
    left, lattrs = _eval_rows(node.left, sources, memo)
    right, rattrs = _eval_rows(node.right, sources, memo)
    return join_match_total(left[:, lattrs.index(node.left_key)],
                            right[:, rattrs.index(node.right_key)])


def _bound(node: Node, sources: Mapping[str, Table],
           memo: Dict[Node, int]) -> int:
    """Structural upper bound on a node's output rows — static shape
    metadata only, zero device *and* host reads."""
    hit = memo.get(node)
    if hit is not None:
        return hit
    if isinstance(node, Scan):
        out = sources[node.source].capacity
    elif isinstance(node, (Project, Select, Distinct)):
        out = _bound(node.children()[0], sources, memo)
    elif isinstance(node, Union):
        out = sum(_bound(c, sources, memo) for c in node.inputs)
    elif isinstance(node, EquiJoin):
        # FK-join heuristic, NOT a true bound (that is |L|·|R|); the
        # runtime overflow flag + recompile-on-overflow covers the gap
        out = _bound(node.left, sources, memo) + \
            _bound(node.right, sources, memo)
    else:
        raise TypeError(f"not a relation node: {type(node).__name__}")
    memo[node] = out
    return out


def annotate(plan: LogicalPlan, mode: str = "exact", slack: float = 1.0,
             cap_fn: Callable[[int], int] = round_cap,
             sources: Optional[Mapping[str, Table]] = None,
             ) -> Tuple[Dict[Node, int], Dict[Node, int]]:
    """(counts, capacities) for every relation and join node reachable from
    the plan's emits — exact (one host read per scanned source) or
    structural bounds (no host pass); see the module docstring."""
    if mode not in ("exact", "bound"):
        raise ValueError(f"unknown annotate mode {mode!r}")
    sources = plan.dis.sources if sources is None else sources
    counts: Dict[Node, int] = {}
    if mode == "bound":
        bmemo: Dict[Node, int] = {}

        def count_of(node: Node) -> int:
            return _bound(node, sources, bmemo)

        def join_of(join: EquiJoin) -> int:
            return _bound(join, sources, bmemo)
    else:
        memo: Dict[Node, Rows] = {}

        def count_of(node: Node) -> int:
            return len(_eval_rows(node, sources, memo)[0])

        def join_of(join: EquiJoin) -> int:
            return _join_total(join, sources, memo)

    for emit in plan.emits():
        assert isinstance(emit, EmitTriples)
        for node in _relation_nodes(emit.input):
            if node not in counts:
                counts[node] = count_of(node)
        for _, join in emit.joins:
            for side in (join.left, join.right):
                for node in _relation_nodes(side):
                    if node not in counts:
                        counts[node] = count_of(node)
            if join not in counts:
                counts[join] = join_of(join)
    caps = {node: cap_fn(int(math.ceil(c * slack)))
            for node, c in counts.items()}
    return counts, caps


def annotate_local(plan: LogicalPlan, n_shards: int,
                   cap_locals: Mapping[str, int], mode: str = "exact",
                   slack: float = 1.0,
                   cap_fn: Callable[[int], int] = round_cap,
                   sources: Optional[Mapping[str, Table]] = None,
                   ) -> Tuple[Dict[Node, int], Dict[Node, int]]:
    """Shard-local (counts, capacities) for the fused mesh closure.

    The fused distributed plan (:mod:`repro.plan.mesh`) runs every node on
    *per-shard row blocks*: a Scan sees at most ``cap_locals[source]`` rows,
    and every downstream buffer only needs to hold that shard's slice. This
    sizes those buffers:

    * ``counts`` are the GLOBAL counts of :func:`annotate` (exact or bound
      mode) — what the engine's Table-1-style stats report.
    * ``caps[node]`` are SHARD-LOCAL: ``min(global count, structural local
      bound)`` where the local bound walks the subtree with Scans clamped
      to ``cap_locals`` (π/σ/δ bounded by their child, ∪ by the sum).

    Both terms of the min are true per-shard bounds in ``"exact"`` mode: a
    shard's slice of any relation node is a sub-multiset of the global
    relation (Scans partition rows; shard-local δ keeps at most one copy of
    each globally-distinct row). An ⋈'s output is bounded by the *global*
    exact match total because the fused plan all_gathers + deduplicates the
    parent side — each shard joins its (duplicate-free slice of the) child
    rows against the full parent relation, so its matches are a subset of
    the global matches. In ``"bound"`` mode the ⋈ keeps the FK heuristic
    (shard-local left + global right) and the runtime overflow flag +
    recompile-on-overflow covers the gap, exactly as on one device.
    """
    counts, _ = annotate(plan, mode=mode, slack=slack, cap_fn=cap_fn,
                         sources=sources)
    lmemo: Dict[Node, int] = {}

    def local_bound(node: Node) -> int:
        hit = lmemo.get(node)
        if hit is not None:
            return hit
        if isinstance(node, Scan):
            out = int(cap_locals[node.source])
        elif isinstance(node, (Project, Select, Distinct)):
            out = local_bound(node.children()[0])
        elif isinstance(node, Union):
            out = sum(local_bound(c) for c in node.inputs)
        else:
            raise TypeError(f"not a relation node: {type(node).__name__}")
        lmemo[node] = out
        return out

    caps: Dict[Node, int] = {}
    for node, c in counts.items():
        if isinstance(node, EquiJoin):
            local = c if mode == "exact" else \
                min(c, local_bound(node.left) + counts[node.right])
        else:
            local = min(c, local_bound(node))
        caps[node] = cap_fn(int(math.ceil(local * slack)))
    return counts, caps


def _relation_nodes(root: Node):
    stack, seen = [root], set()
    while stack:
        n = stack.pop()
        if n in seen or isinstance(n, (EquiJoin, EmitTriples)):
            continue
        seen.add(n)
        stack.extend(n.children())
        yield n
