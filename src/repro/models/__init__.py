from .model_zoo import get_model, MODEL_FAMILIES, auto_rules

__all__ = ["get_model", "MODEL_FAMILIES", "auto_rules"]
