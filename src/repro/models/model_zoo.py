"""Model registry + per-(config, mesh, shape) automatic axis rules.

``get_model(family)`` returns the family module (uniform interface:
``param_specs / apply / cache_specs / prefill / decode_step``).

``auto_rules`` builds the AxisRules table for a concrete (config, mesh,
shape): every tensor-parallel candidate axis is divisibility-checked
against the mesh (e.g. gemma3's 8 q heads cannot shard over model=16 →
replicated; its ffn=10240 can). When the kv-head dim cannot use the
``model`` axis, the KV-cache *sequence* dim takes it instead
(sequence-sharded decode attention — GSPMD lowers the softmax/PV over the
sharded dim to partial reductions + one all-reduce).
"""
from __future__ import annotations

from types import ModuleType

from jax.sharding import Mesh

from repro.distributed.sharding import AxisRules

from . import encdec, moe, rwkv6, transformer, vlm, zamba2

MODEL_FAMILIES = {
    "dense": transformer,
    "moe": moe,
    "rwkv": rwkv6,
    "hybrid": zamba2,
    "encdec": encdec,
    "vlm": vlm,
}


def get_model(family: str) -> ModuleType:
    try:
        return MODEL_FAMILIES[family]
    except KeyError:
        raise KeyError(f"unknown family {family!r}; "
                       f"known: {sorted(MODEL_FAMILIES)}")


def _div(n: int, by: int) -> bool:
    return by > 0 and n % by == 0


def auto_rules(cfg, mesh: Mesh, shape=None) -> AxisRules:
    """Divisibility-checked logical->mesh table for this (arch, mesh)."""
    model_n = mesh.shape.get("model", 1)
    data_n = mesh.shape.get("data", 1)
    pod_n = mesh.shape.get("pod", 1)
    rules = []

    # batch: prefer (pod, data), fall back, else replicate (long_500k B=1)
    if shape is not None:
        gb = shape.global_batch
        if pod_n > 1 and _div(gb, pod_n * data_n):
            rules.append(("batch", ("pod", "data")))
        elif _div(gb, data_n):
            rules.append(("batch", "data"))
        else:
            rules.append(("batch", None))
    else:
        if pod_n > 1:
            rules.append(("batch", ("pod", "data")))
        rules.append(("batch", "data"))

    # tensor-parallel candidates, divisibility-checked
    has_model = "model" in mesh.shape

    def tp(logical: str, dim: int):
        rules.append((logical, "model")
                     if has_model and _div(dim, model_n)
                     else (logical, None))

    tp("heads", cfg.n_heads)
    tp("kv_heads", cfg.n_kv_heads)
    tp("ffn", cfg.d_ff)
    tp("vocab", cfg.vocab_padded)
    tp("heads_flat", cfg.d_model)          # rwkv fused head dim
    tp("embed_out", cfg.d_model)           # square d->d projections
    if cfg.n_experts:
        tp("expert", cfg.n_experts)
    if cfg.family in ("hybrid",):
        tp("ssm_inner", 2 * cfg.d_inner + 2 * cfg.ssm_state +
           cfg.d_inner // cfg.ssm_head_dim)
        rules.append(("embed_cat", None))

    # KV cache seq dim: give the model axis to whoever can't use it
    kv_sharded = _div(cfg.n_kv_heads, model_n)
    rules.append(("kv_seq", "model" if has_model and not kv_sharded
                  else None))

    # FSDP: shard the non-TP param dim over data (within pod) or (pod,data)
    if cfg.fsdp and _div(cfg.d_model, data_n):
        if cfg.fsdp_pods and pod_n > 1:
            rules.append(("embed", ("pod", "data")))
        else:
            rules.append(("embed", "data"))
    rules.append(("embed", None))
    if cfg.fsdp and cfg.n_experts and _div(cfg.d_ff, data_n):
        rules.append(("expert_ffn",
                      ("pod", "data") if cfg.fsdp_pods and pod_n > 1
                      else "data"))
    rules.append(("expert_ffn", None))

    # sequence parallelism on residual-stream checkpoints
    seq_ok = shape is None or _div(shape.seq_len, model_n)
    rules.append(("seq_sp", "model")
                 if (has_model and cfg.seq_shard_activations and seq_ok)
                 else ("seq_sp", None))
    rules += [("seq", None), ("state", None), ("head_dim", None),
              ("layers", None), ("groups", None)]
    return AxisRules(tuple(rules))
