"""Zamba2 hybrid: Mamba2 (SSD) backbone + a weight-shared attention block.

54 Mamba2 layers in 9 groups of 6; ONE shared transformer block (attn+MLP,
its own parameters reused at every invocation) runs at the start of each
group on ``concat(hidden, original_embedding)`` projected back to d_model
(the Zamba2 "shared block + concat skip" scheme; per-invocation LoRAs are
omitted — see DESIGN.md §changed-assumptions).

Scan structure: outer scan over the 9 groups (shared-block params are
*closed over*, so they stay un-stacked), inner scan over the 6 Mamba2
layers with stacked params [9, 6, ...]. Decode state: per mamba layer a
(conv buffer [B,K-1,C], SSD state [B,H,N,P]); per group invocation its own
KV cache for the shared block.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import ParamSpec
from repro.kernels.mamba2 import mamba2_ssd
from .layers import (Params, ShardCtx, attention, attn_block_unroll,
                     attn_out, attn_qkv, attn_specs, cache_update, constrain,
                     embed, embed_specs, layer_unroll, mlp, mlp_specs,
                     norm_specs, rms_norm, stack_specs, unembed)

CONV_K = 4


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def _mamba_specs(cfg) -> Params:
    d = cfg.d_model
    di = cfg.d_inner
    n = cfg.ssm_state
    hd = cfg.ssm_head_dim
    h = di // hd
    conv_ch = di + 2 * n
    return {
        "ln": norm_specs(d),
        "in_proj": ParamSpec((d, 2 * di + 2 * n + h),
                             ("embed", "ssm_inner"), init="scaled"),
        "conv_w": ParamSpec((CONV_K, conv_ch), (None, "ssm_inner"),
                            jnp.float32, "normal", 0.2),
        "conv_b": ParamSpec((conv_ch,), ("ssm_inner",), jnp.float32,
                            "zeros"),
        "a_log": ParamSpec((h,), ("heads",), jnp.float32, "zeros"),
        "dt_bias": ParamSpec((h,), ("heads",), jnp.float32, "zeros"),
        "d_skip": ParamSpec((h,), ("heads",), jnp.float32, "zeros"),
        "norm_w": ParamSpec((di,), ("ssm_inner",), jnp.float32, "zeros"),
        "out_proj": ParamSpec((di, d), ("ssm_inner", "embed"),
                              init="scaled"),
    }


def _shared_block_specs(cfg) -> Params:
    d = cfg.d_model
    return {
        "in_proj": ParamSpec((2 * d, d), ("embed_cat", "embed"),
                             init="scaled"),
        "ln_attn": norm_specs(d),
        "attn": attn_specs(d, cfg.n_heads, cfg.n_kv_heads, cfg.d_head),
        "ln_mlp": norm_specs(d),
        "mlp": mlp_specs(cfg.d_model, cfg.d_ff),
        "out_proj": ParamSpec((d, d), ("embed", "embed_out"), init="scaled"),
    }


def n_groups(cfg) -> int:
    assert cfg.n_layers % cfg.shared_attn_every == 0, \
        (cfg.n_layers, cfg.shared_attn_every)
    return cfg.n_layers // cfg.shared_attn_every


def param_specs(cfg) -> Params:
    per_group = stack_specs(_mamba_specs(cfg), cfg.shared_attn_every)
    return {
        "embed": embed_specs(cfg.vocab_padded, cfg.d_model, tied=True),
        "shared": _shared_block_specs(cfg),
        "groups": stack_specs(per_group, n_groups(cfg)),
        "ln_f": norm_specs(cfg.d_model),
    }


# ---------------------------------------------------------------------------
# mamba2 block
# ---------------------------------------------------------------------------

def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 conv_state: Optional[jax.Array]
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d. x [B,S,C]; w [K,C]; conv_state [B,K-1,C]
    (trailing inputs of the previous call) or None (zeros). Returns
    (y [B,S,C], new_state [B,K-1,C])."""
    bsz, s, ch = x.shape
    k = w.shape[0]
    prev = (jnp.zeros((bsz, k - 1, ch), x.dtype) if conv_state is None
            else conv_state.astype(x.dtype))
    xp = jnp.concatenate([prev, x], axis=1)           # [B, S+K-1, C]
    y = sum(xp[:, i:i + s] * w[i][None, None].astype(x.dtype)
            for i in range(k))
    y = y + b[None, None].astype(x.dtype)
    return y, xp[:, -(k - 1):]


def mamba_block(cfg, p: Params, x: jax.Array, state, ctx) \
        -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """state = (conv [B,K-1,C], ssd [B,H,N,P]) or (None, None)."""
    bsz, s, d = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    hd = cfg.ssm_head_dim
    h = di // hd
    conv_in, ssd_in = state

    hin = rms_norm(x, p["ln"])
    zxbcdt = jnp.einsum("bsd,de->bse", hin, p["in_proj"])
    zxbcdt = constrain(ctx, zxbcdt, "batch", "seq", "ssm_inner")
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    xbc, conv_out = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_in)
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    xs, bmat, cmat = jnp.split(xbc, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None])          # [B,S,H]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))              # [H]
    xh = xs.reshape(bsz, s, h, hd).transpose(0, 2, 1, 3)      # [B,H,S,P]
    xh = constrain(ctx, xh, "batch", "heads", "seq", "state")
    y, ssd_out = mamba2_ssd(xh, dt.transpose(0, 2, 1), a, bmat, cmat,
                            state=ssd_in, use_pallas=cfg.use_pallas)
    y = y + p["d_skip"].astype(jnp.float32)[None, :, None, None] * xh
    y = y.transpose(0, 2, 1, 3).reshape(bsz, s, di)
    y = rms_norm(y, p["norm_w"]) * jax.nn.silu(
        z.astype(jnp.float32)).astype(y.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"]).astype(x.dtype)
    return (x + constrain(ctx, out, "batch", "seq", "embed"),
            (conv_out, ssd_out))


# ---------------------------------------------------------------------------
# shared attention block
# ---------------------------------------------------------------------------

def shared_block(cfg, p: Params, x: jax.Array, x0: jax.Array,
                 positions: jax.Array, kv, index, kv_len, ctx):
    """kv = (ck, cv) one invocation's cache slice (or None for train)."""
    cat = jnp.concatenate([x, x0], axis=-1)
    hin = jnp.einsum("bsc,cd->bsd", cat, p["in_proj"])
    hin = rms_norm(hin, p["ln_attn"])
    q, k, v = attn_qkv(p["attn"], hin, positions,
                       rope_theta=cfg.rope_theta, ctx=ctx)
    if kv is None:
        o = attention(q, k, v, causal=True,
                      use_pallas=cfg.use_pallas or False)
        new_kv = None
    else:
        ck, cv = cache_update(kv[0], kv[1], k, v, index)
        ck = constrain(ctx, ck, "batch", "kv_heads", "kv_seq", "head_dim")
        cv = constrain(ctx, cv, "batch", "kv_heads", "kv_seq", "head_dim")
        o = attention(q, ck, cv, causal=True, kv_len=kv_len,
                      unroll=attn_block_unroll(cfg,
                                               max(1, ck.shape[2] // 1024)),
                      use_pallas=False)
        new_kv = (ck, cv)
    hin = hin + attn_out(p["attn"], o, ctx)
    hin = hin + mlp(p["mlp"], rms_norm(hin, p["ln_mlp"]), ctx)
    out = jnp.einsum("bsd,de->bse", hin, p["out_proj"])
    return x + constrain(ctx, out, "batch", "seq", "embed"), new_kv


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def apply(cfg, params: Params, tokens: jax.Array,
          ctx: Optional[ShardCtx] = None) -> jax.Array:
    x = embed(params["embed"], tokens, ctx)
    x0 = x
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
    x = constrain(ctx, x, "batch", "seq_sp", "embed")

    def group_step(carry, gp):
        x, _ = shared_block(cfg, params["shared"], carry, x0, positions,
                            None, None, None, ctx)

        def mamba_step(c, p):
            y, _ = mamba_block(cfg, p, c, (None, None), ctx)
            return y, None

        x, _ = lax.scan(_remat(cfg, mamba_step), x, gp,
                        unroll=layer_unroll(cfg))
        return x, None

    x, _ = lax.scan(group_step, x, params["groups"],
                    unroll=layer_unroll(cfg))
    x = rms_norm(x, params["ln_f"])
    return unembed(params["embed"], x, ctx)


def cache_specs(cfg, batch: int, max_len: int) -> Params:
    g = n_groups(cfg)
    e = cfg.shared_attn_every
    di, nst = cfg.d_inner, cfg.ssm_state
    h = di // cfg.ssm_head_dim
    conv_ch = di + 2 * nst
    return {
        "conv": ParamSpec((g, e, batch, CONV_K - 1, conv_ch),
                          ("groups", "layers", "batch", None, "ssm_inner"),
                          jnp.bfloat16, "zeros"),
        "ssd": ParamSpec((g, e, batch, h, nst, cfg.ssm_head_dim),
                         ("groups", "layers", "batch", "heads", "state",
                          "state"), jnp.float32, "zeros"),
        "k": ParamSpec((g, batch, cfg.n_kv_heads, max_len, cfg.d_head),
                       ("groups", "batch", "kv_heads", "kv_seq",
                        "head_dim"), jnp.bfloat16, "zeros"),
        "v": ParamSpec((g, batch, cfg.n_kv_heads, max_len, cfg.d_head),
                       ("groups", "batch", "kv_heads", "kv_seq",
                        "head_dim"), jnp.bfloat16, "zeros"),
        "x0": ParamSpec((batch, 1, cfg.d_model), ("batch", None, "embed"),
                        jnp.bfloat16, "zeros"),
        "index": ParamSpec((), (), jnp.int32, "zeros"),
    }


def _run_with_state(cfg, params, tokens, cache, ctx, x0_override=None):
    x = embed(params["embed"], tokens, ctx)
    # Zamba2's concat-skip uses the ORIGINAL embedding; for decode we use
    # the current token's embedding (x0 of this step).
    x0 = x if x0_override is None else x0_override
    index = cache["index"]
    s = tokens.shape[1]
    positions = index + jnp.arange(s, dtype=jnp.int32)[None, :]
    kv_len = index + s

    def group_step(carry, xs):
        x = carry
        gp, conv, ssd, ck, cv = xs
        x, new_kv = shared_block(cfg, params["shared"], x, x0, positions,
                                 (ck, cv), index, kv_len, ctx)

        def mamba_step(c, layer_xs):
            p, cv_in, sd_in = layer_xs
            y, (cv_out, sd_out) = mamba_block(cfg, p, c, (cv_in, sd_in), ctx)
            return y, (cv_out.astype(cv_in.dtype), sd_out)

        x, (conv2, ssd2) = lax.scan(mamba_step, x, (gp, conv, ssd),
                                    unroll=layer_unroll(cfg))
        return x, (conv2, ssd2, new_kv[0], new_kv[1])

    x, (conv2, ssd2, nk, nv) = lax.scan(
        group_step, x,
        (params["groups"], cache["conv"], cache["ssd"], cache["k"],
         cache["v"]), unroll=layer_unroll(cfg))
    x = rms_norm(x, params["ln_f"])
    logits = unembed(params["embed"], x[:, -1:], ctx)
    return logits, {"conv": conv2, "ssd": ssd2, "k": nk, "v": nv,
                    "x0": x0[:, -1:].astype(jnp.bfloat16),
                    "index": index + s}


def prefill(cfg, params, tokens, ctx=None):
    b, s = tokens.shape
    g = n_groups(cfg)
    e = cfg.shared_attn_every
    di, nst = cfg.d_inner, cfg.ssm_state
    h = di // cfg.ssm_head_dim
    zero = {
        "conv": jnp.zeros((g, e, b, CONV_K - 1, di + 2 * nst), jnp.bfloat16),
        "ssd": jnp.zeros((g, e, b, h, nst, cfg.ssm_head_dim), jnp.float32),
        "k": jnp.zeros((g, b, cfg.n_kv_heads, s, cfg.d_head), jnp.bfloat16),
        "v": jnp.zeros((g, b, cfg.n_kv_heads, s, cfg.d_head), jnp.bfloat16),
        "x0": jnp.zeros((b, 1, cfg.d_model), jnp.bfloat16),
        "index": jnp.zeros((), jnp.int32),
    }
    return _run_with_state(cfg, params, tokens, zero, ctx)


def decode_step(cfg, params, cache, tokens, ctx=None):
    return _run_with_state(cfg, params, tokens, cache, ctx)
