"""RWKV6 "Finch" (attention-free, data-dependent decay) — rwkv6-7b.

Block = time-mix (WKV6 recurrence over [H, N, N] states) + channel-mix
(token-shift gated MLP). Both mixes use token-shift (previous-token
lerp); the decay ``w`` is data-dependent via a small LoRA
(the Finch contribution). Recurrent serving state per layer is
(shift_tmix [B,D], shift_cmix [B,D], wkv [B,H,N,N]) — O(1) in sequence
length, which is why this arch runs the ``long_500k`` cell.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import ParamSpec
from repro.kernels.rwkv6 import rwkv6 as wkv6
from .layers import (Params, ShardCtx, constrain, embed, embed_specs,
                     layer_norm, layer_unroll, stack_specs, unembed)


def _use_pallas(cfg) -> Optional[bool]:
    return cfg.use_pallas


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def _ln_specs(d: int) -> Params:
    return {"w": ParamSpec((d,), ("embed",), jnp.float32, "ones"),
            "b": ParamSpec((d,), ("embed",), jnp.float32, "zeros")}


def layer_specs(cfg) -> Params:
    d = cfg.d_model
    n = cfg.ssm_head_dim                    # head size (64)
    h = d // n
    lora = 64
    return {
        "ln1": _ln_specs(d), "ln2": _ln_specs(d),
        "tmix": {
            # token-shift lerp ratios per stream
            "mu_r": ParamSpec((d,), ("embed",), jnp.float32, "zeros"),
            "mu_k": ParamSpec((d,), ("embed",), jnp.float32, "zeros"),
            "mu_v": ParamSpec((d,), ("embed",), jnp.float32, "zeros"),
            "mu_w": ParamSpec((d,), ("embed",), jnp.float32, "zeros"),
            "mu_g": ParamSpec((d,), ("embed",), jnp.float32, "zeros"),
            "w_r": ParamSpec((d, d), ("embed", "heads_flat"), init="scaled"),
            "w_k": ParamSpec((d, d), ("embed", "heads_flat"), init="scaled"),
            "w_v": ParamSpec((d, d), ("embed", "heads_flat"), init="scaled"),
            "w_g": ParamSpec((d, d), ("embed", "heads_flat"), init="scaled"),
            "w_o": ParamSpec((d, d), ("heads_flat", "embed"), init="scaled"),
            # data-dependent decay LoRA (Finch): w = exp(-exp(w0 + B tanh(A x)))
            "decay_a": ParamSpec((d, lora), ("embed", None), init="scaled"),
            "decay_b": ParamSpec((lora, d), (None, "heads_flat"),
                                 init="scaled"),
            "decay_w0": ParamSpec((d,), ("heads_flat",), jnp.float32,
                                  "zeros"),
            "bonus_u": ParamSpec((h, n), ("heads", "state"), jnp.float32,
                                 "zeros"),
            "ln_x_w": ParamSpec((d,), ("heads_flat",), jnp.float32, "ones"),
            "ln_x_b": ParamSpec((d,), ("heads_flat",), jnp.float32, "zeros"),
        },
        "cmix": {
            "mu_k": ParamSpec((d,), ("embed",), jnp.float32, "zeros"),
            "mu_r": ParamSpec((d,), ("embed",), jnp.float32, "zeros"),
            "w_k": ParamSpec((d, cfg.d_ff), ("embed", "ffn"), init="scaled"),
            "w_v": ParamSpec((cfg.d_ff, d), ("ffn", "embed"), init="scaled"),
            "w_r": ParamSpec((d, d), ("embed", "embed_out"), init="scaled"),
        },
    }


def param_specs(cfg) -> Params:
    return {
        "embed": embed_specs(cfg.vocab_padded, cfg.d_model, tied=False),
        "ln_in": _ln_specs(cfg.d_model),
        "layers": stack_specs(layer_specs(cfg), cfg.n_layers),
        "ln_f": _ln_specs(cfg.d_model),
    }


# ---------------------------------------------------------------------------
# block forward
# ---------------------------------------------------------------------------

def _shift(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """Token shift: x[t] -> x[t-1]; position 0 gets ``prev`` (or zeros)."""
    shifted = jnp.roll(x, 1, axis=1)
    first = (jnp.zeros_like(x[:, :1]) if prev is None
             else prev[:, None].astype(x.dtype))
    return shifted.at[:, :1].set(first)


def _lerp(x, xx, mu):
    return x + (xx - x) * mu.astype(x.dtype)


def time_mix(cfg, p: Params, x: jax.Array, shift_state, wkv_state,
             ctx: Optional[ShardCtx]
             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    b, s, d = x.shape
    n = cfg.ssm_head_dim
    h = d // n
    xx = _shift(x, shift_state)
    xr, xk, xv, xw, xg = (_lerp(x, xx, p[f"mu_{c}"]) for c in "rkvwg")
    r = jnp.einsum("bsd,de->bse", xr, p["w_r"])
    k = jnp.einsum("bsd,de->bse", xk, p["w_k"])
    v = jnp.einsum("bsd,de->bse", xv, p["w_v"])
    g = jnp.einsum("bsd,de->bse", xg, p["w_g"])
    # Finch data-dependent decay
    dd = jnp.einsum("bsl,le->bse",
                    jnp.tanh(jnp.einsum("bsd,dl->bsl", xw, p["decay_a"])),
                    p["decay_b"])
    w = jnp.exp(-jnp.exp(
        (p["decay_w0"].astype(jnp.float32) + dd.astype(jnp.float32))
        .clip(-10.0, 5.0)))

    def heads(t):
        return constrain(ctx, t.reshape(b, s, h, n).transpose(0, 2, 1, 3),
                         "batch", "heads", "seq", "state")

    y, wkv_out = wkv6(heads(r), heads(k), heads(v),
                      heads(w.astype(x.dtype)), p["bonus_u"],
                      state=wkv_state, use_pallas=_use_pallas(cfg))
    y = y.transpose(0, 2, 1, 3).reshape(b, s, d)
    y = layer_norm(y, p["ln_x_w"], p["ln_x_b"])   # per-token group norm
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(y.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["w_o"])
    return constrain(ctx, out, "batch", "seq", "embed"), x[:, -1], wkv_out


def channel_mix(cfg, p: Params, x: jax.Array, shift_state,
                ctx: Optional[ShardCtx]) -> Tuple[jax.Array, jax.Array]:
    xx = _shift(x, shift_state)
    xk = _lerp(x, xx, p["mu_k"])
    xr = _lerp(x, xx, p["mu_r"])
    k = jnp.einsum("bsd,df->bsf", xk, p["w_k"])
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    k = constrain(ctx, k, "batch", "seq", "ffn")
    v = jnp.einsum("bsf,fd->bsd", k, p["w_v"])
    r = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", xr, p["w_r"]).astype(jnp.float32))
    return (v * r.astype(v.dtype)), x[:, -1]


def block_fwd(cfg, p: Params, x, state, ctx):
    """state = None (train) or (shift_t [B,D], shift_c [B,D], wkv [B,H,N,N])."""
    st, sc, wkv_in = state if state is not None else (None, None, None)
    y, st_out, wkv_out = time_mix(cfg, p["tmix"],
                                  layer_norm(x, p["ln1"]["w"], p["ln1"]["b"]),
                                  st, wkv_in, ctx)
    x = x + y
    y, sc_out = channel_mix(cfg, p["cmix"],
                            layer_norm(x, p["ln2"]["w"], p["ln2"]["b"]),
                            sc, ctx)
    x = x + y
    x = constrain(ctx, x, "batch", "seq_sp", "embed")
    return x, (st_out, sc_out, wkv_out)


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def apply(cfg, params: Params, tokens: jax.Array,
          ctx: Optional[ShardCtx] = None) -> jax.Array:
    x = embed(params["embed"], tokens, ctx)
    x = layer_norm(x, params["ln_in"]["w"], params["ln_in"]["b"])
    x = constrain(ctx, x, "batch", "seq_sp", "embed")

    def step(carry, p):
        y, _ = block_fwd(cfg, p, carry, None, ctx)
        return y, None

    x, _ = lax.scan(_remat(cfg, step), x, params["layers"],
                    unroll=layer_unroll(cfg))
    x = layer_norm(x, params["ln_f"]["w"], params["ln_f"]["b"])
    return unembed(params["embed"], x, ctx)


def cache_specs(cfg, batch: int, max_len: int) -> Params:
    d = cfg.d_model
    n = cfg.ssm_head_dim
    h = d // n
    L = cfg.n_layers
    return {
        "shift_t": ParamSpec((L, batch, d), ("layers", "batch", "embed"),
                             jnp.bfloat16, "zeros"),
        "shift_c": ParamSpec((L, batch, d), ("layers", "batch", "embed"),
                             jnp.bfloat16, "zeros"),
        "wkv": ParamSpec((L, batch, h, n, n),
                         ("layers", "batch", "heads", "state", "state"),
                         jnp.float32, "zeros"),
        "index": ParamSpec((), (), jnp.int32, "zeros"),
    }


def _run_with_state(cfg, params, tokens, cache, ctx):
    x = embed(params["embed"], tokens, ctx)
    x = layer_norm(x, params["ln_in"]["w"], params["ln_in"]["b"])

    def step(carry, xs):
        p, st, sc, wkv = xs
        y, (st2, sc2, wkv2) = block_fwd(cfg, p, carry, (st, sc, wkv), ctx)
        return y, (st2, sc2, wkv2)

    x, (st, sc, wkv) = lax.scan(
        step, x, (params["layers"], cache["shift_t"], cache["shift_c"],
                  cache["wkv"]), unroll=layer_unroll(cfg))
    x = layer_norm(x, params["ln_f"]["w"], params["ln_f"]["b"])
    logits = unembed(params["embed"], x[:, -1:], ctx)
    new_cache = {"shift_t": st.astype(cache["shift_t"].dtype),
                 "shift_c": sc.astype(cache["shift_c"].dtype),
                 "wkv": wkv,
                 "index": cache["index"] + tokens.shape[1]}
    return logits, new_cache


def prefill(cfg, params, tokens, ctx=None):
    b = tokens.shape[0]
    zero = {
        "shift_t": jnp.zeros((cfg.n_layers, b, cfg.d_model), jnp.bfloat16),
        "shift_c": jnp.zeros((cfg.n_layers, b, cfg.d_model), jnp.bfloat16),
        "wkv": jnp.zeros((cfg.n_layers, b, cfg.d_model // cfg.ssm_head_dim,
                          cfg.ssm_head_dim, cfg.ssm_head_dim), jnp.float32),
        "index": jnp.zeros((), jnp.int32),
    }
    return _run_with_state(cfg, params, tokens, zero, ctx)


def decode_step(cfg, params, cache, tokens, ctx=None):
    return _run_with_state(cfg, params, tokens, cache, ctx)
