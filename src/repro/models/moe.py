"""Mixture-of-experts family (olmoe 64e top-8, kimi-k2 384e top-8).

Token dispatch is **sort-based**, not one-hot-einsum based: the (token,
expert) assignment is materialized as integer gather/scatter indices so HLO
cost analysis sees only the *real* expert FLOPs (a one-hot dispatch einsum
would add a fake 2·T·E·C·D matmul that dwarfs the expert compute — the
same "blind duplicate generation" failure mode the paper attributes to
naive RDFizers, here in FLOP form).

Experts are sharded over the ``model`` axis (24 experts/shard for kimi);
under FSDP the per-expert ffn dim is additionally sharded over ``data``.
The capacity-based buffer [E, C, D] bounds per-expert work; dropped tokens
(over capacity) fall out of the scatter exactly like the relational
``compact`` drops overflow rows.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import shard_map
from repro.distributed.sharding import ParamSpec
from .layers import (Params, ShardCtx, attn_block_unroll, constrain, embed,
                     embed_specs, layer_unroll, mlp, mlp_specs, norm_specs,
                     rms_norm, round_up, stack_specs, unembed)
from . import transformer as tf


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def moe_mlp_specs(cfg) -> Params:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    s: Params = {
        "router": ParamSpec((d, e), ("embed", "expert"), jnp.float32,
                            "scaled"),
        "w_gate": ParamSpec((e, d, f), ("expert", "embed", "expert_ffn"),
                            init="scaled"),
        "w_up": ParamSpec((e, d, f), ("expert", "embed", "expert_ffn"),
                          init="scaled"),
        "w_down": ParamSpec((e, f, d), ("expert", "expert_ffn", "embed"),
                            init="scaled"),
    }
    if cfg.n_shared_experts:
        s["shared"] = mlp_specs(cfg.d_model,
                                cfg.d_ff * cfg.n_shared_experts)
    return s


def layer_specs(cfg) -> Params:
    base = tf.layer_specs(cfg)
    base["moe"] = moe_mlp_specs(cfg)
    del base["mlp"]
    return base


def param_specs(cfg) -> Params:
    return {
        "embed": embed_specs(cfg.vocab_padded, cfg.d_model,
                             tied=cfg.tied_embeddings),
        "layers": stack_specs(layer_specs(cfg), cfg.n_layers),
        "ln_f": norm_specs(cfg.d_model),
    }


def capacity(cfg, n_tokens: int) -> int:
    per = n_tokens * cfg.top_k / cfg.n_experts
    return max(8, round_up(int(per * cfg.capacity_factor), 8))


# ---------------------------------------------------------------------------
# sort-based dispatch MoE block
# ---------------------------------------------------------------------------

def _route_and_sort(cfg, router: jax.Array, xl: jax.Array, cap: int):
    """Shared routing math: xl [t,d] -> (dest, tok_sorted, w_sorted).
    dest[i] = slot in the flat [E*cap] buffer for the i-th sorted
    (token, expert) pair, or the E*cap sentinel when over capacity."""
    t, d = xl.shape
    e, k = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("td,de->te", xl.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, sel = lax.top_k(probs, k)                       # [t,k]
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    e_flat = sel.reshape(t * k).astype(jnp.int32)
    tok_of = (jnp.arange(t * k, dtype=jnp.int32) // k)
    e_sorted, order = lax.sort((e_flat, jnp.arange(t * k, dtype=jnp.int32)),
                               num_keys=1)
    tok_sorted = tok_of[order]
    run_start = jnp.searchsorted(e_sorted, e_sorted, side="left")
    pos = (jnp.arange(t * k, dtype=jnp.int32) - run_start.astype(jnp.int32))
    keep = pos < cap
    dest = jnp.where(keep, e_sorted * cap + pos, e * cap)
    w_sorted = jnp.where(keep, weights.reshape(t * k)[order], 0.0)
    return dest, tok_sorted, w_sorted


def _batch_mesh_axes(ctx: Optional[ShardCtx]):
    """Mesh axes the `batch` logical axis maps to (tuple), or ()."""
    if ctx is None:
        return ()
    spec = ctx.rules.spec_for(("batch",))
    if not len(spec) or spec[0] is None:
        return ()
    ax = spec[0]
    return (ax,) if isinstance(ax, str) else tuple(ax)


def _expert_sharded_over_model(ctx: Optional[ShardCtx]) -> bool:
    if ctx is None or "model" not in ctx.mesh.shape:
        return False
    spec = ctx.rules.spec_for(("expert",))
    return len(spec) > 0 and spec[0] == "model"


def _n_batch_shards(ctx: Optional[ShardCtx]) -> int:
    n = 1
    for a in _batch_mesh_axes(ctx):
        n *= ctx.mesh.shape[a]
    return n


def moe_block_local(cfg, p: Params, x: jax.Array, ctx: ShardCtx
                    ) -> jax.Array:
    """shard_map MoE: the dispatch sort never leaves the data shard, the
    expert matmuls are (data x model)-sharded with no resharding, and the
    combine is a masked scatter-add + ONE f32 psum over `model` per layer
    — the same wire cost as a dense tensor-parallel MLP."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    mesh = ctx.mesh
    dn = _batch_mesh_axes(ctx)
    n_shards = 1
    for a in dn:
        n_shards *= mesh.shape[a]
    t_local = t // n_shards
    cap = capacity(cfg, t_local)
    xf = x.reshape(t, d)
    from jax.sharding import PartitionSpec as P

    e_shards = mesh.shape["model"]
    e_local = e // e_shards

    def dispatch(xl, router):
        # routing math is replicated across `model`; the scatter builds
        # ONLY this rank's expert slice, so no [E,C,D] replicated buffer
        # (and no all-gather in its backward) ever exists.
        xl = xl.reshape(t_local, d)
        dest, tok_sorted, w_sorted = _route_and_sort(cfg, router, xl, cap)
        e0 = lax.axis_index("model") * e_local
        local = dest - e0 * cap
        oob = jnp.where((local >= 0) & (local < e_local * cap), local,
                        e_local * cap)
        buf = jnp.zeros((e_local * cap + 1, d), x.dtype).at[oob].set(
            xl[tok_sorted], mode="drop")[:e_local * cap]
        return (buf.reshape(1, e_local, cap, d), dest[None],
                tok_sorted[None], w_sorted[None])

    buf, dest, tok, ws = shard_map(
        dispatch, mesh=mesh, axis_names=set(dn) | {"model"},
        in_specs=(P(dn, None), P(None, None)),
        out_specs=(P(dn, "model", None, None), P(dn, None), P(dn, None),
                   P(dn, None)), check_vma=False)(xf, p["router"])

    # expert compute: [x(e data),e(model),c,d] x [e(model),d,f] — no comm
    buf = constrain(ctx, buf, "batch", "expert", None, "embed")
    gate = jnp.einsum("xecd,edf->xecf", buf, p["w_gate"])
    up = jnp.einsum("xecd,edf->xecf", buf, p["w_up"])
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    h = constrain(ctx, h, "batch", "expert", None, "expert_ffn")
    out_buf = jnp.einsum("xecf,efd->xecd", h, p["w_down"])
    out_buf = constrain(ctx, out_buf, "batch", "expert", None, "embed")

    def combine(bufo, dest, tok, ws):
        # bufo [1, e_local, cap, d]; dest/tok/ws [1, t_local*k]
        rank = lax.axis_index("model")
        e0 = rank * e_local
        dest, tok, ws = dest[0], tok[0], ws[0]
        expert_of = dest // cap
        mine = (expert_of >= e0) & (expert_of < e0 + e_local) & \
            (dest < e * cap)
        flat = bufo.reshape(e_local * cap, d)
        li = jnp.where(mine, (expert_of - e0) * cap + dest % cap, 0)
        contrib = (flat[li].astype(jnp.float32)
                   * jnp.where(mine, ws, 0.0)[:, None])
        out = jnp.zeros((t_local, d), jnp.float32).at[tok].add(contrib)
        # local accumulation in f32; the cross-rank sum rides the wire in
        # bf16 (each token has at most top_k contributions, so the bf16
        # partial-sum error is one rounding step — same as the baseline's
        # bf16 scatter-add, at half the collective bytes)
        return lax.psum(out.astype(jnp.bfloat16), "model")[None]

    out = shard_map(
        combine, mesh=mesh, axis_names=set(dn) | {"model"},
        in_specs=(P(dn, "model", None, None), P(dn, None), P(dn, None),
                  P(dn, None)),
        out_specs=P(dn, None), check_vma=False)(out_buf, dest, tok, ws)
    out = out.reshape(t, d).astype(x.dtype)

    if "shared" in p:
        out = out + mlp(p["shared"], xf[None], ctx)[0]
    out = out.reshape(b, s, d)
    return constrain(ctx, out, "batch", "seq", "embed")


def moe_block(cfg, p: Params, x: jax.Array,
              ctx: Optional[ShardCtx] = None) -> jax.Array:
    """x [B,S,D] -> [B,S,D]; top-k routing, capacity C per expert."""
    if (cfg.moe_impl == "local" and ctx is not None
            and _expert_sharded_over_model(ctx)
            and (x.shape[0] * x.shape[1])
            % max(1, _n_batch_shards(ctx)) == 0):
        return moe_block_local(cfg, p, x, ctx)
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    cap = capacity(cfg, t)
    xf = x.reshape(t, d)

    # --- routing ----------------------------------------------------------
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    weights, sel = lax.top_k(probs, k)                       # [t,k]
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    # --- sort (token,expert) pairs by expert ------------------------------
    e_flat = sel.reshape(t * k).astype(jnp.int32)
    tok_of = (jnp.arange(t * k, dtype=jnp.int32) // k)
    e_sorted, order = lax.sort((e_flat, jnp.arange(t * k, dtype=jnp.int32)),
                               num_keys=1)
    tok_sorted = tok_of[order]
    # position within the expert's run = rank - start-of-run
    run_start = jnp.searchsorted(e_sorted, e_sorted, side="left")
    pos = (jnp.arange(t * k, dtype=jnp.int32)
           - run_start.astype(jnp.int32))
    keep = pos < cap
    dest = jnp.where(keep, e_sorted * cap + pos, e * cap)    # overflow drops

    # --- gather tokens into the [E,C,D] buffer ----------------------------
    buf = jnp.zeros((e * cap, d), x.dtype).at[dest].set(
        xf[tok_sorted], mode="drop")
    buf = constrain(ctx, buf.reshape(e, cap, d), "expert", None, "embed")

    # --- expert compute (real FLOPs only) ---------------------------------
    gate = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    h = constrain(ctx, h, "expert", None, "expert_ffn")
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(e * cap, d)

    # --- combine: weighted scatter back to tokens -------------------------
    w_sorted = weights.reshape(t * k)[order]
    contrib = out_buf[jnp.minimum(dest, e * cap - 1)] * \
        jnp.where(keep, w_sorted, 0.0)[:, None].astype(x.dtype)
    out = jnp.zeros((t, d), x.dtype).at[tok_sorted].add(contrib)

    if "shared" in p:
        out = out + mlp(p["shared"], xf[None])[0]
    out = out.reshape(b, s, d)
    return constrain(ctx, out, "batch", "seq", "embed")


def aux_load_loss(cfg, p: Params, x: jax.Array) -> jax.Array:
    """Switch-style load-balance penalty (used by the training loss)."""
    b, s, d = x.shape
    logits = jnp.einsum("td,de->te", x.reshape(b * s, d).astype(jnp.float32),
                        p["router"])
    probs = jax.nn.softmax(logits, -1)
    _, sel = lax.top_k(probs, cfg.top_k)
    frac = jnp.zeros((cfg.n_experts,), jnp.float32).at[sel.reshape(-1)].add(
        1.0) / (b * s * cfg.top_k)
    imp = probs.mean(0)
    return cfg.n_experts * jnp.sum(frac * imp)


# ---------------------------------------------------------------------------
# model entry points (dense attention + MoE mlp)
# ---------------------------------------------------------------------------

def _layer_fwd(cfg, p, x, positions, window, ctx):
    h = rms_norm(x, p["ln_attn"])
    q, kk, v = tf.attn_qkv(p["attn"], h, positions,
                           rope_theta=cfg.rope_theta, ctx=ctx)
    o = tf.attention(q, kk, v, causal=True, window=window,
                     use_pallas=tf._use_pallas(cfg))
    x = x + tf.attn_out(p["attn"], o, ctx)
    h = rms_norm(x, p["ln_mlp"])
    x = x + moe_block(cfg, p["moe"], h, ctx)
    return constrain(ctx, x, "batch", "seq_sp", "embed")


def apply(cfg, params: Params, tokens: jax.Array,
          ctx: Optional[ShardCtx] = None) -> jax.Array:
    x = embed(params["embed"], tokens, ctx)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
    x = constrain(ctx, x, "batch", "seq_sp", "embed")

    def body(x, p, w):
        return _layer_fwd(cfg, p, x, positions, w, ctx)

    x = tf.scan_layers(cfg, params["layers"], x, body)
    x = rms_norm(x, params["ln_f"])
    return unembed(params["embed"], x, ctx)


cache_specs = tf.cache_specs


def _decode_layer(cfg, p, ck, cv, x, positions, index, kv_len, window, ctx):
    h = rms_norm(x, p["ln_attn"])
    q, kk, v = tf.attn_qkv(p["attn"], h, positions,
                           rope_theta=cfg.rope_theta, ctx=ctx)
    ck, cv = tf.cache_update(ck, cv, kk, v, index)
    ck = constrain(ctx, ck, "batch", "kv_heads", "kv_seq", "head_dim")
    cv = constrain(ctx, cv, "batch", "kv_heads", "kv_seq", "head_dim")
    o = tf.attention(q, ck, cv, causal=True, window=window, kv_len=kv_len,
                     use_pallas=False,
                     unroll=attn_block_unroll(cfg,
                                              max(1, ck.shape[2] // 1024)))
    x = x + tf.attn_out(p["attn"], o, ctx)
    h = rms_norm(x, p["ln_mlp"])
    x = x + moe_block(cfg, p["moe"], h, ctx)
    return constrain(ctx, x, "batch", "seq", "embed"), ck, cv


def _scan_decode(cfg, params, cache, x, positions, index, kv_len, ctx):
    windows = tf.layer_windows(cfg)

    def step(carry, xs):
        p, ck, cv, w = xs
        y, ck, cv = _decode_layer(cfg, p, ck, cv, carry, positions, index,
                                  kv_len, w, ctx)
        return y, (ck, cv)

    x, (nk, nv) = lax.scan(
        step, x, (params["layers"], cache["k"], cache["v"], windows),
        unroll=layer_unroll(cfg))
    return x, nk, nv


def prefill(cfg, params, tokens, ctx=None):
    x = embed(params["embed"], tokens, ctx)
    b, s = x.shape[:2]
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    x = constrain(ctx, x, "batch", "seq_sp", "embed")
    cache = {"k": jnp.zeros((cfg.n_layers, b, cfg.n_kv_heads, s, cfg.d_head),
                            jnp.bfloat16),
             "v": jnp.zeros((cfg.n_layers, b, cfg.n_kv_heads, s, cfg.d_head),
                            jnp.bfloat16),
             "index": jnp.zeros((), jnp.int32)}
    x, nk, nv = _scan_decode(cfg, params, cache, x, positions,
                             jnp.zeros((), jnp.int32), s, ctx)
    x = rms_norm(x[:, -1:], params["ln_f"])
    return unembed(params["embed"], x, ctx), {
        "k": nk, "v": nv, "index": jnp.full((), s, jnp.int32)}


def decode_step(cfg, params, cache, tokens, ctx=None):
    index = cache["index"]
    positions = jnp.full(tokens.shape, index, jnp.int32)
    x = embed(params["embed"], tokens, ctx)
    x, nk, nv = _scan_decode(cfg, params, cache, x, positions, index,
                             index + tokens.shape[1], ctx)
    x = rms_norm(x, params["ln_f"])
    return unembed(params["embed"], x, ctx), {
        "k": nk, "v": nv, "index": index + tokens.shape[1]}
