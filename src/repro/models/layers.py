"""Shared building blocks for the model zoo.

Everything is functional: a layer is ``(param_specs builder, forward fn)``;
parameters travel as plain dict pytrees so they flow through
``jax.eval_shape`` (dry-run), ``jax.jit`` donation, and checkpointing
without a module system.

Sharding is *logical*: model code annotates activations via
:class:`ShardCtx` (mesh + AxisRules); with ``ctx=None`` (CPU smoke tests)
the constraints are no-ops.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding

from repro.distributed.sharding import AxisRules, ParamSpec

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# sharding context
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Threaded through forward passes to place activation constraints."""

    mesh: Mesh
    rules: AxisRules

    def constrain(self, x: jax.Array, *logical: Optional[str]) -> jax.Array:
        spec = self.rules.spec_for(tuple(logical))
        return lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


def constrain(ctx: Optional[ShardCtx], x: jax.Array,
              *logical: Optional[str]) -> jax.Array:
    return x if ctx is None else ctx.constrain(x, *logical)


def layer_unroll(cfg):
    """lax.scan ``unroll`` argument for scans over layers: fully unrolled
    when the config asks for it (dry-run cost fidelity / overlap), else a
    rolled while loop (O(1) HLO)."""
    return True if getattr(cfg, "unroll_layers", False) else 1


def attn_block_unroll(cfg, n_blocks: int) -> int:
    """Partial-unroll factor for the blockwise-attention kv scan; capped so
    long-context decode (512 blocks) cannot explode the HLO."""
    if not getattr(cfg, "unroll_layers", False):
        return 1
    cap = 32
    u = min(n_blocks, cap)
    while n_blocks % u:
        u -= 1
    return max(u, 1)


# ---------------------------------------------------------------------------
# spec helpers
# ---------------------------------------------------------------------------

def stack_specs(specs, n: int):
    """Prepend a scan-stacked ``layers`` dim to every ParamSpec in a tree."""
    return jax.tree_util.tree_map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.logical_axes,
                            s.dtype, s.init, s.init_scale),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def dense_spec(d_in: int, d_out: int, ax_in: str, ax_out: str,
               dtype=jnp.bfloat16) -> ParamSpec:
    return ParamSpec((d_in, d_out), (ax_in, ax_out), dtype, "scaled")


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + eps)
    return (out * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def norm_specs(d: int) -> ParamSpec:
    # rms_norm weight stored as offset-from-1 (init zeros)
    return ParamSpec((d,), ("embed",), jnp.float32, "zeros")


# ---------------------------------------------------------------------------
# rotary embedding
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: [..., S, n_heads, d_head]; positions: [..., S] (broadcastable)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                               # [d/2]
    ang = positions[..., None].astype(jnp.float32) * freqs     # [..., S, d/2]
    sin = jnp.sin(ang)[..., None, :]                           # [..., S, 1, d/2]
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    inv = jnp.exp(-jnp.log(10000.0) * jnp.arange(0, d, 2, jnp.float32) / d)
    ang = pos * inv[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# attention (blockwise jnp path; Pallas kernel on TPU)
# ---------------------------------------------------------------------------

MASK_VALUE = -1e30


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[jax.Array | int] = None,
                        kv_len: Optional[jax.Array | int] = None,
                        scale: Optional[float] = None,
                        block_k: int = 1024, unroll: int = 1) -> jax.Array:
    """Online-softmax attention scanning kv blocks (the flash ref in pure
    jnp — O(S·block) live memory, so 32k/500k prefill lowers without an
    S x S buffer). ``window`` may be a traced scalar (0/None => full);
    that is what lets gemma3's 5:1 local:global pattern live in ONE scan
    over layers.

    q [B,H,Sq,D]; k/v [B,KH,Sk,D]; Sk % block_k == 0 (caller pads).
    """
    b, h, s_q, d = q.shape
    _, kh, s_k, _ = k.shape
    assert h % kh == 0, (h, kh)
    group = h // kh
    scale = (d ** -0.5) if scale is None else scale
    kv_len = jnp.minimum(s_k, s_k if kv_len is None else kv_len)
    window = 0 if window is None else window
    q_off = kv_len - s_q  # q rows sit at the END of the kv timeline
    if s_k % block_k:     # pad kv to a block multiple; kv_len masks the tail
        pad = block_k - s_k % block_k
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        s_k += pad

    qf = (q.astype(jnp.float32) * scale).reshape(b, kh, group * s_q, d)
    n_blocks = s_k // block_k
    kb = jnp.moveaxis(k.reshape(b, kh, n_blocks, block_k, d), 2, 0)
    vb = jnp.moveaxis(v.reshape(b, kh, n_blocks, block_k, d), 2, 0)

    q_pos = q_off + jnp.arange(s_q, dtype=jnp.int32)
    win = jnp.asarray(window, jnp.int32)

    def step(carry, blk):
        m_prev, l_prev, acc = carry
        kc, vc, start = blk
        s = jnp.einsum("bgqd,bgkd->bgqk", qf, kc.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
        k_pos = start + jnp.arange(block_k, dtype=jnp.int32)
        qp = jnp.tile(q_pos, group)[:, None]
        mask = k_pos[None, :] < kv_len
        if causal:
            mask &= qp >= k_pos[None, :]
        mask &= (win <= 0) | ((qp - k_pos[None, :]) < win)
        s = jnp.where(mask[None, None], s, MASK_VALUE)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + p.sum(axis=-1)
        pv = jnp.einsum("bgqk,bgkd->bgqd", p, vc.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + pv
        return (m_new, l_new, acc), None

    starts = jnp.arange(n_blocks, dtype=jnp.int32) * block_k
    init = (jnp.full((b, kh, group * s_q), MASK_VALUE),
            jnp.zeros((b, kh, group * s_q)),
            jnp.zeros((b, kh, group * s_q, d)))
    # checkpoint the block body: without it, scan-AD stacks every block's
    # f32 scores [B,H,Sq,block_k] for the backward — O(S_k·S_q) memory,
    # the exact thing flash attention exists to avoid (one whisper layer:
    # 20 GiB). With it, backward recomputes scores per block from the
    # saved (kc, vc, carry) — the jnp path becomes memory-flash.
    (m, l, acc), _ = lax.scan(jax.checkpoint(step), init,
                              (kb, vb, starts), unroll=unroll)
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l[..., None]).reshape(b, h, s_q, d)
    return out.astype(q.dtype)


def banded_local_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           window: int, block: int = 1024) -> jax.Array:
    """Sliding-window causal attention that only COMPUTES the band.

    The generic blockwise path must execute every kv block and mask,
    because the window may be traced (gemma3's 5:1 pattern lives in one
    scan). When the window is STATIC (the period-structured scan below),
    each q block attends exactly its own + the previous kv block
    (requires ``window <= block``): S·2·block work instead of S·S — 16×
    less attention compute at 32k. Scanned over q blocks with a
    checkpointed body, so backward memory is one band of scores.

    q/k/v: [B, H|KH, S, D], S % block == 0, full self-attention shapes.
    """
    b, h, s, d = q.shape
    _, kh, _, _ = k.shape
    group = h // kh
    assert s % block == 0 and 0 < window <= block, (s, block, window)
    nb = s // block
    scale = d ** -0.5

    qb = (q.astype(jnp.float32) * scale).reshape(b, kh, group, nb, block, d)
    qb = jnp.moveaxis(qb, 3, 0)                       # [nb,B,KH,G,block,D]
    kb = k.reshape(b, kh, nb, block, d)
    vb = v.reshape(b, kh, nb, block, d)
    zero = jnp.zeros_like(kb[:, :, :1])
    k_band = jnp.concatenate([
        jnp.concatenate([zero, kb[:, :, :-1]], axis=2), kb], axis=3)
    v_band = jnp.concatenate([
        jnp.concatenate([zero, vb[:, :, :-1]], axis=2), vb], axis=3)
    k_band = jnp.moveaxis(k_band, 2, 0)               # [nb,B,KH,2block,D]
    v_band = jnp.moveaxis(v_band, 2, 0)

    q_pos = jnp.arange(block, dtype=jnp.int32)
    k_pos = jnp.arange(2 * block, dtype=jnp.int32) - block

    def body(carry, xs):
        qi, ki, vi, i = xs
        sc = jnp.einsum("bkgqd,bksd->bkgqs", qi, ki.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        qp = q_pos[:, None]
        kp = k_pos[None, :]
        mask = (qp >= kp) & (qp - kp < window) & \
            ((kp >= 0) | (i > 0))                     # block -1 pad rows
        sc = jnp.where(mask[None, None, None], sc, MASK_VALUE)
        p = jax.nn.softmax(sc, axis=-1)
        p = jnp.where(mask[None, None, None], p, 0.0)
        y = jnp.einsum("bkgqs,bksd->bkgqd", p, vi,
                       preferred_element_type=jnp.float32)
        return carry, y

    _, ys = lax.scan(jax.checkpoint(body), (),
                     (qb, k_band, v_band,
                      jnp.arange(nb, dtype=jnp.int32)))
    out = jnp.moveaxis(ys, 0, 3)                      # [B,KH,G,nb,block,D]
    return out.reshape(b, h, s, d).astype(q.dtype)


def dense_decode_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           window=None, kv_len=None,
                           scale: Optional[float] = None) -> jax.Array:
    """Decode-shape attention (s_q small): ONE masked einsum over the full
    kv timeline instead of a scan of kv-block dynamic-slices. With the
    cache's seq dim sharded over `model`, GSPMD lowers the softmax to
    partial max/sum + an all-reduce of [B,H,s_q] stats and the PV product
    to a partial sum + one [B,H,s_q,D] all-reduce — no per-block
    dynamic_slice across shards (which forces involuntary full
    rematerialization in the SPMD partitioner)."""
    b, h, s_q, d = q.shape
    _, kh, s_k, _ = k.shape
    group = h // kh
    scale = (d ** -0.5) if scale is None else scale
    kv_len = s_k if kv_len is None else kv_len
    qf = (q.astype(jnp.float32) * scale).reshape(b, kh, group * s_q, d)
    # k/v stay bf16 on the wire; the MXU accumulates in f32 (an explicit
    # .astype would materialize a second full-cache-sized f32 copy)
    s = jnp.einsum("bgqd,bgkd->bgqk", qf, k,
                   preferred_element_type=jnp.float32)
    k_pos = jnp.arange(s_k, dtype=jnp.int32)
    q_pos = kv_len - s_q + jnp.arange(s_q, dtype=jnp.int32)
    qp = jnp.tile(q_pos, group)[:, None]
    mask = (k_pos[None, :] < kv_len) & (qp >= k_pos[None, :])
    if window is not None:
        win = jnp.asarray(window, jnp.int32)
        mask &= (win <= 0) | ((qp - k_pos[None, :]) < win)
    s = jnp.where(mask[None, None], s, MASK_VALUE)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[None, None], p, 0.0)
    out = jnp.einsum("bgqk,bgkd->bgqd", p, v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, h, s_q, d).astype(q.dtype)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window=None, kv_len=None,
              scale: Optional[float] = None, use_pallas: bool = False,
              block_k: int = 1024, unroll: int = 1) -> jax.Array:
    """Model-facing attention: Pallas flash kernel on TPU (static window
    only), dense one-einsum path for decode shapes, blockwise jnp
    otherwise."""
    if q.shape[2] <= 8 and causal and k.shape[2] > q.shape[2]:
        return dense_decode_attention(q, k, v, window=window, kv_len=kv_len,
                                      scale=scale)
    if use_pallas and isinstance(window, (int, type(None))):
        from repro.kernels.flash_attention import flash_attention
        return flash_attention(q, k, v, causal=causal, window=window,
                               scale=scale, kv_len=kv_len)
    bk = min(block_k, k.shape[2])
    return blockwise_attention(q, k, v, causal=causal, window=window,
                               kv_len=kv_len, scale=scale, block_k=bk,
                               unroll=unroll)


# ---------------------------------------------------------------------------
# attention block (params + forward, GQA + qk_norm + rope + cache)
# ---------------------------------------------------------------------------

def attn_specs(d_model: int, n_heads: int, n_kv_heads: int, d_head: int,
               qk_norm: bool = False) -> Params:
    s: Params = {
        "wq": ParamSpec((d_model, n_heads, d_head),
                        ("embed", "heads", "head_dim"), init="scaled"),
        "wk": ParamSpec((d_model, n_kv_heads, d_head),
                        ("embed", "kv_heads", "head_dim"), init="scaled"),
        "wv": ParamSpec((d_model, n_kv_heads, d_head),
                        ("embed", "kv_heads", "head_dim"), init="scaled"),
        "wo": ParamSpec((n_heads, d_head, d_model),
                        ("heads", "head_dim", "embed"), init="scaled"),
    }
    if qk_norm:
        s["q_norm"] = ParamSpec((d_head,), ("head_dim",), jnp.float32, "zeros")
        s["k_norm"] = ParamSpec((d_head,), ("head_dim",), jnp.float32, "zeros")
    return s


def attn_qkv(p: Params, x: jax.Array, positions: jax.Array, *,
             rope_theta: float = 10000.0, use_rope: bool = True,
             ctx: Optional[ShardCtx] = None
             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x [B,S,D] -> q [B,H,S,Dh], k/v [B,KH,S,Dh] (rope + qk_norm applied)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = constrain(ctx, q, "batch", "seq", "heads", "head_dim")
    k = constrain(ctx, k, "batch", "seq", "kv_heads", "head_dim")
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return (jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
            jnp.moveaxis(v, 2, 1))


def attn_out(p: Params, o: jax.Array,
             ctx: Optional[ShardCtx] = None) -> jax.Array:
    """o [B,H,S,Dh] -> [B,S,D]."""
    out = jnp.einsum("bhsk,hkd->bsd", o, p["wo"])
    return constrain(ctx, out, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------

def mlp_specs(d_model: int, d_ff: int, gated: bool = True) -> Params:
    s: Params = {
        "w_up": ParamSpec((d_model, d_ff), ("embed", "ffn"), init="scaled"),
        "w_down": ParamSpec((d_ff, d_model), ("ffn", "embed"), init="scaled"),
    }
    if gated:
        s["w_gate"] = ParamSpec((d_model, d_ff), ("embed", "ffn"),
                                init="scaled")
    return s


def mlp(p: Params, x: jax.Array, ctx: Optional[ShardCtx] = None,
        act=jax.nn.silu) -> jax.Array:
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    if "w_gate" in p:
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = act(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:
        h = act(up.astype(jnp.float32)).astype(x.dtype)
    h = constrain(ctx, h, "batch", "seq", "ffn")
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    return constrain(ctx, out, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# embedding / unembedding / loss
# ---------------------------------------------------------------------------

def round_up(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def embed_specs(vocab_padded: int, d_model: int,
                tied: bool = True) -> Params:
    s: Params = {"embedding": ParamSpec((vocab_padded, d_model),
                                        ("vocab", "embed"), init="normal")}
    if not tied:
        s["unembed"] = ParamSpec((d_model, vocab_padded),
                                 ("embed", "vocab"), init="scaled")
    return s


def embed(p: Params, tokens: jax.Array,
          ctx: Optional[ShardCtx] = None) -> jax.Array:
    x = jnp.take(p["embedding"], tokens, axis=0)
    return constrain(ctx, x, "batch", "seq", "embed")


def unembed(p: Params, x: jax.Array,
            ctx: Optional[ShardCtx] = None) -> jax.Array:
    if "unembed" in p:
        logits = jnp.einsum("bsd,dv->bsv", x, p["unembed"])
    else:
        logits = jnp.einsum("bsd,vd->bsv", x, p["embedding"])
    return constrain(ctx, logits, "batch", "seq", "vocab")


def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: Optional[jax.Array] = None,
                 vocab_size: Optional[int] = None) -> jax.Array:
    """Mean next-token cross-entropy. ``vocab_size`` masks padded vocab
    rows; safe when the vocab dim is sharded (logsumexp lowers to partial
    reduce + all-reduce under GSPMD)."""
    lf = logits.astype(jnp.float32)
    if vocab_size is not None and vocab_size < lf.shape[-1]:
        pad = jnp.arange(lf.shape[-1]) >= vocab_size
        lf = jnp.where(pad, MASK_VALUE, lf)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# KV cache helpers (decode)
# ---------------------------------------------------------------------------

def kv_cache_specs(n_layers: int, batch: int, n_kv_heads: int, max_len: int,
                   d_head: int, dtype=jnp.bfloat16) -> Params:
    """Ring-buffer style cache: stacked [L, B, KH, S, Dh] + write index.

    The cache ``seq`` dim is sharded over the ``model`` axis when kv_heads
    cannot use it (sequence-sharded decode attention: GSPMD turns the
    softmax/PV over the sharded dim into partial reductions + all-reduce)."""
    kv = ParamSpec((n_layers, batch, n_kv_heads, max_len, d_head),
                   ("layers", "batch", "kv_heads", "kv_seq", "head_dim"),
                   dtype, "zeros")
    return {"k": kv, "v": kv, "index": ParamSpec((), (), jnp.int32, "zeros")}


def cache_update(cache_k: jax.Array, cache_v: jax.Array, k: jax.Array,
                 v: jax.Array, index: jax.Array
                 ) -> Tuple[jax.Array, jax.Array]:
    """Write k/v [B,KH,S_new,Dh] at position ``index`` of one layer's cache
    [B,KH,S_max,Dh] (dynamic_update_slice keeps it in-place under jit)."""
    zero = jnp.zeros((), jnp.int32)
    k = k.astype(cache_k.dtype)
    v = v.astype(cache_v.dtype)
    ck = lax.dynamic_update_slice(cache_k, k, (zero, zero, index, zero))
    cv = lax.dynamic_update_slice(cache_v, v, (zero, zero, index, zero))
    return ck, cv
