"""Whisper-style encoder-decoder (audio family).

The conv frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings [B, n_enc_frames, d_model] (what the two conv
layers would emit). Encoder: non-causal self-attention, GELU MLP,
sinusoidal positions. Decoder: causal self-attention + cross-attention to
the encoder output, learned positions. LayerNorm (with bias) throughout,
MHA (n_kv_heads == n_heads), no rope — per the Whisper architecture.

Decode state: per-layer self KV cache (grows) + per-layer cross K/V
(computed once at prefill from the encoder output).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import ParamSpec
from .layers import (Params, ShardCtx, attention, attn_block_unroll,
                     attn_out, attn_specs, cache_update, constrain, embed,
                     embed_specs, layer_norm, layer_unroll, mlp, mlp_specs,
                     sinusoidal_positions, stack_specs, unembed)


def _ln(d: int) -> Params:
    return {"w": ParamSpec((d,), ("embed",), jnp.float32, "ones"),
            "b": ParamSpec((d,), ("embed",), jnp.float32, "zeros")}


def _qkv_noro(p, x, ctx):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = constrain(ctx, q, "batch", "seq", "heads", "head_dim")
    return (jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
            jnp.moveaxis(v, 2, 1))


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def enc_layer_specs(cfg) -> Params:
    return {"ln_attn": _ln(cfg.d_model),
            "attn": attn_specs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.d_head),
            "ln_mlp": _ln(cfg.d_model),
            "mlp": mlp_specs(cfg.d_model, cfg.d_ff, gated=False)}


def dec_layer_specs(cfg) -> Params:
    s = enc_layer_specs(cfg)
    s["ln_cross"] = _ln(cfg.d_model)
    s["cross"] = attn_specs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                            cfg.d_head)
    return s


def param_specs(cfg) -> Params:
    return {
        "embed": embed_specs(cfg.vocab_padded, cfg.d_model, tied=True),
        "dec_pos": ParamSpec((32768, cfg.d_model), (None, "embed"),
                             jnp.bfloat16, "normal", 0.01),
        "enc": {"layers": stack_specs(enc_layer_specs(cfg), cfg.n_layers),
                "ln_f": _ln(cfg.d_model)},
        "dec": {"layers": stack_specs(dec_layer_specs(cfg), cfg.n_layers),
                "ln_f": _ln(cfg.d_model)},
    }


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def encode(cfg, params: Params, frames: jax.Array,
           ctx: Optional[ShardCtx] = None) -> jax.Array:
    """frames [B, n_enc_frames, d_model] (stub frontend output)."""
    x = frames.astype(jnp.bfloat16)
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model)[None].astype(
        x.dtype)
    x = constrain(ctx, x, "batch", "seq_sp", "embed")

    def step(x, p):
        h = layer_norm(x, p["ln_attn"]["w"], p["ln_attn"]["b"])
        q, k, v = _qkv_noro(p["attn"], h, ctx)
        o = attention(q, k, v, causal=False,
                      use_pallas=cfg.use_pallas or False)
        x = x + attn_out(p["attn"], o, ctx)
        h = layer_norm(x, p["ln_mlp"]["w"], p["ln_mlp"]["b"])
        x = x + mlp(p["mlp"], h, ctx, act=jax.nn.gelu)
        return constrain(ctx, x, "batch", "seq_sp", "embed"), None

    x, _ = lax.scan(_remat(cfg, step), x, params["enc"]["layers"],
                    unroll=layer_unroll(cfg))
    return layer_norm(x, params["enc"]["ln_f"]["w"],
                      params["enc"]["ln_f"]["b"])


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------

def _dec_layer(cfg, p, x, enc_kv, self_kv, index, kv_len, ctx):
    """enc_kv = (ek, ev) cross K/V [B,H,Senc,Dh]; self_kv None (train, full
    causal) or (ck, cv) cache slices."""
    h = layer_norm(x, p["ln_attn"]["w"], p["ln_attn"]["b"])
    q, k, v = _qkv_noro(p["attn"], h, ctx)
    if self_kv is None:
        o = attention(q, k, v, causal=True,
                      use_pallas=cfg.use_pallas or False)
        new_self = None
    else:
        ck, cv = cache_update(self_kv[0], self_kv[1], k, v, index)
        ck = constrain(ctx, ck, "batch", "kv_heads", "kv_seq", "head_dim")
        cv = constrain(ctx, cv, "batch", "kv_heads", "kv_seq", "head_dim")
        o = attention(q, ck, cv, causal=True, kv_len=kv_len,
                      unroll=attn_block_unroll(cfg,
                                               max(1, ck.shape[2] // 1024)),
                      use_pallas=False)
        new_self = (ck, cv)
    x = x + attn_out(p["attn"], o, ctx)

    h = layer_norm(x, p["ln_cross"]["w"], p["ln_cross"]["b"])
    cq = jnp.einsum("bsd,dhk->bshk", h, p["cross"]["wq"])
    cq = jnp.moveaxis(cq, 2, 1)
    o = attention(cq, enc_kv[0], enc_kv[1], causal=False, use_pallas=False)
    x = x + attn_out(p["cross"], o, ctx)

    h = layer_norm(x, p["ln_mlp"]["w"], p["ln_mlp"]["b"])
    x = x + mlp(p["mlp"], h, ctx, act=jax.nn.gelu)
    return constrain(ctx, x, "batch", "seq", "embed"), new_self


def cross_kv(cfg, params: Params, enc_out: jax.Array, ctx) \
        -> Tuple[jax.Array, jax.Array]:
    """Cross K/V for all decoder layers: [L, B, H, Senc, Dh] each."""
    def per_layer(p):
        k = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wv"])
        return jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1)

    return jax.vmap(per_layer)(params["dec"]["layers"])


def decode_train(cfg, params, tokens, enc_out, ctx) -> jax.Array:
    x = embed(params["embed"], tokens, ctx)
    x = x + params["dec_pos"][:x.shape[1]][None].astype(x.dtype)
    x = constrain(ctx, x, "batch", "seq_sp", "embed")
    ek, ev = cross_kv(cfg, params, enc_out, ctx)

    def step(x, xs):
        p, k, v = xs
        y, _ = _dec_layer(cfg, p, x, (k, v), None, None, None, ctx)
        return y, None

    x, _ = lax.scan(_remat(cfg, step), x, (params["dec"]["layers"], ek, ev),
                    unroll=layer_unroll(cfg))
    x = layer_norm(x, params["dec"]["ln_f"]["w"], params["dec"]["ln_f"]["b"])
    return unembed(params["embed"], x, ctx)


def apply(cfg, params: Params, tokens: jax.Array,
          frames: Optional[jax.Array] = None,
          ctx: Optional[ShardCtx] = None) -> jax.Array:
    if frames is None:
        raise ValueError("enc-dec apply() needs `frames`")
    return decode_train(cfg, params, tokens, encode(cfg, params, frames, ctx),
                        ctx)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def cache_specs(cfg, batch: int, max_len: int) -> Params:
    L = cfg.n_layers
    kv = ParamSpec((L, batch, cfg.n_kv_heads, max_len, cfg.d_head),
                   ("layers", "batch", "kv_heads", "kv_seq", "head_dim"),
                   jnp.bfloat16, "zeros")
    ckv = ParamSpec((L, batch, cfg.n_kv_heads, cfg.n_enc_frames, cfg.d_head),
                    ("layers", "batch", "kv_heads", None, "head_dim"),
                    jnp.bfloat16, "zeros")
    return {"k": kv, "v": kv, "ek": ckv, "ev": ckv,
            "index": ParamSpec((), (), jnp.int32, "zeros")}


def _run_decoder(cfg, params, tokens, cache, index, ctx):
    s = tokens.shape[1]
    x = embed(params["embed"], tokens, ctx)
    pos = jnp.take(params["dec_pos"],
                   jnp.minimum(index + jnp.arange(s), 32767), axis=0)
    x = x + pos[None].astype(x.dtype)
    kv_len = index + s

    def step(x, xs):
        p, ck, cv, ek, ev = xs
        y, new_self = _dec_layer(cfg, p, x, (ek, ev), (ck, cv), index,
                                 kv_len, ctx)
        return y, new_self

    x, (nk, nv) = lax.scan(
        step, x, (params["dec"]["layers"], cache["k"], cache["v"],
                  cache["ek"], cache["ev"]), unroll=layer_unroll(cfg))
    x = layer_norm(x, params["dec"]["ln_f"]["w"], params["dec"]["ln_f"]["b"])
    logits = unembed(params["embed"], x[:, -1:], ctx)
    return logits, nk, nv


def prefill(cfg, params, tokens, frames: Optional[jax.Array] = None,
            ctx: Optional[ShardCtx] = None):
    if frames is None:
        raise ValueError("enc-dec prefill() needs `frames`")
    b, s = tokens.shape
    enc_out = encode(cfg, params, frames, ctx)
    ek, ev = cross_kv(cfg, params, enc_out, ctx)
    cache = {"k": jnp.zeros((cfg.n_layers, b, cfg.n_kv_heads, s, cfg.d_head),
                            jnp.bfloat16),
             "v": jnp.zeros((cfg.n_layers, b, cfg.n_kv_heads, s, cfg.d_head),
                            jnp.bfloat16),
             "ek": ek.astype(jnp.bfloat16), "ev": ev.astype(jnp.bfloat16),
             "index": jnp.zeros((), jnp.int32)}
    logits, nk, nv = _run_decoder(cfg, params, tokens, cache,
                                  jnp.zeros((), jnp.int32), ctx)
    return logits, {"k": nk, "v": nv, "ek": cache["ek"], "ev": cache["ev"],
                    "index": jnp.full((), s, jnp.int32)}


def decode_step(cfg, params, cache, tokens, ctx=None):
    index = cache["index"]
    logits, nk, nv = _run_decoder(cfg, params, tokens, cache, index, ctx)
    return logits, {"k": nk, "v": nv, "ek": cache["ek"], "ev": cache["ev"],
                    "index": index + tokens.shape[1]}
