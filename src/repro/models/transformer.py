"""Dense GQA transformer family (internlm2 / qwen3 / gemma3 / mistral /
the internvl2 text backbone).

One ``lax.scan`` over stacked layer params keeps the HLO O(1) in depth.
Heterogeneous attention patterns (gemma3's 5 local : 1 global) are encoded
as a *traced* per-layer ``window`` array so the scan stays homogeneous —
local layers get ``window=window_size``, global layers ``window=0`` (no
window). Remat policy wraps the scan body.

Three entry points per the shape matrix: ``apply`` (train forward),
``prefill`` (no-grad forward materializing the KV cache), ``decode_step``
(one token against the cache).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .layers import (Params, ShardCtx, attention, attn_block_unroll,
                     attn_out, attn_qkv, attn_specs, banded_local_attention,
                     cache_update, constrain, embed, embed_specs,
                     kv_cache_specs, layer_unroll, mlp, mlp_specs,
                     norm_specs, rms_norm, stack_specs, unembed)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def layer_specs(cfg) -> Params:
    return {
        "attn": attn_specs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                           cfg.d_head, qk_norm=cfg.qk_norm),
        "mlp": mlp_specs(cfg.d_model, cfg.d_ff),
        "ln_attn": norm_specs(cfg.d_model),
        "ln_mlp": norm_specs(cfg.d_model),
    }


def param_specs(cfg) -> Params:
    return {
        "embed": embed_specs(cfg.vocab_padded, cfg.d_model,
                             tied=cfg.tied_embeddings),
        "layers": stack_specs(layer_specs(cfg), cfg.n_layers),
        "ln_f": norm_specs(cfg.d_model),
    }


def layer_windows(cfg) -> jnp.ndarray:
    """Per-layer sliding-window widths (0 = full/global attention).

    gemma3 pattern: every (local_global+1)-th layer is global, the rest use
    ``window_size`` — layers i with (i+1) % (local_global+1) == 0 global."""
    if not cfg.local_global:
        return jnp.zeros((cfg.n_layers,), jnp.int32)
    period = cfg.local_global + 1
    idx = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    return jnp.where((idx + 1) % period == 0, 0, cfg.window_size)


# ---------------------------------------------------------------------------
# layer body
# ---------------------------------------------------------------------------

def _use_pallas(cfg) -> bool:
    if cfg.use_pallas is not None:
        return cfg.use_pallas
    return jax.default_backend() == "tpu"


def layer_fwd(cfg, p: Params, x: jax.Array, positions: jax.Array,
              window: jax.Array, ctx: Optional[ShardCtx]) -> jax.Array:
    """Full-sequence causal layer (train / prefill compute)."""
    h = rms_norm(x, p["ln_attn"])
    q, k, v = attn_qkv(p["attn"], h, positions, rope_theta=cfg.rope_theta,
                       ctx=ctx)
    o = attention(q, k, v, causal=True, window=window,
                  use_pallas=_use_pallas(cfg),
                  unroll=attn_block_unroll(cfg, max(1, k.shape[2] // 1024)))
    x = x + attn_out(p["attn"], o, ctx)
    h = rms_norm(x, p["ln_mlp"])
    x = x + mlp(p["mlp"], h, ctx)
    return constrain(ctx, x, "batch", "seq_sp", "embed")


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def scan_layers(cfg, layers: Params, x: jax.Array, body) -> jax.Array:
    """scan(remat(body)) over stacked params + per-layer windows."""
    windows = layer_windows(cfg)

    def step(carry, xs):
        p, w = xs
        return body(carry, p, w), None

    step = _remat(cfg, step)
    x, _ = lax.scan(step, x, (layers, windows), unroll=layer_unroll(cfg))
    return x


def _banded_ok(cfg, seq_len: int) -> bool:
    if not (cfg.local_global and cfg.banded_local and cfg.window_size):
        return False
    if cfg.seq_shard_activations:      # banded reshapes the seq dim
        return False
    block = max(cfg.window_size, min(1024, seq_len))
    return seq_len % block == 0 and seq_len > cfg.window_size


def _local_layer_fwd(cfg, p: Params, x: jax.Array, positions: jax.Array,
                     ctx: Optional[ShardCtx]) -> jax.Array:
    """Local layer with the STATIC-window banded kernel (computes only
    the band; the generic path executes every kv block and masks)."""
    h = rms_norm(x, p["ln_attn"])
    q, k, v = attn_qkv(p["attn"], h, positions, rope_theta=cfg.rope_theta,
                       ctx=ctx)
    block = max(cfg.window_size, min(1024, q.shape[2]))
    o = banded_local_attention(q, k, v, window=cfg.window_size,
                               block=block)
    x = x + attn_out(p["attn"], o, ctx)
    h = rms_norm(x, p["ln_mlp"])
    x = x + mlp(p["mlp"], h, ctx)
    return constrain(ctx, x, "batch", "seq_sp", "embed")


def scan_layers_banded(cfg, layers: Params, x: jax.Array,
                       positions: jax.Array,
                       ctx: Optional[ShardCtx]) -> jax.Array:
    """Period-structured scan for local:global patterns (gemma3): the
    stacked params are reshaped into [n_periods, period, ...] (pure
    slicing — checkpoint layout unchanged); each period runs
    ``local_global`` banded-local layers + one full-attention layer, so
    the local window is STATIC inside its sub-scan. Trailing non-full
    periods (gemma3: 34 = 5·6 + 4) run as a banded tail scan."""
    p_len = cfg.local_global + 1
    n_full = (cfg.n_layers // p_len) * p_len
    n_periods = n_full // p_len
    unroll = layer_unroll(cfg)

    def local_step(carry, pp):
        return _remat(cfg, lambda c, q: (_local_layer_fwd(cfg, q, c,
                                                          positions, ctx),
                                         None))(carry, pp)

    def global_step(carry, pp):
        zero = jnp.zeros((), jnp.int32)      # window 0 = full attention
        return _remat(cfg, lambda c, q: (layer_fwd(cfg, q, c, positions,
                                                   zero, ctx), None)
                      )(carry, pp)

    main = jax.tree_util.tree_map(
        lambda a: a[:n_full].reshape((n_periods, p_len) + a.shape[1:]),
        layers)

    def period(carry, pp):
        locs = jax.tree_util.tree_map(lambda a: a[:p_len - 1], pp)
        glob = jax.tree_util.tree_map(lambda a: a[p_len - 1], pp)
        carry, _ = lax.scan(local_step, carry, locs, unroll=unroll)
        carry, _ = global_step(carry, glob)
        return carry, None

    x, _ = lax.scan(period, x, main, unroll=unroll)
    if n_full < cfg.n_layers:                # trailing local layers
        tail = jax.tree_util.tree_map(lambda a: a[n_full:], layers)
        x, _ = lax.scan(local_step, x, tail, unroll=unroll)
    return x


# ---------------------------------------------------------------------------
# train / prefill / decode
# ---------------------------------------------------------------------------

def apply(cfg, params: Params, tokens: jax.Array,
          ctx: Optional[ShardCtx] = None,
          inputs_embeds: Optional[jax.Array] = None) -> jax.Array:
    """tokens [B,S] -> logits [B,S,V_padded]. ``inputs_embeds`` (vlm) is
    prepended before the token embeddings."""
    x = embed(params["embed"], tokens, ctx)
    if inputs_embeds is not None:
        x = jnp.concatenate([inputs_embeds.astype(x.dtype), x], axis=1)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
    x = constrain(ctx, x, "batch", "seq_sp", "embed")

    if _banded_ok(cfg, x.shape[1]):
        x = scan_layers_banded(cfg, params["layers"], x, positions, ctx)
    else:
        def body(x, p, w):
            return layer_fwd(cfg, p, x, positions, w, ctx)

        x = scan_layers(cfg, params["layers"], x, body)
    x = rms_norm(x, params["ln_f"])
    return unembed(params["embed"], x, ctx)


def cache_specs(cfg, batch: int, max_len: int) -> Params:
    return kv_cache_specs(cfg.n_layers, batch, cfg.n_kv_heads, max_len,
                          cfg.d_head)


def _decode_layer(cfg, p: Params, ck: jax.Array, cv: jax.Array,
                  x: jax.Array, positions: jax.Array, index: jax.Array,
                  kv_len, window: jax.Array, ctx: Optional[ShardCtx]
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One layer against one layer's cache slice; returns (x, ck, cv)."""
    h = rms_norm(x, p["ln_attn"])
    q, k, v = attn_qkv(p["attn"], h, positions, rope_theta=cfg.rope_theta,
                       ctx=ctx)
    ck, cv = cache_update(ck, cv, k, v, index)
    ck = constrain(ctx, ck, "batch", "kv_heads", "kv_seq", "head_dim")
    cv = constrain(ctx, cv, "batch", "kv_heads", "kv_seq", "head_dim")
    o = attention(q, ck, cv, causal=True, window=window, kv_len=kv_len,
                  use_pallas=False,  # traced kv_len => jnp path
                  unroll=attn_block_unroll(cfg, max(1, ck.shape[2] // 1024)))
    x = x + attn_out(p["attn"], o, ctx)
    h = rms_norm(x, p["ln_mlp"])
    x = x + mlp(p["mlp"], h, ctx)
    return constrain(ctx, x, "batch", "seq", "embed"), ck, cv


def _scan_decode(cfg, params, cache, x, positions, index, kv_len, ctx):
    windows = layer_windows(cfg)

    def step(carry, xs):
        p, ck, cv, w = xs
        y, ck, cv = _decode_layer(cfg, p, ck, cv, carry, positions, index,
                                  kv_len, w, ctx)
        return y, (ck, cv)

    x, (new_k, new_v) = lax.scan(
        step, x, (params["layers"], cache["k"], cache["v"], windows),
        unroll=layer_unroll(cfg))
    return x, new_k, new_v


def prefill(cfg, params: Params, tokens: jax.Array,
            ctx: Optional[ShardCtx] = None,
            inputs_embeds: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, Params]:
    """Forward that materializes the KV cache; returns (last-pos logits,
    cache). Cache max_len == prompt len (decode grows a fresh cache in
    real serving; the dry-run shapes pin max_len = seq_len)."""
    x = embed(params["embed"], tokens, ctx)
    if inputs_embeds is not None:
        x = jnp.concatenate([inputs_embeds.astype(x.dtype), x], axis=1)
    b, s = x.shape[:2]
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    x = constrain(ctx, x, "batch", "seq_sp", "embed")
    cache = {
        "k": jnp.zeros((cfg.n_layers, b, cfg.n_kv_heads, s, cfg.d_head),
                       jnp.bfloat16),
        "v": jnp.zeros((cfg.n_layers, b, cfg.n_kv_heads, s, cfg.d_head),
                       jnp.bfloat16),
        "index": jnp.zeros((), jnp.int32),
    }
    x, new_k, new_v = _scan_decode(cfg, params, cache, x, positions,
                                   jnp.zeros((), jnp.int32), s, ctx)
    x = rms_norm(x[:, -1:], params["ln_f"])
    logits = unembed(params["embed"], x, ctx)
    return logits, {"k": new_k, "v": new_v,
                    "index": jnp.full((), s, jnp.int32)}


def decode_step(cfg, params: Params, cache: Params, tokens: jax.Array,
                ctx: Optional[ShardCtx] = None
                ) -> Tuple[jax.Array, Params]:
    """tokens [B,1] + cache -> (logits [B,1,V], updated cache)."""
    index = cache["index"]
    positions = jnp.full(tokens.shape, index, jnp.int32)
    x = embed(params["embed"], tokens, ctx)
    x, new_k, new_v = _scan_decode(cfg, params, cache, x, positions, index,
                                   index + tokens.shape[1], ctx)
    x = rms_norm(x, params["ln_f"])
    logits = unembed(params["embed"], x, ctx)
    return logits, {"k": new_k, "v": new_v, "index": index + tokens.shape[1]}
