"""InternVL2-style VLM: stub ViT frontend + dense LM backbone.

Per the assignment the modality frontend is a STUB: ``input_specs()``
provides precomputed patch embeddings [B, n_prepend, VIT_DIM] (what
InternViT would emit after pixel shuffle). This module owns only the
MLP projector and delegates everything else to the dense transformer
(internlm2-family backbone). Sequence budget: n_prepend patch positions +
(seq_len - n_prepend) text tokens = exactly seq_len positions.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ParamSpec
from .layers import Params, ShardCtx, constrain, layer_norm
from . import transformer as tf

VIT_DIM = 1024


def param_specs(cfg) -> Params:
    base = tf.param_specs(cfg)
    base["projector"] = {
        "ln_w": ParamSpec((VIT_DIM,), (None,), jnp.float32, "ones"),
        "ln_b": ParamSpec((VIT_DIM,), (None,), jnp.float32, "zeros"),
        "w1": ParamSpec((VIT_DIM, cfg.d_model), (None, "embed"),
                        init="scaled"),
        "b1": ParamSpec((cfg.d_model,), ("embed",), jnp.float32, "zeros"),
    }
    return base


def project_patches(p: Params, patches: jax.Array,
                    ctx: Optional[ShardCtx]) -> jax.Array:
    """[B, n_prepend, VIT_DIM] -> [B, n_prepend, d_model]."""
    h = layer_norm(patches.astype(jnp.float32), p["ln_w"], p["ln_b"])
    out = jnp.einsum("bsv,vd->bsd", h, p["w1"].astype(jnp.float32))
    out = (out + p["b1"][None, None]).astype(jnp.bfloat16)
    return constrain(ctx, out, "batch", "seq", "embed")


def apply(cfg, params: Params, tokens: jax.Array,
          patches: Optional[jax.Array] = None,
          ctx: Optional[ShardCtx] = None) -> jax.Array:
    """tokens [B, S - n_prepend]; patches [B, n_prepend, VIT_DIM].
    Returns logits over ALL positions (caller masks the patch span)."""
    if patches is None:
        raise ValueError("vlm apply() needs `patches`")
    emb = project_patches(params["projector"], patches, ctx)
    return tf.apply(cfg, params, tokens, ctx, inputs_embeds=emb)


cache_specs = tf.cache_specs


def prefill(cfg, params, tokens, patches=None, ctx=None):
    if patches is None:
        raise ValueError("vlm prefill() needs `patches`")
    emb = project_patches(params["projector"], patches, ctx)
    return tf.prefill(cfg, params, tokens, ctx, inputs_embeds=emb)


def decode_step(cfg, params, cache, tokens, ctx=None):
    return tf.decode_step(cfg, params, cache, tokens, ctx)
