"""Public session API for MapSDI knowledge-graph creation.

One front door: :class:`KGEngine` (cached plans, incremental ingestion,
overflow-safe re-execution). The historical free functions in
``repro.core.pipeline`` / ``repro.core.rdfizer`` are thin deprecated
wrappers over this package. See ``docs/engine.md``.
"""
from .cache import (PLAN_CACHE, CachedPlan, PlanCache, clear_plan_cache,
                    plan_cache_stats)
from .engine import KGEngine
from .store import (PlanStore, default_store_root, resolve_store,
                    store_envelope, store_key)

__all__ = ["CachedPlan", "KGEngine", "PLAN_CACHE", "PlanCache", "PlanStore",
           "clear_plan_cache", "default_store_root", "plan_cache_stats",
           "resolve_store", "store_envelope", "store_key"]
