"""Public session API for MapSDI knowledge-graph creation AND querying.

One front door: :class:`KGEngine`, configured by a frozen
:class:`EngineConfig` (cached plans, incremental ingestion, overflow-safe
re-execution, jitted BGP queries via :meth:`KGEngine.query`). The stable
surface is exactly ``__all__`` below::

    from repro.api import EngineConfig, KGEngine, PlanStore, Query

    engine = KGEngine(dis, config=EngineConfig(engine="sdm", dedup="hash"))
    kg, stats = engine.create_kg()
    answers = engine.query(Query(patterns=[...]))

:class:`Query` (with :class:`~repro.query.TriplePattern` /
:class:`~repro.query.QueryFilter`) re-exports from :mod:`repro.query`;
:class:`Calibration` (the measured-bandwidth cost model fed to
``EngineConfig(calibrate=...)``) from :mod:`repro.launch.mesh`. The
historical free functions in ``repro.core.pipeline`` / ``repro.core.
rdfizer`` are deprecated shims over this package, tagged with removal
notes. See ``docs/engine.md`` and ``docs/query.md``.

The multi-tenant streaming surface (:class:`~repro.serve.FrontDoor`,
:class:`~repro.serve.Overloaded`, …) lives in :mod:`repro.serve` and is
re-exported here lazily — ``repro.serve.frontdoor`` imports this package,
so the names resolve on first attribute access (PEP 562) instead of at
import time. See ``docs/serve.md``.
"""
from repro.launch.mesh import Calibration
from repro.query import Query, QueryFilter, TriplePattern

from .cache import (PLAN_CACHE, CachedPlan, PlanCache, clear_plan_cache,
                    plan_cache_stats)
from .config import EngineConfig
from .engine import KGEngine
from .store import (PlanStore, default_store_root, resolve_store,
                    store_envelope, store_key)

# serve-tier names resolved lazily (repro.serve.frontdoor imports this
# package, so an eager import here would be circular)
_SERVE_EXPORTS = (
    "FrontDoor", "IngestResult", "Overloaded", "SessionRegistry",
    "TenantSession", "Ticket", "percentile",
)

__all__ = [
    "CachedPlan", "Calibration", "EngineConfig", "KGEngine", "PLAN_CACHE",
    "PlanCache", "PlanStore", "Query", "QueryFilter", "TriplePattern",
    "clear_plan_cache", "default_store_root", "plan_cache_stats",
    "resolve_store", "store_envelope", "store_key", *_SERVE_EXPORTS,
]


def __getattr__(name: str):
    if name in _SERVE_EXPORTS:
        import repro.serve as _serve
        return getattr(_serve, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_SERVE_EXPORTS))
