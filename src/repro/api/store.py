"""The persistent plan store: an AOT-serialized second tier behind the LRU.

:data:`repro.api.cache.PLAN_CACHE` amortizes compilation *within* one
process; a restarting fleet pays the cold cost (~seconds — see
``experiments/bench/engine.json``) per worker × per DIS shape. The store
makes the amortization survive the process: on an LRU miss the
:class:`~repro.api.KGEngine` consults an on-disk store of AOT-compiled
closures, and on a compile (including overflow-ladder recompiles) it
writes back — so a fresh process with a populated store rehydrates a
ready-to-run executable without re-tracing or re-compiling
(``check_warm_process_cold_start`` in ``benchmarks/engine.py`` gates the
speedup at ≥10×).

**Key.** ``store_key(session_key, envelope)`` = sha256 over

* the engine's in-process plan-cache key (structural IR fingerprint ×
  emitter codes × engine × dedup × annotate mode/slack × mesh signature ×
  capacity-bucket signature), canonicalized by :func:`canonical` — which
  *rejects* anything but ``None``/``bool``/``int``/``float``/``str``/
  ``tuple``, so an ``id()``, an unsorted dict, or any other
  process-unstable value can never silently leak into the key (the
  hypothesis suite in ``tests/test_engine_properties.py`` leans on this);
* the **compatibility envelope** (:func:`store_envelope`): store format
  version, jax/jaxlib versions, XLA backend, device kind and count — the
  runtime facts a serialized executable is only valid under. Two
  processes produce the same key iff their in-process keys AND runtimes
  match.

**Entry format** (version :data:`FORMAT_VERSION`, one file per key)::

    MAGIC(8) | header_len u32 LE | sha256(header)(32) | header JSON | payloads

The header carries the envelope (validated for *equality* on load — a
matching filename with a mismatched envelope is rejected), the
node-indexed plan metadata (capacities/counts/⋈ exchanges, keyed by
:func:`repro.plan.ir.node_order` indices so they rehydrate against a
freshly lowered plan), and per-payload sizes + sha256 checksums (what
turns truncation and bit flips into clean rejections). Two payloads:

* ``native`` — the XLA executable via
  :mod:`jax.experimental.serialize_executable` (plus its pickled
  in/out treedefs). Zero-recompile rehydration: the fast tier.
* ``stablehlo`` — the ``jax.export`` blob. Portable within the envelope;
  the fallback when the native payload fails to load (it re-compiles the
  StableHLO, still skipping planning + tracing).

**Failure discipline.** Every load failure — missing file, bad magic,
truncated bytes, checksum mismatch, envelope mismatch, deserialization
error — degrades to a fresh compile and bumps a reject counter
(``stats()['rejects']``; mirrored as ``store_rejects`` on the engine).
Writes go to a temp file in the same directory and ``os.replace`` into
place under a per-entry advisory ``flock``, so a concurrent reader never
observes a torn entry and concurrent writers never interleave; a busy
lock or an unwritable directory skips the write (counted), never raises.

CLI (the CI plan-store leg's step 1)::

    PYTHONPATH=src python -m repro.api.store populate --root /tmp/plan-store
    PYTHONPATH=src python -m repro.api.store ls --root /tmp/plan-store
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pickle
import struct
import tempfile
from typing import Dict, List, Mapping, Optional, Tuple

import jax

MAGIC = b"RPLNSTR1"
# v2: exchange records carry the cost-model provenance (``cost_source``)
# and the envelope may carry a collective-bandwidth calibration tag, so
# plans costed under measured link speeds never collide with static ones.
FORMAT_VERSION = 2

#: payload names inside an entry container
NATIVE, STABLEHLO = "native", "stablehlo"


def default_store_root() -> str:
    """``$REPRO_PLAN_STORE`` if set, else ``~/.cache/repro-plans``."""
    env = os.environ.get("REPRO_PLAN_STORE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro-plans")


# ---------------------------------------------------------------------------
# key canonicalization + envelope
# ---------------------------------------------------------------------------

def canonical(obj) -> str:
    """Deterministic, process-stable encoding of a plan-cache key.

    Only ``None``/``bool``/``int``/``float``/``str``/``tuple`` are
    admitted — these repr identically in every process. Anything else
    (an object whose repr embeds ``id()``, a dict whose iteration order
    depends on insertion, a device array) raises ``TypeError`` instead of
    silently producing a key that only this process can reproduce.
    """
    if obj is None or isinstance(obj, (bool, int, str)):
        return repr(obj)
    if isinstance(obj, float):
        return repr(obj)  # shortest-repr is deterministic in CPython 3
    if isinstance(obj, tuple):
        return "(" + ",".join(canonical(x) for x in obj) + ")"
    raise TypeError(
        f"plan-store keys must be built from None/bool/int/float/str/tuple; "
        f"got {type(obj).__name__} — a process-unstable component would "
        f"make the key irreproducible across workers")


def store_envelope(calibration=None) -> Dict[str, object]:
    """The runtime facts a serialized executable is only valid under.

    ``calibration`` (a :class:`repro.launch.mesh.Calibration` or None)
    tags the envelope with the cost model's bandwidth provenance: a plan
    whose exchange strategies were chosen under measured link speeds must
    not rehydrate into a session costing with the static constants (or
    with a materially different measurement) — calibration drift is an
    envelope mismatch, rejected on load like any other runtime mismatch.
    """
    import jaxlib
    devices = jax.devices()
    env = {
        "format": FORMAT_VERSION,
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "backend": jax.default_backend(),
        "device_kind": devices[0].device_kind,
        "device_count": jax.device_count(),
        "calibration": "static",
    }
    if calibration is not None and calibration.source != "static":
        env["calibration"] = canonical(calibration.signature())
    return env


def _envelope_json(envelope: Mapping[str, object]) -> str:
    return json.dumps(dict(envelope), sort_keys=True, separators=(",", ":"))


def store_key(session_key: Tuple, envelope: Mapping[str, object]) -> str:
    """sha256 hex of the canonicalized in-process key × the envelope."""
    blob = canonical(session_key) + "\n" + _envelope_json(envelope)
    return hashlib.sha256(blob.encode()).hexdigest()


# ---------------------------------------------------------------------------
# container read/write (module-level so tests can tamper surgically)
# ---------------------------------------------------------------------------

def write_container(path: str, header: Dict[str, object],
                    payloads: Mapping[str, bytes]) -> None:
    """Serialize one entry (non-atomic — callers go through
    :meth:`PlanStore.save` for the temp+rename+lock discipline)."""
    names = sorted(payloads)
    header = dict(header)
    header["payloads"] = [{"name": n, "size": len(payloads[n]),
                           "sha256": hashlib.sha256(payloads[n]).hexdigest()}
                          for n in names]
    hjson = json.dumps(header, sort_keys=True).encode()
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(hjson)))
        f.write(hashlib.sha256(hjson).digest())
        f.write(hjson)
        for n in names:
            f.write(payloads[n])
        f.flush()
        os.fsync(f.fileno())


def read_container(path: str) -> Tuple[Dict[str, object], Dict[str, bytes]]:
    """Parse + integrity-check one entry; raises ``ValueError``/``OSError``
    on any corruption (bad magic, truncation, checksum mismatch)."""
    with open(path, "rb") as f:
        blob = f.read()
    if blob[:len(MAGIC)] != MAGIC:
        raise ValueError("bad magic")
    off = len(MAGIC)
    if len(blob) < off + 36:
        raise ValueError("truncated header")
    (hlen,) = struct.unpack("<I", blob[off:off + 4])
    off += 4
    hdigest, off = blob[off:off + 32], off + 32
    hjson = blob[off:off + hlen]
    if len(hjson) != hlen or hashlib.sha256(hjson).digest() != hdigest:
        raise ValueError("header checksum mismatch")
    header = json.loads(hjson.decode())
    off += hlen
    payloads: Dict[str, bytes] = {}
    for spec in header.get("payloads", []):
        data = blob[off:off + int(spec["size"])]
        if len(data) != int(spec["size"]):
            raise ValueError(f"truncated payload {spec['name']!r}")
        if hashlib.sha256(data).hexdigest() != spec["sha256"]:
            raise ValueError(f"payload checksum mismatch {spec['name']!r}")
        payloads[spec["name"]] = data
        off += int(spec["size"])
    return header, payloads


# ---------------------------------------------------------------------------
# node-indexed entry metadata (caps/counts/exchanges survive the process)
# ---------------------------------------------------------------------------

def pack_entry_meta(entry, plan) -> Dict[str, object]:
    """Serialize a :class:`~repro.api.cache.CachedPlan`'s node-keyed
    metadata as :func:`repro.plan.ir.node_order` index lists (the order is
    fingerprint-stable, so a same-key process maps indices back onto its
    own freshly lowered nodes)."""
    from repro.plan.ir import node_order
    index = {n: i for i, n in enumerate(node_order(plan.emits()))}
    meta: Dict[str, object] = {
        "node_count": len(index),
        "engine": entry.engine,
        "dedup": entry.dedup,
        "mode": entry.mode,
        "build_seconds": entry.build_seconds,
        "counts": sorted([index[n], int(v)]
                         for n, v in entry.counts.items()),
        "caps": sorted([index[n], int(v)] for n, v in entry.caps.items()),
    }
    if entry.cap_locals is not None:      # mesh entry: shard layout
        meta["cap_locals"] = {k: int(v)
                              for k, v in sorted(entry.cap_locals.items())}
        meta["out_cap_local"] = int(entry.out_cap_local)
        meta["sink_slack"] = float(entry.sink_slack)
        meta["safe_exchange"] = bool(entry.safe_exchange)
        meta["exchanges"] = sorted(
            [index[n], x.strategy, int(x.gather_bytes),
             int(x.repartition_bytes), float(x.gather_seconds),
             float(x.repartition_seconds),
             getattr(x, "cost_source", "static"),
             int(getattr(x, "parent_fanout", 1))]
            for n, x in (entry.exchanges or {}).items())
    return meta


def unpack_entry_meta(meta: Mapping[str, object], plan) -> Dict[str, object]:
    """Rebuild node-keyed dicts against *this* process's plan nodes;
    raises ``ValueError`` when the stored indices do not fit the local
    plan (a corrupted or key-colliding entry must reject, not mis-map)."""
    from repro.plan.annotate import JoinExchange
    from repro.plan.ir import node_order
    order = node_order(plan.emits())
    if int(meta["node_count"]) != len(order):
        raise ValueError("stored node metadata does not match the plan "
                         f"({meta['node_count']} nodes vs {len(order)})")
    out: Dict[str, object] = {
        "counts": {order[i]: int(v) for i, v in meta["counts"]},
        "caps": {order[i]: int(v) for i, v in meta["caps"]},
        "mode": meta["mode"],
        "build_seconds": float(meta["build_seconds"]),
    }
    if "cap_locals" in meta:
        out["cap_locals"] = {str(k): int(v)
                             for k, v in sorted(meta["cap_locals"].items())}
        out["out_cap_local"] = int(meta["out_cap_local"])
        out["sink_slack"] = float(meta["sink_slack"])
        out["safe_exchange"] = bool(meta["safe_exchange"])
        # pre-fanout entries carry 7 fields; parent_fanout defaults to 1
        # (same format version — the amortization changed pricing, not the
        # envelope)
        out["exchanges"] = {
            order[i]: JoinExchange(strategy=s, gather_bytes=int(gb),
                                   repartition_bytes=int(rb),
                                   gather_seconds=float(gs),
                                   repartition_seconds=float(rs),
                                   cost_source=str(src),
                                   parent_fanout=int(rest[0]) if rest else 1)
            for i, s, gb, rb, gs, rs, src, *rest
            in meta.get("exchanges", [])}
    return out


# ---------------------------------------------------------------------------
# AOT payload (de)serialization
# ---------------------------------------------------------------------------

_export_registered = False


def _register_export_types() -> None:
    """Teach ``jax.export`` to serialize the :class:`repro.relalg.Table`
    pytrees crossing the closure boundary (idempotent)."""
    global _export_registered
    if _export_registered:
        return
    from jax import export
    from repro.relalg import Table
    try:
        export.register_pytree_node_serialization(
            Table, serialized_name="repro.relalg.Table",
            serialize_auxdata=lambda attrs: json.dumps(list(attrs)).encode(),
            deserialize_auxdata=lambda b: tuple(json.loads(b.decode())))
    except ValueError:   # another caller registered it first — fine
        pass
    _export_registered = True


def serialize_native(compiled) -> bytes:
    """Pickle the AOT-compiled executable with its calling convention
    (:mod:`jax.experimental.serialize_executable` + the in/out treedefs)."""
    from jax.experimental import serialize_executable as se
    payload, in_tree, out_tree = se.serialize(compiled)
    return pickle.dumps((payload, in_tree, out_tree),
                        protocol=pickle.HIGHEST_PROTOCOL)


def deserialize_native(blob: bytes):
    """Load a :func:`serialize_native` payload back into a callable with
    the original positional calling convention (zero recompilation)."""
    from jax.experimental import serialize_executable as se
    payload, in_tree, out_tree = pickle.loads(blob)
    return se.deserialize_and_load(payload, in_tree, out_tree)


def serialize_stablehlo(fn_jit, abstract_args: Tuple) -> bytes:
    """``jax.export`` the jitted closure traced over abstract inputs —
    the portable tier (StableHLO; re-compiled on load)."""
    from jax import export
    _register_export_types()
    return export.export(fn_jit)(*abstract_args).serialize()


def deserialize_stablehlo(blob: bytes):
    """Rehydrate the portable tier: the StableHLO module wrapped back in
    ``jax.jit`` (XLA re-compiles it on first call — slower than the
    native tier but still skips planning and tracing)."""
    from jax import export
    _register_export_types()
    return jax.jit(export.deserialize(blob).call)


# ---------------------------------------------------------------------------
# the store
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LoadResult:
    """Outcome of one :meth:`PlanStore.load`: ``status`` is ``"hit"``
    (header+payloads returned), ``"miss"`` (no entry) or ``"reject"``
    (an entry exists but failed validation — ``reason`` says why)."""

    status: str
    header: Optional[Dict[str, object]] = None
    payloads: Optional[Dict[str, bytes]] = None
    reason: Optional[str] = None


class PlanStore:
    """Disk-backed tier of the plan cache: one entry file per store key.

    ``portable=False`` skips writing the ``stablehlo`` payload (faster
    write-back, native-tier-only entries). ``max_entries`` prunes the
    oldest entries (by mtime) after each save.
    """

    def __init__(self, root: Optional[str] = None, *, portable: bool = True,
                 max_entries: Optional[int] = None):
        self.root = os.path.abspath(root or default_store_root())
        self.portable = portable
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        self.rejects = 0
        self.writes = 0
        self.write_errors = 0
        self.write_skipped = 0
        self.reject_reasons: List[str] = []   # bounded diagnostic ring

    # -- paths ---------------------------------------------------------------
    def entry_path(self, key: str) -> str:
        return os.path.join(self.root, f"{key}.plan")

    def _reject(self, reason: str) -> LoadResult:
        self.rejects += 1
        self.reject_reasons.append(reason)
        del self.reject_reasons[:-16]
        return LoadResult(status="reject", reason=reason)

    # -- read ----------------------------------------------------------------
    def load(self, key: str,
             envelope: Mapping[str, object]) -> LoadResult:
        """Validated read of one entry. NEVER raises: every failure mode
        (missing file, corrupt container, envelope mismatch) returns a
        ``miss``/``reject`` result and the caller compiles fresh."""
        path = self.entry_path(key)
        try:
            if not os.path.exists(path):
                self.misses += 1
                return LoadResult(status="miss")
            header, payloads = read_container(path)
            if header.get("envelope") != dict(envelope):
                return self._reject("envelope mismatch")
            if header.get("key") != key:
                return self._reject("key mismatch")
            self.hits += 1
            return LoadResult(status="hit", header=header, payloads=payloads)
        except Exception as e:   # corrupt bytes must degrade, not crash
            return self._reject(f"{type(e).__name__}: {e}")

    # -- write ---------------------------------------------------------------
    def save(self, key: str, envelope: Mapping[str, object],
             meta: Mapping[str, object],
             payloads: Mapping[str, bytes]) -> bool:
        """Atomic write-back: temp file + ``os.replace`` under a per-entry
        advisory ``flock``. A busy lock (another writer is mid-flight on
        the same entry) skips; any OS error (read-only store, full disk)
        is swallowed and counted. Returns True iff the entry landed."""
        path = self.entry_path(key)
        lock_path = path + ".lock"
        tmp_path = None
        lock_fd = None
        try:
            os.makedirs(self.root, exist_ok=True)
            lock_fd = os.open(lock_path, os.O_CREAT | os.O_RDWR, 0o644)
            try:
                import fcntl
                fcntl.flock(lock_fd, fcntl.LOCK_EX | fcntl.LOCK_NB)
            except (ImportError, BlockingIOError, PermissionError):
                self.write_skipped += 1
                return False
            fd, tmp_path = tempfile.mkstemp(dir=self.root,
                                            prefix=f".{key[:16]}.tmp.")
            os.close(fd)
            header = {"version": FORMAT_VERSION, "key": key,
                      "envelope": dict(envelope), "meta": dict(meta)}
            write_container(tmp_path, header, payloads)
            os.replace(tmp_path, path)   # readers see old or new, never torn
            tmp_path = None
            self.writes += 1
            if self.max_entries is not None:
                self._prune()
            return True
        except OSError:
            self.write_errors += 1
            return False
        finally:
            if tmp_path is not None:
                try:
                    os.unlink(tmp_path)
                except OSError:
                    pass
            if lock_fd is not None:
                os.close(lock_fd)   # closing drops the flock

    def _prune(self) -> None:
        """Drop the oldest entries beyond ``max_entries`` — tolerant of
        concurrent stores (the serving norm): an entry vanishing or being
        replaced between the listing and the mtime read is skipped and
        counted under ``write_errors`` (the store's NEVER-raises contract
        covers pruning too), and the unlink itself is missing-ok."""
        stamped = []
        for path in self._entry_files():
            try:
                stamped.append((os.path.getmtime(path), path))
            except OSError:      # pruned/replaced behind our back
                self.write_errors += 1
        stamped.sort()
        for _, path in stamped[:max(0, len(stamped) - self.max_entries)]:
            try:
                os.unlink(path)
            except FileNotFoundError:   # a concurrent pruner won the race
                pass
            except OSError:
                self.write_errors += 1

    # -- introspection -------------------------------------------------------
    def _entry_files(self) -> List[str]:
        try:
            return [os.path.join(self.root, f) for f in os.listdir(self.root)
                    if f.endswith(".plan")]
        except OSError:
            return []

    def __len__(self) -> int:
        return len(self._entry_files())

    def stats(self) -> Dict[str, object]:
        files = self._entry_files()
        size = 0
        for p in files:     # same listing/stat race discipline as _prune
            try:
                size += os.path.getsize(p)
            except OSError:
                pass
        return {"root": self.root, "entries": len(files),
                "bytes": size,
                "hits": self.hits, "misses": self.misses,
                "rejects": self.rejects, "writes": self.writes,
                "write_errors": self.write_errors,
                "write_skipped": self.write_skipped}

    def clear(self) -> None:
        for path in self._entry_files():
            try:
                os.unlink(path)
            except OSError:
                pass


def resolve_store(plan_store) -> Optional[PlanStore]:
    """Normalize the ``KGEngine(plan_store=...)`` argument:

    * ``None``/``False`` — store disabled (the in-process LRU only);
    * ``True`` or ``"default"`` — :func:`default_store_root`
      (``$REPRO_PLAN_STORE`` or ``~/.cache/repro-plans``);
    * a path — a :class:`PlanStore` rooted there;
    * a :class:`PlanStore` — used as-is (sessions may share one).
    """
    if plan_store is None or plan_store is False:
        return None
    if isinstance(plan_store, PlanStore):
        return plan_store
    if plan_store is True or plan_store == "default":
        return PlanStore(default_store_root())
    if isinstance(plan_store, (str, os.PathLike)):
        return PlanStore(os.fspath(plan_store))
    raise TypeError(f"plan_store must be None, True, 'default', a path or "
                    f"a PlanStore; got {type(plan_store).__name__}")


# ---------------------------------------------------------------------------
# CLI — the CI plan-store leg's populate step
# ---------------------------------------------------------------------------

def _populate(root: str, n_rows: int) -> int:
    """Compile the standard smoke configurations into ``root`` (every
    engine × dedup, plus a fused-mesh session over all visible devices) —
    a separate process then runs the tier-1 plan-store tests against the
    populated store."""
    from repro.api.config import EngineConfig
    from repro.api.engine import KGEngine
    from repro.api.store import PlanStore as _PlanStore   # NOT the
    # ``__main__`` alias of this class: under ``python -m repro.api.store``
    # the module exists twice, and the engine isinstance-checks against
    # the canonically imported one
    from repro.data.synthetic import make_group_b_dis
    from repro.launch.mesh import make_mesh
    store = _PlanStore(root)
    for engine in ("rmlmapper", "sdm"):
        for dedup in ("lex", "hash"):
            session = KGEngine(make_group_b_dis(n_rows, 0.6, seed=0),
                               config=EngineConfig(engine=engine,
                                                   dedup=dedup,
                                                   plan_store=store))
            session.create_kg()
    mesh = make_mesh((jax.device_count(),), ("data",))
    session = KGEngine(make_group_b_dis(n_rows, 0.6, seed=0),
                       config=EngineConfig(engine="sdm", dedup="hash",
                                           mesh=mesh, plan_store=store))
    session.create_kg()
    print(json.dumps(store.stats(), indent=1))
    return 0 if store.writes > 0 and store.write_errors == 0 else 1


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(prog="python -m repro.api.store")
    sub = ap.add_subparsers(dest="cmd", required=True)
    p = sub.add_parser("populate", help="compile smoke configs into a store")
    p.add_argument("--root", default=None)
    p.add_argument("--rows", type=int, default=48)
    p = sub.add_parser("ls", help="list store entries")
    p.add_argument("--root", default=None)
    p = sub.add_parser("clear", help="delete every entry")
    p.add_argument("--root", default=None)
    args = ap.parse_args(argv)
    root = args.root or default_store_root()
    if args.cmd == "populate":
        return _populate(root, args.rows)
    store = PlanStore(root)
    if args.cmd == "clear":
        store.clear()
    for path in sorted(store._entry_files()):
        try:
            header, payloads = read_container(path)
            print(f"{os.path.basename(path)}  "
                  f"{os.path.getsize(path)}B  "
                  f"payloads={sorted(payloads)}  "
                  f"jax={header['envelope']['jax']}  "
                  f"devices={header['envelope']['device_count']}")
        except Exception as e:
            print(f"{os.path.basename(path)}  INVALID ({e})")
    print(json.dumps(store.stats(), indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
