"""The session plan cache: content-keyed compiled-closure reuse.

MapSDI's amortization story is "extract knowledge from the mapping rules
once, semantify many extensions cheaply". The cache makes *once* literal
across sessions: a compiled plan is keyed by

* the **structural fingerprint** of the optimized IR
  (:func:`repro.plan.ir.fingerprint` — node structure, σ predicate codes,
  π/⋈ wiring, full triple maps),
* the **emitter signature** (every dictionary code the closure embeds:
  predicates, classes, constants, templates, null code — two DISes whose
  codes differ must not share a closure even if their plans look alike),
* engine × dedup × annotate mode/slack, and
* the **capacity-bucket signature** of the source extensions
  (:func:`repro.relalg.bucket_cap` of each source's row count, plus its
  buffer capacity) — the quantization that lets *ranges* of extension
  sizes share one jitted program, and that turns a growing source into
  O(log n) recompiles.

Entries are replaced in place when the engine recompiles on overflow (the
bigger capacities serve every smaller extension of the same bucket), and
evicted LRU beyond ``maxsize``.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Dict, Optional, Tuple

from repro.plan.ir import Node


@dataclasses.dataclass
class CachedPlan:
    """One compiled execution plan: the jitted closure plus everything the
    session needs to report stats without re-planning.

    Mesh entries (``compile_mesh_plan`` closures) additionally carry the
    shard layout the closure was traced for: ``cap_locals`` (per-source
    per-shard row-block capacity — part of the cache key, so a source
    crossing its shard-local bucket gets a fresh closure), ``out_cap_local``
    (per-shard capacity of the returned KG block, what ``unshard_rows``
    needs), ``sink_slack`` (the fused sink δ's bucket headroom; grown on
    bucket overflow), ``exchanges`` (the resolved per-⋈
    :class:`repro.plan.annotate.JoinExchange` decisions the closure was
    compiled with — what ``explain`` and the bench gates inspect) and
    ``safe_exchange`` (True after an overflow recompile escalated every
    exchange bucket/post-exchange cap to its hard-safe bound).
    ``caps``/``counts`` for mesh entries are the shard-local capacities /
    global counts of ``annotate_local``."""

    key: Tuple
    plan: object                 # repro.plan.lower.LogicalPlan
    emitter: object              # repro.core.rdfizer.RDFizer
    counts: Dict[Node, int]      # plan-time row counts (exact or bound)
    caps: Dict[Node, int]        # plan-time buffer capacities
    fn: Callable                 # sources -> (kg, raw, overflowed)
    engine: str
    dedup: Optional[str]
    mode: str
    build_seconds: float = 0.0
    cap_locals: Optional[Dict[str, int]] = None   # mesh: per-shard source caps
    out_cap_local: Optional[int] = None           # mesh: per-shard KG capacity
    sink_slack: float = 1.0                       # mesh: sink δ bucket slack
    exchanges: Optional[Dict[Node, object]] = None  # mesh: per-⋈ decisions
    safe_exchange: bool = False                   # mesh: hard-safe buckets
    #: where the closure came from: ``"build"`` (compiled in this process)
    #: or ``"store"`` (rehydrated from the persistent plan store — the
    #: engine treats a call-time failure of such a closure as one more
    #: store-reject and rebuilds fresh instead of crashing)
    origin: str = "build"


class PlanCache:
    """Tiny LRU keyed on the tuple above; shared across sessions."""

    def __init__(self, maxsize: int = 128):
        self.maxsize = maxsize
        self._entries: "OrderedDict[Tuple, CachedPlan]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, key: Tuple) -> Optional[CachedPlan]:
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return entry

    def put(self, key: Tuple, entry: CachedPlan) -> None:
        self._entries[key] = entry
        self._entries.move_to_end(key)
        while len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)

    def __len__(self) -> int:
        return len(self._entries)

    def clear(self) -> None:
        self._entries.clear()
        self.hits = 0
        self.misses = 0

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "size": len(self._entries)}


#: process-wide cache shared by every :class:`~repro.api.KGEngine` session
PLAN_CACHE = PlanCache()


def clear_plan_cache() -> None:
    """Drop every cached plan (benchmarks use this to measure cold paths)."""
    PLAN_CACHE.clear()


def plan_cache_stats() -> Dict[str, int]:
    return PLAN_CACHE.stats()
