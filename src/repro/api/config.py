"""``EngineConfig`` — the one frozen value that configures a session.

:class:`~repro.api.engine.KGEngine` historically grew a 12-kwarg
constructor; every knob was validated (or not) ad hoc at a different
depth, and the plan-cache/store key derivation read the knobs back off
scattered instance attributes. ``EngineConfig`` consolidates them::

    engine = KGEngine(dis, config=EngineConfig(engine="sdm", dedup="hash"))

* **Construction-time validation, named errors.** Every field is checked
  in ``__post_init__`` — a bad ``engine``/``dedup``/``mode``/``slack``/
  ``mesh_axis``/``join_exchange``/``verify`` raises ``ValueError`` naming
  the field *before* any planning work starts (previously a bad ``dedup``
  or ``slack`` only surfaced deep inside the first compile).
* **Single key input.** :meth:`EngineConfig.cache_sig` is the static
  configuration component of the plan-cache key (and, through it, of the
  persistent-store key) — the engine derives both keys from the config,
  never from loose attributes.

The legacy ``KGEngine(dis, engine=..., dedup=..., ...)`` kwargs still
work but emit a one-time ``DeprecationWarning``; they are internally
folded into an ``EngineConfig``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

#: δ strategies :func:`repro.relalg.ops.dedup_rows` implements
#: (``None`` = engine default, :data:`repro.relalg.DEFAULT_DEDUP`)
DEDUP_STRATEGIES = (None, "lex", "hash")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Frozen configuration of one :class:`~repro.api.KGEngine` session.

    Field semantics are documented on :class:`~repro.api.KGEngine` (they
    are the former constructor kwargs, unchanged); this class owns their
    validation and the derivation of the session's cache-key component.
    """

    engine: str = "sdm"
    dedup: Optional[str] = None
    optimize: bool = True
    mode: str = "exact"
    slack: float = 1.0
    mesh: object = None
    mesh_axis: str = "data"
    jit: bool = True
    join_exchange: str = "auto"
    plan_store: object = None
    calibrate: object = False
    verify: str = "plan"

    def __post_init__(self):
        from repro.plan.annotate import JOIN_EXCHANGES
        if self.engine not in ("rmlmapper", "sdm"):
            raise ValueError(f"unknown engine {self.engine!r} "
                             "(expected 'rmlmapper' or 'sdm')")
        if self.dedup not in DEDUP_STRATEGIES:
            raise ValueError(f"unknown dedup strategy {self.dedup!r} "
                             "(expected None, 'lex' or 'hash')")
        if self.mode not in ("exact", "bound"):
            raise ValueError(f"unknown annotate mode {self.mode!r} "
                             "(expected 'exact' or 'bound')")
        try:
            slack = float(self.slack)
        except (TypeError, ValueError):
            raise ValueError(f"bad slack {self.slack!r} (expected a finite "
                             "number >= 1)") from None
        if not math.isfinite(slack) or slack < 1.0:
            raise ValueError(f"bad slack {self.slack!r} (expected a finite "
                             "number >= 1 — capacities below the annotated "
                             "counts would truncate on the first run)")
        object.__setattr__(self, "slack", slack)
        if not isinstance(self.mesh_axis, str) or not self.mesh_axis:
            raise ValueError(f"bad mesh_axis {self.mesh_axis!r} "
                             "(expected a non-empty axis name)")
        if self.mesh is not None:
            axes = tuple(getattr(self.mesh, "shape", {}))
            if self.mesh_axis not in axes:
                raise ValueError(f"mesh_axis {self.mesh_axis!r} is not an "
                                 f"axis of the mesh (axes: {axes})")
        if self.join_exchange not in JOIN_EXCHANGES:
            raise ValueError(f"unknown join exchange "
                             f"{self.join_exchange!r} "
                             f"(expected one of {JOIN_EXCHANGES})")
        if self.verify not in ("off", "plan", "full"):
            raise ValueError(f"unknown verify level {self.verify!r} "
                             "(expected 'off', 'plan' or 'full')")

    def cache_sig(self) -> Tuple:
        """The static configuration component of the plan-cache key —
        every config field that changes the traced program and is not
        already covered by the IR fingerprint, the emitter signature or
        the mesh signature. Restricted to
        :func:`repro.api.store.canonical`-admissible values."""
        return (self.engine, self.dedup, self.mode, self.slack, self.jit)
