"""``KGEngine`` — the stateful session front door to the MapSDI pipeline.

The paper's framework amortizes: extract knowledge from the mapping rules
once, then semantify large and *growing* sources cheaply. The repo's
historical entry points (``mapsdi_create_kg``, ``make_planned_fn``,
``make_mapsdi_fn``, ``rdfize``) each re-planned, re-annotated and re-jitted
from scratch, and silently truncated when an extension outgrew its
plan-time capacities. ``KGEngine`` replaces them with one session object::

    engine = KGEngine(dis, config=EngineConfig(engine="sdm", dedup="hash"))
    kg, stats = engine.create_kg()           # plan + compile (or cache hit)
    kg, stats = engine.ingest(delta_sources) # micro-batch extension
    ans = engine.query(q)                    # jitted BGP over the KG
    engine.stats()                           # session counters

Three mechanisms (see ``docs/engine.md``):

* **Plan cache** — compiled closures are keyed by the structural
  fingerprint of the optimized IR × the emitter's dictionary codes ×
  engine × dedup × the capacity *bucket* of every source extension
  (:data:`repro.api.cache.PLAN_CACHE`). A structurally-identical DIS — or
  the same session re-executing after a within-bucket ingest — reuses one
  jitted closure with zero re-trace.
* **Overflow-safe re-execution** — capacities are sized per bucket
  (``annotate`` in ``"exact"`` or ``"bound"`` mode ×
  :func:`repro.relalg.bucket_cap`); the closure reports a truncation flag,
  and the engine transparently recompiles into the next capacity bucket
  and re-runs, counting ``recompiles``. The KG is never silently wrong.
* **Fully device-resident distributed plans** — with a ``mesh``, the
  WHOLE pipeline (Scan over shard-local row blocks, π/σ/δ, ⋈ with
  gathered parents, semantification, and the global sink δ as a fused
  hash-repartition collective) runs inside one ``shard_map`` closure
  (:func:`repro.plan.mesh.compile_mesh_plan`). Intermediate triples never
  touch the host: the engine shards the session sources once per ingest,
  re-executes the cached mesh closure, and only reads back the final
  deduplicated KG. Capacities are annotated *per shard*
  (:func:`repro.plan.annotate.annotate_local`) and the cache key extends
  to (mesh shape, axis, device ids, per-source shard-local capacity
  bucket), so recompile-on-overflow and bucket-crossing ingests work
  exactly as on one device.
"""
from __future__ import annotations

import time
import warnings
from typing import Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rdfizer import RDFizer
from repro.core.schema import DIS, TRIPLE_ATTRS
from repro.core.transform import TransformStats, plan_mapsdi
from repro.plan.annotate import annotate, annotate_local
from repro.plan.compile import abstract_sources, compile_plan, input_names
from repro.plan.ir import fingerprint
from repro.plan.lower import LogicalPlan, lower
from repro.query import (KG_SOURCE, Query, annotate_query,
                         annotate_query_local, compile_query, lower_query,
                         query_session_key)
from repro.relalg import (PAD_ID, Table, append_rows, bucket_cap, distinct,
                          host_int)

from .cache import PLAN_CACHE, CachedPlan
from .config import EngineConfig
from .store import (NATIVE, STABLEHLO, deserialize_native,
                    deserialize_stablehlo, pack_entry_meta, resolve_store,
                    serialize_native, serialize_stablehlo, store_envelope,
                    store_key, unpack_entry_meta)

#: sentinel distinguishing "kwarg not passed" from every real value — a
#: bare ``KGEngine(dis)`` must not warn; an explicit legacy kwarg must
_UNSET = object()
_WARNED_LEGACY: set = set()


def _warn_legacy_kwargs(names: Tuple[str, ...]) -> None:
    """One ``DeprecationWarning`` per distinct legacy-kwarg combination
    per process — enough to steer migrations without drowning loops."""
    if names in _WARNED_LEGACY:
        return
    _WARNED_LEGACY.add(names)
    warnings.warn(
        "KGEngine keyword configuration (" + ", ".join(names) + ") is "
        "deprecated; pass config=EngineConfig(...) instead — the legacy "
        "kwargs will be removed once out-of-tree callers have migrated",
        DeprecationWarning, stacklevel=3)


def _to_bucket(table: Table) -> Table:
    """Pad a table's buffer up to its geometric capacity bucket (device
    concat, no host read) — the headroom that keeps small ingests
    shape-stable."""
    cap = bucket_cap(table.capacity)
    if cap == table.capacity:
        return table
    pad = jnp.full((cap - table.capacity, table.n_attrs), jnp.int32(PAD_ID))
    return Table(data=jnp.concatenate([table.data, pad], axis=0),
                 count=table.count, attrs=table.attrs)


def _emitter_signature(emitter: RDFizer) -> Tuple:
    """Every dictionary code the compiled closure embeds, read off the
    emitter's pre-interned tables: two plans may only share a closure if
    these match (same strings under different vocabs get different codes —
    and different programs). Reading the tables — instead of re-interning —
    keeps the engine's vocab-growth order identical to the historical
    RDFizer paths, so old- and new-API outputs stay bit-identical."""
    return (emitter.dis.null_code, emitter.rdf_type_code,
            tuple(sorted(emitter._pred.items())),
            tuple(sorted(emitter._class.items())),
            tuple(sorted((str(k), v) for k, v in emitter._const.items())),
            tuple(sorted((str(k), v)
                         for k, v in emitter._subj_const.items())),
            tuple(sorted((str(k), v) for k, v in emitter._sel.items())),
            tuple(sorted(emitter._subject_tmpl.items())),
            tuple(sorted((repr(k), v)
                         for k, v in emitter._tmpl_ids.items())))


class KGEngine:
    """Stateful MapSDI session: cached plans, incremental ingestion,
    overflow-safe re-execution.

    Parameters
    ----------
    dis
        The data integration system. The engine owns a session *view* of
        its sources (``dis`` itself is never mutated); ``ingest`` appends
        to the view.
    config
        An :class:`~repro.api.EngineConfig` holding every knob below —
        the canonical spelling::

            KGEngine(dis, config=EngineConfig(engine="sdm", dedup="hash"))

        The individual keyword arguments still work but are deprecated
        (one-time ``DeprecationWarning``); passing both raises
        ``ValueError``. All validation lives in ``EngineConfig`` — bad
        values raise named errors at construction, before any planning.
    engine
        ``"sdm"`` (duplicate-aware per-map δ) or ``"rmlmapper"`` (blind
        generation, sink δ only).
    dedup
        δ strategy (``"lex"`` | ``"hash"`` | None = engine default).
    optimize
        Run the Rule 1–3 + σ + CSE fixpoint (default). ``False`` compiles
        the un-rewritten plan — the T-framework/``rdfize`` semantics, where
        ``raw_triples`` counts blind generation.
    mode
        ``annotate`` mode: ``"exact"`` (host pass per bucket change, tight
        buffers) or ``"bound"`` (structural upper bounds, zero host reads —
        for huge sources where exact counting doubles host work).
    slack
        Multiplier on annotated counts before bucketing — headroom that
        absorbs extension growth without recompiling.
    mesh / mesh_axis
        When given, the whole plan — per-map pipeline AND the global sink
        δ — compiles into one mesh-resident ``shard_map`` closure over
        row-sharded sources (:func:`repro.plan.mesh.compile_mesh_plan`);
        intermediate triples never leave the devices, and only the final
        deduplicated KG is gathered back (then canonically re-ordered so
        the output is bit-identical to the single-device path).
    join_exchange
        ⋈ exchange strategy inside the fused mesh closure (ignored without
        a mesh): ``"gather"`` all_gathers the parent side to every shard,
        ``"repartition"`` hash-partitions both sides by join key with one
        ``all_to_all`` each, ``"auto"`` (default) lets the per-join cost
        model pick whichever moves fewer estimated wire bytes
        (:func:`repro.plan.annotate.join_exchange_cost`). All three
        produce bit-identical KGs; the knob is part of the plan-cache key.
        ``"auto"`` decisions are resolved at compile time from the
        plan-time counts, so they re-resolve on every capacity-bucket
        crossing.
    plan_store
        Persistent second tier behind the in-process LRU
        (``docs/plan_store.md``): ``None`` (default) disables it; ``True``
        or ``"default"`` uses ``$REPRO_PLAN_STORE`` /
        ``~/.cache/repro-plans``; a path or a
        :class:`repro.api.store.PlanStore` uses that store. With a store,
        compiles go through AOT lowering, the executable is serialized to
        disk keyed by the plan-cache key × a runtime compatibility
        envelope, and an LRU-missing session in a *fresh process*
        rehydrates it without re-tracing or re-compiling. Every load
        failure (corruption, envelope mismatch, deserialization error)
        silently degrades to a fresh compile — counted in ``stats()`` as
        ``store_rejects``, never a crash, never a wrong KG. Requires
        ``jit=True`` (eager sessions skip the store).
    calibrate
        Measured-bandwidth cost model (ignored without a mesh). ``True``
        microbenchmarks ``all_gather``/``all_to_all`` over the mesh axis
        once at session start (memoized per process and mesh) and prices
        every ⋈ exchange with the fitted bandwidths and launch constant
        instead of the static v5e datasheet numbers; a
        :class:`repro.launch.mesh.Calibration` instance injects known
        numbers. The calibration signature joins the plan-cache key and
        the persistent-store envelope, so calibrated and static plans
        (or plans measured under different link speeds) never collide.
        ``explain()`` shows the provenance as each ⋈ line's ``cost=`` bit.
    """

    def __init__(self, dis: DIS, engine: str = _UNSET,
                 dedup: Optional[str] = _UNSET, *,
                 config: Optional[EngineConfig] = None,
                 optimize: bool = _UNSET, mode: str = _UNSET,
                 slack: float = _UNSET, mesh=_UNSET, mesh_axis: str = _UNSET,
                 jit: bool = _UNSET, join_exchange: str = _UNSET,
                 plan_store=_UNSET, calibrate=_UNSET, verify: str = _UNSET):
        legacy = {name: value for name, value in (
            ("engine", engine), ("dedup", dedup), ("optimize", optimize),
            ("mode", mode), ("slack", slack), ("mesh", mesh),
            ("mesh_axis", mesh_axis), ("jit", jit),
            ("join_exchange", join_exchange), ("plan_store", plan_store),
            ("calibrate", calibrate), ("verify", verify))
            if value is not _UNSET}
        if config is not None:
            if legacy:
                raise ValueError(
                    "pass either config=EngineConfig(...) or the legacy "
                    "keyword arguments, not both (got config plus "
                    f"{sorted(legacy)})")
            if not isinstance(config, EngineConfig):
                raise TypeError("config must be an EngineConfig, got "
                                f"{type(config).__name__}")
        else:
            if legacy:
                _warn_legacy_kwargs(tuple(sorted(legacy)))
            config = EngineConfig(**legacy)   # validates every field
        self.config = config
        engine, dedup = config.engine, config.dedup
        optimize, mode, slack = config.optimize, config.mode, config.slack
        mesh, mesh_axis, jit = config.mesh, config.mesh_axis, config.jit
        join_exchange = config.join_exchange
        plan_store, calibrate = config.plan_store, config.calibrate
        verify = config.verify
        # static verification level: "plan" (default) gates every rewrite
        # with its soundness contract and verifies each annotated plan
        # before compiling (and every store-rehydrated entry before
        # adoption); "full" additionally audits the lowered closure's
        # jaxpr (collectives vs the exchange plan, zero host
        # callbacks/transfers, dtype stability); "off" disables all of it
        self.verify = verify
        self._verify_plan_checks = 0
        self._verify_audits = 0
        self._verify_store_checks = 0
        self.join_exchange = join_exchange
        # measured-bandwidth cost model: ``True`` runs the session-start
        # collective microbenchmark once per mesh (memoized process-wide);
        # a Calibration instance injects known numbers (tests/replays);
        # False (default) keeps the static datasheet constants. The
        # calibration signature joins the plan-cache key and the store
        # envelope, so plans priced under different link speeds never
        # collide.
        self.calibration = None
        if mesh is not None and calibrate is not False:
            from repro.launch.mesh import Calibration, calibrate_mesh
            self.calibration = (calibrate if isinstance(calibrate,
                                                        Calibration)
                                else calibrate_mesh(mesh, mesh_axis))
        self.engine = engine
        self.dedup = dedup
        self._store = resolve_store(plan_store)
        self._store_hits = 0
        self._store_misses = 0
        self._store_rejects = 0
        self.optimize = optimize
        self.mode = mode
        self.slack = float(slack)
        self.mesh, self.mesh_axis = mesh, mesh_axis
        self.jit = jit
        self._dis = dis.copy()
        # session view of the extensions, re-buffered into geometric
        # capacity buckets so within-bucket ingests never change shapes
        self._dis.sources = {name: _to_bucket(t)
                             for name, t in dis.sources.items()}
        self.sources: Dict[str, Table] = self._dis.sources
        self._tstats = TransformStats()
        t0 = time.perf_counter()
        self._plan = (plan_mapsdi(self._dis, stats=self._tstats,
                                  gate=self._rewrite_gate())
                      if optimize else lower(self._dis))
        # the session emitter is built here, over the rewritten maps, in
        # the same order the historical paths did — vocab growth (and so
        # every embedded code) stays bit-compatible with the old API
        view = self._dis.copy()
        view.maps = list(self._plan.maps)
        self._emitter = RDFizer(view, engine, join_caps={}, dedup=dedup)
        view.sources = {}   # the emitter never reads extensions; dropping
        # them keeps cached closures from pinning device tables for the
        # lifetime of the process-wide plan cache
        self._ir_fp = fingerprint(self._plan.emits())
        self._emit_sig = _emitter_signature(self._emitter)
        self._plan_seconds = time.perf_counter() - t0
        # mesh sessions keep the sharded source blocks device-resident
        # between runs, keyed by the source Table object's identity — any
        # replacement (ingest's append_rows, direct assignment) re-shards
        self._shard_cache: Dict[str, Tuple] = {}
        self._scan_names_cache: Optional[Tuple[str, ...]] = None
        # the mesh's identity is fixed for the session: key prefix once
        self._mesh_static = None if mesh is None else (
            tuple(mesh.shape.items()), mesh_axis,
            tuple(int(d.id) for d in np.asarray(mesh.devices).flat))
        self._have_plan = False     # a closure has been obtained (any way)
        self._builds = 0            # closures actually compiled HERE (not
        # LRU hits, not store rehydrations) — what the serving layer's
        # compile-dedup ratio counts across tenant sessions
        # sticky per-session escalation: once adversarial key/hash skew
        # forced a safe-capacity rebuild, later builds (e.g. after a
        # bucket-crossing ingest of the same skewed stream) start safe
        # instead of re-paying a Poisson-then-safe double compile
        self._safe_exchange = False
        self._recompiles = 0        # compiles beyond the session's first
        self._executions = 0
        self._ingests = 0
        self._ingested_rows = 0
        self._cache_hits = 0
        self._cache_misses = 0
        self._last: Dict[str, object] = {}
        # query tier (KGEngine.query): the session KG the BGP engine reads,
        # its capacity-bucketed view and sharded device blocks (both
        # identity-keyed — a new KG from run()/ingest() re-buckets and
        # re-shards), the query-side sticky safe-exchange escalation, and
        # the per-session query counters surfaced as ``stats()["query"]``
        self._kg: Optional[Table] = None
        self._kg_bucket: Optional[Tuple[Table, Table]] = None
        self._kg_shard: Optional[Tuple] = None
        self._q_safe_exchange = False
        self._q_executions = 0
        self._q_cache_hits = 0
        self._q_cache_misses = 0
        self._q_recompiles = 0
        self._q_store_hits = 0
        self._q_store_misses = 0
        self._q_store_rejects = 0
        self._q_last: Dict[str, object] = {}

    # -- plan cache ----------------------------------------------------------
    @property
    def plan(self):
        """The optimized :class:`~repro.plan.lower.LogicalPlan`."""
        return self._plan

    @property
    def plan_signature(self) -> Tuple:
        """The session's *shape*: structural IR fingerprint × emitter
        dictionary codes × static config signature — every plan-cache key
        component except the (data-dependent) source/mesh capacity
        buckets. Two sessions with equal signatures share compiled
        closures bucket-for-bucket; the serving layer's session registry
        (:mod:`repro.serve`) keys tenants on it to assert the
        K-compiles-for-T-tenants dedup."""
        return (self._ir_fp, self._emit_sig) + self.config.cache_sig()

    @property
    def builds(self) -> int:
        """Closures compiled *by this session* (plan-cache hits and
        plan-store rehydrations excluded) — the denominator of the serve
        layer's compile-dedup ratio."""
        return self._builds

    @property
    def recompiles(self) -> int:
        """Compiles beyond the session's first (capacity-bucket crossings,
        overflow ladders) — the serve layer's admission controller watches
        this to detect recompile storms."""
        return self._recompiles

    def explain(self) -> str:
        """Annotated plan tree over the session's current sources. On a
        mesh session every ⋈ line additionally shows the cost model's
        exchange decision under the session's ``join_exchange`` knob plus
        the estimated per-device wire bytes of both strategies. Once a
        closure has been compiled, the tree renders the *compiled* entry's
        counts/caps/exchanges — exactly what the cached closure was built
        with (an ``"auto"`` decision near the crossover could otherwise
        differ from a fresh estimate); before the first execution it
        predicts with the session's own mode/slack/bucketing and sticky
        safe-exchange state."""
        from repro.plan.explain import dump_plan
        if self.mesh is None:
            counts, caps = annotate(self._plan)
            exchanges = None
        else:
            entry = self._last.get("entry") if self._last else None
            if entry is not None and entry.exchanges is not None:
                counts, caps = entry.counts, entry.caps
                exchanges = entry.exchanges
            else:
                counts, caps, exchanges = annotate_local(
                    self._plan,
                    n_shards=int(self.mesh.shape[self.mesh_axis]),
                    cap_locals=self._cap_locals(self.sources),
                    mode=self.mode, slack=self.slack, cap_fn=bucket_cap,
                    sources=self.sources,
                    join_exchange=self.join_exchange,
                    safe_exchange=self._safe_exchange,
                    calibration=self.calibration)
        schemas = verdict = None
        if self.verify != "off":
            from repro.analysis.verify import verify_plan
            report = verify_plan(
                self._plan, self.engine, counts=counts, caps=caps,
                sources=self.sources, shard_local=self.mesh is not None,
                slack=self.slack, check_canonical=self.optimize,
                check_cse=self.optimize)
            schemas, verdict = report.schemas, report.describe()
        return dump_plan(self._plan, self.engine, counts, caps, exchanges,
                         schemas=schemas, verdict=verdict)

    def _source_sig(self, sources: Mapping[str, Table]) -> Tuple:
        return tuple(sorted(
            (name, t.capacity, tuple(t.attrs), bucket_cap(host_int(t.count)))
            for name, t in sources.items()))

    def _cap_locals(self, sources: Mapping[str, Table]) -> Dict[str, int]:
        """Per-shard row-block capacity bucket per scanned source — the
        shard-local analogue of the source capacity bucket, and part of
        the mesh cache key (a source crossing its shard-local bucket must
        get a freshly-shaped closure)."""
        n = int(self.mesh.shape[self.mesh_axis])
        return {name: bucket_cap(-(-sources[name].capacity // n))
                for name in self._scan_names}

    @property
    def _scan_names(self) -> Tuple[str, ...]:
        """Source names the current plan scans — static per plan, cached
        so the per-run cache-key computation never re-walks the IR DAG."""
        if self._scan_names_cache is None:
            from repro.plan.mesh import plan_scans
            self._scan_names_cache = tuple(sorted(plan_scans(self._plan)))
        return self._scan_names_cache

    def _mesh_sig(self, sources: Mapping[str, Table]) -> Optional[Tuple]:
        """Mesh part of the cache key: shape, axis, device ids (static,
        computed once), per-source shard-local capacity bucket, the
        u16-packability of the vocab (baked into every exchange's
        all_to_all payload), and the ⋈ exchange knob (different strategies
        are different collective programs; ``"auto"``'s per-join
        resolution is a build-time perf decision, so within-bucket count
        drift never invalidates a cached closure)."""
        if self.mesh is None:
            return None
        cal_sig = (None if self.calibration is None
                   else self.calibration.signature())
        return self._mesh_static + (
            tuple(sorted(self._cap_locals(sources).items())),
            len(self._dis.vocab) < (1 << 16), self.join_exchange, cal_sig)

    def _key(self, sources: Mapping[str, Table]) -> Tuple:
        # the static configuration component comes off the EngineConfig —
        # the one input to key derivation — never off loose attributes
        return (self._ir_fp, self._emit_sig) + self.config.cache_sig() + (
            self._mesh_sig(sources), self._source_sig(sources))

    def _rewrite_gate(self):
        """The optimizer's per-rewrite soundness hook (``None`` when
        verification is off)."""
        if self.verify == "off":
            return None
        from repro.analysis.soundness import soundness_gate
        return soundness_gate

    def _verify_built(self, counts, caps, sources,
                      shard_local: bool) -> None:
        """Statically verify the annotated plan before it is compiled;
        a failure raises :class:`repro.analysis.PlanVerificationError`
        (a malformed plan must never reach XLA, let alone a KG)."""
        if self.verify == "off":
            return
        from repro.analysis.verify import verify_plan
        verify_plan(self._plan, self.engine, counts=counts, caps=caps,
                    sources=sources, shard_local=shard_local,
                    slack=self.slack, check_canonical=self.optimize,
                    check_cse=self.optimize).raise_for_status()
        self._verify_plan_checks += 1

    def _replan(self) -> None:
        """Re-lower/re-optimize after a provenance change (e.g. σ-baked
        flags dropped by :meth:`ingest`); the cache key follows the new
        plan structure, so the next execution compiles fresh."""
        t0 = time.perf_counter()
        self._plan = (plan_mapsdi(self._dis, gate=self._rewrite_gate())
                      if self.optimize else lower(self._dis))
        self._ir_fp = fingerprint(self._plan.emits())
        self._scan_names_cache = None   # the new plan may scan differently
        self._plan_seconds += time.perf_counter() - t0

    def _slim_plan(self):
        """The plan as stored/captured by cache entries: same nodes and
        maps, but a DIS stub without the source extensions, so entries
        outliving this session never pin its device tables."""
        stub = self._dis.copy()
        stub.sources = {}
        return LogicalPlan(dis=stub, maps=list(self._plan.maps),
                           inputs=dict(self._plan.inputs),
                           names=dict(self._plan.names),
                           preprocessed=self._plan.preprocessed,
                           sigma_baked=self._plan.sigma_baked)

    def _build(self, key: Tuple, sources: Mapping[str, Table],
               mode: Optional[str] = None,
               floor_caps: Optional[Mapping] = None,
               sink_slack: float = 1.0,
               safe_exchange: bool = False) -> CachedPlan:
        t0 = time.perf_counter()
        safe_exchange = safe_exchange or self._safe_exchange
        self._safe_exchange = safe_exchange
        plan = self._slim_plan()
        # with a persistent store, compiles go through explicit AOT
        # lowering so the SAME executable both serves this session
        # (entry.fn) and serializes to disk — never a second XLA compile
        # just to write the entry back
        aot = self._store is not None and self.jit
        if self.mesh is None:
            counts, caps = annotate(self._plan, mode=mode or self.mode,
                                    slack=self.slack, cap_fn=bucket_cap,
                                    sources=sources)
            if floor_caps:  # growth must be monotone or overflow ping-pongs
                caps = {n: max(c, floor_caps.get(n, 0))
                        for n, c in caps.items()}
            self._verify_built(counts, caps, sources, shard_local=False)
            fn = compile_plan(plan, self._emitter, engine=self.engine,
                              dedup=self.dedup, caps=caps, jit=self.jit,
                              report_overflow=True)
            abstract = ((abstract_sources(sources),)
                        if aot or self.verify == "full" else None)
            if self.verify == "full":
                from repro.analysis.audit import audit_closure
                audit_closure(fn, abstract, plan=self._plan,
                              engine=self.engine,
                              single_device=True).raise_for_status()
                self._verify_audits += 1
            entry = CachedPlan(key=key, plan=plan, emitter=self._emitter,
                               counts=counts, caps=caps, fn=fn,
                               engine=self.engine, dedup=self.dedup,
                               mode=mode or self.mode,
                               build_seconds=time.perf_counter() - t0)
        else:
            from repro.plan.mesh import compile_mesh_plan
            n = int(self.mesh.shape[self.mesh_axis])
            cap_locals = self._cap_locals(sources)
            counts, caps, exchanges = annotate_local(
                self._plan, n_shards=n, cap_locals=cap_locals,
                mode=mode or self.mode, slack=self.slack,
                cap_fn=bucket_cap, sources=sources,
                join_exchange=self.join_exchange,
                safe_exchange=safe_exchange,
                calibration=self.calibration)
            if floor_caps:
                caps = {n_: max(c, floor_caps.get(n_, 0))
                        for n_, c in caps.items()}
            self._verify_built(counts, caps, sources, shard_local=True)
            fn, out_cap_local = compile_mesh_plan(
                plan, self._emitter, self.mesh, self.mesh_axis,
                engine=self.engine, dedup=self.dedup, caps=caps,
                cap_locals=cap_locals, sink_slack=sink_slack,
                pack_u16=len(self._dis.vocab) < (1 << 16), jit=self.jit,
                exchanges=exchanges, safe_exchange=safe_exchange)
            if aot or self.verify == "full":
                from repro.plan.mesh import mesh_abstract_inputs
                abstract = mesh_abstract_inputs(self._plan, cap_locals, n,
                                                self.mesh, self.mesh_axis)
            if self.verify == "full":
                from repro.analysis.audit import audit_closure
                audit_closure(fn, abstract, plan=self._plan,
                              engine=self.engine, n_shards=n,
                              exchanges=exchanges).raise_for_status()
                self._verify_audits += 1
            entry = CachedPlan(key=key, plan=plan, emitter=self._emitter,
                               counts=counts, caps=caps, fn=fn,
                               engine=self.engine, dedup=self.dedup,
                               mode=mode or self.mode,
                               build_seconds=time.perf_counter() - t0,
                               cap_locals=cap_locals,
                               out_cap_local=out_cap_local,
                               sink_slack=sink_slack,
                               exchanges=exchanges,
                               safe_exchange=safe_exchange)
        if aot:
            try:
                entry.fn = fn.lower(*abstract).compile()
            except Exception:   # AOT unavailable: keep the jitted closure
                self._store.write_errors += 1
                aot = False
            entry.build_seconds = time.perf_counter() - t0
        PLAN_CACHE.put(key, entry)
        self._builds += 1
        if aot:
            self._store_save(entry, fn, abstract)
        if self._have_plan:
            self._recompiles += 1
        return entry

    def _store_save(self, entry: CachedPlan, fn_jit, abstract) -> None:
        """Write the AOT-compiled entry back to the persistent store —
        best-effort: any serialization/IO failure is counted, never
        raised (a full disk must not take the session down)."""
        store = self._store
        try:
            env = store_envelope(self.calibration)
            skey = store_key(entry.key, env)
            payloads = {NATIVE: serialize_native(entry.fn)}
            if store.portable:
                payloads[STABLEHLO] = serialize_stablehlo(fn_jit, abstract)
            store.save(skey, env, pack_entry_meta(entry, entry.plan),
                       payloads)
        except Exception:
            store.write_errors += 1

    def _store_load(self, key: Tuple,
                    sources: Mapping[str, Table]) -> Optional[CachedPlan]:
        """Second-tier lookup: validate, deserialize, and rehydrate a
        :class:`CachedPlan` without re-tracing. Returns ``None`` (and
        counts a miss or reject) whenever anything is off — the caller
        then compiles fresh, so a bad store can delay but never corrupt
        a session."""
        store = self._store
        if store is None or not self.jit:
            return None
        try:
            env = store_envelope(self.calibration)
            skey = store_key(key, env)
        except TypeError:       # a non-canonical key component: no store
            self._store_rejects += 1
            return None
        res = store.load(skey, env)
        if res.status == "miss":
            self._store_misses += 1
            return None
        if res.status == "reject":
            self._store_rejects += 1
            return None
        t0 = time.perf_counter()
        try:
            meta = res.header["meta"]
            if (meta.get("engine") != self.engine
                    or meta.get("dedup") != self.dedup):
                raise ValueError("entry engine/dedup mismatch")
            unpacked = unpack_entry_meta(meta, self._plan)
            if ("cap_locals" in unpacked) != (self.mesh is not None):
                raise ValueError("mesh/single-device entry mismatch")
            if self.verify != "off":
                # the rehydrated node-index lists mapped onto THIS
                # process's freshly lowered DAG must still describe a
                # well-formed plan — a colliding or corrupted entry that
                # slipped past the checksums rejects here, before its
                # executable is adopted
                from repro.analysis.verify import verify_plan
                report = verify_plan(
                    self._plan, self.engine, counts=unpacked["counts"],
                    caps=unpacked["caps"], sources=sources,
                    shard_local="cap_locals" in unpacked,
                    slack=self.slack, check_canonical=self.optimize,
                    check_cse=self.optimize)
                if not report.ok:
                    raise ValueError("stored plan metadata failed static "
                                     "verification: "
                                     + "; ".join(str(d) for d in
                                                 report.diagnostics[:3]))
                self._verify_store_checks += 1
            fn = None
            if NATIVE in res.payloads:
                try:          # fast tier: zero-recompile executable
                    fn = deserialize_native(res.payloads[NATIVE])
                except Exception:
                    fn = None
            if fn is None and STABLEHLO in res.payloads:
                fn = deserialize_stablehlo(res.payloads[STABLEHLO])
            if fn is None:
                raise ValueError("no loadable payload")
        except Exception as e:  # rehydration failure degrades to compile
            self._store_rejects += 1
            store._reject(f"rehydrate: {type(e).__name__}: {e}")
            return None
        self._store_hits += 1
        if unpacked.get("safe_exchange"):
            self._safe_exchange = True   # keep the sticky escalation
        entry = CachedPlan(key=key, plan=self._slim_plan(),
                           emitter=self._emitter,
                           counts=unpacked["counts"], caps=unpacked["caps"],
                           fn=fn, engine=self.engine, dedup=self.dedup,
                           mode=unpacked["mode"],
                           build_seconds=time.perf_counter() - t0,
                           cap_locals=unpacked.get("cap_locals"),
                           out_cap_local=unpacked.get("out_cap_local"),
                           sink_slack=unpacked.get("sink_slack", 1.0),
                           exchanges=unpacked.get("exchanges"),
                           safe_exchange=unpacked.get("safe_exchange",
                                                      False),
                           origin="store")
        PLAN_CACHE.put(key, entry)
        return entry

    def _ensure(self, sources: Mapping[str, Table]) -> Tuple[CachedPlan, bool]:
        key = self._key(sources)
        entry = PLAN_CACHE.get(key)
        hit = entry is not None
        if hit:
            self._cache_hits += 1
        else:
            self._cache_misses += 1
            entry = self._store_load(key, sources)
            if entry is None:
                entry = self._build(key, sources)
        self._have_plan = True
        return entry, hit

    # -- execution -----------------------------------------------------------
    def run(self, sources: Optional[Mapping[str, Table]] = None
            ) -> Tuple[Table, jax.Array]:
        """Execute the (cached) plan over ``sources`` (default: the session
        sources); transparently recompiles into bigger capacities when the
        closure reports truncation. Returns ``(kg, raw_count)``."""
        sources = self.sources if sources is None else sources
        first = not self._have_plan
        t0 = time.perf_counter()
        entry, hit = self._ensure(sources)
        plan_s = time.perf_counter() - t0
        t1 = time.perf_counter()
        if self.mesh is not None:
            kg, raw, entry, hit = self._run_mesh(entry, sources, hit)
        else:
            try:
                kg, raw, over = entry.fn(sources)
            except Exception:
                # a store-loaded executable that slipped past envelope
                # validation but cannot actually execute here is one more
                # store reject: rebuild fresh, never crash the session
                if entry.origin != "store":
                    raise
                self._store_rejects += 1
                hit = False
                entry = self._build(entry.key, sources)
                kg, raw, over = entry.fn(sources)
            if host_int(over):
                # some buffer was truncated: re-annotate exactly against the
                # *current* extension, grow caps monotonically, re-run — the
                # one recompile per capacity-bucket crossing
                hit = False   # the hit did not actually serve this execution
                entry = self._build(entry.key, sources, mode="exact",
                                    floor_caps=entry.caps)
                kg, raw, over = entry.fn(sources)
                if host_int(over):  # exact caps cannot under-size
                    raise RuntimeError("capacity overflow persisted after "
                                       "recompile — please report")
        exec_s = time.perf_counter() - t1
        self._executions += 1
        self._last = {"entry": entry, "cache_hit": hit, "first": first,
                      "plan_seconds": plan_s, "exec_seconds": exec_s,
                      "sources": sources}
        self._kg = kg          # the device-resident KG the query tier reads
        return kg, raw

    __call__ = run

    def create_kg(self) -> Tuple[Table, Dict[str, object]]:
        """Plan (or reuse) + execute; returns ``(KG, stats)`` with the
        Table-1-style sizes of ``mapsdi_create_kg`` plus the session's
        cache/recompile counters. ``source_rows_after`` is recounted
        against the *current* extension (a cache hit's plan-time counts
        may stem from a different same-bucket extension)."""
        before = {k: host_int(v.count) for k, v in self.sources.items()}
        kg, raw = self.run()
        return kg, self._run_stats(kg, raw, source_rows_before=before,
                                   exact_rows=True)

    def ingest(self, deltas: Mapping[str, Table]
               ) -> Tuple[Table, Dict[str, object]]:
        """Append extension rows and re-execute (micro-batch/streaming).

        ``deltas`` maps source names to tables of *new* rows (columns
        aligned by name; encode them with the session's vocab, e.g. via
        ``Table.from_records(..., vocab=engine.vocab)``). Appends are
        shape-stable inside a capacity bucket — re-execution reuses the
        cached closure with zero re-trace; crossing a bucket (or
        overflowing an interior buffer) triggers exactly one transparent
        recompile. Returns ``(KG, stats)`` over the *accumulated* sources
        (the stats' ``source_rows_after`` are the cached plan-time counts —
        the steady-state path never re-reads the data; call
        :meth:`create_kg` when you need them recounted).
        """
        # validate the whole batch before touching any session state, so a
        # bad name can never leave the session half-mutated
        unknown = sorted(set(deltas) - set(self.sources))
        if unknown:
            raise KeyError(f"unknown source(s) {unknown}")
        # σ-baked provenance only certifies the *materialized* rows; raw
        # delta rows may violate the owning maps' selections, so the flag
        # must be dropped (re-instating the join-parent re-select) before
        # the appended rows can reach a child join unfiltered
        tainted = {name for name in deltas
                   if name in self._dis.sigma_baked}
        if tainted:
            self._dis.sigma_baked -= tainted
            self._replan()
        for name, delta in deltas.items():
            self.sources[name] = append_rows(self.sources[name], delta)
            self._ingested_rows += host_int(delta.count)
        # (the appended rows are fresh Table objects, which invalidates the
        # identity-keyed device-resident shard blocks — and, via the cache
        # key's shard-local capacity buckets, any cached closure whose
        # per-shard annotations a grown source outran)
        self._ingests += 1
        kg, raw = self.run()
        return kg, self._run_stats(kg, raw)

    # -- fused distributed execution -----------------------------------------
    def _shard_sources(self, sources: Mapping[str, Table],
                       cap_locals: Mapping[str, int]) -> Tuple[Dict, Dict]:
        """Row-shard the scanned sources onto the mesh (the input
        distribution step — the one place source rows cross the host
        boundary). Session sources are cached device-side keyed on the
        Table object's identity, so any replacement — an ingest's
        ``append_rows`` or a direct ``engine.sources[name] = ...`` — and
        any shard-bucket growth re-shards, while untouched sources reuse
        their resident blocks."""
        from repro.core.distributed import shard_table
        own = sources is self.sources
        datas: Dict[str, jax.Array] = {}
        counts: Dict[str, jax.Array] = {}
        for name in sorted(cap_locals):
            cap, table = cap_locals[name], sources[name]
            if own:
                hit = self._shard_cache.get(name)
                if hit is not None and hit[0] == cap and hit[1] is table:
                    datas[name], counts[name] = hit[2], hit[3]
                    continue
            d, c, _ = shard_table(table, self.mesh, self.mesh_axis,
                                  cap_local=cap)
            if own:
                self._shard_cache[name] = (cap, table, d, c)
            datas[name], counts[name] = d, c
        return datas, counts

    def _run_mesh(self, entry: CachedPlan, sources: Mapping[str, Table],
                  hit: bool):
        """Execute the fused mesh closure: shard inputs, run on device,
        recompile on (shard-local) capacity/exchange overflow or sink-δ
        bucket overflow, gather ONLY the final deduplicated KG and
        canonicalize its row order (one δ over the result — both paths end
        in the same δ kernel, so the output is bit-identical to the
        single-device plan)."""
        from repro.core.distributed import unshard_rows
        datas, counts = self._shard_sources(sources, entry.cap_locals)
        try:
            kg_d, kg_c, raw, over, sink_over = entry.fn(datas, counts)
        except Exception:
            # store-loaded mesh executable failed at call time (see run())
            if entry.origin != "store":
                raise
            self._store_rejects += 1
            hit = False
            entry = self._build(entry.key, sources)
            kg_d, kg_c, raw, over, sink_over = entry.fn(datas, counts)
        for _ in range(2):   # ≤1 capacity recompile + ≤1 sink-slack growth
            grow_caps, grow_sink = host_int(over), host_int(sink_over)
            if not (grow_caps or grow_sink):
                break
            hit = False   # the hit did not actually serve this execution
            # floors are ALWAYS the current entry's caps (growth must be
            # monotone or overflow ping-pongs), and a sink-only rebuild
            # must keep the mode a previous capacity rebuild escalated to.
            # A capacity/exchange overflow escalates to safe_exchange:
            # exact global counts as post-exchange caps and hard-safe
            # exchange buckets (cap_bucket = cap_local) are true bounds
            # even under adversarial key skew, so ONE recompile suffices.
            entry = self._build(
                entry.key, sources,
                mode="exact" if grow_caps else entry.mode,
                floor_caps=entry.caps,
                sink_slack=entry.sink_slack * (4.0 if grow_sink else 1.0),
                safe_exchange=bool(grow_caps) or entry.safe_exchange)
            kg_d, kg_c, raw, over, sink_over = entry.fn(datas, counts)
        if host_int(over):   # exact shard-local caps cannot under-size
            raise RuntimeError("mesh capacity overflow persisted after "
                               "recompile — please report")
        if host_int(sink_over):
            raise RuntimeError("distributed δ bucket overflow at "
                               f"slack={entry.sink_slack:g}")
        rows = unshard_rows(kg_d, kg_c, entry.out_cap_local)   # final KG only
        kg = distinct(Table.from_codes(rows, TRIPLE_ATTRS), dedup=self.dedup)
        return kg, raw, entry, hit

    # -- queries -------------------------------------------------------------
    def _kg_table(self, kg: Optional[Table]) -> Table:
        """Resolve + bucket the KG table a query reads: the session KG by
        default (materialized on first use), an explicit ``kg=`` override
        otherwise. The bucketed view is cached on the KG object's identity,
        so repeated queries over one KG share a buffer (and, on a mesh,
        the resident shard blocks)."""
        if kg is None:
            if self._kg is None:
                self.run()          # materialize the session KG first
            kg = self._kg
        if tuple(kg.attrs) != TRIPLE_ATTRS:
            raise ValueError("query target must be a coded KG table with "
                             f"attrs {TRIPLE_ATTRS}, got {tuple(kg.attrs)}")
        hit = self._kg_bucket
        if hit is not None and hit[0] is kg:
            return hit[1]
        bucketed = _to_bucket(kg)
        self._kg_bucket = (kg, bucketed)
        return bucketed

    def _kg_cap_local(self, kg: Table) -> int:
        n = int(self.mesh.shape[self.mesh_axis])
        return bucket_cap(-(-kg.capacity // n))

    def _query_mesh_sig(self, kg: Table) -> Optional[Tuple]:
        """Query analogue of :meth:`_mesh_sig`: same static mesh identity
        and exchange/calibration components, with the KG's shard-local
        capacity bucket as the (single) source term."""
        if self.mesh is None:
            return None
        cal_sig = (None if self.calibration is None
                   else self.calibration.signature())
        return self._mesh_static + (
            self._kg_cap_local(kg), len(self._dis.vocab) < (1 << 16),
            self.join_exchange, cal_sig)

    def _query_key(self, query: Query, kg: Table) -> Tuple:
        c = self.config
        return query_session_key(query, dedup=c.dedup, mode=c.mode,
                                 slack=c.slack, jit=c.jit,
                                 kg_bucket_cap=kg.capacity,
                                 mesh_sig=self._query_mesh_sig(kg))

    def _verify_query_built(self, qplan, counts, caps, sources,
                            shard_local: bool) -> None:
        if self.verify == "off":
            return
        from repro.analysis.verify import verify_query_plan
        verify_query_plan(qplan, counts=counts, caps=caps, sources=sources,
                          shard_local=shard_local,
                          slack=self.slack).raise_for_status()
        self._verify_plan_checks += 1

    def _build_query(self, key: Tuple, qplan, kg: Table,
                     mode: Optional[str] = None,
                     floor_caps: Optional[Mapping] = None,
                     safe_exchange: bool = False) -> CachedPlan:
        """Query sibling of :meth:`_build`: annotate (globally or
        shard-locally), statically verify, compile (single-device or fused
        mesh), optionally audit and AOT-serialize to the plan store."""
        t0 = time.perf_counter()
        safe_exchange = safe_exchange or self._q_safe_exchange
        self._q_safe_exchange = safe_exchange
        sources = {KG_SOURCE: kg}
        aot = self._store is not None and self.jit
        abstract = None
        if self.mesh is None:
            counts, caps = annotate_query(qplan, sources,
                                          mode=mode or self.mode,
                                          slack=self.slack,
                                          cap_fn=bucket_cap)
            if floor_caps:  # growth must be monotone or overflow ping-pongs
                caps = {n: max(c, floor_caps.get(n, 0))
                        for n, c in caps.items()}
            self._verify_query_built(qplan, counts, caps, sources,
                                     shard_local=False)
            fn = compile_query(qplan, dedup=self.dedup, caps=caps,
                               jit=self.jit, report_overflow=True)
            if aot or self.verify == "full":
                abstract = (abstract_sources(sources),)
            if self.verify == "full":
                from repro.analysis.audit import audit_closure
                audit_closure(fn, abstract,
                              expected_counts={"all_gather": 0,
                                               "all_to_all": 0},
                              single_device=True).raise_for_status()
                self._verify_audits += 1
            entry = CachedPlan(key=key, plan=qplan, emitter=None,
                               counts=counts, caps=caps, fn=fn,
                               engine=self.engine, dedup=self.dedup,
                               mode=mode or self.mode,
                               build_seconds=time.perf_counter() - t0)
        else:
            from repro.query.mesh import (compile_query_mesh,
                                          query_mesh_abstract_inputs)
            n = int(self.mesh.shape[self.mesh_axis])
            cap_local = self._kg_cap_local(kg)
            counts, caps, exchanges = annotate_query_local(
                qplan, n_shards=n, cap_locals={KG_SOURCE: cap_local},
                mode=mode or self.mode, slack=self.slack,
                cap_fn=bucket_cap, sources=sources,
                join_exchange=self.join_exchange,
                safe_exchange=safe_exchange, calibration=self.calibration)
            if floor_caps:
                caps = {n_: max(c, floor_caps.get(n_, 0))
                        for n_, c in caps.items()}
            self._verify_query_built(qplan, counts, caps, sources,
                                     shard_local=True)
            fn, out_cap_local = compile_query_mesh(
                qplan, self.mesh, self.mesh_axis, dedup=self.dedup,
                caps=caps, cap_local=cap_local,
                pack_u16=len(self._dis.vocab) < (1 << 16), jit=self.jit,
                exchanges=exchanges, safe_exchange=safe_exchange)
            if aot or self.verify == "full":
                abstract = query_mesh_abstract_inputs(
                    cap_local, n, self.mesh, self.mesh_axis)
            if self.verify == "full":
                from repro.analysis.audit import (
                    audit_closure, expected_query_collectives)
                audit_closure(
                    fn, abstract, n_shards=n,
                    expected_counts=expected_query_collectives(
                        qplan, n, exchanges=exchanges)).raise_for_status()
                self._verify_audits += 1
            entry = CachedPlan(key=key, plan=qplan, emitter=None,
                               counts=counts, caps=caps, fn=fn,
                               engine=self.engine, dedup=self.dedup,
                               mode=mode or self.mode,
                               build_seconds=time.perf_counter() - t0,
                               cap_locals={KG_SOURCE: cap_local},
                               out_cap_local=out_cap_local,
                               exchanges=exchanges,
                               safe_exchange=safe_exchange)
        if aot:
            try:
                entry.fn = fn.lower(*abstract).compile()
            except Exception:   # AOT unavailable: keep the jitted closure
                self._store.write_errors += 1
                aot = False
            entry.build_seconds = time.perf_counter() - t0
        PLAN_CACHE.put(key, entry)
        self._builds += 1
        if aot:
            self._store_save(entry, fn, abstract)
        return entry

    def _query_store_load(self, key: Tuple, qplan,
                          sources: Mapping[str, Table]
                          ) -> Optional[CachedPlan]:
        """Query sibling of :meth:`_store_load`: the stored node-index
        metadata rehydrates against THIS process's freshly lowered query
        DAG (lowering is deterministic, so node_order matches); every
        failure degrades to a fresh compile."""
        store = self._store
        if store is None or not self.jit:
            return None
        try:
            env = store_envelope(self.calibration)
            skey = store_key(key, env)
        except TypeError:       # a non-canonical key component: no store
            self._q_store_rejects += 1
            return None
        res = store.load(skey, env)
        if res.status == "miss":
            self._q_store_misses += 1
            return None
        if res.status == "reject":
            self._q_store_rejects += 1
            return None
        t0 = time.perf_counter()
        try:
            meta = res.header["meta"]
            if (meta.get("engine") != self.engine
                    or meta.get("dedup") != self.dedup):
                raise ValueError("entry engine/dedup mismatch")
            unpacked = unpack_entry_meta(meta, qplan)
            if ("cap_locals" in unpacked) != (self.mesh is not None):
                raise ValueError("mesh/single-device entry mismatch")
            if self.verify != "off":
                from repro.analysis.verify import verify_query_plan
                report = verify_query_plan(
                    qplan, counts=unpacked["counts"],
                    caps=unpacked["caps"], sources=sources,
                    shard_local="cap_locals" in unpacked, slack=self.slack)
                if not report.ok:
                    raise ValueError("stored query metadata failed static "
                                     "verification: "
                                     + "; ".join(str(d) for d in
                                                 report.diagnostics[:3]))
                self._verify_store_checks += 1
            fn = None
            if NATIVE in res.payloads:
                try:          # fast tier: zero-recompile executable
                    fn = deserialize_native(res.payloads[NATIVE])
                except Exception:
                    fn = None
            if fn is None and STABLEHLO in res.payloads:
                fn = deserialize_stablehlo(res.payloads[STABLEHLO])
            if fn is None:
                raise ValueError("no loadable payload")
        except Exception as e:  # rehydration failure degrades to compile
            self._q_store_rejects += 1
            store._reject(f"rehydrate: {type(e).__name__}: {e}")
            return None
        self._q_store_hits += 1
        if unpacked.get("safe_exchange"):
            self._q_safe_exchange = True
        entry = CachedPlan(key=key, plan=qplan, emitter=None,
                           counts=unpacked["counts"], caps=unpacked["caps"],
                           fn=fn, engine=self.engine, dedup=self.dedup,
                           mode=unpacked["mode"],
                           build_seconds=time.perf_counter() - t0,
                           cap_locals=unpacked.get("cap_locals"),
                           out_cap_local=unpacked.get("out_cap_local"),
                           exchanges=unpacked.get("exchanges"),
                           safe_exchange=unpacked.get("safe_exchange",
                                                      False),
                           origin="store")
        PLAN_CACHE.put(key, entry)
        return entry

    def query(self, q: Query, kg: Optional[Table] = None) -> Table:
        """Evaluate a BGP :class:`~repro.query.Query` over the
        device-resident KG; returns the answer :class:`Table`
        (``SELECT DISTINCT`` semantics, one ``v__t``/``v__v`` column pair
        per term variable, ``v__p`` per predicate variable).

        The query goes through the same machinery as creation: lowered to
        the relational IR (:func:`repro.query.lower_query`), annotated with
        capacities, statically verified per the session's ``verify`` level,
        compiled to one jitted device-resident closure (fused ``shard_map``
        on a mesh session), cached in the process-wide plan cache under its
        own structural-fingerprint key tier, and AOT-persisted to the plan
        store when one is configured. A truncation flag triggers the same
        transparent recompile-with-exact-caps ladder as :meth:`run`.

        ``kg`` defaults to the session KG (materialized via :meth:`run` on
        first use); pass an explicit coded triple table to query something
        else (it shares the session's vocab codes by construction)."""
        t0 = time.perf_counter()
        table = self._kg_table(kg)
        qplan = lower_query(q)
        sources = {KG_SOURCE: table}
        key = self._query_key(q, table)
        entry = PLAN_CACHE.get(key)
        hit = entry is not None
        if hit:
            self._q_cache_hits += 1
        else:
            self._q_cache_misses += 1
            entry = self._query_store_load(key, qplan, sources)
            if entry is None:
                entry = self._build_query(key, qplan, table)
        plan_s = time.perf_counter() - t0
        t1 = time.perf_counter()
        if self.mesh is not None:
            result, entry, hit = self._run_query_mesh(entry, qplan, table,
                                                      hit)
        else:
            try:
                result, over = entry.fn(sources)
            except Exception:
                # store-loaded executable failed at call time (see run())
                if entry.origin != "store":
                    raise
                self._q_store_rejects += 1
                hit = False
                entry = self._build_query(key, qplan, table)
                result, over = entry.fn(sources)
            if host_int(over):
                hit = False   # the hit did not actually serve this query
                self._q_recompiles += 1
                entry = self._build_query(key, qplan, table, mode="exact",
                                          floor_caps=entry.caps)
                result, over = entry.fn(sources)
                if host_int(over):  # exact caps cannot under-size
                    raise RuntimeError("query capacity overflow persisted "
                                       "after recompile — please report")
        self._q_executions += 1
        self._q_last = {"entry": entry, "cache_hit": hit,
                        "plan_seconds": plan_s,
                        "exec_seconds": time.perf_counter() - t1}
        return result

    def _run_query_mesh(self, entry: CachedPlan, qplan, table: Table,
                        hit: bool):
        """Execute the fused mesh query closure; mirrors :meth:`_run_mesh`:
        shard the (bucketed) KG once per KG object, run, recompile on
        overflow with exact caps + hard-safe exchange buckets, gather only
        the final rows and δ them canonically — which is what makes the
        mesh answer bit-identical to the single-device one."""
        from repro.core.distributed import unshard_rows
        datas, counts = self._shard_kg(table, entry.cap_locals[KG_SOURCE])
        try:
            out_d, out_c, over = entry.fn(datas, counts)
        except Exception:
            if entry.origin != "store":
                raise
            self._q_store_rejects += 1
            hit = False
            entry = self._build_query(entry.key, qplan, table)
            out_d, out_c, over = entry.fn(datas, counts)
        if host_int(over):
            hit = False
            self._q_recompiles += 1
            entry = self._build_query(entry.key, qplan, table, mode="exact",
                                      floor_caps=entry.caps,
                                      safe_exchange=True)
            out_d, out_c, over = entry.fn(datas, counts)
            if host_int(over):   # exact caps + safe buckets cannot under-size
                raise RuntimeError("mesh query capacity overflow persisted "
                                   "after recompile — please report")
        rows = unshard_rows(out_d, out_c, entry.out_cap_local)
        result = distinct(Table.from_codes(rows, entry.plan.out_attrs),
                          dedup=self.dedup)
        return result, entry, hit

    def _shard_kg(self, table: Table, cap_local: int) -> Tuple:
        """Shard the bucketed KG onto the mesh, cached on the table
        object's identity (a fresh KG from run()/ingest() re-shards)."""
        hit = self._kg_shard
        if hit is not None and hit[0] is table and hit[1] == cap_local:
            return hit[2], hit[3]
        from repro.core.distributed import shard_table
        d, c, _ = shard_table(table, self.mesh, self.mesh_axis,
                              cap_local=cap_local)
        self._kg_shard = (table, cap_local, d, c)
        return d, c

    def explain_query(self, q: Query, kg: Optional[Table] = None) -> str:
        """Annotated query-plan tree — the query analogue of
        :meth:`explain`: per-node rows/caps from the session's annotation
        mode, per-⋈ exchange decisions and wire-byte estimates on a mesh,
        and the static verifier's schema/verdict when verification is on."""
        from repro.plan.explain import dump_root
        table = self._kg_table(kg)
        qplan = lower_query(q)
        sources = {KG_SOURCE: table}
        exchanges = None
        if self.mesh is None:
            counts, caps = annotate_query(qplan, sources, mode=self.mode,
                                          slack=self.slack,
                                          cap_fn=bucket_cap)
        else:
            counts, caps, exchanges = annotate_query_local(
                qplan, n_shards=int(self.mesh.shape[self.mesh_axis]),
                cap_locals={KG_SOURCE: self._kg_cap_local(table)},
                mode=self.mode, slack=self.slack, cap_fn=bucket_cap,
                sources=sources, join_exchange=self.join_exchange,
                safe_exchange=self._q_safe_exchange,
                calibration=self.calibration)
        schemas = verdict = None
        if self.verify != "off":
            from repro.analysis.verify import verify_query_plan
            report = verify_query_plan(qplan, counts=counts, caps=caps,
                                       sources=sources,
                                       shard_local=self.mesh is not None,
                                       slack=self.slack)
            schemas, verdict = report.schemas, report.describe()
        return dump_root(qplan.root, counts=counts, caps=caps,
                         exchanges=exchanges, schemas=schemas,
                         verdict=verdict)

    # -- stats ---------------------------------------------------------------
    @property
    def vocab(self):
        return self._dis.vocab

    def _run_stats(self, kg: Table, raw, source_rows_before=None,
                   exact_rows: bool = False) -> Dict[str, object]:
        entry: CachedPlan = self._last["entry"]
        names = input_names(entry.plan)
        counts = entry.counts   # plan-time: exact for the extension the
        # entry was annotated against, an upper bound in "bound" mode
        if exact_rows and entry.mode == "exact" \
                and (self._last["cache_hit"] or entry.origin == "store"):
            # a hit reuses counts from whichever same-bucket extension
            # built the entry; recount for honest Table-1 reduced sizes
            counts, _ = annotate(entry.plan, mode="exact",
                                 sources=self._last["sources"])
        rows_after = {names[tm.name]: counts[entry.plan.inputs[tm.name]]
                      for tm in entry.plan.maps}
        pre_s = self._last["plan_seconds"]
        if self._last["first"]:
            pre_s += self._plan_seconds  # symbolic fixpoint, paid once
        return {
            "raw_triples": host_int(raw),
            "kg_triples": host_int(kg.count),
            "preprocess_seconds": pre_s,
            "semantify_seconds": self._last["exec_seconds"],
            "source_rows_before": (source_rows_before if source_rows_before
                                   is not None else
                                   {k: host_int(v.count)
                                    for k, v in self.sources.items()}),
            "source_rows_after": rows_after,
            "rule1": self._tstats.rule1_applications,
            "rule2": self._tstats.rule2_applications,
            "rule3": self._tstats.rule3_merges,
            "sigma": self._tstats.sigma_pushdowns,
            "cse_shared": self._tstats.cse_shared_subplans,
            "recompiles": self._recompiles,
            "plan_cache_hit": self._last["cache_hit"],
            "plan_cache_hits": self._cache_hits,
            "plan_cache_misses": self._cache_misses,
            "store_hits": self._store_hits,
            "store_misses": self._store_misses,
            "store_rejects": self._store_rejects,
        }

    def stats(self) -> Dict[str, object]:
        """Session-level counters (no execution side effects)."""
        out = {
            "engine": self.engine, "dedup": self.dedup, "mode": self.mode,
            "slack": self.slack, "optimize": self.optimize,
            "join_exchange": self.join_exchange,
            "verify": {"mode": self.verify,
                       "plan_checks": self._verify_plan_checks,
                       "audits": self._verify_audits,
                       "store_checks": self._verify_store_checks},
            "cost_model": ("static" if self.calibration is None
                           else self.calibration.source),
            "calibration": (None if self.calibration is None else {
                "all_gather_bw": self.calibration.all_gather_bw,
                "all_to_all_bw": self.calibration.all_to_all_bw,
                "launch_s": self.calibration.launch_s,
                "source": self.calibration.source,
            }),
            "executions": self._executions, "ingests": self._ingests,
            "ingested_rows": self._ingested_rows,
            "builds": self._builds,
            "recompiles": self._recompiles,
            "plan_cache_hits": self._cache_hits,
            "plan_cache_misses": self._cache_misses,
            "plan_cache": PLAN_CACHE.stats(),
            "store_hits": self._store_hits,
            "store_misses": self._store_misses,
            "store_rejects": self._store_rejects,
            "plan_store": (None if self._store is None
                           else self._store.stats()),
            "plan_seconds": self._plan_seconds,
            "source_buckets": {k: v.capacity
                               for k, v in self.sources.items()},
            "rule1": self._tstats.rule1_applications,
            "rule2": self._tstats.rule2_applications,
            "rule3": self._tstats.rule3_merges,
            "sigma": self._tstats.sigma_pushdowns,
            "cse_shared": self._tstats.cse_shared_subplans,
            "query": {
                "executions": self._q_executions,
                "cache_hits": self._q_cache_hits,
                "cache_misses": self._q_cache_misses,
                "recompiles": self._q_recompiles,
                "store_hits": self._q_store_hits,
                "store_misses": self._q_store_misses,
                "store_rejects": self._q_store_rejects,
            },
        }
        if self._last:
            out["last_preprocess_seconds"] = self._last["plan_seconds"]
            out["last_semantify_seconds"] = self._last["exec_seconds"]
        if self._q_last:
            out["query"]["last_plan_seconds"] = self._q_last["plan_seconds"]
            out["query"]["last_exec_seconds"] = self._q_last["exec_seconds"]
            out["query"]["last_cache_hit"] = self._q_last["cache_hit"]
        return out
