"""Host-side dictionary encoding.

Strings (and arbitrary hashable values) never live on device. A ``Vocab``
interns every value appearing in a source to a dense int32 id; all device
relational work happens on the ids. This mirrors the paper's observation that
comparisons in the relational model are cheaper than over RDF terms — here we
go further and make every device comparison an int32 vector compare.

Ids are allocated densely from 0; the fill/pad sentinel is INT32_MAX, so
``intern`` asserts we stay far away from it.
"""
from __future__ import annotations

from typing import Any, Dict, Hashable, Iterable, List

import numpy as np

PAD_ID = np.int32(2**31 - 1)  # sentinel for invalid/padding rows; sorts last
MAX_ID = 2**31 - 2


class Vocab:
    """Bidirectional value <-> int32 id mapping (host side)."""

    def __init__(self) -> None:
        self._to_id: Dict[Hashable, int] = {}
        self._to_value: List[Hashable] = []

    def __len__(self) -> int:
        return len(self._to_value)

    def intern(self, value: Hashable) -> int:
        vid = self._to_id.get(value)
        if vid is None:
            vid = len(self._to_value)
            if vid > MAX_ID:
                raise OverflowError("Vocab exhausted int32 id space")
            self._to_id[value] = vid
            self._to_value.append(value)
        return vid

    def intern_many(self, values: Iterable[Hashable]) -> np.ndarray:
        return np.asarray([self.intern(v) for v in values], dtype=np.int32)

    def decode(self, vid: int) -> Any:
        if vid == PAD_ID:
            return None
        return self._to_value[int(vid)]

    def decode_many(self, ids: np.ndarray) -> List[Any]:
        return [self.decode(i) for i in np.asarray(ids).reshape(-1)]

    def __contains__(self, value: Hashable) -> bool:
        return value in self._to_id

    def lookup(self, value: Hashable) -> int:
        """Id for an existing value (KeyError if never interned)."""
        return self._to_id[value]
