"""Relational-algebra substrate: fixed-shape columnar tables on device."""
from .encoding import PAD_ID, Vocab
from .guard import (TransferLedger, count_transfers, forbid_transfers,
                    host_get, host_int)
from .table import Table, bucket_cap, round_cap, shrink_to_fit
from .ops import (DEFAULT_DEDUP, append_rows, compact, dedup_rows, distinct,
                  distinct_rows, distinct_rows_hashed, equi_join, project,
                  project_as, rename, select_eq, select_mask, select_neq,
                  sort_lex, union)

__all__ = [
    "DEFAULT_DEDUP", "PAD_ID", "TransferLedger", "Vocab", "Table",
    "append_rows", "bucket_cap", "compact", "count_transfers", "dedup_rows",
    "distinct", "distinct_rows", "distinct_rows_hashed", "equi_join",
    "forbid_transfers", "host_get", "host_int", "project", "project_as",
    "rename", "round_cap", "select_eq", "select_mask", "select_neq",
    "shrink_to_fit", "sort_lex", "union",
]
