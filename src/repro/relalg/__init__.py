"""Relational-algebra substrate: fixed-shape columnar tables on device."""
from .encoding import PAD_ID, Vocab
from .table import Table
from .ops import (compact, distinct, distinct_rows, equi_join, project,
                  project_as, rename, select_eq, select_mask, select_neq,
                  sort_lex, union)

__all__ = [
    "PAD_ID", "Vocab", "Table", "compact", "distinct", "distinct_rows",
    "equi_join", "project", "project_as", "rename", "select_eq",
    "select_mask", "select_neq", "sort_lex", "union",
]
