"""Relational-algebra substrate: fixed-shape columnar tables on device."""
from .encoding import PAD_ID, Vocab
from .table import Table
from .ops import (DEFAULT_DEDUP, compact, dedup_rows, distinct, distinct_rows,
                  distinct_rows_hashed, equi_join, project, project_as,
                  rename, select_eq, select_mask, select_neq, sort_lex, union)

__all__ = [
    "DEFAULT_DEDUP", "PAD_ID", "Vocab", "Table", "compact", "dedup_rows",
    "distinct", "distinct_rows", "distinct_rows_hashed", "equi_join",
    "project", "project_as", "rename", "select_eq", "select_mask",
    "select_neq", "sort_lex", "union",
]
