"""Fixed-capacity columnar tables on device.

A ``Table`` is the SPMD-friendly stand-in for the paper's CSV sources: an
int32 matrix ``data[capacity, n_attrs]`` of dictionary codes plus a dynamic
``count`` of valid rows. Rows ``>= count`` are padding filled with ``PAD_ID``
(INT32_MAX) so that lexicographic sorts push them to the end.

Static metadata (attribute names, capacity) is pytree aux data, so tables
flow through ``jax.jit``/``shard_map`` unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Mapping, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .encoding import PAD_ID, Vocab
from .guard import host_get, host_int


def round_cap(n: int, mult: int = 8) -> int:
    """Round a row count up to a capacity multiple (minimum one multiple)."""
    return max(mult, ((int(n) + mult - 1) // mult) * mult)


def bucket_cap(n: int, mult: int = 8, growth: float = 2.0) -> int:
    """Round a row count up to a *geometric* capacity bucket (8, 16, 32, …).

    :func:`round_cap` sizes a buffer exactly; ``bucket_cap`` sizes it for a
    whole *range* of row counts, so a plan compiled for one bucket stays
    valid for every extension that fits the bucket, and a steadily growing
    source crosses only O(log n) buckets — hence O(log n) recompiles — over
    its lifetime. This is the capacity quantization the ``KGEngine`` plan
    cache keys on (see ``docs/engine.md``).
    """
    cap = mult
    n = int(n)
    while cap < n:
        cap = round_cap(int(cap * growth), mult)
    return cap


def shrink_to_fit(table: "Table", mult: int = 8) -> "Table":
    """Materialize a table at capacity == round_cap(count) (host sync)."""
    n = host_int(table.count)
    cap = round_cap(n, mult)
    data = host_get(table.data)[:n]
    return Table.from_codes(data, table.attrs, cap)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Table:
    """Columnar relation: ``data[capacity, len(attrs)]`` int32 + valid count."""

    data: jax.Array          # [capacity, n_attrs] int32
    count: jax.Array         # scalar int32, number of valid rows
    attrs: Tuple[str, ...]   # static: column names, in column order

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self):
        return (self.data, self.count), self.attrs

    @classmethod
    def tree_unflatten(cls, attrs, children):
        data, count = children
        return cls(data=data, count=count, attrs=attrs)

    # -- static properties ---------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.data.shape[0]

    @property
    def n_attrs(self) -> int:
        return len(self.attrs)

    def col_index(self, attr: str) -> int:
        try:
            return self.attrs.index(attr)
        except ValueError:
            raise KeyError(f"attribute {attr!r} not in table {self.attrs}")

    def column(self, attr: str) -> jax.Array:
        return self.data[:, self.col_index(attr)]

    @property
    def valid_mask(self) -> jax.Array:
        return jnp.arange(self.capacity, dtype=jnp.int32) < self.count

    # -- constructors --------------------------------------------------------
    @classmethod
    def empty(cls, attrs: Sequence[str], capacity: int) -> "Table":
        data = jnp.full((capacity, len(attrs)), PAD_ID, dtype=jnp.int32)
        return cls(data=data, count=jnp.int32(0), attrs=tuple(attrs))

    @classmethod
    def from_codes(cls, codes: np.ndarray, attrs: Sequence[str],
                   capacity: int | None = None) -> "Table":
        """Build from an [n, k] int32 code matrix (host)."""
        codes = np.asarray(codes, dtype=np.int32)
        n, k = codes.shape
        if k != len(attrs):
            raise ValueError("codes width != len(attrs)")
        capacity = n if capacity is None else capacity
        if n > capacity:
            raise ValueError(f"{n} rows exceed capacity {capacity}")
        data = np.full((capacity, k), PAD_ID, dtype=np.int32)
        data[:n] = codes
        return cls(data=jnp.asarray(data), count=jnp.int32(n),
                   attrs=tuple(attrs))

    @classmethod
    def from_records(cls, records: Iterable[Mapping[str, object]],
                     attrs: Sequence[str], vocab: Vocab,
                     capacity: int | None = None) -> "Table":
        """Intern host records (list of dicts) into a device table."""
        rows: List[List[int]] = []
        for rec in records:
            rows.append([vocab.intern(rec[a]) for a in attrs])
        codes = (np.asarray(rows, dtype=np.int32)
                 if rows else np.zeros((0, len(attrs)), np.int32))
        return cls.from_codes(codes, attrs, capacity)

    # -- host-side views (tests / sinks only) ---------------------------------
    def to_codes(self) -> np.ndarray:
        n = host_int(self.count)
        return host_get(self.data)[:n]

    def to_records(self, vocab: Vocab) -> List[Dict[str, object]]:
        return [
            {a: vocab.decode(row[i]) for i, a in enumerate(self.attrs)}
            for row in self.to_codes()
        ]

    def row_set(self) -> set:
        """Set of valid rows as tuples — order-insensitive comparison."""
        return {tuple(int(x) for x in row) for row in self.to_codes()}

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        traced = isinstance(self.count, jax.core.Tracer)
        count = "?" if traced else int(self.count)
        return (f"Table(attrs={self.attrs}, capacity={self.capacity}, "
                f"count={count})")
