"""Masked fixed-shape relational operators on :class:`Table`.

Every operator is jit-compatible: outputs have static capacities and a
dynamic valid-row ``count``. Padding rows carry ``PAD_ID`` in every column so
lexicographic sorts (``lax.sort`` with ``num_keys``) push them to the end.

These are the building blocks the MapSDI transformation rules are defined
over: projection (Rule 1/2), union+rename (Rule 3), distinct (duplicate
elimination), and the sort-merge equi-join used by triple-map join
conditions.

Duplicate elimination (δ) — the single hottest operator in both MapSDI
pre-processing and the RDFizer sinks — comes in two strategies:

* ``"lex"``  — full K-key lexicographic ``lax.sort`` over every column,
  then a neighbor compare. Always exact; cost grows with K.
* ``"hash"`` — the default: one Pallas ``rowhash`` pass turns each row into
  a 32-bit key, a single-key sort carries the row permutation, and a fused
  hash+neighbor-flag kernel verifies full-row equality of sorted neighbors.
  Detected 32-bit collisions (equal hash, unequal row) trigger a
  ``lax.cond`` fallback to the exact lex path, so the result is always
  bit-identical to ``"lex"``. See ``docs/relalg.md`` for the correctness
  argument.

``DEFAULT_DEDUP`` selects the engine-wide default; every δ entry point
(:func:`distinct`, :func:`union` with dedup, the RDFizer, the Rule 1–3
transforms and the distributed dedup) accepts a ``dedup`` override.
"""
from __future__ import annotations

from typing import Callable, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.radix_partition import radix_partition
from repro.kernels.rowhash import hash_neighbor_flags, rowhash

from .encoding import PAD_ID
from .table import Table

# Engine-wide default δ strategy. "hash" is exact (collision fallback) and
# turns the K-key sort into a single-key sort; "lex" is the classic path.
DEFAULT_DEDUP = "hash"

# The hash δ swaps its single global sort for a radix partition + per-bucket
# sorts once the matrix has this many rows (sort cost is O(N log N); B
# independent bucket sorts cost O(N log(N/B)) and the partition is one
# linear kernel pass). Below the threshold the partition overhead dominates.
RADIX_DEDUP_MIN_ROWS = 4096
RADIX_DEDUP_BUCKETS = 8

_UINT32_MAX = 0xFFFFFFFF


def _resolve_dedup(dedup: Optional[str]) -> str:
    strategy = DEFAULT_DEDUP if dedup is None else dedup
    if strategy not in ("lex", "hash"):
        raise ValueError(f"unknown dedup strategy {strategy!r} "
                         "(expected 'lex' or 'hash')")
    return strategy


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _masked_data(table: Table) -> jax.Array:
    """Table data with padding rows forced to PAD_ID in every column."""
    return jnp.where(table.valid_mask[:, None], table.data,
                     jnp.int32(PAD_ID))


def compact(data: jax.Array, keep: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Scatter rows with ``keep`` set to the front; return (data, count)."""
    keep = keep.astype(jnp.int32)
    pos = jnp.cumsum(keep) - 1                      # destination row per kept row
    capacity = data.shape[0]
    dest = jnp.where(keep == 1, pos, capacity)      # out-of-range => dropped
    out = jnp.full_like(data, jnp.int32(PAD_ID)).at[dest].set(
        data, mode="drop")
    return out, keep.sum().astype(jnp.int32)


def sort_lex(table: Table) -> jax.Array:
    """Rows sorted lexicographically by all columns; padding last."""
    masked = _masked_data(table)
    cols = tuple(masked[:, k] for k in range(table.n_attrs))
    sorted_cols = lax.sort(cols, dimension=0, num_keys=table.n_attrs)
    return jnp.stack(sorted_cols, axis=1)


# ---------------------------------------------------------------------------
# unary operators
# ---------------------------------------------------------------------------

def project(table: Table, attrs: Sequence[str]) -> Table:
    """π_attrs — keep only ``attrs`` (bag semantics: rows unchanged)."""
    idx = [table.col_index(a) for a in attrs]
    return Table(data=table.data[:, jnp.asarray(idx)], count=table.count,
                 attrs=tuple(attrs))


def project_as(table: Table, spec: Sequence[Tuple[str, str]]) -> Table:
    """π with renaming: ``spec`` is ``[(source_attr, new_name), ...]``.

    Unlike :func:`project`, a source attribute may appear several times
    (needed when one attribute plays multiple roles after a Rule-3 merge).
    """
    names = [n for _, n in spec]
    if len(set(names)) != len(names):
        raise ValueError(f"project_as produces duplicate attrs: {names}")
    idx = [table.col_index(a) for a, _ in spec]
    return Table(data=table.data[:, jnp.asarray(idx)], count=table.count,
                 attrs=tuple(names))


def rename(table: Table, mapping: Mapping[str, str]) -> Table:
    """ρ — rename attributes (data untouched)."""
    new_attrs = tuple(mapping.get(a, a) for a in table.attrs)
    if len(set(new_attrs)) != len(new_attrs):
        raise ValueError(f"rename produces duplicate attrs: {new_attrs}")
    return Table(data=table.data, count=table.count, attrs=new_attrs)


def select_mask(table: Table, mask: jax.Array) -> Table:
    """σ — keep rows where ``mask`` holds (and the row is valid)."""
    keep = mask & table.valid_mask
    data, count = compact(table.data, keep)
    return Table(data=data, count=count, attrs=table.attrs)


def select_eq(table: Table, attr: str, code: jax.Array | int) -> Table:
    return select_mask(table, table.column(attr) == jnp.int32(code))


def select_neq(table: Table, attr: str, code: jax.Array | int) -> Table:
    return select_mask(table, table.column(attr) != jnp.int32(code))


def distinct_rows(data: jax.Array, count: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    """Matrix-level lex δ: ``data[N, K]`` with ``count`` valid rows ->
    deduplicated ``(data, count)``. Shared by Table ops and the shard_map
    distributed dedup (which works on raw row matrices inside shards).

    Lexicographic full-row sort, then first-occurrence compaction. This is
    the TPU-native replacement for a hash table: one fused ``lax.sort`` over
    all columns, a neighbour compare, and a cumsum scatter. Always exact;
    also the collision fallback of :func:`distinct_rows_hashed`.
    """
    capacity, k = data.shape
    valid_in = jnp.arange(capacity, dtype=jnp.int32) < count
    masked = jnp.where(valid_in[:, None], data, jnp.int32(PAD_ID))
    cols = tuple(masked[:, c] for c in range(k))
    sorted_cols = lax.sort(cols, dimension=0, num_keys=k)
    sorted_data = jnp.stack(sorted_cols, axis=1)
    prev = jnp.roll(sorted_data, 1, axis=0)
    first = jnp.any(sorted_data != prev, axis=1)
    first = first.at[0].set(True)
    valid = jnp.arange(capacity, dtype=jnp.int32) < count
    return compact(sorted_data, first & valid)


def distinct_rows_hashed(data: jax.Array, count: jax.Array, *,
                         use_pallas: Optional[bool] = None,
                         hash_fn: Optional[Callable[[jax.Array], jax.Array]]
                         = None,
                         radix: Optional[bool] = None
                         ) -> Tuple[jax.Array, jax.Array]:
    """Matrix-level hash-first δ — bit-identical results to
    :func:`distinct_rows`.

    Two layouts share the hash-first idea; both end in the fused
    hash+neighbor-flag pass and a first-occurrence compaction:

    * **sorted** — one stable single-key sort on the 32-bit row hash
      carrying the row permutation;
    * **radix** — an order-preserving radix partition into
      :data:`RADIX_DEDUP_BUCKETS` hash buckets (bucket = the hash's top
      bits, so concatenated buckets stay in global hash order) followed by
      independent per-bucket sorts. Picked automatically at
      :data:`RADIX_DEDUP_MIN_ROWS` rows (``radix`` overrides); falls back
      to the sorted layout on bucket overflow, so the output is a pure
      function of the row set regardless of layout.

    Correctness under collisions: the keep-mask only merges *adjacent equal
    rows*, so a collision can never drop a distinct row. It could keep a
    duplicate (two equal rows separated by a colliding distinct row), but
    that interleaving requires an equal-hash run containing two different
    row values — exactly the ``collide`` flag the fused kernel raises, which
    routes the whole call through the exact lex path via ``lax.cond``.

    ``hash_fn`` overrides the row hash (tests force collisions with it);
    the pure-jnp flag path and sorted layout are used then, since the
    fused kernel and the partition kernel hard-code the production hash.
    """
    capacity, _ = data.shape
    if radix is None:
        radix = hash_fn is None and capacity >= RADIX_DEDUP_MIN_ROWS
    if radix and hash_fn is None:
        return _distinct_hashed_radix(data, count, use_pallas=use_pallas)
    return _distinct_hashed_sorted(data, count, use_pallas=use_pallas,
                                   hash_fn=hash_fn)


def _distinct_hashed_sorted(data: jax.Array, count: jax.Array, *,
                            use_pallas: Optional[bool] = None,
                            hash_fn: Optional[Callable[[jax.Array],
                                                       jax.Array]] = None
                            ) -> Tuple[jax.Array, jax.Array]:
    """Single-global-sort layout of the hash δ (see
    :func:`distinct_rows_hashed`)."""
    capacity, k = data.shape
    idx = jnp.arange(capacity, dtype=jnp.int32)
    valid_in = idx < count
    masked = jnp.where(valid_in[:, None], data, jnp.int32(PAD_ID))

    h = (rowhash(masked, use_pallas=use_pallas) if hash_fn is None
         else hash_fn(masked))
    # padding sorts last: stable sort keeps valid rows (smaller original
    # index) ahead of pads even when a valid row genuinely hashes to max
    h = jnp.where(valid_in, h, jnp.uint32(_UINT32_MAX))
    _, perm = lax.sort((h, idx), dimension=0, num_keys=1)
    rows = masked[perm]
    valid_s = perm < count

    if hash_fn is None:
        _, keep_raw, coll_raw = hash_neighbor_flags(rows,
                                                    use_pallas=use_pallas)
        keep_raw = keep_raw.astype(bool)
        coll_raw = coll_raw.astype(bool)
    else:
        hs = h[perm]
        prev_rows = jnp.roll(rows, 1, axis=0)
        row_eq = jnp.all(rows == prev_rows, axis=1)
        hash_eq = hs == jnp.roll(hs, 1)
        keep_raw = (~(hash_eq & row_eq)).at[0].set(True)
        coll_raw = (hash_eq & ~row_eq).at[0].set(False)

    prev_valid = jnp.roll(valid_s, 1).at[0].set(False)
    collision = jnp.any(coll_raw & valid_s & prev_valid)
    keep = keep_raw & valid_s

    return lax.cond(collision,
                    lambda: distinct_rows(data, count),
                    lambda: compact(rows, keep))


def _radix_dedup_cap(capacity: int, n_buckets: int) -> int:
    """Per-bucket capacity: Poisson mean + 6σ slack (same bound family as
    ``repro.core.distributed.sink_bucket_cap``; overflow falls back)."""
    m = capacity / n_buckets
    return max(8, int(-(-(m + 6.0 * m ** 0.5 + 8.0) // 1)))


def _distinct_hashed_radix(data: jax.Array, count: jax.Array, *,
                           use_pallas: Optional[bool] = None
                           ) -> Tuple[jax.Array, jax.Array]:
    """Radix-bucketed layout of the hash δ (see
    :func:`distinct_rows_hashed`).

    The order-preserving partition buckets rows by the hash's *top* bits
    and keeps original order inside each bucket, so per-bucket stable
    sorts on (hash, position) concatenate to exactly the global stable
    hash order — the flattened buckets feed the same neighbor-flag pass
    as the sorted layout and yield a bit-identical δ.

    Two extra fallback triggers relative to the sorted layout:

    * bucket **overflow** (adversarially skewed hashes) would drop rows —
      re-run through the sorted layout (identical output, just slower);
    * a valid row whose *content* is all PAD_ID can sit right after a
      bucket's padding tail and be merged into it by the neighbor compare
      (the sorted layout can't hit this: stable sort keeps valid rows
      ahead of same-key pads). Detected as a suppressed keep with an
      invalid predecessor and routed through the fallback too.
    """
    capacity, k = data.shape
    nb = RADIX_DEDUP_BUCKETS
    cb = _radix_dedup_cap(capacity, nb)
    buckets, counts, overflow = radix_partition(
        data, count, n_buckets=nb, cap_bucket=cb, order_preserving=True,
        use_pallas=use_pallas)

    flat = buckets.reshape(nb * cb, k)
    h = rowhash(flat, use_pallas=use_pallas).reshape(nb, cb)
    pos = jnp.arange(cb, dtype=jnp.int32)[None, :]
    valid2d = pos < counts[:, None]
    h = jnp.where(valid2d, h, jnp.uint32(_UINT32_MAX))  # pads sort last
    _, perm = lax.sort((h, jnp.broadcast_to(pos, (nb, cb))),
                       dimension=1, num_keys=1)
    rows = jnp.take_along_axis(buckets, perm[..., None], axis=1
                               ).reshape(nb * cb, k)
    # valid rows occupy each bucket's head before AND after the sort
    # (stable; within-bucket pads start at counts[b] and sort behind any
    # valid row even on a max-hash tie), so the mask needs no permuting
    valid_s = valid2d.reshape(nb * cb)

    _, keep_raw, coll_raw = hash_neighbor_flags(rows, use_pallas=use_pallas)
    keep_raw = keep_raw.astype(bool)
    coll_raw = coll_raw.astype(bool)
    prev_valid = jnp.roll(valid_s, 1).at[0].set(False)
    collision = jnp.any(coll_raw & valid_s & prev_valid)
    pad_merge = jnp.any(~keep_raw & valid_s & ~prev_valid)
    keep = keep_raw & valid_s

    def _fallback() -> Tuple[jax.Array, jax.Array]:
        return _distinct_hashed_sorted(data, count, use_pallas=use_pallas)

    def _take() -> Tuple[jax.Array, jax.Array]:
        out, n = compact(rows, keep)
        # δ output fits the input capacity (n <= count <= capacity) and
        # compact fronts the kept rows, so the slack tail is all-PAD
        return out[:capacity], n

    return lax.cond(overflow | collision | pad_merge, _fallback, _take)


def dedup_rows(data: jax.Array, count: jax.Array,
               dedup: Optional[str] = None, *,
               use_pallas: Optional[bool] = None
               ) -> Tuple[jax.Array, jax.Array]:
    """Matrix-level δ under the selected strategy (None = engine default).

    The single implementation shared by :func:`distinct`, set-:func:`union`,
    the RDFizer sinks and the distributed shard-local dedup.
    """
    if _resolve_dedup(dedup) == "lex":
        return distinct_rows(data, count)
    return distinct_rows_hashed(data, count, use_pallas=use_pallas)


def distinct(table: Table, dedup: Optional[str] = None) -> Table:
    """δ — eliminate duplicate rows (set semantics).

    ``dedup`` picks the strategy (``"lex"`` | ``"hash"``; None = engine
    default, :data:`DEFAULT_DEDUP`). Both produce identical row sets.
    """
    data, count = dedup_rows(table.data, table.count, dedup)
    return Table(data=data, count=count, attrs=table.attrs)


# ---------------------------------------------------------------------------
# binary operators
# ---------------------------------------------------------------------------

def union(a: Table, b: Table, dedup: bool | str = False) -> Table:
    """∪ — concatenate rows (b's columns aligned to a's attr order).

    ``dedup`` selects the semantics: ``False`` is bag-union; ``True`` is
    set-union (π/∪/δ as in Transformation Rule 3) under the engine-default
    δ strategy; a strategy string (``"lex"`` | ``"hash"``) is set-union
    under that strategy.
    """
    if set(a.attrs) != set(b.attrs):
        raise ValueError(f"union schema mismatch: {a.attrs} vs {b.attrs}")
    b_aligned = project(b, a.attrs)
    data = jnp.concatenate([_masked_data(a), _masked_data(b_aligned)], axis=0)
    keep = jnp.concatenate([a.valid_mask, b_aligned.valid_mask])
    data, count = compact(data, keep)
    out = Table(data=data, count=count, attrs=a.attrs)
    if dedup is False:
        return out
    return distinct(out, dedup=None if dedup is True else dedup)


def append_rows(base: Table, delta: Table,
                capacity: Optional[int] = None) -> Table:
    """Append ``delta``'s valid rows after ``base``'s (micro-batch ingestion).

    ``delta``'s columns are aligned to ``base.attrs`` by name. When the
    combined rows fit ``base.capacity`` the write lands in the padding
    region and the output keeps base's shape — a shape-stable update, so a
    jitted closure over the table re-runs with zero re-trace. Otherwise the
    buffer grows to ``capacity`` (default: the next :func:`bucket_cap`
    bucket), which changes the shape — the caller's recompile signal.

    Host cost: two scalar syncs (the row counts); row data stays on device.
    """
    from .guard import host_int
    from .table import bucket_cap
    aligned = project(delta, base.attrs)
    n0, n1 = host_int(base.count), host_int(delta.count)
    total = n0 + n1
    if total > base.capacity:
        cap = bucket_cap(total) if capacity is None else capacity
        if cap < total:
            raise ValueError(f"{total} rows exceed capacity {cap}")
        pad = jnp.full((cap - base.capacity, base.n_attrs), jnp.int32(PAD_ID))
        grown = jnp.concatenate([_masked_data(base), pad], axis=0)
        base = Table(data=grown, count=base.count, attrs=base.attrs)
    idx = jnp.arange(aligned.capacity, dtype=jnp.int32)
    dest = jnp.where(idx < jnp.int32(n1), idx + jnp.int32(n0),
                     jnp.int32(base.capacity))      # invalid rows -> dropped
    data = _masked_data(base).at[dest].set(_masked_data(aligned), mode="drop")
    return Table(data=data, count=jnp.int32(total), attrs=base.attrs)


def equi_join(left: Table, right: Table, left_key: str, right_key: str,
              out_capacity: int, right_suffix: str = "r_",
              ) -> Tuple[Table, jax.Array]:
    """⋈ — sort-merge equi-join with a static output capacity.

    Returns ``(table, total_matches)``; ``total_matches`` may exceed the
    capacity (overflow detection is the caller's job — the MapSDI planner
    sizes capacities from source cardinalities).

    Output attrs: left attrs followed by right attrs, right-side names that
    collide with a left name get ``right_suffix`` prepended. The join key is
    kept on both sides (they are equal by construction).
    """
    lk = jnp.where(left.valid_mask, left.column(left_key), jnp.int32(PAD_ID))
    rk = jnp.where(right.valid_mask, right.column(right_key),
                   jnp.int32(PAD_ID))

    cap_r = right.capacity
    rk_sorted, perm = lax.sort(
        (rk, jnp.arange(cap_r, dtype=jnp.int32)), dimension=0, num_keys=1)

    lo = jnp.searchsorted(rk_sorted, lk, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(rk_sorted, lk, side="right").astype(jnp.int32)
    counts = jnp.where(left.valid_mask & (lk != PAD_ID), hi - lo, 0)

    offsets = jnp.cumsum(counts)                       # inclusive
    starts = offsets - counts
    total = offsets[left.capacity - 1] if left.capacity > 0 else jnp.int32(0)

    j = jnp.arange(out_capacity, dtype=jnp.int32)
    left_idx = jnp.searchsorted(offsets, j, side="right").astype(jnp.int32)
    left_idx_c = jnp.clip(left_idx, 0, left.capacity - 1)
    within = j - starts[left_idx_c]
    right_pos = jnp.clip(lo[left_idx_c] + within, 0, cap_r - 1)
    right_idx = perm[right_pos]
    valid_out = j < jnp.minimum(total, out_capacity)

    left_rows = left.data[left_idx_c]
    right_rows = right.data[right_idx]
    rows = jnp.concatenate([left_rows, right_rows], axis=1)
    rows = jnp.where(valid_out[:, None], rows, jnp.int32(PAD_ID))

    left_names = set(left.attrs)
    right_attrs = tuple(
        (right_suffix + a) if a in left_names else a for a in right.attrs)
    out = Table(data=rows, count=jnp.minimum(total, out_capacity),
                attrs=left.attrs + right_attrs)
    return out, total.astype(jnp.int32)
