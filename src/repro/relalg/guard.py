"""Device↔host transfer accounting.

The MapSDI planner's headline invariant is that the Rule 1–3 fixpoint runs
*symbolically* — zero device work, zero host syncs — until one final
materialization. This module makes that invariant observable:

* Every host materialization in the repo goes through :func:`host_get`
  (array) / :func:`host_int` (scalar) instead of bare ``np.asarray`` /
  ``int``. The helpers behave identically but tick any active
  :class:`TransferLedger`.
* :func:`count_transfers` counts device→host syncs over a region (the
  planner benchmark reports eager-vs-planned sync counts with it).
* :func:`forbid_transfers` additionally arms ``jax.transfer_guard`` so even
  an *un*-instrumented implicit transfer raises — the belt-and-braces check
  the planner tests use on the symbolic fixpoint.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Iterator, List

import jax
import numpy as np


@dataclasses.dataclass
class TransferLedger:
    """Counts device→host materializations observed while active."""

    device_to_host: int = 0

    def tick(self, n: int = 1) -> None:
        self.device_to_host += n


_ACTIVE: List[TransferLedger] = []


def host_get(x) -> np.ndarray:
    """``np.asarray`` that ticks active transfer ledgers.

    The single sanctioned way to pull a device array to host; jax-array
    inputs count as one device→host sync, numpy inputs are free.
    """
    if isinstance(x, jax.Array):
        for ledger in _ACTIVE:
            ledger.tick()
    return np.asarray(x)


def host_int(x) -> int:
    """``int()`` that ticks active transfer ledgers for device scalars."""
    if isinstance(x, jax.Array):
        for ledger in _ACTIVE:
            ledger.tick()
    return int(x)


@contextlib.contextmanager
def count_transfers() -> Iterator[TransferLedger]:
    """Count instrumented device→host syncs inside the ``with`` block."""
    ledger = TransferLedger()
    _ACTIVE.append(ledger)
    try:
        yield ledger
    finally:
        _ACTIVE.remove(ledger)


@contextlib.contextmanager
def forbid_transfers() -> Iterator[TransferLedger]:
    """Raise on ANY device→host sync inside the ``with`` block.

    Combines the instrumented ledger (raises on :func:`host_get` /
    :func:`host_int`) with ``jax.transfer_guard("disallow")``, which makes
    jax itself reject implicit transfers (e.g. ``int(count)``) that might
    bypass the instrumentation.
    """
    with count_transfers() as ledger:
        with jax.transfer_guard("disallow"):
            yield ledger
        if ledger.device_to_host:
            raise RuntimeError(
                f"{ledger.device_to_host} device→host transfer(s) inside a "
                "forbid_transfers() region")
