"""Fused distributed query execution: the whole BGP inside one shard_map.

The mesh sibling of :func:`repro.query.compile.compile_query`, built from
the same collective machinery as :func:`repro.plan.mesh.compile_mesh_plan`:
the KG table arrives row-sharded over the mesh axis, σ/π/``ColEq`` run on
the shard's block, every ⋈ moves its sides with the cost-modeled exchange
the annotator picked (``gather`` the right side vs hash-``repartition``
both sides on the join key), and every δ — including the root — is a
global hash-repartition δ. Self-joins of the KG against itself work
unchanged: both ⋈ inputs derive from the same shard-local Scan block, and
the exchange re-co-locates rows by join key, so per-shard outputs are
exact multiset partitions of the single-device relation.

The closure returns the root still sharded (``data [n·cap_local, k]``,
``counts [n]``) plus the any-shard overflow flag; the engine gathers the
rows once and re-δs them canonically, exactly like ``_run_mesh`` does for
the KG — which is what makes the mesh query result bit-identical to the
single-device one.
"""
from __future__ import annotations

from typing import Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.distributed import repartition_by_key, sink_bucket_cap
from repro.plan.compile import execute_node
from repro.plan.ir import Node
from repro.plan.mesh import gather_table
from repro.relalg import Table
from repro.relalg.ops import _masked_data, dedup_rows

from .lower import QueryPlan, query_scan


def query_mesh_abstract_inputs(cap_local: int, n_shards: int, mesh=None,
                               axis: Optional[str] = None):
    """Abstract ``(data, counts)`` of the sharded KG table — the query
    analogue of :func:`repro.plan.mesh.mesh_abstract_inputs` (one source,
    5 columns), with NamedShardings when ``mesh``/``axis`` are given so
    AOT lowering bakes the shard layout for the plan store."""
    shard_d = shard_c = None
    if mesh is not None:
        from jax.sharding import NamedSharding
        shard_d = NamedSharding(mesh, P(axis, None))
        shard_c = NamedSharding(mesh, P(axis))
    data = jax.ShapeDtypeStruct((n_shards * int(cap_local), 5), jnp.int32,
                                sharding=shard_d)
    counts = jax.ShapeDtypeStruct((n_shards,), jnp.int32, sharding=shard_c)
    return data, counts


def compile_query_mesh(plan: QueryPlan, mesh, axis: str,
                       dedup: Optional[str] = None,
                       caps: Optional[Mapping[Node, int]] = None,
                       cap_local: int = 0, pack_u16: bool = False,
                       jit: bool = True,
                       exchanges: Optional[Mapping[Node, object]] = None,
                       safe_exchange: bool = False):
    """Lower a query DAG to one mesh-resident closure; returns
    ``(run, out_cap_local)`` where ``run(data, counts) -> (out_data,
    out_counts, overflowed)`` keeps the result sharded over ``axis``.

    ``caps`` are the SHARD-LOCAL node capacities from
    :func:`repro.query.annotate.annotate_query_local`; ``cap_local`` the
    per-shard KG row-block capacity; ``exchanges``/``safe_exchange``
    follow :func:`repro.plan.mesh.compile_mesh_plan` exactly (unmapped ⋈
    gather; ``safe_exchange`` sizes every exchange bucket at the hard-safe
    ``cap_bucket = cap_local``)."""
    n_shards = int(mesh.shape[axis])
    scan = query_scan(plan)
    strategies = {node: getattr(x, "strategy", x)
                  for node, x in (exchanges or {}).items()}

    def _bucket_cap(cap: int) -> int:
        if n_shards == 1 or safe_exchange:
            return cap
        return min(cap, sink_bucket_cap(cap, n_shards))

    def body(data: jax.Array, counts: jax.Array):
        sources = {scan.source: Table(data=data, count=counts.reshape(()),
                                      attrs=scan.scan_attrs)}
        gathered: Dict[Node, Table] = {}
        exchanged: Dict[Tuple[Node, str], Table] = {}
        flags = []

        def exchange_table(side_node: Node, table: Table,
                           key_attr: str) -> Table:
            hit = exchanged.get((side_node, key_attr))
            if hit is None:
                d, cnt, over = repartition_by_key(
                    _masked_data(table), table.count, axis=axis,
                    n_shards=n_shards,
                    cap_bucket=_bucket_cap(table.capacity),
                    key_cols=(table.attrs.index(key_attr),),
                    pack_u16=pack_u16)
                flags.append(over)
                hit = exchanged[(side_node, key_attr)] = Table(
                    data=d, count=cnt, attrs=table.attrs)
            return hit

        def join_exchange(node: Node, left: Table, right: Table):
            if strategies.get(node) == "repartition":
                return (exchange_table(node.left, left, node.left_key),
                        exchange_table(node.right, right, node.right_key))
            hit = gathered.get(node.right)
            if hit is None:
                hit = gathered[node.right] = gather_table(right, axis,
                                                          n_shards)
            return left, hit

        def distinct_global(node: Node, child: Table) -> Table:
            d, cnt = dedup_rows(_masked_data(child), child.count, dedup)
            if n_shards > 1:
                d, cnt, over = repartition_by_key(
                    d, cnt, axis=axis, n_shards=n_shards,
                    cap_bucket=_bucket_cap(child.capacity), key_cols=None,
                    pack_u16=pack_u16)
                flags.append(over)
                d, cnt = dedup_rows(d, cnt, dedup)
            return Table(data=d, count=cnt, attrs=child.attrs)

        memo: Dict[Node, Table] = {}
        out = execute_node(plan.root, sources, memo, None, dedup, caps,
                           flags, join_exchange=join_exchange,
                           distinct_global=distinct_global)
        over = (jnp.any(jnp.stack(flags)) if flags
                else jnp.zeros((), dtype=bool))
        return out.data, out.count.reshape(1), over.reshape(1)

    fn = shard_map(body, mesh=mesh, in_specs=(P(axis, None), P(axis)),
                   out_specs=(P(axis, None), P(axis), P(axis)))

    def run(data: jax.Array, counts: jax.Array):
        out_data, out_counts, over = fn(data, counts)
        return out_data, out_counts, jnp.any(over)

    if jit:
        run = jax.jit(run)

    abstract = query_mesh_abstract_inputs(cap_local, n_shards)
    out_shape = jax.eval_shape(run, *abstract)[0]
    out_cap_local = out_shape.shape[0] // n_shards
    return run, out_cap_local
