"""Lowering: a BGP :class:`~repro.query.spec.Query` becomes one IR DAG.

The query compiler reuses the creation path's relational IR unchanged —
plus :class:`~repro.plan.ir.ColEq`, the column-vs-column σ — over a single
synthetic source: the coded KG table, scanned under
:data:`~repro.query.spec.KG_SOURCE` with the 5 triple attrs.

Per pattern: constants become ``eq`` predicates on the term columns
(``make_select``), a variable repeated *within* the pattern becomes
``ColEq`` between its column pairs, and a π renames the surviving columns
to variable-derived names (``x__t``/``x__v`` for term variables, ``x__p``
for predicate variables). Patterns then join left-deep in input order on
the first shared variable's value column, with ``ColEq`` equating the
remaining shared columns (template columns of the join variable, both
columns of every further shared variable) and a π dropping the
``r_``-renamed duplicates. Filters lower to σ (term-``neq`` as the
disjoint ∪ of the two conjunctive branches), the projection to a final π,
and the root is always δ — query results have set semantics.

Hash-consing (:func:`repro.plan.ir.intern`) runs over the finished DAG, so
every pattern shares one KG Scan and structurally-equal pattern relations
collapse — the query-side analogue of the creation planner's CSE.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.core.schema import TRIPLE_ATTRS
from repro.plan.ir import (Distinct, EquiJoin, Node, Pred, Project, Scan,
                           Select, Union, intern, make_coleq, make_select)

from .spec import KG_SOURCE, Query, is_var, var_attrs, var_name

#: the KG columns carrying each pattern position
_POS_COLS = {"s": ("s_t", "s_v"), "p": ("p",), "o": ("o_t", "o_v")}


@dataclasses.dataclass
class QueryPlan:
    """A lowered query: the DAG root plus the spec it came from.

    ``emits()`` returns the root as a one-element list so the plan-store
    metadata packers (:func:`repro.api.store.pack_entry_meta` /
    ``unpack_entry_meta``), which enumerate nodes via
    :func:`repro.plan.ir.node_order` over ``plan.emits()``, work on query
    plans exactly as on creation plans.
    """

    query: Query
    root: Node
    out_attrs: Tuple[str, ...]

    def emits(self) -> List[Node]:
        return [self.root]


def _pattern_relation(pat, kinds: Dict[str, str]) -> Tuple[Node, Tuple[str, ...]]:
    """One pattern's relation: σ(constants) → ColEq(repeats) → π(vars).
    Returns ``(node, bound_var_names)``."""
    base: Node = Scan(KG_SOURCE, TRIPLE_ATTRS)
    preds: List[Pred] = []
    var_cols: Dict[str, List[Tuple[str, ...]]] = {}
    for pos, term in (("s", pat.s), ("p", pat.p), ("o", pat.o)):
        cols = _POS_COLS[pos]
        if is_var(term):
            var_cols.setdefault(var_name(term), []).append(cols)
        elif pos == "p":
            preds.append(Pred(cols[0], "eq", int(term)))
        else:
            preds.append(Pred(cols[0], "eq", int(term[0])))
            preds.append(Pred(cols[1], "eq", int(term[1])))
    node = make_select(base, tuple(preds))
    for name in sorted(var_cols):
        first, *rest = var_cols[name]
        for other in rest:     # same var twice in one pattern (?x p ?x)
            for a, b in zip(first, other):
                node = make_coleq(node, a, b)
    if not var_cols:
        return node, ()        # all-constant: keep the triple columns
    spec: List[Tuple[str, str]] = []
    for name in sorted(var_cols):
        src = var_cols[name][0]
        for col, out in zip(src, var_attrs(name, kinds[name])):
            spec.append((col, out))
    return Project(node, tuple(spec)), tuple(sorted(var_cols))


def _join(left: Node, left_vars: Tuple[str, ...], right: Node,
          right_vars: Tuple[str, ...], kinds: Dict[str, str]) -> Node:
    """Left-deep BGP join step: ⋈ on the first shared variable's value
    column, ColEq the rest, π away the ``r_``-renamed duplicates."""
    shared = sorted(set(left_vars) & set(right_vars))
    key = shared[0]
    key_col = var_attrs(key, kinds[key])[-1]   # x__v (term) or x__p (pred)
    node: Node = EquiJoin(left, right, key_col, key_col)
    # remaining equalities: the join variable's template column, plus every
    # column of every further shared variable (the ⋈ equated one column)
    for name in shared:
        for col in var_attrs(name, kinds[name]):
            if name == key and col == key_col:
                continue
            node = make_coleq(node, col, "r_" + col)
    left_set = set(left.attrs)
    keep = left.attrs + tuple(a for a in right.attrs if a not in left_set)
    return Project(node, tuple((a, a) for a in keep))


def _filter(node: Node, f, kinds: Dict[str, str]) -> Node:
    name = var_name(f.var)
    cols = var_attrs(name, kinds[name])
    if kinds[name] == "pred":
        return make_select(node, (Pred(cols[0], f.op, int(f.term)),))
    t_col, v_col = cols
    t_code, v_code = int(f.term[0]), int(f.term[1])
    if f.op == "eq":
        return make_select(node, (Pred(t_col, "eq", t_code),
                                  Pred(v_col, "eq", v_code)))
    # term ≠ const  ≡  (t ≠ tc) ∪ (t = tc ∧ v ≠ vc) — disjoint branches,
    # so the bag ∪ introduces no duplicates
    return Union((make_select(node, (Pred(t_col, "neq", t_code),)),
                  make_select(node, (Pred(t_col, "eq", t_code),
                                     Pred(v_col, "neq", v_code)))))


def lower_query(query: Query) -> QueryPlan:
    """``Query -> QueryPlan`` (see the module docstring for the shape).

    Raises ``ValueError`` for disconnected BGPs: every pattern after the
    first must share a variable with the accumulated relation (the IR has
    no cartesian product, and unconstrained cross products are almost
    always a query bug).
    """
    kinds = query.var_kinds()
    rels = [_pattern_relation(p, kinds) for p in query.patterns]
    if not kinds:
        if len(rels) > 1:
            raise ValueError("disconnected BGP: all-constant existence "
                             "queries must be a single pattern")
        root: Node = Distinct(rels[0][0])
        return QueryPlan(query, intern(root), TRIPLE_ATTRS)
    if any(not vars_ for _, vars_ in rels):
        raise ValueError("disconnected BGP: an all-constant pattern "
                         "cannot join the variable-bearing patterns")

    acc, acc_vars = rels[0]
    bound = set(acc_vars)
    pending = list(rels[1:])
    while pending:
        idx = next((i for i, (_, vs) in enumerate(pending)
                    if bound & set(vs)), None)
        if idx is None:
            missing = sorted(set(v for _, vs in pending for v in vs) - bound)
            raise ValueError("disconnected BGP: no shared variable links "
                             f"the patterns binding {missing} to the rest "
                             "(cartesian products are not supported)")
        right, right_vars = pending.pop(idx)
        acc = _join(acc, tuple(sorted(bound)), right, right_vars, kinds)
        bound |= set(right_vars)

    for f in query.filters:
        acc = _filter(acc, f, kinds)

    out_attrs = query.answer_attrs()
    if acc.attrs != out_attrs:
        acc = Project(acc, tuple((a, a) for a in out_attrs))
    return QueryPlan(query, intern(Distinct(acc)), out_attrs)


def query_scan(plan: QueryPlan) -> Scan:
    """The (single) KG Scan of a lowered query — what the mesh compiler
    shards."""
    from repro.plan.ir import iter_nodes
    for node in iter_nodes(plan.root):
        if isinstance(node, Scan):
            return node
    raise ValueError("query plan has no Scan")  # pragma: no cover
