"""KGQuery: jitted BGP queries over the device-resident KG.

The read-side counterpart of the creation pipeline, built from the same
relational IR, annotation, verification, plan-cache/store and shard_map
machinery (see ``docs/query.md``). The public spec types re-export from
:mod:`repro.api`; the compilation entry points live here:

* :class:`Query` / :class:`TriplePattern` / :class:`QueryFilter` — the BGP
  spec (:mod:`repro.query.spec`, also the query cache-key module).
* :func:`lower_query` — spec → IR DAG (:mod:`repro.query.lower`).
* :func:`annotate_query` / :func:`annotate_query_local` — capacity
  annotation (:mod:`repro.query.annotate`).
* :func:`compile_query` / :func:`compile_query_mesh` — single-device and
  fused-mesh closures.

Served by :meth:`repro.api.KGEngine.query`.
"""
from .annotate import annotate_query, annotate_query_local
from .compile import compile_query
from .lower import QueryPlan, lower_query, query_scan
from .mesh import compile_query_mesh, query_mesh_abstract_inputs
from .spec import (KG_SOURCE, Query, QueryFilter, TriplePattern,
                   query_session_key)

__all__ = [
    "KG_SOURCE",
    "Query",
    "QueryFilter",
    "QueryPlan",
    "TriplePattern",
    "annotate_query",
    "annotate_query_local",
    "compile_query",
    "compile_query_mesh",
    "lower_query",
    "query_mesh_abstract_inputs",
    "query_scan",
    "query_session_key",
]
