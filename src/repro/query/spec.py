"""BGP query specs + their structural fingerprints and cache keys.

A :class:`Query` is a basic graph pattern over the coded KG table: a
conjunction of :class:`TriplePattern`\\ s whose subject/predicate/object
positions hold either a *constant* (dictionary codes — a ``(template,
value)`` pair for subject/object terms, a single code for predicates) or a
*variable* (a ``"?name"`` string), plus optional :class:`QueryFilter`\\ s
and a projection. Semantics are SPARQL ``SELECT DISTINCT`` restricted to
connected BGPs (every pattern must share a variable with the patterns
before it — there is no cartesian-product operator in the IR).

This module is also the query tier's **cache-key module**: fingerprints and
session keys derived here must be process-stable (no ``id()``/``hash()``,
sorted iteration only — enforced by ``tools/lint_invariants.py``) because
they feed the plan cache and the persistent plan store
(:mod:`repro.api.store`) exactly like :func:`repro.plan.ir.fingerprint`
does for creation plans.
"""
from __future__ import annotations

import dataclasses
import hashlib
import re
from typing import Dict, Optional, Tuple, Union

#: the reserved source name the query DAG's Scan reads the KG table under
KG_SOURCE = "__kg__"

_VAR_RE = re.compile(r"^\?[A-Za-z][A-Za-z0-9_]*$")

Term = Union[str, int, Tuple[int, int]]


def is_var(term) -> bool:
    """True iff ``term`` is a variable (``"?name"`` string)."""
    return isinstance(term, str)


def var_name(term: str) -> str:
    return term[1:]


def _check_var(term: str, where: str) -> None:
    if not _VAR_RE.match(term):
        raise ValueError(f"bad query variable {term!r} in {where} "
                         "(expected '?name', name = [A-Za-z][A-Za-z0-9_]*)")
    if term[1:].startswith("r_"):
        raise ValueError(f"bad query variable {term!r} in {where} "
                         "(names starting with 'r_' collide with the ⋈ "
                         "rename suffix)")


def _check_term_const(term, where: str) -> None:
    if not (isinstance(term, tuple) and len(term) == 2
            and all(isinstance(c, int) and not isinstance(c, bool)
                    for c in term)):
        raise ValueError(f"bad term constant {term!r} in {where} "
                         "(expected a (template, value) code pair or a "
                         "'?var')")


@dataclasses.dataclass(frozen=True)
class TriplePattern:
    """One BGP triple pattern over coded terms.

    ``s``/``o`` are ``"?var"`` or an ``(template_code, value_code)`` int
    pair; ``p`` is ``"?var"`` or a single predicate code. A variable may
    appear in term (subject/object) positions or in predicate positions,
    never both (the coded spaces differ: terms are column pairs,
    predicates single codes).
    """

    s: Term
    p: Term
    o: Term

    def __post_init__(self):
        for pos, term in (("s", self.s), ("o", self.o)):
            if is_var(term):
                _check_var(term, f"pattern position {pos!r}")
            else:
                _check_term_const(term, f"pattern position {pos!r}")
        if is_var(self.p):
            _check_var(self.p, "pattern position 'p'")
        elif not (isinstance(self.p, int) and not isinstance(self.p, bool)):
            raise ValueError(f"bad predicate constant {self.p!r} "
                             "(expected a single code or a '?var')")

    def vars(self) -> Tuple[str, ...]:
        """Distinct variable names in s, p, o order."""
        out = []
        for term in (self.s, self.p, self.o):
            if is_var(term) and var_name(term) not in out:
                out.append(var_name(term))
        return tuple(out)


@dataclasses.dataclass(frozen=True)
class QueryFilter:
    """One filter conjunct: ``?var <op> constant`` over coded terms.

    ``op`` is ``"eq"`` or ``"neq"``; ``term`` is a ``(template, value)``
    pair when ``var`` binds terms, a single code when it binds predicates
    (checked against the query's variable kinds at :class:`Query`
    construction).
    """

    var: str
    op: str
    term: Union[int, Tuple[int, int]]

    def __post_init__(self):
        _check_var(self.var, "filter")
        if self.op not in ("eq", "neq"):
            raise ValueError(f"bad filter op {self.op!r} "
                             "(expected 'eq' or 'neq')")


@dataclasses.dataclass(frozen=True)
class Query:
    """A BGP query: patterns + optional filters and projection.

    ``project`` selects (and orders) the answer variables; ``None`` means
    every variable, sorted by name. Results always have set semantics
    (``SELECT DISTINCT``). A query with no variables is an existence check:
    it must be a single all-constant pattern and returns the matching
    triple rows themselves (0 or 1 after δ).
    """

    patterns: Tuple[TriplePattern, ...]
    filters: Tuple[QueryFilter, ...] = ()
    project: Optional[Tuple[str, ...]] = None

    def __post_init__(self):
        object.__setattr__(self, "patterns", tuple(self.patterns))
        object.__setattr__(self, "filters", tuple(self.filters))
        if self.project is not None:
            object.__setattr__(self, "project", tuple(self.project))
        if not self.patterns:
            raise ValueError("empty query (no triple patterns)")
        kinds = self.var_kinds()
        for f in self.filters:
            name = var_name(f.var)
            kind = kinds.get(name)
            if kind is None:
                raise ValueError(f"filter on unknown variable {f.var!r}")
            if kind == "term":
                _check_term_const(f.term, f"filter on {f.var!r}")
            elif not (isinstance(f.term, int)
                      and not isinstance(f.term, bool)):
                raise ValueError(f"filter on predicate variable {f.var!r} "
                                 "needs a single predicate code, got "
                                 f"{f.term!r}")
        if self.project is not None:
            if not self.project:
                raise ValueError("empty projection (project=None selects "
                                 "all variables)")
            for v in self.project:
                _check_var(v, "projection")
                if var_name(v) not in kinds:
                    raise ValueError(f"projected variable {v!r} not bound "
                                     "by any pattern")
            if len(set(self.project)) != len(self.project):
                raise ValueError("duplicate variable in projection")

    def var_kinds(self) -> Dict[str, str]:
        """``{name: "term" | "pred"}`` for every variable, validating that
        no variable is used in both position kinds."""
        kinds: Dict[str, str] = {}

        def seen(term, kind: str):
            if not is_var(term):
                return
            name = var_name(term)
            if kinds.setdefault(name, kind) != kind:
                raise ValueError(
                    f"variable ?{name} used in both predicate and term "
                    "positions (the coded spaces are incomparable)")

        for pat in self.patterns:
            seen(pat.s, "term")
            seen(pat.p, "pred")
            seen(pat.o, "term")
        return kinds

    def answer_vars(self) -> Tuple[str, ...]:
        """Projected variable names, in output order."""
        if self.project is not None:
            return tuple(var_name(v) for v in self.project)
        return tuple(sorted(self.var_kinds()))

    def answer_attrs(self) -> Tuple[str, ...]:
        """Result-table attr names: ``(v__t, v__v)`` per term variable,
        ``v__p`` per predicate variable, in answer order — or the 5 triple
        attrs for a variable-free existence query."""
        kinds = self.var_kinds()
        if not kinds:
            from repro.core.schema import TRIPLE_ATTRS
            return TRIPLE_ATTRS
        out = []
        for name in self.answer_vars():
            out.extend(var_attrs(name, kinds[name]))
        return tuple(out)

    def fingerprint(self) -> str:
        """Deterministic structural digest (sha1 hex) — what the query
        plan-cache/store key tiers key on. Two queries fingerprint equal
        iff they lower to the same IR DAG over the same codes."""
        lines = []
        for pat in self.patterns:
            lines.append(f"pattern {pat.s!r} {pat.p!r} {pat.o!r}")
        for f in self.filters:
            lines.append(f"filter {f.var!r} {f.op} {f.term!r}")
        lines.append(f"project {self.project!r}")
        return hashlib.sha1("\n".join(lines).encode()).hexdigest()


def var_attrs(name: str, kind: str) -> Tuple[str, ...]:
    """The relation columns carrying variable ``name``."""
    if kind == "pred":
        return (f"{name}__p",)
    return (f"{name}__t", f"{name}__v")


def query_session_key(query: Query, *, dedup, mode: str, slack: float,
                      jit: bool, kg_bucket_cap: int,
                      mesh_sig=None) -> tuple:
    """The in-process plan-cache key of one compiled query closure.

    Everything that changes the traced program is in here: the query's
    structural fingerprint, the δ strategy of the final Distinct, the
    annotation mode/slack (they size the capacities), ``jit``, the KG
    table's capacity bucket (the Scan's static shape), and — distributed —
    the engine's mesh signature (mesh shape/axis/devices, shard-local
    caps, exchange strategy, calibration). Components are restricted to
    :func:`repro.api.store.canonical`-admissible values so the same tuple
    derives the persistent store key.
    """
    return ("bgp", query.fingerprint(), dedup, mode, float(slack),
            bool(jit), int(kg_bucket_cap), mesh_sig)
