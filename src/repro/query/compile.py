"""Compiling a lowered query DAG to device execution (single device).

Same machinery as :func:`repro.plan.compile.compile_plan`, minus the
emitter/sink: the query root is already the δ the spec's set semantics
require, so the closure is ``{KG_SOURCE: Table} -> (result, overflowed)``
with every capped node reporting the same truncation flag the creation
path uses — ``KGEngine.query`` answers an overflow with one exact
recompile at floored capacities, exactly like ``run()``.
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional

import jax
import jax.numpy as jnp

from repro.plan.compile import execute_node
from repro.plan.ir import Node
from repro.relalg import Table

from .lower import QueryPlan


def compile_query(plan: QueryPlan, dedup: Optional[str] = None,
                  caps: Optional[Mapping[Node, int]] = None,
                  jit: bool = True, report_overflow: bool = False):
    """Lower a query DAG to one ``sources -> result`` closure (jitted by
    default); with ``report_overflow=True`` it returns
    ``(result, overflowed)``. ``sources`` maps
    :data:`~repro.query.spec.KG_SOURCE` to the coded KG table."""
    root = plan.root

    def fn(sources: Mapping[str, Table]):
        memo: Dict[Node, Table] = {}
        flags: Optional[List[jax.Array]] = [] if report_overflow else None
        out = execute_node(root, sources, memo, None, dedup, caps, flags)
        if not report_overflow:
            return out
        over = (jnp.any(jnp.stack(flags)) if flags
                else jnp.zeros((), dtype=bool))
        return out, over

    return jax.jit(fn) if jit else fn
