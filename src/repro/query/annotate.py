"""Capacity annotation for query DAGs.

The creation-path annotator (:mod:`repro.plan.annotate`) walks
``plan.emits()`` and treats ⋈ as a leaf-adjacent special case (joins feed
``EmitTriples`` directly). Query DAGs stack π/δ/``ColEq`` *on top of*
joins, so these entry points walk the whole DAG in :func:`node_order`
post-order instead — reusing the same row evaluator / structural bounds /
Poisson shard bounds / ⋈ exchange cost model, so the capacity semantics
(exact vs bound mode, slack, bucketed cap_fn, overflow-recompile ladder,
gather-vs-repartition pricing) are identical to the creation path's.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, Mapping, Optional, Tuple

from repro.plan.annotate import (JoinExchange, _bound, _eval_rows,
                                 join_exchange_cost, parent_fanouts,
                                 poisson_shard_bound)
from repro.plan.ir import (ColEq, Distinct, EquiJoin, Node, Project, Scan,
                           Select, Union, node_order)
from repro.relalg.table import Table, round_cap

from .lower import QueryPlan


def annotate_query(plan: QueryPlan,
                   sources: Mapping[str, Table], mode: str = "exact",
                   slack: float = 1.0,
                   cap_fn: Callable[[int], int] = round_cap,
                   ) -> Tuple[Dict[Node, int], Dict[Node, int]]:
    """(counts, capacities) for every node of a query DAG.

    ``mode="exact"`` evaluates rows on the host (joins materialized — see
    :func:`repro.plan.annotate._eval_rows`); ``mode="bound"`` uses the
    structural bounds (⋈ = FK heuristic, backstopped by the runtime
    overflow flag + recompile ladder exactly as for creation plans).
    """
    if mode not in ("exact", "bound"):
        raise ValueError(f"unknown annotate mode {mode!r}")
    counts: Dict[Node, int] = {}
    if mode == "bound":
        bmemo: Dict[Node, int] = {}

        def count_of(node: Node) -> int:
            return _bound(node, sources, bmemo)
    else:
        memo: Dict[Node, object] = {}

        def count_of(node: Node) -> int:
            return len(_eval_rows(node, sources, memo)[0])

    for node in node_order([plan.root]):
        counts[node] = count_of(node)
    caps = {node: cap_fn(int(math.ceil(c * slack)))
            for node, c in counts.items()}
    return counts, caps


def annotate_query_local(plan: QueryPlan, n_shards: int,
                         cap_locals: Mapping[str, int], mode: str = "exact",
                         slack: float = 1.0,
                         cap_fn: Callable[[int], int] = round_cap,
                         sources: Optional[Mapping[str, Table]] = None,
                         join_exchange: str = "gather",
                         safe_exchange: bool = False,
                         calibration=None,
                         ) -> Tuple[Dict[Node, int], Dict[Node, int],
                                    Dict[Node, JoinExchange]]:
    """Shard-local (counts, capacities, exchanges) for the fused mesh query
    closure — the query-DAG analogue of
    :func:`repro.plan.annotate.annotate_local` (same global counts, same
    post-exchange Poisson bounds for δ and repartitioned ⋈, same
    ``safe_exchange`` hard bounds, same cost-model inputs: the children's
    already-bucketed shard-local caps).
    """
    counts, _ = annotate_query(plan, sources, mode=mode, slack=slack,
                               cap_fn=cap_fn)
    locals_: Dict[Node, int] = {}
    caps: Dict[Node, int] = {}
    exchanges: Dict[Node, JoinExchange] = {}
    # gather amortization divisor per shared parent (BGP joins habitually
    # share the KG-pattern parent) — same grouping as the creation path
    fanout = parent_fanouts(n for n in node_order([plan.root])
                            if isinstance(n, EquiJoin))
    for node in node_order([plan.root]):    # post-order: children first
        c = counts[node]
        if isinstance(node, Scan):
            local = int(cap_locals[node.source])
        elif isinstance(node, Distinct):
            # executed as a global hash-repartition δ: the shard holds the
            # distinct rows hashing to it, not its pre-exchange slice
            local = c if safe_exchange else poisson_shard_bound(c, n_shards)
        elif isinstance(node, (Project, Select, ColEq)):
            local = locals_[node.children()[0]]
        elif isinstance(node, Union):
            local = sum(locals_[ch] for ch in node.inputs)
        elif isinstance(node, EquiJoin):
            exch = join_exchange_cost(
                caps[node.left], len(node.left.attrs),
                caps[node.right], len(node.right.attrs),
                n_shards, strategy=join_exchange, calibration=calibration,
                parent_fanout=fanout[node.right])
            exchanges[node] = exch
            if exch.strategy == "repartition":
                local = (c if safe_exchange
                         else poisson_shard_bound(c, n_shards))
            elif mode == "exact":
                local = c
            else:
                local = min(c, locals_[node.left] + counts[node.right])
        else:
            raise TypeError(f"cannot annotate {type(node).__name__}")
        locals_[node] = min(c, local)
        caps[node] = cap_fn(int(math.ceil(locals_[node] * slack)))
    return counts, caps, exchanges
