"""KGEngine session tests: plan cache, incremental ingestion, overflow-safe
re-execution, bound-mode annotation, distributed closure reuse.

The hypothesis-based ingest property sweep lives in
``test_engine_properties.py`` (skipped without the test extra); this file
keeps a seeded sweep so the same invariant — ``engine.ingest`` stays
bit-identical to a fresh run over the accumulated sources — is exercised
in every environment.
"""
import numpy as np
import pytest

from repro.api import KGEngine, PLAN_CACHE
from repro.core import parse_dis
from repro.core.rdfizer import RDFizer
from repro.data.synthetic import make_group_b_dis
from repro.relalg import Table, bucket_cap, forbid_transfers


def _oracle(dis, sources, engine="sdm", dedup=None):
    """Fresh un-cached run over explicit sources — the bit-level oracle."""
    acc = dis.copy()
    acc.sources = dict(sources)
    kg, raw = RDFizer(acc, engine, dedup=dedup)()
    return kg


def _reencode(src_dis, name, vocab, attrs, limit=None):
    """Rows of ``src_dis.sources[name]`` re-interned under ``vocab``."""
    recs = src_dis.sources[name].to_records(src_dis.vocab)
    return Table.from_records(recs[:limit], attrs, vocab)


# ---------------------------------------------------------------------------
# capacity buckets
# ---------------------------------------------------------------------------

def test_bucket_cap_geometric():
    assert bucket_cap(0) == 8
    assert bucket_cap(8) == 8
    assert bucket_cap(9) == 16
    assert bucket_cap(100) == 128
    assert bucket_cap(128) == 128
    assert bucket_cap(129) == 256
    # monotone, and always a round_cap multiple
    prev = 0
    for n in range(1, 300, 7):
        cap = bucket_cap(n)
        assert cap >= n and cap >= prev and cap % 8 == 0
        prev = cap


# ---------------------------------------------------------------------------
# create_kg: correctness + plan cache
# ---------------------------------------------------------------------------

def test_create_kg_bit_identical_to_fresh_rdfizer():
    mk = lambda: make_group_b_dis(96, 0.6, seed=1)  # noqa: E731
    kg_ref = _oracle(mk(), mk().sources)
    kg, stats = KGEngine(mk()).create_kg()
    np.testing.assert_array_equal(kg.to_codes(), kg_ref.to_codes())
    for key in ("recompiles", "plan_cache_hit", "plan_cache_hits",
                "preprocess_seconds", "semantify_seconds", "raw_triples"):
        assert key in stats


def test_structurally_identical_sessions_share_one_plan():
    mk = lambda: make_group_b_dis(80, 0.5, seed=2)  # noqa: E731
    kg1, s1 = KGEngine(mk()).create_kg()
    size_after_first = PLAN_CACHE.stats()["size"]
    kg2, s2 = KGEngine(mk()).create_kg()
    assert s2["plan_cache_hit"]
    assert PLAN_CACHE.stats()["size"] == size_after_first  # no new entry
    np.testing.assert_array_equal(kg1.to_codes(), kg2.to_codes())
    # the hit skips annotation + compilation: the second session never
    # jit-traces, so its execution wall time drops by orders of magnitude
    assert s2["semantify_seconds"] < s1["semantify_seconds"]


def test_cache_key_distinguishes_engine_and_dedup():
    mk = lambda: make_group_b_dis(48, 0.5, seed=3)  # noqa: E731
    _, s1 = KGEngine(mk(), engine="sdm", dedup="hash").create_kg()
    _, s2 = KGEngine(mk(), engine="rmlmapper", dedup="hash").create_kg()
    _, s3 = KGEngine(mk(), engine="sdm", dedup="lex").create_kg()
    assert not s2["plan_cache_hit"] and not s3["plan_cache_hit"]


def test_run_accepts_external_same_shape_sources():
    dis = make_group_b_dis(64, 0.5, seed=4)
    eng = KGEngine(dis)
    kg1, _ = eng.create_kg()
    other = make_group_b_dis(64, 0.5, seed=4)
    kg2, _raw = eng.run(other.sources)
    np.testing.assert_array_equal(kg1.to_codes(), kg2.to_codes())


# ---------------------------------------------------------------------------
# ingest: within-bucket reuse, bucket crossing, interior overflow
# ---------------------------------------------------------------------------

def test_ingest_within_bucket_reuses_closure():
    dis = make_group_b_dis(100, 0.6, seed=5)   # bucket 128: room for +28
    eng = KGEngine(dis)
    eng.create_kg()
    delta_src = make_group_b_dis(16, 0.5, seed=50)
    kg, stats = eng.ingest(
        {"gene": _reencode(delta_src, "gene", eng.vocab,
                           dis.sources["gene"].attrs)})
    assert stats["recompiles"] == 0
    assert stats["plan_cache_hit"]
    kg_ref = _oracle(dis, eng.sources)
    np.testing.assert_array_equal(kg.to_codes(), kg_ref.to_codes())


def test_ingest_bucket_crossing_exactly_one_recompile():
    dis = make_group_b_dis(64, 0.6, seed=6)
    eng = KGEngine(dis)
    eng.create_kg()
    assert eng.stats()["recompiles"] == 0
    big = make_group_b_dis(16 * 64, 0.6, seed=60)   # 16x the seed size
    kg, stats = eng.ingest(
        {"gene": _reencode(big, "gene", eng.vocab,
                           dis.sources["gene"].attrs)})
    assert stats["recompiles"] == 1
    kg_ref = _oracle(dis, eng.sources)
    np.testing.assert_array_equal(kg.to_codes(), kg_ref.to_codes())


def test_interior_overflow_recompiles_once_not_truncates():
    """Same source bucket, but the ingested rows blow past an *interior*
    δ capacity (plan-time distinct count) — the runtime overflow flag must
    trigger exactly one recompile instead of silently truncating the KG."""
    values = [f"v{i % 4}" for i in range(40)]    # 40 rows, 4 distinct
    spec = {"sources": {"s": {"attrs": ["a", "b"], "records": [
        {"a": v, "b": v} for v in values]}},
        "maps": [{"name": "m", "source": "s",
                  "subject": {"template": "http://ex/T/{a}",
                              "class": "ex:C"},
                  "poms": [{"predicate": "ex:p",
                            "object": {"reference": "b"}}]}]}
    dis = parse_dis(spec)
    eng = KGEngine(dis)
    eng.create_kg()
    # +10 rows with 10 NEW distinct values: source count 50 stays in the
    # 64-bucket, but δ output 14 > the plan-time distinct cap of 8
    fresh = [{"a": f"w{i}", "b": f"w{i}"} for i in range(10)]
    delta = Table.from_records(fresh, ("a", "b"), eng.vocab)
    kg, stats = eng.ingest({"s": delta})
    assert stats["recompiles"] == 1
    assert stats["kg_triples"] == 2 * (4 + 10)   # class + literal per subject
    kg_ref = _oracle(dis, eng.sources)
    np.testing.assert_array_equal(kg.to_codes(), kg_ref.to_codes())
    # create_kg recounts Table-1 sizes against the CURRENT extension even
    # on a cache hit (4 + 10 distinct subjects now)
    _kg2, stats2 = eng.create_kg()
    assert sum(stats2["source_rows_after"].values()) == 14


@pytest.mark.parametrize("seed,factor", [(7, 1), (8, 4), (9, 16)])
def test_ingest_seeded_sweep_bit_identical(seed, factor):
    """Seeded mirror of the hypothesis property: extensions 1x-16x the seed
    stay bit-identical to a fresh eager run over the accumulated sources."""
    dis = make_group_b_dis(32, 0.6, seed=seed)
    eng = KGEngine(dis)
    eng.create_kg()
    ext = make_group_b_dis(32 * factor, 0.6, seed=seed + 100)
    deltas = {name: _reencode(ext, name, eng.vocab,
                              dis.sources[name].attrs)
              for name in ("gene", "chrom")}
    kg, stats = eng.ingest(deltas)
    kg_ref = _oracle(dis, eng.sources)
    np.testing.assert_array_equal(kg.to_codes(), kg_ref.to_codes())


def test_ingest_into_sigma_baked_source_revalidates_selections():
    """A planner-materialized DIS' flags σ-baked sources and skips the
    join-parent re-select; ingesting RAW delta rows into such a source
    must drop the flag (and replan), or a row violating the map's σ
    selection would leak triples through child joins."""
    from repro.core.transform import apply_mapsdi
    spec = {
        "sources": {
            "g": {"attrs": ["k", "v", "sp"], "records": [
                {"k": "k1", "v": "o1", "sp": "HUMAN"},
                {"k": "k2", "v": "o2", "sp": "MOUSE"},
                {"k": "k3", "v": "o3", "sp": "HUMAN"}]},
            "h": {"attrs": ["k", "w"], "records": [
                {"k": "k1", "w": "b1"}, {"k": "k2", "w": "b2"},
                {"k": "k3", "w": "b3"}]},
        },
        "maps": [
            {"name": "parent", "source": "g",
             "subject": {"template": "http://ex/P/{k}"},
             "poms": [{"predicate": "ex:v", "object": {"reference": "v"}}],
             "selections": [{"attr": "sp", "eq": "HUMAN"}]},
            {"name": "child", "source": "h",
             "subject": {"template": "http://ex/C/{w}"},
             "poms": [{"predicate": "ex:j",
                       "object": {"parentTriplesMap": "parent",
                                  "joinCondition": {"child": "k",
                                                    "parent": "k"}}}]},
        ],
    }
    dis2, _ = apply_mapsdi(parse_dis(spec))
    parent_src = dis2.map_by_name("parent").source
    assert parent_src in dis2.sigma_baked
    eng = KGEngine(dis2)
    kg0, _stats = eng.create_kg()
    assert int(kg0.count) == 2 + 2   # 2 HUMAN literals + 2 join triples
    # raw delta row VIOLATING the selection (sp=MOUSE) joining child k2
    attrs = eng.sources[parent_src].attrs
    delta = Table.from_records(
        [{"k": "k2", "v": "oX", "sp": "MOUSE"}], attrs, eng.vocab)
    kg, stats = eng.ingest({parent_src: delta})
    assert parent_src not in eng._dis.sigma_baked   # flag dropped
    assert int(kg.count) == int(kg0.count)          # no leaked join triple
    acc = dis2.copy()
    acc.sources = dict(eng.sources)
    acc.sigma_baked = set()                         # honest oracle
    kg_ref, _ = RDFizer(acc, "sdm")()
    np.testing.assert_array_equal(kg.to_codes(), kg_ref.to_codes())


def test_ingest_unknown_source_raises_without_mutating():
    dis = make_group_b_dis(16, 0.5, seed=10)
    eng = KGEngine(dis)
    n_before = int(eng.sources["gene"].count)
    good = Table.from_codes(dis.sources["gene"].to_codes()[:2],
                            dis.sources["gene"].attrs)
    with pytest.raises(KeyError):
        eng.ingest({"gene": good, "nope": Table.empty(("x",), 8)})
    # the whole batch is validated up front: nothing was appended
    assert int(eng.sources["gene"].count) == n_before
    assert eng.stats()["ingests"] == 0


# ---------------------------------------------------------------------------
# bound-mode annotation
# ---------------------------------------------------------------------------

def test_bound_annotation_reads_no_data():
    from repro.core.transform import plan_mapsdi
    from repro.plan.annotate import annotate
    from repro.plan.ir import Scan
    dis = make_group_b_dis(64, 0.5, seed=11)
    plan = plan_mapsdi(dis)
    with forbid_transfers():        # bound mode: zero device->host syncs
        counts, caps = annotate(plan, mode="bound", slack=1.5)
    for node in counts:
        if isinstance(node, Scan):
            assert counts[node] == dis.sources[node.source].capacity
        assert caps[node] >= counts[node]


def test_bound_mode_engine_matches_exact():
    mk = lambda: make_group_b_dis(72, 0.6, seed=12)  # noqa: E731
    kg_e, _ = KGEngine(mk(), mode="exact").create_kg()
    kg_b, stats = KGEngine(mk(), mode="bound", slack=1.0).create_kg()
    np.testing.assert_array_equal(kg_b.to_codes(), kg_e.to_codes())


# ---------------------------------------------------------------------------
# distributed sink: the session reuses the cached collective closure
# ---------------------------------------------------------------------------

def test_mesh_sink_reuses_cached_collective_closure():
    from repro.core.distributed import repartition_trace_count
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    dis = make_group_b_dis(96, 0.6, seed=13)
    eng = KGEngine(dis, mesh=mesh)
    kg, _ = eng.create_kg()
    kg_ref = _oracle(dis, eng.sources)
    assert kg.row_set() == kg_ref.row_set()
    traces0 = repartition_trace_count()
    delta_src = make_group_b_dis(8, 0.5, seed=130)
    for b in range(2):              # same-bucket ingests: zero re-traces
        kg, _stats = eng.ingest(
            {"gene": _reencode(delta_src, "gene", eng.vocab,
                               dis.sources["gene"].attrs)})
    assert repartition_trace_count() == traces0
    assert kg.row_set() == _oracle(dis, eng.sources).row_set()


# ---------------------------------------------------------------------------
# session stats
# ---------------------------------------------------------------------------

def test_session_stats_counters():
    dis = make_group_b_dis(48, 0.5, seed=14)
    eng = KGEngine(dis)
    eng.create_kg()
    eng.run()
    st = eng.stats()
    assert st["executions"] == 2
    assert st["ingests"] == 0
    assert st["engine"] == "sdm" and st["mode"] == "exact"
    assert st["plan_cache_hits"] + st["plan_cache_misses"] == 2
    assert set(st["source_buckets"]) == {"gene", "chrom"}
    assert all(cap % 8 == 0 for cap in st["source_buckets"].values())
