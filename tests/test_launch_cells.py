"""Launch-layer tests: cell building, EF lowering, VMEM tile budgets."""
import os
import subprocess
import sys

import jax
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_opt_state_specs_match_init_structure():
    import jax
    from repro.configs.base import get_config, reduced_config
    from repro.distributed.sharding import init_params
    from repro.launch.specs import opt_state_specs
    from repro.models import get_model
    from repro.train.optimizer import make_optimizer
    for arch, opt_name in (("qwen3-1.7b", "adamw"),
                           ("mistral-large-123b", "adafactor")):
        cfg = reduced_config(get_config(arch))
        model = get_model(cfg.family)
        p_specs = model.param_specs(cfg)
        params = init_params(p_specs, jax.random.PRNGKey(0))
        opt = make_optimizer(opt_name)
        real = opt.init(params)
        spec = opt_state_specs(opt_name, p_specs)
        s_real = jax.tree_util.tree_structure(real)
        s_spec = jax.tree_util.tree_structure(
            jax.tree_util.tree_map(lambda s: 0, spec,
                                   is_leaf=lambda x: hasattr(x, "shape")
                                   and not isinstance(x, dict)))
        assert s_real == s_spec, (arch, opt_name)


def test_param_counts_active_vs_total():
    from repro.configs.base import get_config
    from repro.launch.specs import model_param_counts
    k = model_param_counts(get_config("kimi_k2_1t_a32b"))
    assert k["active"] < k["total"] * 0.05     # 384e top-8 => ~2% + dense
    d = model_param_counts(get_config("qwen3_1p7b"))
    assert d["active"] == d["total"]           # dense: all params active


@pytest.mark.skipif(not hasattr(jax, "shard_map"),
                    reason="partial-auto shard_map lowering needs jax>=0.6 "
                           "(pinned 0.4.x hits PartitionId UNIMPLEMENTED)")
def test_ef_pod_decoupled_cell_lowers():
    """grad_compress_pods=True on a non-FSDP arch: the pod-decoupled
    shard_map train step lowers + compiles on the multi-pod mesh, and the
    cross-pod classifier finds the quantized psum."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import run_cell
from repro.launch.hlo_analysis import collective_bytes
rec = run_cell('qwen3_1p7b', 'train_4k', 'multi', unroll=False,
               cfg_overrides={"grad_compress_pods": True}, keep_hlo=True)
assert rec["status"] == "ok"
st = collective_bytes(rec["hlo_text"], pod_boundary=256)
assert st.cross_pod_bytes > 0
print("OK", st.cross_pod_bytes)
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2500:]
    assert "OK" in out.stdout


def test_kernel_tiles_fit_vmem():
    """Analytic VMEM budgets for the default BlockSpec tiles at production
    dims (v5e: ~16 MiB VMEM/core; keep tiles under half for double
    buffering)."""
    VMEM = 16 * 2**20
    budget = VMEM // 2

    # flash attention: q/k/v/acc tiles at block 128, d_head<=256, f32 acc
    bq = bk = 128
    for d in (64, 128, 256):
        tile = (bq * d + 2 * bk * d) * 2 + bq * d * 4 + 3 * bq * 4
        assert tile < budget, ("flash", d, tile)

    # rwkv6: per-chunk r/k/v/w [chunk, N] + state [N, N] f32, chunk 32
    for n in (64, 128):
        tile = 4 * 32 * n * 4 + n * n * 4 + 32 * 32 * 4
        assert tile < budget, ("rwkv6", n, tile)

    # mamba2 SSD: chunk 64, headdim<=128, state<=128
    for p, n in ((64, 64), (128, 128)):
        tile = 64 * p * 4 + 2 * 64 * n * 4 + n * p * 4 + 64 * 64 * 4
        assert tile < budget, ("mamba2", p, n, tile)

    # rowhash: [block_n, K] int32 rows + [block_n] u32 out, block 256
    tile = 256 * 16 * 4 + 256 * 4
    assert tile < budget, ("rowhash", tile)
