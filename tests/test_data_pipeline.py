"""KG -> token pipeline: determinism, elasticity, weighted rebalance."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="test extra: pip install -r "
                    "requirements.txt")
from hypothesis import given, settings, strategies as st

from repro.core.pipeline import mapsdi_create_kg
from repro.data.pipeline import BOT, EOT, KGTokenPipeline, N_SPECIAL, linearize_kg, random_lm_batch
from repro.data.synthetic import make_group_a_dis


def _stream(n=5000, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 250, size=n).astype(np.int32) + N_SPECIAL


def test_batch_deterministic():
    p1 = KGTokenPipeline(_stream(), seq_len=32, global_batch=8)
    p2 = KGTokenPipeline(_stream(), seq_len=32, global_batch=8)
    for step in (0, 1, 17):
        b1, b2 = p1.batch(step), p2.batch(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_labels_are_shifted_tokens():
    p = KGTokenPipeline(_stream(), seq_len=16, global_batch=4)
    b = p.batch(3)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_shards_partition_global_batch():
    p = KGTokenPipeline(_stream(), seq_len=32, global_batch=8)
    full = p.batch(5)["tokens"]
    for n_shards in (1, 2, 4, 8):
        parts = [p.shard_batch(5, i, n_shards)["tokens"]
                 for i in range(n_shards)]
        np.testing.assert_array_equal(np.concatenate(parts), full)


def test_elastic_reshard_same_rows():
    """Same step yields the same global rows for any shard count."""
    p = KGTokenPipeline(_stream(), seq_len=32, global_batch=8)
    a = np.concatenate([p.shard_batch(9, i, 2)["tokens"] for i in range(2)])
    b = np.concatenate([p.shard_batch(9, i, 4)["tokens"] for i in range(4)])
    np.testing.assert_array_equal(a, b)


def test_weighted_rebalance_preserves_total():
    p = KGTokenPipeline(_stream(), seq_len=32, global_batch=12)
    p.rebalance([1.0, 1.0, 4.0])
    sizes = [p.shard_batch(0, i, 3)["tokens"].shape[0] for i in range(3)]
    assert sum(sizes) == 12
    assert sizes[2] > sizes[0]
    full = p.batch(0)["tokens"]
    parts = np.concatenate([p.shard_batch(0, i, 3)["tokens"]
                            for i in range(3)])
    np.testing.assert_array_equal(parts, full)


@given(st.integers(2, 64), st.integers(1, 16), st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_any_grid_fillable(seq_len, batch, step):
    """Property: every (seq_len, batch, step) grid is fillable, in range."""
    p = KGTokenPipeline(_stream(300), seq_len=seq_len, global_batch=batch)
    b = p.batch(step)
    assert b["tokens"].shape == (batch, seq_len)
    assert b["tokens"].min() >= 0
    assert (b["loss_mask"] >= 0).all()


def test_linearize_kg_structure():
    dis = make_group_a_dis(200, 0.8, seed=3)
    kg, _ = mapsdi_create_kg(dis)
    stream = linearize_kg(kg, vocab_size=256, seed=0)
    assert stream.dtype == np.int32
    assert stream.min() >= 0
    # stream is triple-framed: starts with BOT, contains EOT terminators
    assert stream[0] == BOT
    assert (stream == EOT).sum() == int(kg.count)
    assert (stream == BOT).sum() == int(kg.count)


def test_linearize_distinct_triples_distinct_rows():
    dis = make_group_a_dis(300, 0.9, seed=4)
    kg, _ = mapsdi_create_kg(dis)
    stream = linearize_kg(kg, vocab_size=1024, seed=0)
    # split back on EOT framing: every triple encodes uniquely
    rows = np.split(stream, np.where(stream == EOT)[0] + 1)
    rows = [tuple(r) for r in rows if len(r)]
    assert len(set(rows)) == int(kg.count)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "internvl2-2b",
                                  "whisper-large-v3"])
def test_random_lm_batch_families(arch):
    from repro.configs.base import get_config, reduced_config
    cfg = reduced_config(get_config(arch))
    b = random_lm_batch(np.random.default_rng(0), cfg, 2, 32)
    assert b["tokens"].shape[0] == 2
    if cfg.family == "vlm":
        assert b["patches"].shape == (2, cfg.n_prepend, 1024)
        assert b["tokens"].shape[1] == 32 - cfg.n_prepend
    if cfg.family == "encdec":
        assert b["frames"].shape == (2, cfg.n_enc_frames, cfg.d_model)
