"""End-to-end system tests: MapSDI KG -> token pipeline -> LM training,
with checkpoint/restart determinism and fault-injected recovery."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced_config
from repro.core.pipeline import mapsdi_create_kg
from repro.core.tframework import t_framework_create_kg
from repro.data.pipeline import KGTokenPipeline, linearize_kg
from repro.data.synthetic import make_group_a_dis
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.fault import (FailureInjector, RestartPolicy,
                                     run_with_restarts)
from repro.distributed.sharding import init_params
from repro.models import get_model
from repro.train.optimizer import make_optimizer
from repro.train.train_step import make_train_step


@pytest.fixture(scope="module")
def small_world():
    """Shared tiny model + MapSDI-derived pipeline."""
    cfg = reduced_config(get_config("qwen3-1.7b"))
    cfg = dataclasses.replace(cfg, n_layers=2)
    dis = make_group_a_dis(400, 0.8, seed=0)
    kg, stats = mapsdi_create_kg(dis)
    stream = linearize_kg(kg, cfg.vocab_size, seed=0)
    pipe = KGTokenPipeline(stream, seq_len=32, global_batch=4)
    model = get_model(cfg.family)
    return cfg, model, pipe, stats


def _train(cfg, model, pipe, *, steps, manager=None, injector=None,
           resume=True, seed=0):
    opt = make_optimizer(cfg.optimizer, lr=1e-2)
    step_fn = jax.jit(make_train_step(cfg, optimizer=opt))
    params = init_params(model.param_specs(cfg), jax.random.PRNGKey(seed))
    opt_state = opt.init(params)
    start = 0
    if manager is not None and resume and manager.latest_step() is not None:
        (params, opt_state), extra = manager.restore((params, opt_state))
        start = int(extra["step"]) + 1
    losses = []
    for s in range(start, steps):
        if injector is not None:
            injector.maybe_fail(s)
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(s).items()}
        params, opt_state, m = step_fn(params, opt_state, batch,
                                       jnp.asarray(s, jnp.int32))
        losses.append(float(m["loss"]))
        if manager is not None:
            manager.save(s, (params, opt_state), extra={"step": s})
    if manager is not None:
        manager.wait()
    return params, losses


def test_loss_decreases_on_kg_data(small_world):
    cfg, model, pipe, _ = small_world
    _, losses = _train(cfg, model, pipe, steps=15)
    assert losses[-1] < losses[0] * 0.9, losses


def test_mapsdi_and_tframework_feed_identical_training(small_world):
    """Q1 at the system level: the MapSDI-preprocessed DIS yields the SAME
    kg -> the same token stream -> identical training data."""
    cfg, _, _, _ = small_world
    dis = make_group_a_dis(300, 0.75, seed=1)
    kg_m, _ = mapsdi_create_kg(dis)
    kg_t, _ = t_framework_create_kg(make_group_a_dis(300, 0.75, seed=1))
    assert kg_m.row_set() == kg_t.row_set()
    s_m = linearize_kg(kg_m, cfg.vocab_size, seed=0)
    s_t = linearize_kg(kg_t, cfg.vocab_size, seed=0)
    assert sorted(s_m.tolist()) == sorted(s_t.tolist())


def test_checkpoint_restart_bitwise_resume(tmp_path, small_world):
    """Interrupted-and-resumed training == uninterrupted training."""
    cfg, model, pipe, _ = small_world
    m1 = CheckpointManager(str(tmp_path / "a"), keep_n=2, async_write=False)
    p_full, _ = _train(cfg, model, pipe, steps=8, manager=m1)

    m2 = CheckpointManager(str(tmp_path / "b"), keep_n=2, async_write=False)
    _train(cfg, model, pipe, steps=4, manager=m2)          # phase 1
    p_res, _ = _train(cfg, model, pipe, steps=8, manager=m2)  # resume

    fa = jax.tree_util.tree_leaves(p_full)
    fb = jax.tree_util.tree_leaves(p_res)
    for a, b in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_fault_injected_run_completes(tmp_path, small_world):
    cfg, model, pipe, _ = small_world
    manager = CheckpointManager(str(tmp_path / "c"), keep_n=2,
                                async_write=False)
    injector = FailureInjector(schedule=(3, 6))

    def loop(resume):
        return _train(cfg, model, pipe, steps=10, manager=manager,
                      injector=injector)

    (params, losses), report = run_with_restarts(
        loop, RestartPolicy(max_restarts=4))
    assert report.restarts == 2
    assert manager.latest_step() == 9


def test_mapsdi_stats_reduce_rows(small_world):
    _, _, _, stats = small_world
    before = sum(stats["source_rows_before"].values())
    after = sum(stats["source_rows_after"].values())
    assert after < before
    assert stats["kg_triples"] <= stats["raw_triples"]
    assert stats["rule1"] >= 1 or stats["rule3"] >= 1
