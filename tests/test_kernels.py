"""Per-kernel correctness sweeps: Pallas (interpret=True on CPU) vs the
pure-jnp oracle, across shapes and dtypes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import \
    flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba2.mamba2 import mamba2_ssd_pallas
from repro.kernels.mamba2.ref import ssd_chunked, ssd_scan_ref
from repro.kernels.rowhash.ops import rowhash
from repro.kernels.rowhash.ref import rowhash_ref
from repro.kernels.rowhash.rowhash import rowhash_pallas
from repro.kernels.rwkv6.ref import rwkv6_chunked, rwkv6_scan_ref
from repro.kernels.rwkv6.rwkv6 import rwkv6_pallas


def _rng(seed=0):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,kh,s,d", [
    (1, 4, 4, 256, 64),      # MHA
    (2, 4, 2, 128, 64),      # GQA 2:1
    (1, 8, 1, 256, 32),      # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(b, h, kh, s, d, dtype):
    r = _rng(1)
    q = jnp.asarray(r.normal(0, 1, (b, h, s, d)), dtype)
    k = jnp.asarray(r.normal(0, 1, (b, kh, s, d)), dtype)
    v = jnp.asarray(r.normal(0, 1, (b, kh, s, d)), dtype)
    out = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_window(window):
    r = _rng(2)
    q = jnp.asarray(r.normal(0, 1, (1, 2, 256, 64)), jnp.float32)
    k = jnp.asarray(r.normal(0, 1, (1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(r.normal(0, 1, (1, 2, 256, 64)), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 interpret=True)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_kv_len_mask():
    r = _rng(3)
    q = jnp.asarray(r.normal(0, 1, (1, 2, 1, 64)), jnp.float32)  # decode
    k = jnp.asarray(r.normal(0, 1, (1, 2, 384, 64)), jnp.float32)
    v = jnp.asarray(r.normal(0, 1, (1, 2, 384, 64)), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=False, kv_len=200,
                                 interpret=True)
    ref = attention_ref(q, k, v, causal=False, kv_len=200)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_unpadded_seq():
    """Non-block-multiple seq exercises the padding path."""
    r = _rng(4)
    q = jnp.asarray(r.normal(0, 1, (1, 2, 200, 64)), jnp.float32)
    k = jnp.asarray(r.normal(0, 1, (1, 2, 200, 64)), jnp.float32)
    v = jnp.asarray(r.normal(0, 1, (1, 2, 200, 64)), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# rwkv6
# ---------------------------------------------------------------------------

def _rwkv_inputs(b, h, t, n, dtype=jnp.float32, seed=5):
    r = _rng(seed)
    rr = jnp.asarray(r.normal(0, 1, (b, h, t, n)), dtype)
    k = jnp.asarray(r.normal(0, 0.3, (b, h, t, n)), dtype)
    v = jnp.asarray(r.normal(0, 1, (b, h, t, n)), dtype)
    w = jnp.asarray(r.uniform(0.6, 0.999, (b, h, t, n)), jnp.float32)
    u = jnp.asarray(r.normal(0, 0.3, (h, n)), jnp.float32)
    return rr, k, v, w, u


@pytest.mark.parametrize("b,h,t,n", [(1, 1, 64, 16), (2, 3, 128, 32),
                                     (1, 2, 96, 64)])
def test_rwkv6_chunked_vs_scan(b, h, t, n):
    rr, k, v, w, u = _rwkv_inputs(b, h, t, n)
    y_ref, s_ref = rwkv6_scan_ref(rr, k, v, w, u)
    y, s = rwkv6_chunked(rr, k, v, w, u, chunk=32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("b,h,t,n,chunk", [(1, 2, 64, 16, 16),
                                           (2, 1, 128, 32, 32),
                                           (1, 1, 64, 64, 32)])
def test_rwkv6_pallas_vs_scan(b, h, t, n, chunk):
    rr, k, v, w, u = _rwkv_inputs(b, h, t, n, seed=6)
    y_ref, s_ref = rwkv6_scan_ref(rr, k, v, w, u)
    y, s = rwkv6_pallas(rr, k, v, w, u, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               atol=1e-3, rtol=1e-3)


def test_rwkv6_bf16_inputs():
    rr, k, v, w, u = _rwkv_inputs(1, 2, 64, 32, dtype=jnp.bfloat16, seed=7)
    y_ref, _ = rwkv6_scan_ref(rr, k, v, w, u)
    y, _ = rwkv6_pallas(rr, k, v, w, u, chunk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=5e-2, rtol=5e-2)


def test_rwkv6_carried_state():
    """Chunked path with a carried state == scan continued from it."""
    rr, k, v, w, u = _rwkv_inputs(1, 2, 128, 16, seed=8)
    y_all, s_all = rwkv6_scan_ref(rr, k, v, w, u)
    half = 64
    _, s_half = rwkv6_scan_ref(rr[:, :, :half], k[:, :, :half],
                               v[:, :, :half], w[:, :, :half], u)
    y2, s2 = rwkv6_chunked(rr[:, :, half:], k[:, :, half:], v[:, :, half:],
                           w[:, :, half:], u, state=s_half, chunk=32)
    np.testing.assert_allclose(np.asarray(y2),
                               np.asarray(y_all[:, :, half:]),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_all),
                               atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# mamba2 SSD
# ---------------------------------------------------------------------------

def _ssd_inputs(b, h, t, p, n, seed=9):
    r = _rng(seed)
    x = jnp.asarray(r.normal(0, 1, (b, h, t, p)), jnp.float32)
    dt = jnp.asarray(r.uniform(0.001, 0.1, (b, h, t)), jnp.float32)
    a = jnp.asarray(-r.uniform(0.5, 2.0, (h,)), jnp.float32)
    bb = jnp.asarray(r.normal(0, 1, (b, t, n)), jnp.float32)
    c = jnp.asarray(r.normal(0, 1, (b, t, n)), jnp.float32)
    return x, dt, a, bb, c


@pytest.mark.parametrize("b,h,t,p,n", [(1, 1, 64, 16, 16), (2, 2, 128, 32, 16),
                                       (1, 3, 192, 64, 64)])
def test_ssd_chunked_vs_scan(b, h, t, p, n):
    x, dt, a, bb, c = _ssd_inputs(b, h, t, p, n)
    y_ref, s_ref = ssd_scan_ref(x, dt, a, bb, c)
    y, s = ssd_chunked(x, dt, a, bb, c, chunk=64)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("b,h,t,p,n,chunk", [(1, 2, 128, 16, 16, 32),
                                             (2, 1, 128, 32, 64, 64)])
def test_ssd_pallas_vs_scan(b, h, t, p, n, chunk):
    x, dt, a, bb, c = _ssd_inputs(b, h, t, p, n, seed=10)
    y_ref, s_ref = ssd_scan_ref(x, dt, a, bb, c)
    la = dt * a[None, :, None]
    xdt = x * dt[..., None]
    y, s = mamba2_ssd_pallas(xdt, la, bb, c, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               atol=1e-3, rtol=1e-3)


def test_ssd_carried_state():
    x, dt, a, bb, c = _ssd_inputs(1, 2, 128, 16, 16, seed=11)
    y_all, s_all = ssd_scan_ref(x, dt, a, bb, c)
    _, s_half = ssd_scan_ref(x[:, :, :64], dt[:, :, :64], a,
                             bb[:, :64], c[:, :64])
    y2, s2 = ssd_chunked(x[:, :, 64:], dt[:, :, 64:], a, bb[:, 64:],
                         c[:, 64:], state=s_half, chunk=32)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_all[:, :, 64:]),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_all),
                               atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# rowhash
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,k", [(16, 1), (256, 3), (1000, 5), (4096, 8)])
def test_rowhash_matches_ref(n, k):
    r = _rng(12)
    x = jnp.asarray(r.integers(-2**31, 2**31 - 1, (n, k)), jnp.int32)
    got = rowhash_pallas(x, block_n=256, interpret=True)
    ref = rowhash_ref(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_rowhash_equal_rows_equal_hash():
    x = jnp.asarray([[1, 2, 3], [1, 2, 3], [3, 2, 1]], jnp.int32)
    h = rowhash(x)
    assert h[0] == h[1]
    assert h[0] != h[2]          # (vanishingly unlikely to collide)


def test_rowhash_distribution():
    """Mixed hashes should spread across buckets (chi-square sanity)."""
    r = _rng(13)
    x = jnp.asarray(r.integers(0, 4, (8192, 2)), jnp.int32)  # few distinct
    h = np.asarray(rowhash(x)).astype(np.uint64)
    buckets = h % 16
    # distinct rows only: 16 possible rows -> their buckets should not all
    # collide into one or two values
    distinct = np.unique(np.asarray(x), axis=0)
    hd = np.asarray(rowhash(jnp.asarray(distinct))).astype(np.uint64) % 8
    assert len(np.unique(hd)) >= 4
    assert len(np.unique(buckets)) >= 4


# ---------------------------------------------------------------------------
# fused hash + neighbor-flag kernel (hash-first dedup)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,k,block_n", [
    (64, 2, 16), (300, 4, 64), (1024, 5, 256), (257, 3, 128),
])
def test_hash_neighbor_flags_matches_ref(n, k, block_n):
    from repro.kernels.rowhash.ref import hash_neighbor_flags_ref
    from repro.kernels.rowhash.rowhash import hash_neighbor_flags_pallas
    r = _rng(21)
    rows = r.integers(0, 6, (n, k)).astype(np.int32)  # many duplicate runs
    h = np.asarray(rowhash_ref(jnp.asarray(rows)))
    rows = jnp.asarray(rows[np.argsort(h, kind="stable")])  # hash-sorted
    got = hash_neighbor_flags_pallas(rows, block_n=block_n, interpret=True)
    ref = hash_neighbor_flags_ref(rows)
    for g, want in zip(got, ref):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(want))


def test_hash_neighbor_flags_semantics():
    """keep = first occurrence of each duplicate run; collide = equal hash,
    different row (checked on a crafted sequence with both cases)."""
    from repro.kernels.rowhash.ref import hash_neighbor_flags_ref
    rows = jnp.asarray([[1, 2], [1, 2], [1, 2], [5, 6]], jnp.int32)
    h, keep, coll = hash_neighbor_flags_ref(rows)
    np.testing.assert_array_equal(np.asarray(keep), [1, 0, 0, 1])
    np.testing.assert_array_equal(np.asarray(coll), [0, 0, 0, 0])
    # the collide case: adjacent distinct rows with a REAL 32-bit hash
    # collision (pair brute-forced against the production hash)
    rows = jnp.asarray([[573955, 771106], [1046201, 851388]], jnp.int32)
    h, keep, coll = hash_neighbor_flags_ref(rows)
    assert h[0] == h[1]
    np.testing.assert_array_equal(np.asarray(keep), [1, 1])  # rows differ
    np.testing.assert_array_equal(np.asarray(coll), [0, 1])  # flagged
