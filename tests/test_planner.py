"""Planner tests: plan correctness (planned == eager == raw, bit-identical),
sync-free symbolic fixpoint, selection pushdown, CSE, capacity annotation.

The hypothesis-based property sweep lives in ``test_planner_properties.py``
(skipped without the test extra); this file keeps a seeded random-DIS sweep
so the same invariants are exercised in every environment.
"""
import numpy as np
import pytest

from repro.core import (apply_mapsdi, apply_mapsdi_eager, parse_dis, rdfize)
from repro.core.pipeline import make_planned_fn, mapsdi_create_kg
from repro.core.transform import _dis_signature, plan_mapsdi
from repro.plan import Scan, Select, annotate, dump_plan, explain, iter_nodes, lower, optimize
from repro.relalg import forbid_transfers


# ---------------------------------------------------------------------------
# seeded random DIS generator (joins, nulls, selections, duplicates)
# ---------------------------------------------------------------------------

def random_dis_spec(seed: int, with_nulls: bool = True,
                    with_selections: bool = True) -> dict:
    rng = np.random.default_rng(seed)
    values = ["a", "b", "c", "d", "e"]
    n_sources = int(rng.integers(1, 4))
    sources, src_attrs = {}, {}
    for si in range(n_sources):
        attrs = [f"x{si}_{k}" for k in range(int(rng.integers(1, 5)))]
        n_rows = int(rng.integers(0, 13))
        records = []
        for _ in range(n_rows):
            rec = {}
            for a in attrs:
                if with_nulls and rng.random() < 0.2:
                    rec[a] = None
                else:
                    rec[a] = values[int(rng.integers(0, len(values)))]
            records.append(rec)
        sources[f"s{si}"] = {"attrs": attrs, "records": records}
        src_attrs[f"s{si}"] = attrs

    maps = []
    for mi in range(int(rng.integers(1, 4))):
        src = sorted(sources)[int(rng.integers(0, len(sources)))]
        attrs = src_attrs[src]
        subj_attr = attrs[int(rng.integers(0, len(attrs)))]
        tmpl = ["http://ex/T/{%s}" % subj_attr,
                "http://ex/Shared/{%s}" % subj_attr][int(rng.integers(0, 2))]
        subj = {"template": tmpl}
        if rng.random() < 0.5:
            subj["class"] = ["ex:C1", "ex:C2"][int(rng.integers(0, 2))]
        poms = []
        for _ in range(int(rng.integers(0, 4))):
            kind = ["reference", "constant", "template"][
                int(rng.integers(0, 3))]
            pred = ["ex:p1", "ex:p2", "ex:p3"][int(rng.integers(0, 3))]
            if kind == "reference":
                obj = {"reference": attrs[int(rng.integers(0, len(attrs)))]}
            elif kind == "constant":
                obj = {"constant": ["ex:k1", "ex:k2"][int(rng.integers(0, 2))]}
            else:
                obj = {"template": "http://ex/O/{%s}" %
                       attrs[int(rng.integers(0, len(attrs)))]}
            poms.append({"predicate": pred, "object": obj})
        m = {"name": f"m{mi}", "source": src, "subject": subj, "poms": poms}
        if with_selections and rng.random() < 0.3:
            attr = attrs[int(rng.integers(0, len(attrs)))]
            if rng.random() < 0.5:
                m["selections"] = [{"attr": attr, "eq": values[
                    int(rng.integers(0, len(values)))]}]
            else:
                m["selections"] = [{"attr": attr, "notnull": True}]
        maps.append(m)

    if len(maps) >= 2 and rng.random() < 0.5:
        child, parent = maps[-1], maps[0]
        if parent["name"] != child["name"]:
            ca = src_attrs[child["source"]]
            pa = src_attrs[parent["source"]]
            child["poms"] = child["poms"] + [{
                "predicate": "ex:join",
                "object": {"parentTriplesMap": parent["name"],
                           "joinCondition": {
                               "child": ca[int(rng.integers(0, len(ca)))],
                               "parent": pa[int(rng.integers(0, len(pa)))]}}}]
    return {"sources": sources, "maps": maps}


# ---------------------------------------------------------------------------
# planned == eager == raw, bit-identically, across engines and δ strategies
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(12))
def test_planned_pipeline_bit_identical_to_rdfize(seed):
    """execute(optimize(lower(dis))) == rdfize(dis), bit for bit."""
    spec = random_dis_spec(seed)
    for engine in ("rmlmapper", "sdm"):
        for dedup in ("lex", "hash"):
            dis = parse_dis(spec)
            kg0, raw0 = rdfize(dis, engine=engine, dedup=dedup)
            fn, _plan = make_planned_fn(parse_dis(spec), engine=engine,
                                        dedup=dedup)
            kg1, raw1 = fn(parse_dis(spec).sources)
            np.testing.assert_array_equal(kg1.to_codes(), kg0.to_codes())
            assert int(raw1) <= raw0


@pytest.mark.parametrize("seed", range(12, 20))
def test_planned_apply_mapsdi_matches_eager(seed):
    """The planner-backed apply_mapsdi and the historical materializing
    fixpoint yield the same KG (and the planner never yields more rows)."""
    spec = random_dis_spec(seed)
    kg0, _ = rdfize(parse_dis(spec))
    dis_e, stats_e = apply_mapsdi_eager(parse_dis(spec))
    dis_p, stats_p = apply_mapsdi(parse_dis(spec))
    kg_e, _ = rdfize(dis_e)
    kg_p, _ = rdfize(dis_p)
    np.testing.assert_array_equal(kg_e.to_codes(), kg0.to_codes())
    np.testing.assert_array_equal(kg_p.to_codes(), kg0.to_codes())
    assert sum(stats_p.source_rows_after.values()) <= \
        sum(stats_e.source_rows_after.values())
    assert stats_p.rule3_merges == stats_e.rule3_merges


def test_planned_apply_mapsdi_idempotent():
    spec = random_dis_spec(3)
    dis2, _ = apply_mapsdi(parse_dis(spec))
    dis3, _ = apply_mapsdi(dis2)
    assert _dis_signature(dis2) == _dis_signature(dis3)


# ---------------------------------------------------------------------------
# the fixpoint is symbolic: zero device↔host syncs until materialization
# ---------------------------------------------------------------------------

def test_fixpoint_performs_no_host_sync():
    """Rules 1–3 + σ + CSE to fixpoint under a transfer guard: any
    device→host materialization (implicit or instrumented) raises."""
    from repro.data import make_group_b_dis
    dis = make_group_b_dis(n_rows=200, redundancy=0.6, seed=7)
    with forbid_transfers() as ledger:
        plan = plan_mapsdi(dis)
    assert ledger.device_to_host == 0
    assert len(plan.maps) == 2


def test_eager_fixpoint_does_sync():
    """Sanity check the instrumentation: the eager driver ticks it."""
    from repro.data import make_group_b_dis
    from repro.relalg import count_transfers
    dis = make_group_b_dis(n_rows=100, redundancy=0.6, seed=8)
    with count_transfers() as ledger:
        apply_mapsdi_eager(dis)
    assert ledger.device_to_host > 0


# ---------------------------------------------------------------------------
# selection pushdown (σ) fires and is lossless
# ---------------------------------------------------------------------------

def _sigma_spec():
    return {
        "sources": {"s": {"attrs": ["a", "b", "c"], "records": [
            {"a": "x1", "b": "u", "c": "HUMAN"},
            {"a": None, "b": "v", "c": "HUMAN"},
            {"a": "x2", "b": None, "c": "MOUSE"},
            {"a": "x2", "b": "w", "c": "MOUSE"},
            {"a": "x1", "b": "u", "c": "HUMAN"},
        ]}},
        "maps": [{"name": "m", "source": "s",
                  "subject": {"template": "http://ex/T/{a}", "class": "ex:C"},
                  "poms": [{"predicate": "ex:p", "object": {"reference": "b"}}],
                  "selections": [{"attr": "c", "eq": "HUMAN"}]}],
    }


def test_selection_pushdown_fires_below_projection():
    plan = lower(parse_dis(_sigma_spec()))
    stats = optimize(plan)
    assert stats.sigma_pushdowns >= 1
    (node,) = plan.inputs.values()
    # canonical shape: δ(π(σ(scan))) — σ sits on the scan, below π and δ
    selects = [n for n in iter_nodes(node) if isinstance(n, Select)]
    assert len(selects) == 1
    assert isinstance(selects[0].child, Scan)
    ops = {p.op for p in selects[0].preds}
    assert ops == {"notnull", "eq"}  # null-filter AND constant-equality


def test_selection_pushdown_shrinks_source_same_kg():
    kg0, _ = rdfize(parse_dis(_sigma_spec()))
    dis_e, _ = apply_mapsdi_eager(parse_dis(_sigma_spec()))
    dis_p, _ = apply_mapsdi(parse_dis(_sigma_spec()))
    (rows_e,) = [int(t.count) for t in dis_e.sources.values()]
    (rows_p,) = [int(t.count) for t in dis_p.sources.values()]
    assert rows_p < rows_e          # σ removed never-emitting rows
    kg_p, _ = rdfize(dis_p)
    np.testing.assert_array_equal(kg_p.to_codes(), kg0.to_codes())


def test_selection_pushdown_skips_join_parent_object_filters():
    """A join parent's object null-filter must NOT be pushed (its rows feed
    child joins); its subject null-filter must be."""
    spec = {
        "sources": {
            "g": {"attrs": ["k", "v"], "records": [
                {"k": "k1", "v": None}, {"k": "k2", "v": "o"}]},
            "h": {"attrs": ["k", "w"], "records": [
                {"k": "k1", "w": "b1"}, {"k": "k2", "w": "b2"}]},
        },
        "maps": [
            {"name": "parent", "source": "g",
             "subject": {"template": "http://ex/P/{k}"},
             "poms": [{"predicate": "ex:v", "object": {"reference": "v"}}]},
            {"name": "child", "source": "h",
             "subject": {"template": "http://ex/C/{w}"},
             "poms": [{"predicate": "ex:j",
                       "object": {"parentTriplesMap": "parent",
                                  "joinCondition": {"child": "k",
                                                    "parent": "k"}}}]},
        ],
    }
    kg0, _ = rdfize(parse_dis(spec))
    assert int(kg0.count) == 3  # 1 parent literal + 2 join triples
    dis_p, _ = apply_mapsdi(parse_dis(spec))
    kg_p, _ = rdfize(dis_p)
    np.testing.assert_array_equal(kg_p.to_codes(), kg0.to_codes())
    # the parent's pre-processed relation kept the null-v row
    parent_src = dis_p.sources[dis_p.map_by_name("parent").source]
    assert int(parent_src.count) == 2


def _sigma_parent_join_spec():
    """Join whose parent map carries an explicit σ selection."""
    return {
        "sources": {
            "g": {"attrs": ["k", "v", "sp"], "records": [
                {"k": "k1", "v": "o1", "sp": "HUMAN"},
                {"k": "k2", "v": "o2", "sp": "MOUSE"},
                {"k": "k3", "v": "o3", "sp": "HUMAN"}]},
            "h": {"attrs": ["k", "w"], "records": [
                {"k": "k1", "w": "b1"}, {"k": "k2", "w": "b2"},
                {"k": "k3", "w": "b3"}]},
        },
        "maps": [
            {"name": "parent", "source": "g",
             "subject": {"template": "http://ex/P/{k}"},
             "poms": [{"predicate": "ex:v", "object": {"reference": "v"}}],
             "selections": [{"attr": "sp", "eq": "HUMAN"}]},
            {"name": "child", "source": "h",
             "subject": {"template": "http://ex/C/{w}"},
             "poms": [{"predicate": "ex:j",
                       "object": {"parentTriplesMap": "parent",
                                  "joinCondition": {"child": "k",
                                                    "parent": "k"}}}]},
        ],
    }


def test_sigma_baked_provenance_skips_parent_reselect():
    """Planner-materialized DIS' bakes σ into the extension and flags it, so
    re-planning skips the (idempotent) parent re-select; the eager DIS'
    never bakes σ and must keep it. Same KG on both paths (ROADMAP item)."""
    from repro.plan import Select
    kg0, _ = rdfize(parse_dis(_sigma_parent_join_spec()))
    assert int(kg0.count) == 2 + 2   # 2 HUMAN parent literals + 2 joins

    dis_p, _ = apply_mapsdi(parse_dis(_sigma_parent_join_spec()))
    parent_src = dis_p.map_by_name("parent").source
    assert parent_src in dis_p.sigma_baked
    plan_p = lower(dis_p)
    join = plan_p.join_node(plan_p.map_by_name("child"), 0)
    assert not any(isinstance(n, Select) for n in iter_nodes(join.right))
    kg_p, _ = rdfize(dis_p)
    np.testing.assert_array_equal(kg_p.to_codes(), kg0.to_codes())

    dis_e, _ = apply_mapsdi_eager(parse_dis(_sigma_parent_join_spec()))
    assert not dis_e.sigma_baked    # eager materialization: σ NOT baked
    plan_e = lower(dis_e)
    join_e = plan_e.join_node(plan_e.map_by_name("child"), 0)
    assert any(isinstance(n, Select) for n in iter_nodes(join_e.right))
    kg_e, _ = rdfize(dis_e)
    np.testing.assert_array_equal(kg_e.to_codes(), kg0.to_codes())


# ---------------------------------------------------------------------------
# common-subplan elimination
# ---------------------------------------------------------------------------

def test_constant_subject_maps_join_both_sides():
    """Constant-subject maps work as join child AND join parent (the old
    _join_block crashed on ``column(None)``)."""
    spec = {
        "sources": {
            "g": {"attrs": ["k"], "records": [{"k": "k1"}, {"k": "k2"}]},
            "h": {"attrs": ["k"], "records": [{"k": "k1"}, {"k": "k1"}]},
        },
        "maps": [
            {"name": "parent", "source": "g",
             "subject": {"constant": "ex:P"}, "poms": []},
            {"name": "child", "source": "h",
             "subject": {"constant": "ex:C"},
             "poms": [{"predicate": "ex:j",
                       "object": {"parentTriplesMap": "parent",
                                  "joinCondition": {"child": "k",
                                                    "parent": "k"}}}]},
        ],
    }
    kg0, raw0 = rdfize(parse_dis(spec))
    assert raw0 == 2           # two k1 child rows match one parent row
    assert int(kg0.count) == 1  # (ex:C, ex:j, ex:P), deduplicated
    fn, _ = make_planned_fn(parse_dis(spec), engine="rmlmapper")
    kg1, _ = fn(parse_dis(spec).sources)
    np.testing.assert_array_equal(kg1.to_codes(), kg0.to_codes())


def test_cse_shares_identical_projections_across_maps():
    spec = {
        "sources": {"s": {"attrs": ["a", "b"], "records": [
            {"a": "x", "b": "y"}, {"a": "x", "b": "z"}]}},
        "maps": [
            {"name": "m0", "source": "s",
             "subject": {"template": "http://ex/A/{a}"},
             "poms": [{"predicate": "ex:p", "object": {"reference": "b"}}]},
            {"name": "m1", "source": "s",
             "subject": {"template": "http://ex/B/{a}"},  # different head
             "poms": [{"predicate": "ex:q", "object": {"reference": "b"}}]},
        ],
    }
    plan = lower(parse_dis(spec))
    stats = optimize(plan)
    assert plan.inputs["m0"] is plan.inputs["m1"]   # hash-consed, one node
    assert stats.cse_shared_subplans > 0


def test_cse_shares_join_parent_relation():
    """The parent relation is one node feeding both the parent's own emit
    and the child's ⋈ — shared subplans beyond (source, attrs) pairs."""
    from repro.data import fig5_join_dis
    plan = lower(fig5_join_dis())
    optimize(plan)
    child = plan.map_by_name("TripleMap1")
    join = plan.join_node(child, 0)
    # the ⋈ right side projects exactly the parent map's relation node
    assert join.right.child is plan.inputs["TripleMap2"]


# ---------------------------------------------------------------------------
# capacity annotation + explain
# ---------------------------------------------------------------------------

def test_capacity_annotation_is_exact():
    from repro.data import make_group_b_dis
    dis = make_group_b_dis(n_rows=64, redundancy=0.5, seed=9)
    plan = lower(dis)
    optimize(plan)
    counts, caps = annotate(plan)
    dis2, _ = apply_mapsdi(make_group_b_dis(n_rows=64, redundancy=0.5,
                                            seed=9))
    for tm in plan.maps:
        node = plan.inputs[tm.name]
        materialized = dis2.sources[dis2.map_by_name(tm.name).source]
        assert counts[node] == int(materialized.count)
        assert caps[node] == materialized.capacity


def test_explain_renders_tree_with_capacities():
    from repro.data import make_group_a_dis
    plan = lower(make_group_a_dis(n_rows=16, redundancy=0.5, seed=2))
    optimize(plan)
    text = explain(plan, "sdm")
    assert "δ" in text and "π" in text and "scan" in text
    assert "∪" in text          # Rule-3 merged union
    assert "cap=" in text and "rows=" in text
    assert "emit[TM_merged_0]" in text
    # unannotated dump still renders
    assert "scan" in dump_plan(plan)


def test_tracing_is_side_effect_free():
    """Satellite of the planner refactor: RDFizer.__init__ pre-interns
    every constant; evaluating a map the engine was NOT built for raises
    instead of silently interning mid-trace."""
    import dataclasses
    from repro.core import RDFizer, TermMap, PredicateObjectMap
    spec = random_dis_spec(0, with_nulls=False, with_selections=False)
    dis = parse_dis(spec)
    rdfizer = RDFizer(dis)
    vocab_len = len(dis.vocab)
    kg, _ = rdfizer()
    assert len(dis.vocab) == vocab_len   # tracing interned nothing
    foreign = dataclasses.replace(
        dis.maps[0],
        poms=(PredicateObjectMap(
            predicate=dis.maps[0].poms[0].predicate if dis.maps[0].poms
            else "ex:p1",
            object=TermMap(kind="constant", constant="ex:never-interned")),))
    with pytest.raises(RuntimeError, match="not pre-interned"):
        rdfizer.eval_map(foreign, dis.sources)


def test_pipeline_stats_report_planner_counters():
    from repro.data import make_group_a_dis
    kg, stats = mapsdi_create_kg(make_group_a_dis(48, 0.5, seed=4))
    assert stats["rule3"] == 1
    assert stats["cse_shared"] >= 0
    assert stats["kg_triples"] == int(kg.count)
    assert sum(stats["source_rows_after"].values()) < \
        sum(stats["source_rows_before"].values())
