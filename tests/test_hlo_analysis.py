"""Unit tests for the HLO collective parser + roofline helpers."""
import pytest

from repro.launch.hlo_analysis import collective_bytes, _type_bytes


FAKE = """
HloModule jit_step

%fused (a: f32[128,256]) -> f32[128,256] {
  ...
}

ENTRY %main (p0: f32[128,256], p1: bf16[64]) -> f32[128,256] {
  %p0 = f32[128,256]{1,0} parameter(0)
  %p1 = bf16[64]{0} parameter(1)
  %ar = f32[128,256]{1,0} all-reduce(%p0), replica_groups={}, to_apply=%add
  %ag = bf16[128]{0} all-gather(%p1), dimensions={0}
  %a2a = f32[128,256]{1,0} all-to-all(%ar), dimensions={0}
  %cp = bf16[64]{0} collective-permute(%p1), source_target_pairs={{0,1}}
  ROOT %out = f32[128,256]{1,0} add(%ar, %a2a)
}
"""


def test_type_bytes():
    assert _type_bytes("f32[128,256]") == 128 * 256 * 4
    assert _type_bytes("bf16[64]") == 128
    assert _type_bytes("(f32[2,2], bf16[4])") == 16 + 8
    assert _type_bytes("pred[]") == 1


def test_collective_bytes_by_op():
    st = collective_bytes(FAKE)
    f32mat = 128 * 256 * 4
    assert st.by_op["all-reduce"] == f32mat
    assert st.by_op["all-gather"] == 64 * 2
    assert st.by_op["all-to-all"] == f32mat
    assert st.by_op["collective-permute"] == 64 * 2
    assert st.by_op_count["all-reduce"] == 1
    assert st.total_bytes == 2 * f32mat + 2 * 128


def test_async_pairs_not_double_counted():
    text = """
ENTRY %e (p: f32[16]) -> f32[16] {
  %p = f32[16]{0} parameter(0)
  %s = f32[16]{0} all-reduce-start(%p), to_apply=%add
  ROOT %d = f32[16]{0} all-reduce-done(%s)
}
"""
    st = collective_bytes(text)
    assert st.by_op_count["all-reduce"] == 1
    assert st.by_op["all-reduce"] == 64


def test_real_compiled_psum_collectives():
    """Compile a psum over 4 forced-host devices in a subprocess and check
    the parser finds exactly one all-reduce of the right size."""
    import os
    import subprocess
    import sys
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.launch.hlo_analysis import collective_bytes

mesh = make_mesh((4,), ("data",))
sh = NamedSharding(mesh, P("data"))
x = jax.ShapeDtypeStruct((1024, 64), jnp.float32, sharding=sh)

def f(x):
    return jax.lax.with_sharding_constraint(
        jnp.broadcast_to(x.sum(axis=0, keepdims=True), x.shape),
        NamedSharding(mesh, P("data")))

txt = jax.jit(f).lower(x).compile().as_text()
st = collective_bytes(txt)
assert st.by_op_count["all-reduce"] >= 1, st.by_op_count
# partial sum operand: [1, 64] f32 per device
assert st.by_op["all-reduce"] >= 64 * 4, st.by_op
print("OK", st.by_op)
"""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout


def test_depth_extrapolation_affine():
    from repro.launch.roofline import extrapolate
    f0 = {"flops": 10.0, "bytes": 100.0}
    f1 = {"flops": 16.0, "bytes": 130.0}
    f = extrapolate(f0, f1, 4, 8, 28)
    assert f["flops"] == pytest.approx(10 + 1.5 * 24)
    assert f["bytes"] == pytest.approx(100 + 7.5 * 24)


def test_model_flops_conventions():
    from repro.configs.base import SHAPES, get_config
    from repro.launch.roofline import model_flops
    from repro.launch.specs import model_param_counts
    cfg = get_config("qwen3_1p7b")
    params = model_param_counts(cfg)
    train = model_flops(cfg, SHAPES["train_4k"], 256, params)
    decode = model_flops(cfg, SHAPES["decode_32k"], 256, params)
    # train: 6*N*D / devices
    want = 6 * params["body_active"] * 4096 * 256 / 256
    assert train == pytest.approx(want)
    assert decode < train / 1000
