"""Persistent plan-store tests: cross-process differential + adversarial.

Two suites gate the store (``repro.api.store``):

* **Cross-process round trips** — a WRITER subprocess compiles every
  engine × dedup combination (plus the fused mesh plan) into a store; a
  fresh READER subprocess rehydrates each from disk and must report
  ``store_hits`` with ``to_codes()`` and raw counts **bit-identical** to
  the writer's cold compiles and to the eager RDFizer oracle — on 1 and
  8 virtual devices (the multi-device legs follow the
  ``test_distributed.py`` subprocess idiom).
* **Adversarial degradation** — truncated files, bit flips, envelope /
  key tampering, concurrent writers, an unwritable store root: every one
  must degrade to a fresh compile with a bumped reject/error counter in
  ``stats()``; never a crash, never a wrong KG.
"""
import json
import os
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro.api import KGEngine, PlanStore, clear_plan_cache, resolve_store, store_envelope
from repro.api.store import FORMAT_VERSION, NATIVE, STABLEHLO, read_container, write_container
from repro.core import parse_dis
from repro.core.rdfizer import RDFizer

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: the writer/reader configuration matrix (mesh == fused plan over every
#: visible device; single-device legs run it on a 1-device mesh)
CONFIGS = [("sdm", "hash", False), ("sdm", None, False),
           ("rmlmapper", "hash", False), ("rmlmapper", None, False),
           ("sdm", "hash", True)]

# one process plays WRITER (cold compiles, writes back) or READER (fresh
# process, must rehydrate every entry from disk without compiling)
_CHILD = r"""
import json, sys
from repro.api import KGEngine
from repro.core.rdfizer import RDFizer
from repro.data.synthetic import make_group_b_dis
from repro.launch.mesh import make_mesh

root, role = sys.argv[1], sys.argv[2]
configs = json.loads(sys.argv[3])
out = {}
for engine, dedup, mesh in configs:
    kwargs = dict(engine=engine, dedup=dedup, plan_store=root)
    if mesh:
        import jax
        kwargs["mesh"] = make_mesh((jax.device_count(),), ("data",))
    session = KGEngine(make_group_b_dis(48, 0.6, seed=3), **kwargs)
    kg, stats = session.create_kg()
    acc = session._dis.copy()
    acc.sources = dict(session.sources)
    kg_ref, _ = RDFizer(acc, engine, dedup=dedup)()
    assert (kg.to_codes().tolist() == kg_ref.to_codes().tolist()), \
        f"{role} {engine}/{dedup}/mesh={mesh}: KG differs from eager oracle"
    out[f"{engine}/{dedup}/{mesh}"] = {
        "codes": kg.to_codes().tolist(),
        "raw": stats["raw_triples"],
        "store_hits": stats["store_hits"],
        "store_misses": stats["store_misses"],
        "store_rejects": stats["store_rejects"]}
print(json.dumps(out))
"""


def _run_child(args, n_devices=1, extra_env=None, code=_CHILD):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    if extra_env:
        env.update(extra_env)
    out = subprocess.run([sys.executable, "-c", code] + list(args), env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, \
        f"stderr:\n{out.stderr}\nstdout:\n{out.stdout}"
    return out.stdout


# ---------------------------------------------------------------------------
# cross-process round trips: compile there, rehydrate here, bit-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_devices", [1, 8])
def test_cross_process_round_trip_bit_identical(tmp_path, n_devices):
    """Every engine × dedup combination (plus the fused mesh plan)
    compiled by one process is served from disk to a FRESH process —
    ``store_hits`` for each, zero compiles re-traced, and the rehydrated
    executables produce byte-for-byte the writer's KG codes and raw
    counts (both already oracle-checked in-child)."""
    root = str(tmp_path / "store")
    cfg = json.dumps(CONFIGS)
    writer = json.loads(_run_child([root, "writer", cfg], n_devices))
    reader = json.loads(_run_child([root, "reader", cfg], n_devices))
    assert set(writer) == set(reader) == {
        f"{e}/{d}/{m}" for e, d, m in CONFIGS}
    for name, w in writer.items():
        r = reader[name]
        assert w["store_hits"] == 0, (name, w)       # cold: nothing to hit
        assert w["store_misses"] >= 1, (name, w)
        assert r["store_hits"] == 1, (name, r)       # warm: served from disk
        assert r["store_rejects"] == 0, (name, r)
        assert r["codes"] == w["codes"], f"{name}: KG codes differ"
        assert r["raw"] == w["raw"], f"{name}: raw counts differ"


def test_cross_process_store_keys_stable_under_hash_randomization(tmp_path):
    """The store key must be a pure function of DIS structure + runtime —
    two processes with different ``PYTHONHASHSEED`` (str hashes, set/dict
    iteration) derive the identical key, or workers could never share a
    store."""
    code = r"""
import json, sys
from repro.api import KGEngine
from repro.api.store import store_key
from repro.data.synthetic import make_group_b_dis
session = KGEngine(make_group_b_dis(32, 0.6, seed=5), dedup="hash")
env = {"format": 1, "jax": "x", "jaxlib": "y", "backend": "cpu",
       "device_kind": "cpu", "device_count": 1}
print(store_key(session._key(session.sources), env))
"""
    keys = {_run_child([], extra_env={"PYTHONHASHSEED": seed},
                       code=code).strip()
            for seed in ("0", "4242")}
    assert len(keys) == 1, f"hash-seed-dependent store keys: {keys}"


# ---------------------------------------------------------------------------
# adversarial: corruption / mismatch / contention must degrade, not break
# ---------------------------------------------------------------------------

def _tiny_dis():
    """One source, one map, no join — the cheapest real compile."""
    return parse_dis({
        "sources": {"s": {"attrs": ["a", "b"], "records": [
            {"a": f"e{i}", "b": f"x{i}"} for i in range(6)]}},
        "maps": [{"name": "m", "source": "s",
                  "subject": {"template": "http://ex/S/{a}",
                              "class": "ex:C"},
                  "poms": [{"predicate": "ex:p",
                            "object": {"reference": "b"}}]}]})


def _populate_tiny(root):
    """Compile the tiny DIS into ``root``; returns (entry path, KG codes)."""
    clear_plan_cache()
    store = PlanStore(str(root))
    session = KGEngine(_tiny_dis(), plan_store=store)
    kg, _stats = session.create_kg()
    files = store._entry_files()
    assert len(files) == 1 and store.writes == 1
    return files[0], kg.to_codes()


def _load_fresh(root):
    """A fresh session over an LRU-cleared cache: forced store lookup."""
    clear_plan_cache()
    store = PlanStore(str(root))
    session = KGEngine(_tiny_dis(), plan_store=store)
    kg, stats = session.create_kg()
    return kg, stats, store


def test_clean_store_round_trip_in_process(tmp_path):
    path, codes = _populate_tiny(tmp_path)
    kg, stats, store = _load_fresh(tmp_path)
    assert stats["store_hits"] == 1 and stats["store_rejects"] == 0
    assert store.hits == 1
    np.testing.assert_array_equal(kg.to_codes(), codes)


@pytest.mark.parametrize("damage", ["truncate_header", "truncate_payload",
                                    "bitflip_payload", "bitflip_magic",
                                    "empty"])
def test_corrupt_entry_degrades_to_fresh_compile(tmp_path, damage):
    """Torn/flipped/emptied entry files are rejected by checksum — the
    session compiles fresh, counts the reject, and the KG is exact."""
    path, codes = _populate_tiny(tmp_path)
    blob = open(path, "rb").read()
    if damage == "truncate_header":
        blob = blob[:20]
    elif damage == "truncate_payload":
        blob = blob[:int(len(blob) * 0.7)]
    elif damage == "bitflip_payload":
        i = len(blob) - 8
        blob = blob[:i] + bytes([blob[i] ^ 0xFF]) + blob[i + 1:]
    elif damage == "bitflip_magic":
        blob = b"X" + blob[1:]
    elif damage == "empty":
        blob = b""
    with open(path, "wb") as f:
        f.write(blob)
    kg, stats, store = _load_fresh(tmp_path)
    assert stats["store_hits"] == 0
    assert stats["store_rejects"] == 1 and store.rejects == 1
    np.testing.assert_array_equal(kg.to_codes(), codes)
    # the fresh compile wrote a VALID entry back over the corpse
    header, payloads = read_container(path)
    assert header["version"] == FORMAT_VERSION and NATIVE in payloads


@pytest.mark.parametrize("field,value", [
    ("jax", "0.0.0-other"), ("jaxlib", "0.0.0-other"),
    ("backend", "not-a-backend"), ("device_kind", "alien"),
    ("device_count", 4096), ("format", FORMAT_VERSION + 1)])
def test_envelope_mismatch_rejected(tmp_path, field, value):
    """An entry whose compatibility envelope differs in ANY field — wrong
    jax/jaxlib, another backend or device kind/count, a future format —
    must reject (a serialized executable is only valid under the runtime
    that produced it), then recompile correctly."""
    path, codes = _populate_tiny(tmp_path)
    header, payloads = read_container(path)
    header["envelope"][field] = value
    write_container(path, header, payloads)
    kg, stats, store = _load_fresh(tmp_path)
    assert stats["store_hits"] == 0 and stats["store_rejects"] == 1
    assert any("envelope mismatch" in r for r in store.reject_reasons)
    np.testing.assert_array_equal(kg.to_codes(), codes)


def test_header_key_mismatch_rejected(tmp_path):
    """A container whose self-declared key disagrees with its filename
    (e.g. a mis-copied store) rejects rather than serving a foreign
    plan."""
    path, codes = _populate_tiny(tmp_path)
    header, payloads = read_container(path)
    header["key"] = "0" * 64
    write_container(path, header, payloads)
    kg, stats, store = _load_fresh(tmp_path)
    assert stats["store_rejects"] == 1
    assert any("key mismatch" in r for r in store.reject_reasons)
    np.testing.assert_array_equal(kg.to_codes(), codes)


def test_unloadable_payloads_reject_then_recompile(tmp_path):
    """Entries whose payload bytes pass checksums but are not loadable
    executables (checksum recomputed over garbage) reject at rehydration
    and the session recompiles."""
    path, codes = _populate_tiny(tmp_path)
    header, _payloads = read_container(path)
    garbage = {NATIVE: b"not a pickle", STABLEHLO: b"not stablehlo"}
    write_container(path, header, garbage)   # recomputes payload checksums
    kg, stats, store = _load_fresh(tmp_path)
    assert stats["store_hits"] == 0 and stats["store_rejects"] == 1
    assert any("rehydrate" in r for r in store.reject_reasons)
    np.testing.assert_array_equal(kg.to_codes(), codes)


def test_unwritable_store_root_counts_write_errors(tmp_path):
    """A store root that cannot be created (here: parented by a regular
    file — robust even when tests run as root, where chmod is advisory)
    must not take the session down: the compile succeeds, the KG is
    exact, and ``stats()['plan_store']`` reports the write failure."""
    blocker = tmp_path / "blocker"
    blocker.write_text("not a directory")
    clear_plan_cache()
    store = PlanStore(str(blocker / "store"))
    session = KGEngine(_tiny_dis(), plan_store=store)
    kg, stats = session.create_kg()
    ps = session.stats()["plan_store"]
    assert ps["writes"] == 0 and ps["write_errors"] >= 1
    assert ps["entries"] == 0
    kg_ref, _ = RDFizer(_tiny_dis())()
    np.testing.assert_array_equal(kg.to_codes(), kg_ref.to_codes())


def test_concurrent_writer_lock_skips_then_succeeds(tmp_path):
    """A held per-entry flock makes a second writer SKIP (counted), not
    block or corrupt; once released, the write lands and loads back."""
    import fcntl
    store = PlanStore(str(tmp_path))
    env = store_envelope()
    key = "ab" * 32
    os.makedirs(store.root, exist_ok=True)
    lock_fd = os.open(store.entry_path(key) + ".lock",
                      os.O_CREAT | os.O_RDWR, 0o644)
    fcntl.flock(lock_fd, fcntl.LOCK_EX)
    try:
        assert store.save(key, env, {"m": 1}, {NATIVE: b"x"}) is False
        assert store.write_skipped == 1 and store.writes == 0
    finally:
        os.close(lock_fd)
    assert store.save(key, env, {"m": 1}, {NATIVE: b"x"}) is True
    res = store.load(key, env)
    assert res.status == "hit" and res.payloads[NATIVE] == b"x"


def test_concurrent_writer_race_never_tears(tmp_path):
    """N threads hammering the same entry: every attempt either lands
    atomically or skips; the surviving file always parses + checksums."""
    store = PlanStore(str(tmp_path))
    env = store_envelope()
    key = "cd" * 32
    n = 8
    payloads = [f"payload-{i}".encode() * 100 for i in range(n)]

    def writer(i):
        PlanStore(str(tmp_path)).save(key, env, {"i": i},
                                      {NATIVE: payloads[i]})

    threads = [threading.Thread(target=writer, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    header, got = read_container(store.entry_path(key))
    assert got[NATIVE] in payloads           # exactly one writer's bytes
    assert header["key"] == key
    res = store.load(key, env)
    assert res.status == "hit"
    # no temp droppings left behind
    assert [f for f in os.listdir(store.root) if ".tmp." in f] == []


def test_max_entries_prunes_oldest(tmp_path):
    store = PlanStore(str(tmp_path), max_entries=2)
    env = store_envelope()
    for i in range(4):
        key = f"{i:02d}" * 32
        assert store.save(key, env, {"i": i}, {NATIVE: b"z"})
        os.utime(store.entry_path(key), (i, i))   # deterministic mtimes
    assert len(store) == 2
    kept = sorted(os.listdir(store.root))
    assert f"{3:02d}" * 32 + ".plan" in kept


def test_resolve_store_argument_forms(tmp_path):
    assert resolve_store(None) is None
    assert resolve_store(False) is None
    s = PlanStore(str(tmp_path))
    assert resolve_store(s) is s
    assert resolve_store(str(tmp_path)).root == str(tmp_path)
    assert resolve_store(tmp_path).root == str(tmp_path)
    with pytest.raises(TypeError):
        resolve_store(123)


def test_store_disabled_by_default(tmp_path):
    """No ``plan_store=`` → no disk IO, stats report the tier as absent."""
    clear_plan_cache()
    session = KGEngine(_tiny_dis())
    session.create_kg()
    st = session.stats()
    assert st["plan_store"] is None
    assert st["store_hits"] == 0 and st["store_misses"] == 0


def test_overflow_recompile_writes_back_bigger_entry(tmp_path):
    """The overflow ladder's recompile (bigger monotone caps) replaces
    the store entry under the SAME session key — a fresh process then
    rehydrates the big-capacity executable directly and serves the grown
    extension with zero recompiles."""
    from repro.data.synthetic import make_group_b_dis
    from repro.relalg import Table

    def mk():
        return make_group_b_dis(24, 0.6, seed=11)

    clear_plan_cache()
    store = PlanStore(str(tmp_path))
    session = KGEngine(mk(), plan_store=store)
    session.create_kg()
    ext = make_group_b_dis(24 * 16, 0.6, seed=42)
    recs = ext.sources["gene"].to_records(ext.vocab)
    kg, stats = session.ingest({"gene": Table.from_records(
        recs, mk().sources["gene"].attrs, session.vocab)})
    assert stats["recompiles"] == 1     # crossed the bucket: ladder fired
    assert store.writes >= 2            # ... and wrote the bigger entry back
    # fresh "process" (cleared LRU): the grown sources' key hits the store
    clear_plan_cache()
    store2 = PlanStore(str(tmp_path))
    session2 = KGEngine(mk(), plan_store=store2)
    session2.sources.update(session.sources)
    kg2, stats2 = session2.create_kg()
    assert stats2["store_hits"] == 1 and stats2["recompiles"] == 0
    np.testing.assert_array_equal(kg2.to_codes(), kg.to_codes())


# ---------------------------------------------------------------------------
# CI leg: tests against the store the workflow populated in a prior step
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not os.environ.get("REPRO_PLAN_STORE_PREPOPULATED"),
                    reason="CI plan-store leg only (populate step 1 sets "
                           "REPRO_PLAN_STORE_PREPOPULATED)")
@pytest.mark.parametrize("engine,dedup,mesh", [
    ("sdm", "hash", False), ("sdm", "lex", False),
    ("rmlmapper", "hash", False), ("rmlmapper", "lex", False),
    ("sdm", "hash", True)])
def test_ci_prepopulated_store_serves_every_config(engine, dedup, mesh):
    """Step 2 of the CI plan-store leg: `python -m repro.api.store
    populate` ran in a separate process (step 1); every configuration it
    compiled must now load as a store hit and match the eager oracle."""
    from repro.data.synthetic import make_group_b_dis
    from repro.launch.mesh import make_mesh
    import jax
    clear_plan_cache()
    kwargs = dict(engine=engine, dedup=dedup, plan_store="default")
    if mesh:
        kwargs["mesh"] = make_mesh((jax.device_count(),), ("data",))
    session = KGEngine(make_group_b_dis(48, 0.6, seed=0), **kwargs)
    kg, stats = session.create_kg()
    assert stats["store_hits"] == 1, session.stats()["plan_store"]
    assert stats["store_rejects"] == 0
    acc = session._dis.copy()
    acc.sources = dict(session.sources)
    kg_ref, _ = RDFizer(acc, engine, dedup=dedup)()
    np.testing.assert_array_equal(kg.to_codes(), kg_ref.to_codes())


def test_prune_tolerates_concurrent_deletion(tmp_path, monkeypatch):
    """Entries vanishing between the listing and the mtime read (a
    concurrent pruner or writer replacing them — the serving norm) must
    not raise out of ``_prune``: vanished files are skipped and counted
    under ``write_errors``, and losing the unlink race is free."""
    store = PlanStore(str(tmp_path), max_entries=1)
    env = store_envelope()
    for i in range(4):
        key = f"{i:02d}" * 32
        assert store.save(key, env, {"i": i}, {NATIVE: b"z"})
        os.utime(store.entry_path(key), (i, i))
    assert len(store) == 1                      # pruned down on each save

    # repopulate without pruning interference, then race the snapshot:
    # the first getmtime call sees its file deleted under it
    store.max_entries = 100
    for i in range(4, 7):
        key = f"{i:02d}" * 32
        assert store.save(key, env, {"i": i}, {NATIVE: b"z"})
        os.utime(store.entry_path(key), (i, i))
    real_getmtime = os.path.getmtime
    vanished = []

    def racing_getmtime(path):
        if not vanished:
            vanished.append(path)
            os.unlink(path)                     # the concurrent pruner
        return real_getmtime(path)              # raises for the victim

    monkeypatch.setattr(os.path, "getmtime", racing_getmtime)
    store.max_entries = 1
    errors_before = store.write_errors
    store._prune()                              # must not raise
    monkeypatch.undo()
    assert store.write_errors == errors_before + 1
    assert len(store) == 1                      # still pruned to the cap

    # losing the unlink race itself is silent (missing-ok semantics)
    key = "aa" * 32
    assert store.save(key, env, {"i": 99}, {NATIVE: b"z"})
    real_unlink = os.unlink

    def racing_unlink(path, *a, **kw):
        real_unlink(path, *a, **kw)
        raise FileNotFoundError(path)           # loser's view of the race

    monkeypatch.setattr(os, "unlink", racing_unlink)
    errors_before = store.write_errors
    store._prune()                              # must not raise
    monkeypatch.undo()
    assert store.write_errors == errors_before  # not an error


def test_stats_tolerates_vanishing_entries(tmp_path, monkeypatch):
    store = PlanStore(str(tmp_path))
    env = store_envelope()
    assert store.save("bb" * 32, env, {}, {NATIVE: b"z"})
    real_getsize = os.path.getsize

    def racing_getsize(path):
        if path.endswith(".plan"):
            raise FileNotFoundError(path)
        return real_getsize(path)

    monkeypatch.setattr(os.path, "getsize", racing_getsize)
    st = store.stats()                          # must not raise
    assert st["entries"] == 1 and st["bytes"] == 0
