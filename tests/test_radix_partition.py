"""Radix-partition kernel package + cost-model calibration tests.

Adversarial coverage for :mod:`repro.kernels.radix_partition` — the local
bucketization stage under every join exchange and global-δ repartition:

* bit-identity of ref oracle, Pallas kernel (interpret mode) and the
  historical sort path across shapes, counts and ``key_cols`` subsets,
* overflow is a *flag*, never silent corruption (all-rows-to-one-bucket),
* empty shards, whole-row vs subset keys, order-preserving top-bit mode,
* a hypothesis property: valid bucket rows are a permutation of the valid
  input rows whenever nothing overflowed,
* the radix-accelerated δ (``distinct_rows_hashed``) is bit-identical to
  the single-sort path it replaces,
* an 8-virtual-device subprocess leg proving the exchange paths built on
  the kernel stay exact,

plus the measured-bandwidth calibration surface: signatures, degenerate
fits, ``join_exchange_cost(calibration=...)`` and store-envelope drift.
"""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distributed import _partition_local, _partition_local_sorted
from repro.kernels import (pallas_interpret_forced, resolve_use_pallas)
from repro.kernels.radix_partition import (bucket_shift, kernel_feasible,
                                           radix_partition,
                                           radix_partition_pallas,
                                           radix_partition_ref)
from repro.kernels.radix_partition import ref as radix_ref_mod
from repro.relalg.encoding import PAD_ID

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _rows(n, k, seed=0, lo=0, hi=1 << 20):
    rng = np.random.default_rng(seed)
    return rng.integers(lo, hi, size=(n, k)).astype(np.int32)


def _as_tuples(buckets, counts):
    out = []
    for b in range(buckets.shape[0]):
        out.append([tuple(int(v) for v in row)
                    for row in np.asarray(buckets[b][: int(counts[b])])])
    return out


# ---------------------------------------------------------------------------
# differential: ref == Pallas(interpret) == historical sort path
# ---------------------------------------------------------------------------

CASES = [
    # (n, k, n_buckets, cap_bucket, count, key_cols)
    (64, 3, 4, 64, 64, None),
    (200, 5, 8, 128, 137, None),
    (256, 2, 2, 256, 0, None),          # empty shard
    (300, 4, 16, 64, 300, (1, 3)),      # join-key subset
    (128, 1, 4, 64, 100, (0,)),
    (512, 6, 8, 32, 512, None),         # tight caps → likely overflow
]


@pytest.mark.parametrize("n,k,nb,cb,count,key_cols", CASES)
def test_ref_matches_sort_path(n, k, nb, cb, count, key_cols):
    data = jnp.asarray(_rows(n, k, seed=n + k))
    cnt = jnp.int32(count)
    rb, rc, ro = radix_partition_ref(data, cnt, n_buckets=nb, cap_bucket=cb,
                                     key_cols=key_cols)
    sb, sc, so = _partition_local_sorted(data, cnt, nb, cb, None,
                                         key_cols=key_cols)
    assert bool(ro) == bool(so)
    np.testing.assert_array_equal(np.asarray(rc), np.asarray(sc))
    np.testing.assert_array_equal(np.asarray(rb), np.asarray(sb))


@pytest.mark.parametrize("n,k,nb,cb,count,key_cols", CASES)
def test_pallas_interpret_matches_ref(n, k, nb, cb, count, key_cols):
    data = jnp.asarray(_rows(n, k, seed=n + k))
    cnt = jnp.int32(count)
    rb, rc, ro = radix_partition_ref(data, cnt, n_buckets=nb, cap_bucket=cb,
                                     key_cols=key_cols)
    pb, pc, po = radix_partition_pallas(
        data, cnt, n_buckets=nb, cap_bucket=cb, key_cols=key_cols,
        block_n=128, interpret=True)
    assert bool(po) == bool(ro)
    np.testing.assert_array_equal(np.asarray(pc), np.asarray(rc))
    np.testing.assert_array_equal(np.asarray(pb), np.asarray(rb))


def test_dispatcher_matches_partition_local():
    # the production wiring: _partition_local IS the dispatcher
    data = jnp.asarray(_rows(333, 4, seed=9))
    cnt = jnp.int32(301)
    for key_cols in (None, (0, 2)):
        db, dc, do = _partition_local(data, cnt, 8, 128, None,
                                      key_cols=key_cols)
        sb, sc, so = _partition_local_sorted(data, cnt, 8, 128, None,
                                             key_cols=key_cols)
        assert bool(do) == bool(so)
        np.testing.assert_array_equal(np.asarray(dc), np.asarray(sc))
        np.testing.assert_array_equal(np.asarray(db), np.asarray(sb))


# ---------------------------------------------------------------------------
# adversarial shapes
# ---------------------------------------------------------------------------

def test_all_rows_one_bucket_overflows_without_corruption():
    # every row identical → every row hashes to ONE bucket; cap too small
    row = np.array([[7, 11, 13]], dtype=np.int32)
    data = jnp.asarray(np.repeat(row, 96, axis=0))
    buckets, counts, overflow = radix_partition(
        data, jnp.int32(96), n_buckets=4, cap_bucket=32)
    assert bool(overflow), "overflow must be FLAGGED, not silently dropped"
    counts = np.asarray(counts)
    assert counts.sum() == 32 and counts.max() == 32   # clamped, not garbage
    hot = int(counts.argmax())
    # surviving rows are pristine copies; other buckets stay all-PAD
    np.testing.assert_array_equal(np.asarray(buckets[hot][:32]),
                                  np.repeat(row, 32, axis=0))
    for b in range(4):
        if b != hot:
            assert (np.asarray(buckets[b]) == PAD_ID).all()


def test_empty_shard():
    data = jnp.asarray(_rows(64, 3, seed=1))
    buckets, counts, overflow = radix_partition(
        data, jnp.int32(0), n_buckets=4, cap_bucket=16)
    assert not bool(overflow)
    assert (np.asarray(counts) == 0).all()
    assert (np.asarray(buckets) == PAD_ID).all()


def test_key_cols_subset_groups_equal_keys():
    # equal join keys must land in one bucket regardless of payload cols
    keys = np.repeat(np.arange(16, dtype=np.int32), 8)[:, None]
    payload = _rows(128, 2, seed=3)
    data = jnp.asarray(np.concatenate([keys, payload], axis=1))
    buckets, counts, overflow = radix_partition(
        data, jnp.int32(128), n_buckets=8, cap_bucket=64, key_cols=(0,))
    assert not bool(overflow)
    for b, rows in enumerate(_as_tuples(buckets, counts)):
        for r in rows:
            other = [o for o in rows if o[0] == r[0]]
            assert len(other) == 8       # all 8 payload variants co-located


def test_order_preserving_top_bits():
    nb = 8
    shift = bucket_shift(nb)
    from repro.kernels.rowhash import rowhash
    data = jnp.asarray(_rows(256, 3, seed=4))
    buckets, counts, overflow = radix_partition(
        data, jnp.int32(256), n_buckets=nb, cap_bucket=128,
        order_preserving=True)
    assert not bool(overflow)
    for b in range(nb):
        cnt = int(counts[b])
        if cnt == 0:
            continue
        h = np.asarray(rowhash(buckets[b][:cnt])).astype(np.uint32)
        assert ((h >> shift) == b).all()


def test_bucket_shift_validation():
    assert bucket_shift(2) == 31 and bucket_shift(64) == 26
    for bad in (0, 3, 12):
        with pytest.raises(ValueError):
            bucket_shift(bad)
    with pytest.raises(ValueError):
        radix_partition_pallas(jnp.zeros((8, 2), jnp.int32), jnp.int32(8),
                               n_buckets=3, cap_bucket=8)


def test_kernel_feasibility_gate():
    assert kernel_feasible(1024, 5, 8, 256)
    assert not kernel_feasible(0, 5, 8, 256)          # empty
    assert not kernel_feasible(1024, 5, 3, 256)       # non-power-of-two
    assert not kernel_feasible(1024, 5, 128, 256)     # too many buckets
    assert not kernel_feasible(1 << 22, 8, 64, 1 << 20)   # VMEM blowout


def test_pad_id_parity():
    # the kernel package hard-codes the sentinel; pin it to the encoder's
    assert radix_ref_mod.PAD_ID == PAD_ID


def test_interpret_env_flag(monkeypatch):
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    assert not pallas_interpret_forced()
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert pallas_interpret_forced()
    assert resolve_use_pallas(None)          # forced on, even off-TPU
    assert not resolve_use_pallas(False)     # explicit override still wins
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert not pallas_interpret_forced()


# ---------------------------------------------------------------------------
# property: partition is a permutation of the valid rows
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - bare environment
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    @given(
        n=st.integers(1, 200),
        k=st.integers(1, 6),
        nb=st.sampled_from([2, 4, 8, 16]),
        frac=st.floats(0.0, 1.0),
        lo_card=st.booleans(),        # low-cardinality values → collisions
        seed=st.integers(0, 2**16),
    )
    @settings(deadline=None)
    def test_partition_is_permutation_of_valid_rows(n, k, nb, frac,
                                                    lo_card, seed):
        count = int(round(n * frac))
        hi = 4 if lo_card else (1 << 20)
        data = jnp.asarray(_rows(n, k, seed=seed, hi=hi))
        cap = n + 8                   # generous: overflow impossible
        buckets, counts, overflow = radix_partition(
            data, jnp.int32(count), n_buckets=nb, cap_bucket=cap)
        assert not bool(overflow)
        got = sorted(r for rows in _as_tuples(buckets, counts) for r in rows)
        want = sorted(tuple(int(v) for v in row)
                      for row in np.asarray(data)[:count])
        assert got == want


# ---------------------------------------------------------------------------
# δ on the radix path
# ---------------------------------------------------------------------------

def test_radix_dedup_bit_identical_to_sorted():
    from repro.relalg.ops import distinct_rows, distinct_rows_hashed
    for seed, hi in ((0, 50), (1, 1 << 20), (2, 3)):
        data = jnp.asarray(_rows(4096, 4, seed=seed, hi=hi))
        cnt = jnp.int32(4000)
        rd, rn = distinct_rows_hashed(data, cnt, radix=True)
        sd, sn = distinct_rows_hashed(data, cnt, radix=False)
        assert int(rn) == int(sn)
        np.testing.assert_array_equal(np.asarray(rd), np.asarray(sd))
        ld, ln = distinct_rows(data, cnt)
        got = {tuple(map(int, r)) for r in np.asarray(rd)[: int(rn)]}
        want = {tuple(map(int, r)) for r in np.asarray(ld)[: int(ln)]}
        assert got == want


def test_radix_dedup_auto_threshold():
    from repro.relalg.ops import (RADIX_DEDUP_MIN_ROWS, distinct_rows_hashed)
    small = jnp.asarray(_rows(RADIX_DEDUP_MIN_ROWS - 1, 3, seed=5, hi=9))
    big = jnp.asarray(_rows(RADIX_DEDUP_MIN_ROWS, 3, seed=5, hi=9))
    for data in (small, big):
        n = data.shape[0]
        d, cnt = distinct_rows_hashed(data, jnp.int32(n))
        got = {tuple(map(int, r)) for r in np.asarray(d)[: int(cnt)]}
        want = {tuple(map(int, r)) for r in np.asarray(data)}
        assert got == want


def test_radix_dedup_all_pad_content_rows():
    # valid rows whose CONTENT equals the padding sentinel must survive
    from repro.relalg.ops import distinct_rows_hashed
    data = np.full((4096, 3), PAD_ID, dtype=np.int32)
    data[: 2048] = _rows(2048, 3, seed=6, hi=7)
    d, cnt = distinct_rows_hashed(jnp.asarray(data), jnp.int32(4096))
    got = {tuple(map(int, r)) for r in np.asarray(d)[: int(cnt)]}
    want = {tuple(map(int, r)) for r in data}
    assert got == want                   # includes the all-PAD-content row


# ---------------------------------------------------------------------------
# multi-device leg (subprocess so this process keeps 1 device)
# ---------------------------------------------------------------------------

def _run_with_devices(n_devices: int, code: str,
                      extra_env: dict = None) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.update(extra_env or {})
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr}\nstdout:\n{out.stdout}"
    return out.stdout


_EIGHT_DEVICE_CODE = """
import numpy as np, jax
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.launch.mesh import make_mesh
from repro.relalg import Table, distinct
from repro.core.distributed import (distributed_distinct_table,
                                    repartition_by_key, shard_table,
                                    unshard_rows)
mesh = make_mesh((8,), ("data",))
rng = np.random.default_rng(11)
rows = rng.integers(0, 60, size=(4096, 5)).astype(np.int32)
t = Table.from_codes(rows, list("abcde"))
out, overflow = distributed_distinct_table(t, mesh, "data")
assert not overflow
assert out.row_set() == distinct(t).row_set()
# the join-exchange primitive: hash-repartition by a key column subset
data, counts, cap = shard_table(t, mesh, "data")
def body(d, c):
    out, cnt, ov = repartition_by_key(d, c.reshape(()), axis="data",
                                      n_shards=8, cap_bucket=cap,
                                      key_cols=(0,))
    return out, cnt.reshape(1), ov.reshape(1)
rdata, rcounts, rover = jax.jit(shard_map(
    body, mesh, in_specs=(P("data"), P("data")),
    out_specs=(P("data"), P("data"), P("data"))))(data, counts)
assert not bool(np.asarray(rover).any()), "exchange bucket overflow"
back = unshard_rows(rdata, rcounts, 8 * cap)
assert sorted(map(tuple, back)) == sorted(map(tuple, rows)), "rows lost"
shard_of_key = {}
for s in range(8):
    block = np.asarray(rdata)[s * 8 * cap:(s + 1) * 8 * cap]
    for r in block[: int(np.asarray(rcounts)[s])]:
        assert shard_of_key.setdefault(int(r[0]), s) == s, "key split"
print("OK")
"""


def test_eight_device_exchange_paths_exact():
    out = _run_with_devices(8, _EIGHT_DEVICE_CODE)
    assert "OK" in out


def test_eight_device_interpret_mode_leg():
    # the CI interpret leg: Pallas kernels in interpreter mode, 8 devices
    out = _run_with_devices(8, _EIGHT_DEVICE_CODE,
                            extra_env={"REPRO_PALLAS_INTERPRET": "1"})
    assert "OK" in out


# ---------------------------------------------------------------------------
# measured-bandwidth calibration
# ---------------------------------------------------------------------------

def test_static_calibration_signature():
    from repro.launch.mesh import Calibration, static_calibration
    assert static_calibration().signature() == ("static",)
    measured = Calibration(all_gather_bw=1e9, all_to_all_bw=2e9,
                           launch_s=1e-5, source="measured")
    sig = measured.signature()
    assert sig != ("static",) and sig[0] == "measured"


def test_degenerate_fit_falls_back_to_static():
    from repro.launch.mesh import (_fit_line, make_mesh,
                                   measure_collective_bandwidth)
    # single-device axis: nothing to measure
    mesh = make_mesh((1,), ("data",))
    assert measure_collective_bandwidth(mesh, "data").source == "static"
    # non-positive slope → NaN sentinel
    bw, _ = _fit_line([1e6, 2e6, 3e6], [3e-3, 2e-3, 1e-3])
    assert np.isnan(bw)


def test_join_exchange_cost_consumes_calibration():
    from repro.launch.mesh import Calibration
    from repro.plan.annotate import join_exchange_cost
    base = join_exchange_cost(1024, 4, 65536, 6, 8)
    assert base.cost_source == "static"
    # 100x slower links, same wire bytes → same strategy inputs, higher
    # seconds, "measured" provenance
    slow = Calibration(all_gather_bw=50e9 / 100, all_to_all_bw=50e9 / 100,
                       launch_s=0.0, source="measured")
    priced = join_exchange_cost(1024, 4, 65536, 6, 8, calibration=slow)
    assert priced.cost_source == "measured"
    assert priced.gather_bytes == base.gather_bytes
    assert priced.repartition_bytes == base.repartition_bytes
    assert priced.gather_seconds > base.gather_seconds * 10
    assert priced.repartition_seconds > base.repartition_seconds * 10


def test_store_envelope_calibration_drift():
    from repro.api.store import store_envelope
    from repro.launch.mesh import Calibration, static_calibration
    none_env = store_envelope()
    static_env = store_envelope(static_calibration())
    assert none_env == static_env            # static fallback ≡ no calibration
    m1 = Calibration(all_gather_bw=1e9, all_to_all_bw=1e9, launch_s=1e-5,
                     source="measured")
    m2 = Calibration(all_gather_bw=9e9, all_to_all_bw=9e9, launch_s=1e-5,
                     source="measured")
    e1, e2 = store_envelope(m1), store_envelope(m2)
    assert e1 != none_env                    # measured ≠ static
    assert e1 != e2                          # drifted measurement ≠ old one
    assert store_envelope(m1) == e1          # deterministic
