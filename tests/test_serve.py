"""Serve-tier tests: percentiles, batching, admission, the front door.

Covers the three serving claims end to end at test scale (the full-size
versions gate in ``benchmarks/serve.py --smoke``):

* latency quantiles are linear-interpolation percentiles — regression
  for the historical ``int(n * 0.99)`` index arithmetic whose "p99" was
  the sample max for every N ≤ 100 (``repro.launch.kg_serve``);
* T tenants over K structural shapes cost exactly K compiles;
* admission never drops silently — every submit yields a Ticket or a
  typed ``Overloaded``, and stop paths fail tickets loudly;
* a multiplexed tenant's KG is bit-identical to a dedicated session.
"""
import threading

import numpy as np
import pytest

from repro.api import EngineConfig, KGEngine, clear_plan_cache
from repro.data.synthetic import (make_group_b_dis,
                                  make_group_b_extension_records)
from repro.relalg import Table, host_int
from repro.serve import (AdmissionController, FrontDoor, IngestResult,
                         LatencyWindow, MicroBatcher, Overloaded,
                         SessionRegistry, Ticket, percentile)

CONFIG = EngineConfig(engine="sdm", dedup="hash")


def _dis(shape=0, rows=24):
    return make_group_b_dis(rows, 0.5, seed=40 + shape)


def _recs(n=2, seed=0):
    return make_group_b_extension_records(n, seed=seed)


# ---------------------------------------------------------------------------
# percentile: the shared quantile helper


def test_percentile_matches_numpy():
    rng = np.random.default_rng(0)
    for n in (1, 2, 3, 10, 100, 101, 997):
        vals = rng.exponential(size=n).tolist()
        for q in (0, 25, 50, 75, 90, 99, 99.9, 100):
            assert percentile(vals, q) == pytest.approx(
                float(np.percentile(vals, q)), rel=1e-12)


def test_percentile_interpolates_not_max():
    # the historical int(n * 0.99) index returned the MAX for any n <= 100
    vals = list(range(1, 11))     # 1..10
    assert percentile(vals, 99) < 10
    assert percentile(vals, 99) == pytest.approx(9.91)
    # even-N median interpolates instead of picking the upper sample
    assert percentile([1.0, 2.0], 50) == pytest.approx(1.5)


def test_percentile_rejects_bad_input():
    with pytest.raises(ValueError, match="empty"):
        percentile([], 50)
    with pytest.raises(ValueError, match=r"\[0, 100\]"):
        percentile([1.0], 101)
    with pytest.raises(ValueError, match=r"\[0, 100\]"):
        percentile([1.0], -1)


def test_latency_window_bounds_and_snapshot():
    w = LatencyWindow(maxlen=4)
    assert w.snapshot() == {"count": 0, "total": 0, "p50_s": 0.0,
                            "p99_s": 0.0, "max_s": 0.0}
    w.extend([1.0, 2.0, 3.0, 4.0, 5.0])
    snap = w.snapshot()
    assert snap["count"] == 4 and snap["total"] == 5   # ring dropped 1.0
    assert snap["max_s"] == 5.0
    assert snap["p50_s"] == pytest.approx(
        float(np.percentile([2, 3, 4, 5], 50)))


# ---------------------------------------------------------------------------
# micro-batcher


def _ticket(t=0.0, tenant="t"):
    tk = Ticket(tenant, enqueued_at=t)
    return tk


def test_batcher_coalesces_in_arrival_order():
    clock = [0.0]
    b = MicroBatcher(flush_window=1.0, clock=lambda: clock[0])
    b.add("a", {"gene": [{"x": 1}], "chrom": [{"y": 1}]}, _ticket(0.0))
    b.add("a", {"gene": [{"x": 2}]}, _ticket(0.0))
    assert b.depth() == 2 and b.depth("a") == 2 and b.depth("b") == 0
    assert b.due() == []                       # window not elapsed
    clock[0] = 1.5
    assert b.due() == ["a"]
    taken, merged = b.pop_batch("a")
    assert [r.rows for r in taken] == [2, 1]
    assert merged == {"gene": [{"x": 1}, {"x": 2}], "chrom": [{"y": 1}]}
    assert b.depth() == 0 and b.pop_batch("a") == ([], {})


def test_batcher_row_cap_splits_batches_but_never_starves():
    b = MicroBatcher(flush_window=0.0, max_batch_rows=3)
    big = {"gene": [{"x": i} for i in range(5)]}    # 5 rows > cap alone
    b.add("a", big, _ticket())
    b.add("a", {"gene": [{"x": 9}]}, _ticket())
    assert b.due(force=True) == ["a"]
    taken, _ = b.pop_batch("a")
    assert len(taken) == 1          # oversize request flushes alone
    taken, _ = b.pop_batch("a")
    assert len(taken) == 1
    # rows >= max_batch_rows makes a tenant due regardless of the window
    b2 = MicroBatcher(flush_window=999.0, max_batch_rows=2, clock=lambda: 0)
    b2.add("a", big, _ticket())
    assert b2.due() == ["a"]


def test_batcher_next_deadline_and_drain():
    clock = [10.0]
    b = MicroBatcher(flush_window=2.0, clock=lambda: clock[0])
    assert b.next_deadline() is None
    b.add("a", {"gene": [{}]}, _ticket(10.0))
    clock[0] = 10.5
    assert b.next_deadline() == pytest.approx(1.5)
    b.add("b", {"gene": [{}]}, _ticket(10.5))
    pending = b.drain_tickets()
    assert len(pending) == 2 and b.depth() == 0


def test_batcher_validation():
    with pytest.raises(ValueError, match="flush_window"):
        MicroBatcher(flush_window=-1)
    with pytest.raises(ValueError, match="max_batch_rows"):
        MicroBatcher(max_batch_rows=0)


# ---------------------------------------------------------------------------
# admission control


def test_admission_queue_full_and_storm():
    clock = [0.0]
    adm = AdmissionController(max_queue=4, storm_queue=1,
                              stall_window_s=10.0, clock=lambda: clock[0])
    assert adm.admit("t", 3) is None
    shed = adm.admit("t", 4)
    assert isinstance(shed, Overloaded) and shed.reason == "queue_full"
    assert shed.queue_depth == 4 and shed.retry_after_s > 0
    assert not shed                      # falsy by design
    assert not adm.in_storm()
    adm.note_recompile(2)
    assert adm.in_storm() and adm.recompile_stalls == 2
    assert adm.admit("t", 0) is None     # below the storm low-water
    storm = adm.admit("t", 1)
    assert storm is not None and storm.reason == "recompile_storm"
    assert storm.retry_after_s == pytest.approx(10.0)
    clock[0] = 11.0                      # storm window expired
    assert not adm.in_storm() and adm.admit("t", 1) is None
    assert adm.stats()["sheds"] == {"queue_full": 1, "recompile_storm": 1}


def test_admission_validation():
    with pytest.raises(ValueError, match="max_queue"):
        AdmissionController(max_queue=0)
    with pytest.raises(ValueError, match="storm_queue"):
        AdmissionController(max_queue=4, storm_queue=5)


def test_ticket_result_timeout_and_error():
    tk = Ticket("t", enqueued_at=0.0)
    assert not tk.done()
    with pytest.raises(TimeoutError, match="'t'"):
        tk.result(timeout=0.01)
    tk.fail(RuntimeError("boom"))
    with pytest.raises(RuntimeError, match="boom"):
        tk.result(timeout=1)


# ---------------------------------------------------------------------------
# registry + compile dedup


def test_registry_rejects_duplicates_and_unknown():
    reg = SessionRegistry(default_config=CONFIG)
    reg.register("a", _dis())
    with pytest.raises(ValueError, match="already registered"):
        reg.register("a", _dis())
    with pytest.raises(KeyError, match="unknown tenant"):
        reg.get("nope")
    assert "a" in reg and "nope" not in reg and len(reg) == 1


def test_front_door_k_compiles_for_t_tenants_and_bit_identity():
    clear_plan_cache()
    door = FrontDoor(CONFIG, flush_window=0.0, max_queue=64)
    tenants, shapes = 4, 2
    for t in range(tenants):
        door.register(f"t{t}", _dis(shape=t % shapes))
    assert door.registry.compile_dedup()["shapes"] == shapes

    history = [[] for _ in range(tenants)]
    for rnd in range(2):
        tickets = []
        for t in range(tenants):
            recs = _recs(2, seed=100 + rnd * tenants + t)
            history[t].append(recs)
            resp = door.submit(f"t{t}", recs)
            assert isinstance(resp, Ticket)
            tickets.append(resp)
        door.pump(force=True)
        for tk in tickets:
            res = tk.result(timeout=600)
            assert isinstance(res, IngestResult)
            assert res.kg_triples > 0 and res.latency_s >= res.ingest_s >= 0

    dedup = door.registry.compile_dedup()
    assert dedup == {"tenants": tenants, "shapes": shapes,
                     "compiles": shapes, "ratio": tenants / shapes}

    # every tenant bit-identical to a dedicated session fed the same
    # stream in the same flush granularity
    for t in range(tenants):
        engine = KGEngine(_dis(shape=t % shapes), config=CONFIG)
        kg, _ = engine.create_kg()
        for recs in history[t]:
            deltas = {n: Table.from_records(r, engine.sources[n].attrs,
                                            engine.vocab)
                      for n, r in recs.items() if r}
            kg, _ = engine.ingest(deltas)
        served = door.kg(f"t{t}")
        assert host_int(served.count) == host_int(kg.count)
        n = host_int(kg.count)
        np.testing.assert_array_equal(np.asarray(served.data)[:n],
                                      np.asarray(kg.data)[:n])


def test_front_door_coalesces_and_reports_stats():
    clear_plan_cache()
    door = FrontDoor(CONFIG, flush_window=0.0, max_queue=64)
    door.register("a", _dis())
    t1 = door.submit("a", _recs(1, seed=1))
    t2 = door.submit("a", _recs(1, seed=2))
    assert door.pump(force=True) == 1          # ONE flush for both
    r1, r2 = t1.result(timeout=600), t2.result(timeout=600)
    assert r1.batched_requests == r2.batched_requests == 2
    assert r1.flush_id == r2.flush_id

    st = door.serve_stats()
    assert st["tenants"] == 1 and st["accepted"] == 2
    assert st["completed"] == 2 and st["rejected"] == 0
    assert st["flushes"] == 1 and st["queue_depth"] == 0
    assert st["compiles"] == 1 and st["compile_dedup_ratio"] == 1.0
    assert st["latency"]["count"] == 2
    per = st["per_tenant"]["a"]
    assert per["requests"] == 2 and per["ingests"] == 1
    assert per["rows"] == 4 and per["kg_triples"] > 0
    assert len(per["shape_id"]) == 12


def test_front_door_backpressure_no_silent_drops():
    clear_plan_cache()
    door = FrontDoor(CONFIG, flush_window=0.0, max_queue=2, storm_queue=1,
                     stall_window_s=600.0)
    door.register("a", _dis())
    responses = [door.submit("a", _recs(1, seed=i)) for i in range(4)]
    tickets = [r for r in responses if isinstance(r, Ticket)]
    sheds = [r for r in responses if isinstance(r, Overloaded)]
    assert len(tickets) == 2 and len(sheds) == 2
    assert all(s.reason == "queue_full" for s in sheds)
    door.pump(force=True)
    assert all(tk.result(timeout=600).kg_triples > 0 for tk in tickets)

    # bucket-crossing delta -> recompile -> storm window opens
    tk = door.submit("a", _recs(64, seed=9))   # 24-row seed: crosses bucket
    door.pump(force=True)
    assert tk.result(timeout=600).recompiles >= 1
    st = door.serve_stats()
    assert st["recompile_stalls"] >= 1 and st["admission"]["in_storm"]
    ok = door.submit("a", _recs(1, seed=10))     # depth 0 < storm_queue
    storm = door.submit("a", _recs(1, seed=11))  # depth 1 >= storm_queue
    assert isinstance(ok, Ticket) and isinstance(storm, Overloaded)
    assert storm.reason == "recompile_storm"
    door.pump(force=True)
    st = door.serve_stats()
    assert st["accepted"] + st["rejected"] == 7   # every submit accounted
    assert st["completed"] == st["accepted"] and st["errors"] == 0


def test_front_door_error_path_fails_tickets_loudly():
    clear_plan_cache()
    door = FrontDoor(CONFIG, flush_window=0.0, max_queue=8)
    door.register("a", _dis())
    tk = door.submit("a", {"no_such_source": [{"x": 1}]})
    door.pump(force=True)
    with pytest.raises(KeyError):
        tk.result(timeout=600)
    st = door.serve_stats()
    assert st["errors"] == 1 and st["per_tenant"]["a"]["errors"] == 1

    # stop(drain=False) fails queued tickets instead of dropping them
    tk2 = door.submit("a", _recs(1, seed=1))
    door.stop(drain=False)
    with pytest.raises(RuntimeError, match="stopped before flush"):
        tk2.result(timeout=1)


def test_front_door_worker_thread_mode():
    clear_plan_cache()
    door = FrontDoor(CONFIG, flush_window=0.005, max_queue=64).start()
    try:
        with pytest.raises(RuntimeError, match="already started"):
            door.start()
        with pytest.raises(RuntimeError, match="worker thread"):
            door.pump()
        door.register("a", _dis())
        tickets = [door.submit("a", _recs(1, seed=i)) for i in range(3)]
        results = [tk.result(timeout=600) for tk in tickets]
        assert all(r.kg_triples > 0 for r in results)
        door.drain(timeout=60)
    finally:
        door.stop()
    assert door.serve_stats()["completed"] == 3
    assert threading.active_count() >= 1    # worker joined cleanly


def test_front_door_unknown_tenant_raises_at_the_door():
    door = FrontDoor(CONFIG)
    with pytest.raises(KeyError, match="register"):
        door.submit("ghost", _recs(1))


def test_api_reexports_serve_surface():
    import repro.api as api
    assert api.FrontDoor is FrontDoor
    assert api.Overloaded is Overloaded
    assert api.percentile is percentile
    assert "FrontDoor" in dir(api)
    with pytest.raises(AttributeError):
        api.not_a_real_name
